"""SPMD scale-out sweep: the ``pallas_spmd`` backend across shard counts.

Runs one int8 SFC conv workload on 1/2/4/8-way meshes, sharding the batch
over 'data' or C_out over 'model', and appends per-shard-count rows to
``BENCH_conv.json`` (key ``"scaleout"``) next to the per-layer sweep from
``table3_throughput`` — the artifact CI uploads to track the perf
trajectory.

Needs multiple devices.  When the process owns only one, it re-execs
itself in a subprocess with a *forced host-device mesh*
(``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — CPU "devices"
are host threads, so intra-host speedup is NOT the point; the rows track
per-shard correctness (every row asserts bit-identity against the
single-device backend) and the shard_map dispatch overhead trajectory).
On real multi-chip hosts the same sweep measures actual scaling.

  PYTHONPATH=src python -m benchmarks.run scaleout
  REPRO_SCALEOUT_DEVICES=4 PYTHONPATH=src python -m benchmarks.scaleout
"""
import json
import os
import subprocess
import sys
import tempfile

import numpy as np

DEVICES = int(os.environ.get("REPRO_SCALEOUT_DEVICES", "8"))
BENCH_PATH = os.environ.get("REPRO_BENCH_OUT", "BENCH_conv.json")


def _sweep(log) -> list:
    """Time the workload per (shards, axis); asserts single-device parity."""
    import jax
    import jax.numpy as jnp

    from repro.api import ConvSpec, get_backend, plan
    from repro.api.tuning import calibrate_act_scale, time_fn
    from repro.launch.mesh import make_forced_host_mesh
    from repro.quant import INT8_FREQ

    n = len(jax.devices())
    hw = int(os.environ.get("REPRO_BENCH_SPATIAL_CAP", "28"))
    reps = int(os.environ.get("REPRO_BENCH_REPS", "2"))
    B, cin, cout = 8, 64, 128
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(B, hw, hw, cin), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, cin, cout) * 0.1, jnp.float32)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)

    def measure(p):
        act = calibrate_act_scale(x, p.algorithm, spec.quant)
        prep = p.prepare_weights(w, act_scale=act)
        y = p.apply(x, prep)
        dt = time_fn(jax.jit(lambda a: p.apply(a, prep)), x, reps=reps)
        return dt, y

    base_ms, y_ref = measure(plan(spec, backend="pallas", algo="sfc6_6"))
    base_ms *= 1e3
    rows = [{"shards": 1, "axis": None, "backend": "pallas",
             "ms": base_ms, "bit_identical": True}]
    log(f"scaleout shards=1 (single-device pallas): {base_ms:.2f}ms")

    backend = get_backend("pallas_spmd")
    try:
        for shards in (s for s in (1, 2, 4, 8) if s <= n):
            # shards=1 collapses both axes to the same (1, 1) mesh — one
            # row (the spmd dispatch overhead at 1 shard) is enough
            for axis in (("data",) if shards == 1 else ("data", "model")):
                shape = (shards, 1) if axis == "data" else (1, shards)
                backend.set_mesh(make_forced_host_mesh(shape))
                dt, y = measure(plan(spec, backend="pallas_spmd",
                                     algo="sfc6_6"))
                same = bool(jnp.all(y == y_ref))
                rows.append({"shards": shards, "axis": axis,
                             "backend": "pallas_spmd", "ms": dt * 1e3,
                             "bit_identical": same})
                log(f"scaleout shards={shards} axis={axis}: "
                    f"{dt*1e3:.2f}ms bit_identical={same}")
                assert same, f"SPMD output diverged at {shards}x{axis}"
    finally:
        backend.set_mesh(None)
    return rows


def _respawn(log) -> list:
    """Re-exec in a subprocess with forced host devices; collect rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={DEVICES}"
                        ).strip()
    fd, out = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        log(f"scaleout: single-device host, re-exec with {DEVICES} "
            f"forced host devices")
        subprocess.run([sys.executable, "-m", "benchmarks.scaleout",
                        "--worker", out], env=env, check=True)
        with open(out) as f:
            return json.load(f)
    finally:
        os.unlink(out)


def run(log=print, bench_path: str = None) -> dict:
    import jax
    bench_path = bench_path or BENCH_PATH
    rows = _sweep(log) if len(jax.devices()) >= 2 else _respawn(log)
    bench = {}
    if os.path.exists(bench_path):
        try:
            with open(bench_path) as f:
                bench = json.load(f)
        except ValueError:
            bench = {}
    bench["scaleout"] = {
        "workload": {"batch": 8, "cin": 64, "cout": 128, "algo": "sfc6_6",
                     "quant": "int8", "spatial_cap":
                     int(os.environ.get("REPRO_BENCH_SPATIAL_CAP", "28"))},
        "forced_host_devices": DEVICES,
        "rows": rows,
    }
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    log(f"bench_artifact,{bench_path}")
    return {"bench_path": bench_path, "rows": rows}


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--worker":
        rows = _sweep(print)
        with open(sys.argv[2], "w") as f:
            json.dump(rows, f)
    else:
        run()
