"""Paper Table 3 surrogate: compute-efficiency of the SFC datapath.

The paper's Table 3 is an FPGA synthesis (DSP counts); on TPU the analogue
is (a) the multiplication/BOPs reduction of the transform-domain pipeline
and (b) measured wall-clock of the jitted conv paths on this host (CPU
numbers are indicative only; the roofline analysis in EXPERIMENTS.md covers
the TPU target).  VGG-16's conv stack (all 3x3 stride-1, the paper's pick)
is the workload.

Besides the human-readable log this module emits ``BENCH_conv.json``: a
machine-readable per-layer wall-clock sweep of the five datapaths

  direct  — XLA native convolution, fp32
  staged  — three-kernel Pallas int8 pipeline (transform+quant / tdmm /
            inverse, two HBM round-trips of the transform-domain tensor)
  fused   — single-``pallas_call`` int8 pipeline (``sfc_fused``),
            one tile-row per grid step
  batched — the fused kernel with the multi-tile-row grid
            (``rows_per_step=None``: VMEM-budget auto grouping) — the
            small-image variant ROADMAP calls for
  int8    — reference-backend static-int8 simulation (jnp)

plus the ``resnet_lowered`` rows: ResNet-18's stride-2 stem and stage
transitions and a 2-D depthwise conv — the workloads the lowering layer
(``repro.api.lowering``) opened up — each timed direct-vs-``lowered``
(the per-run ``lowered_totals_ms`` ride the trajectory entries).
The perf trajectory is tracked from PR 2 onward (EXPERIMENTS.md §Perf).
The artifact is ACCUMULATED, not overwritten: existing keys written by
other suites (``scaleout``) survive, and every run appends a timestamped,
git-SHA-tagged entry to ``trajectory`` so the CI artifact carries the
cross-PR perf history.  Spatial extents are scaled by
``REPRO_BENCH_SPATIAL_CAP`` (default 28 — interpret-mode Pallas on CPU
makes full 224x224 sweeps impractically slow; channel counts, the
dimension that decides datapath ranking, stay full).
"""
import dataclasses
import datetime
import json
import os
import subprocess

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ConvSpec, get_algorithm, plan
from repro.api.tuning import (DEFAULT_BATCHED, DEFAULT_FUSED, DEFAULT_STAGED,
                              calibrate_act_scale, time_fn)
from repro.quant import ConvWorkload, bops_reduction, INT8_FREQ

# VGG-16 conv layers (HxW, Cin, Cout) at 224 input — per paper §6.2
VGG_LAYERS = [(224, 3, 64), (224, 64, 64), (112, 64, 128), (112, 128, 128),
              (56, 128, 256), (56, 256, 256), (56, 256, 256),
              (28, 256, 512), (28, 512, 512), (28, 512, 512),
              (14, 512, 512), (14, 512, 512), (14, 512, 512)]

# The workloads the lowering layer opened up (ISSUE 5): ResNet-18's
# stride-2 stem + stage transitions (polyphase onto stride-1 SFC
# sub-convs) and a MobileNet-style 2-D depthwise conv (transform-domain
# elementwise path).  (name, HxW, Cin, Cout, R, stride, depthwise) at 224.
RESNET_LOWERED_LAYERS = [
    ("stem7x7s2", 224, 3, 64, 7, 2, False),
    ("s1tos2", 56, 64, 128, 3, 2, False),
    ("s2tos3", 28, 128, 256, 3, 2, False),
    ("s3tos4", 14, 256, 512, 3, 2, False),
    ("dw3x3", 28, 256, 256, 3, 1, True),
]

BENCH_PATH = os.environ.get("REPRO_BENCH_OUT", "BENCH_conv.json")


# one warmup (compile) call, then mean over reps — the tuner's protocol
_time = time_fn


def _scaled_layers(cap: int):
    """VGG stack with spatial extents capped (channels stay full)."""
    out = []
    for hw, cin, cout in VGG_LAYERS:
        hw_s = max(round(hw * cap / 224), 7) if cap < 224 else hw
        out.append((hw_s, cin, cout))
    return out


def _layer_sweep(layers, algo_name: str, reps: int, log) -> list:
    """Per-layer wall-clock of direct / staged / fused / int8-sim paths."""
    rng = np.random.RandomState(0)
    rows = []
    for hw, cin, cout in layers:
        x = jnp.asarray(rng.randn(1, hw, hw, cin), jnp.float32)
        w = jnp.asarray(rng.randn(3, 3, cin, cout) * 0.1, jnp.float32)
        spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)
        p_direct = plan(spec, algo="direct")
        p_fused = plan(spec, backend="pallas", algo=algo_name)
        p_ref = plan(spec, backend="reference", algo=algo_name)
        act = calibrate_act_scale(x, p_fused.algorithm, spec.quant)
        prep = p_fused.prepare_weights(w, act_scale=act)
        # every path timed under one jax.jit, so the comparison measures
        # the datapath rather than eager dispatch overhead
        fns = {
            "direct": jax.jit(lambda a: p_direct.apply(a, w)),
            "fused": jax.jit(
                lambda a, _p=dataclasses.replace(p_fused,
                                                 config=DEFAULT_FUSED):
                _p.apply(a, prep)),
            "batched": jax.jit(
                lambda a, _p=dataclasses.replace(p_fused,
                                                 config=DEFAULT_BATCHED):
                _p.apply(a, prep)),
            "staged": jax.jit(
                lambda a, _p=dataclasses.replace(p_fused,
                                                 config=DEFAULT_STAGED):
                _p.apply(a, prep)),
            "int8": jax.jit(lambda a: p_ref.apply(a, prep)),
        }
        row = {"hw": hw, "cin": cin, "cout": cout}
        for key, fn in fns.items():
            row[f"{key}_ms"] = _time(fn, x, reps=reps) * 1e3
        rows.append(row)
        log(f"layer{hw}x{hw}x{cin}->{cout},"
            f"direct={row['direct_ms']:.2f}ms,"
            f"staged={row['staged_ms']:.2f}ms,"
            f"fused={row['fused_ms']:.2f}ms,"
            f"batched={row['batched_ms']:.2f}ms,"
            f"int8sim={row['int8_ms']:.2f}ms")
    return rows


def _lowered_sweep(cap: int, reps: int, log) -> list:
    """Wall-clock of the lowered datapaths vs strided/grouped direct.

    One row per :data:`RESNET_LOWERED_LAYERS` entry with a ``lowered_ms``
    column: the int8 plan the planner resolves for the workload (polyphase
    composite over fused sub-kernels for stride-2; the transform-domain
    elementwise kernel for depthwise) against the XLA strided direct
    baseline.  ``algo='sfc6_6'`` forces lowering even at reduced bench
    shapes where the BOPs model would keep tiny workloads direct — the
    row's ``path`` records what ``algo='auto'`` would have picked.
    """
    from repro.api.tuning import calibrate_act_scale as _cal
    rng = np.random.RandomState(1)
    rows = []
    for name, hw, cin, cout, r, stride, dw in RESNET_LOWERED_LAYERS:
        hw_s = max(round(hw * cap / 224), 7) if cap < 224 else hw
        x = jnp.asarray(rng.randn(1, hw_s, hw_s, cin), jnp.float32)
        w = jnp.asarray(rng.randn(r, r, 1 if dw else cin, cout) * 0.1,
                        jnp.float32)
        if dw:
            spec = ConvSpec.for_conv2d_depthwise(x.shape, w.shape,
                                                 quant=INT8_FREQ)
        else:
            spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=stride,
                                       quant=INT8_FREQ)
        p_direct = plan(spec, algo="direct")
        p_fast = plan(spec, backend="pallas", algo="sfc6_6")
        if p_fast.path == "lowered":
            prep = p_fast.prepare_weights(w, act_scale=p_fast.calibrate(x))
        else:
            act = _cal(x, p_fast.algorithm, spec.quant, spec.padding)
            prep = p_fast.prepare_weights(w, act_scale=act)
        row = {"layer": name, "hw": hw_s, "cin": cin, "cout": cout,
               "kernel": r, "stride": stride, "depthwise": dw,
               "path": p_fast.path,
               # auto's verdict for the backend actually benchmarked (its
               # tuning-cache entries are keyed per backend)
               "auto_path": plan(spec, backend="pallas", algo="auto").path}
        fns = {
            "direct": jax.jit(lambda a, _p=p_direct: _p.apply(a, w)),
            "lowered": jax.jit(lambda a, _p=p_fast, _pr=prep:
                               _p.apply(a, _pr)),
        }
        for key, fn in fns.items():
            row[f"{key}_ms"] = _time(fn, x, reps=reps) * 1e3
        rows.append(row)
        log(f"lowered {name} {hw_s}x{hw_s}x{cin}->{cout}"
            f"{'dw' if dw else ''}s{stride},"
            f"direct={row['direct_ms']:.2f}ms,"
            f"lowered={row['lowered_ms']:.2f}ms,path={row['path']}")
    return rows


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, check=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__))).stdout.strip()
    except Exception:
        return "unknown"


def run(log=print, bench_path: str = None, reps: int = None,
        spatial_cap: int = None):
    algo = get_algorithm("sfc6_7")
    total_direct_bops = total_sfc_bops = 0.0
    for hw, cin, cout in VGG_LAYERS:
        wl = ConvWorkload(hw, hw, cin, cout, 3)
        total_direct_bops += wl.H * wl.W * wl.C_out * wl.R**2 * wl.C_in
        total_sfc_bops += (wl.H * wl.W * wl.C_out * wl.R**2 * wl.C_in
                           / bops_reduction(wl, algo))
    log(f"vgg16_bops_reduction,{total_direct_bops/total_sfc_bops:.2f}x")

    # per-layer wall-clock sweep of the four datapaths -> BENCH_conv.json
    bench_path = bench_path or BENCH_PATH
    reps = reps or int(os.environ.get("REPRO_BENCH_REPS", "2"))
    spatial_cap = spatial_cap or int(
        os.environ.get("REPRO_BENCH_SPATIAL_CAP", "28"))
    layers = _scaled_layers(spatial_cap)
    rows = _layer_sweep(layers, "sfc6_6", reps, log)
    totals = {k: sum(r[f"{k}_ms"] for r in rows)
              for k in ("direct", "staged", "fused", "batched", "int8")}
    for k, v in totals.items():
        log(f"vgg16_stack_{k}_ms,{v:.2f}")
    small = [r for r in rows if r["hw"] <= 14]
    if small:
        gain = sum(r["fused_ms"] for r in small) \
            / max(sum(r["batched_ms"] for r in small), 1e-9)
        log(f"small_image_batched_speedup_hw_le_14,{gain:.2f}x")

    # the lowered workloads: ResNet-18 stride-2 + depthwise rows
    lowered_rows = _lowered_sweep(spatial_cap, reps, log)
    lowered_totals = {k: sum(r[f"{k}_ms"] for r in lowered_rows)
                      for k in ("direct", "lowered")}
    for k, v in lowered_totals.items():
        log(f"resnet18_lowered_stack_{k}_ms,{v:.2f}")

    # accumulate, never overwrite: other suites' keys (scaleout) and the
    # cross-PR trajectory survive this run
    bench = {}
    if os.path.exists(bench_path):
        try:
            with open(bench_path) as f:
                bench = json.load(f)
        except ValueError:
            bench = {}
    if not isinstance(bench, dict):      # valid JSON but not an object
        bench = {}
    bench.update({
        "host": {"platform": jax.default_backend(), "jax": jax.__version__,
                 "interpret": True},
        "workload": "vgg16_conv_stack", "algo": "sfc6_6", "batch": 1,
        "spatial_cap": spatial_cap, "reps": reps,
        "layers": rows,
        "totals_ms": totals,
        "resnet_lowered": lowered_rows,
    })
    entry = {
        "ts": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "git_sha": _git_sha(),
        "platform": jax.default_backend(), "jax": jax.__version__,
        "spatial_cap": spatial_cap, "reps": reps,
        "totals_ms": totals,
        "lowered_totals_ms": lowered_totals,
    }
    bench.setdefault("trajectory", []).append(entry)
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    log(f"bench_artifact,{bench_path} "
        f"(trajectory: {len(bench['trajectory'])} entries)")

    # paper's GOPs/DSP analogue: mults per output
    log(f"mults_per_output_direct,{9*64}")
    log(f"mults_per_output_sfc,{algo.mults_2d/algo.M**2*64:.1f}")
    return {"bops_reduction": total_direct_bops / total_sfc_bops,
            "bench_path": bench_path, "totals_ms": totals,
            "lowered_totals_ms": lowered_totals}


if __name__ == "__main__":
    run()
