"""Paper Table 3 surrogate: compute-efficiency of the SFC datapath.

The paper's Table 3 is an FPGA synthesis (DSP counts); on TPU the analogue
is (a) the multiplication/BOPs reduction of the transform-domain pipeline
and (b) measured wall-clock of the jitted conv paths on this host (CPU
numbers are indicative only; the roofline analysis in EXPERIMENTS.md covers
the TPU target).  VGG-16's conv stack (all 3x3 stride-1, the paper's pick)
is the workload.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ConvSpec, get_algorithm, plan
from repro.quant import ConvWorkload, bops_reduction, INT8_FREQ

# VGG-16 conv layers (HxW, Cin, Cout) at 224 input — per paper §6.2
VGG_LAYERS = [(224, 3, 64), (224, 64, 64), (112, 64, 128), (112, 128, 128),
              (56, 128, 256), (56, 256, 256), (56, 256, 256),
              (28, 256, 512), (28, 512, 512), (28, 512, 512),
              (14, 512, 512), (14, 512, 512), (14, 512, 512)]


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(log=print):
    algo = get_algorithm("sfc6_7")
    total_direct_bops = total_sfc_bops = 0.0
    for hw, cin, cout in VGG_LAYERS:
        wl = ConvWorkload(hw, hw, cin, cout, 3)
        total_direct_bops += wl.H * wl.W * wl.C_out * wl.R**2 * wl.C_in
        total_sfc_bops += (wl.H * wl.W * wl.C_out * wl.R**2 * wl.C_in
                           / bops_reduction(wl, algo))
    log(f"vgg16_bops_reduction,{total_direct_bops/total_sfc_bops:.2f}x")

    # wall-clock of one representative mid-network layer on this host
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(1, 56, 56, 64), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 64, 64) * 0.05, jnp.float32)
    spec = ConvSpec.for_conv2d(x.shape, w.shape)
    p_direct = plan(spec, algo="direct")
    p_sfc = plan(spec, algo="sfc6_7")
    direct = jax.jit(lambda x, w: p_direct.apply(x, w))
    sfc_fp = jax.jit(lambda x, w: p_sfc.apply(x, w))
    hook = INT8_FREQ.hook()
    sfc_q = jax.jit(lambda x, w: p_sfc.apply(x, w, elementwise_hook=hook))
    td = _time(direct, x, w)
    tf = _time(sfc_fp, x, w)
    tq = _time(sfc_q, x, w)
    log(f"layer56x56x64_direct_ms,{td*1e3:.2f}")
    log(f"layer56x56x64_sfc_fp_ms,{tf*1e3:.2f}")
    log(f"layer56x56x64_sfc_int8sim_ms,{tq*1e3:.2f}")
    # paper's GOPs/DSP analogue: mults per output
    log(f"mults_per_output_direct,{9*64}")
    log(f"mults_per_output_sfc,{algo.mults_2d/algo.M**2*64:.1f}")
    return {"bops_reduction": total_direct_bops / total_sfc_bops}


if __name__ == "__main__":
    run()
