"""Paper Tables 4/5: quantization-granularity ablation.

Measures output MSE of one SFC / Winograd conv layer against the fp32
reference under every (activation x weight) granularity combination and
bitwidth — the paper's ablation axes — on realistic (low-pass, positive-
mean) feature statistics where frequency-wise scaling matters.
"""
import itertools
import time

import jax.numpy as jnp
import numpy as np

from repro.api import ConvSpec, plan
from repro.quant.fake_quant import QuantConfig


def _feature_batch(rng, B=4, H=28, W=28, C=32):
    """Low-frequency-dominated activations (post-ReLU-like)."""
    base = rng.randn(B, H // 4, W // 4, C)
    up = np.kron(base, np.ones((1, 4, 4, 1)))[:, :H, :W, :]
    x = np.maximum(up + 0.3 * rng.randn(B, H, W, C), 0)
    return jnp.asarray(x, jnp.float32)


def run(log=print):
    t0 = time.time()
    rng = np.random.RandomState(0)
    x = _feature_batch(rng)
    w = jnp.asarray(rng.randn(3, 3, 32, 32) * 0.1, jnp.float32)
    spec = ConvSpec.for_conv2d(x.shape, w.shape)
    ref = plan(spec, algo="direct").apply(x, w)

    def rel_err(name, qc):
        y = plan(spec, algo=name).apply(x, w, elementwise_hook=qc.hook())
        return float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))

    log("algo,bits,act_gran,w_gran,rel_err")
    table4 = {}
    for algo_name in ("sfc6_7", "wino4"):
        for act_g, w_g in [("tensor", "channel"), ("frequency", "channel"),
                           ("frequency", "frequency"),
                           ("frequency", "channel+frequency")]:
            e = rel_err(algo_name, QuantConfig(8, 8, act_g, w_g))
            table4[(algo_name, act_g, w_g)] = e
            log(f"{algo_name},8,{act_g},{w_g},{e:.4f}")
    table5 = {}
    for bits in (8, 6, 4):
        for act_g, w_g in [("tensor", "channel"),
                           ("frequency", "channel"),
                           ("frequency", "channel+frequency")]:
            e = rel_err("sfc6_7", QuantConfig(bits, bits, act_g, w_g))
            table5[(bits, act_g, w_g)] = e
            log(f"sfc6_7,{bits},{act_g},{w_g},{e:.4f}")
    # paper's qualitative claims as assertions
    assert table4[("wino4", "tensor", "channel")] > \
        table4[("sfc6_7", "tensor", "channel")], "wino should be more sensitive"
    assert table5[(4, "frequency", "channel+frequency")] < \
        table5[(4, "tensor", "channel")], "freq-wise must help at int4"
    log(f"# table45 done in {time.time()-t0:.1f}s")
    return {"table4": table4, "table5": table5}


if __name__ == "__main__":
    run()
