"""Paper Fig. 4 / Table 2: accuracy vs computation cost (BOPs) under PTQ.

Offline surrogate: a small ResNet trained on structured synthetic images
stands in for TorchVision/ImageNet; the *relative* orderings the paper
claims are what we measure:
  - SFC int8 ~= direct fp accuracy (paper: -0.17%)
  - SFC at int6/int8 dominates Winograd F(4x4,3x3) at matched bits
  - SFC cuts BOPs vs both direct-int8 and Winograd at matched accuracy.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.resnet18 import CNNConfig
from repro.core.generator import generate_sfc, generate_winograd
from repro.data import ImagePipelineConfig, SyntheticImagePipeline
from repro.api import get_algorithm
from repro.models.cnn import cnn_loss, init_resnet, resnet_forward
from repro.optim.optimizers import AdamW
from repro.quant import ConvWorkload, direct_conv_bops, fastconv_bops

BASE = CNNConfig(name="bench-cnn", stages=(1, 1), widths=(16, 32),
                 image_size=24, n_classes=10)


def _train(cfg, pipe, steps=80, lr=3e-3):
    params = init_resnet(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=lr, weight_decay=1e-4)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (_, m), g = jax.value_and_grad(
            lambda p: cnn_loss(p, cfg, batch), has_aux=True)(params)
        params, state, _ = opt.apply(params, g, state)
        return params, state, m

    for i in range(steps):
        b = pipe.batch(i)
        params, state, m = step(params, state,
                                {"images": jnp.asarray(b["images"]),
                                 "labels": jnp.asarray(b["labels"])})
    return params


def _acc(cfg, params, pipe, n=4):
    correct = total = 0
    for i in range(1000, 1000 + n):
        b = pipe.batch(i)
        lg = resnet_forward(params, cfg, jnp.asarray(b["images"]))
        correct += int((np.argmax(np.asarray(lg), -1) == b["labels"]).sum())
        total += len(b["labels"])
    return correct / total


def _bops(algo_name, bits):
    """Aggregate BOPs of the bench CNN's fast-conv layers."""
    wl_list = [ConvWorkload(24, 24, 16, 16, 3, bits, bits),
               ConvWorkload(12, 12, 32, 32, 3, bits, bits)]
    total = 0.0
    for wl in wl_list:
        if algo_name == "direct":
            total += direct_conv_bops(wl)
        else:
            total += fastconv_bops(wl, get_algorithm(algo_name))
    return total


def run(log=print):
    t0 = time.time()
    pipe = SyntheticImagePipeline(ImagePipelineConfig(
        image_size=BASE.image_size, n_classes=BASE.n_classes,
        global_batch=32, seed=3))
    params = _train(BASE, pipe)
    rows = []
    grid = [("direct", "none", 32), ("direct", "int8", 8),
            ("sfc6_6", "int8", 8), ("sfc6_7", "int8", 8),
            ("sfc6_6", "int6", 6), ("wino4", "int8", 8),
            ("wino4", "int6", 6), ("sfc6_6", "int4", 4)]
    log("algo,quant,acc,gbops")
    for algo, quant, bits in grid:
        cfg = dataclasses.replace(BASE, conv_algo=algo, quant=quant)
        acc = _acc(cfg, params, pipe)
        gb = _bops(algo, bits) / 1e9
        rows.append((algo, quant, acc, gb))
        log(f"{algo},{quant},{acc:.3f},{gb:.3f}")
    # headline check rows
    accs = {(a, q): acc for a, q, acc, _ in rows}
    log(f"# sfc-int8 vs fp delta: {accs[('sfc6_6','int8')]-accs[('direct','none')]:+.3f}")
    log(f"# wino-int6 vs sfc-int6 delta: "
        f"{accs[('wino4','int6')]-accs[('sfc6_6','int6')]:+.3f}")
    log(f"# fig4 done in {time.time()-t0:.1f}s")
    return rows


if __name__ == "__main__":
    run()
