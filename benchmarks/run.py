"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table1     # one table
"""
import sys
import time


def main() -> None:
    from benchmarks import (appendixB_iterative, fig4_accuracy_vs_bops,
                            fig5_layer_mse, roofline, table1_algorithms,
                            table3_throughput, table45_granularity)
    suites = {
        "table1": table1_algorithms.run,
        "fig4": fig4_accuracy_vs_bops.run,
        "table3": table3_throughput.run,
        "table45": table45_granularity.run,
        "fig5": fig5_layer_mse.run,
        "appendixB": appendixB_iterative.run,
        "roofline": roofline.run,
    }
    selected = sys.argv[1:] or list(suites)
    t0 = time.time()
    for name in selected:
        print(f"\n===== {name} =====")
        suites[name]()
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
