"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table3     # one table

``table3`` additionally writes the machine-readable per-layer conv sweep
``BENCH_conv.json`` (path via ``REPRO_BENCH_OUT``; reduced shapes via
``REPRO_BENCH_SPATIAL_CAP``, default 28) — the artifact CI uploads to
track the perf trajectory across PRs.  The file is merged, never
overwritten: each run refreshes the per-layer snapshot (now including
the batched multi-tile-row fused variant) and APPENDS a timestamped
git-SHA entry to ``BENCH_conv.json["trajectory"]``, so the accumulated
history rides the committed file across PRs.  ``scaleout`` appends the
SPMD per-shard-count rows to the same artifact (forced host-device mesh
on single-device hosts); ``serving`` appends the open-loop
continuous-batching SLO rows (``repro.serve`` engine, p50/p95/p99 +
goodput + occupancy + cache hit rate) under the ``"serving"`` key;
``chaos`` appends goodput/SLO under injected fault rates plus breaker
recovery time under the ``"chaos"`` key; ``roofline`` appends the
dry-run roofline cells under ``"roofline"``; ``costmodel`` fits the
analytic cost model and appends its predicted-vs-measured validation
(rank correlation, top-1/top-k agreement, coefficients) under the
``"costmodel"`` key.
"""
import sys
import time


def main() -> None:
    from benchmarks import (appendixB_iterative, chaos,
                            fig4_accuracy_vs_bops, fig5_layer_mse,
                            roofline, scaleout, serving,
                            table1_algorithms, table3_throughput,
                            table45_granularity)
    suites = {
        "table1": table1_algorithms.run,
        "fig4": fig4_accuracy_vs_bops.run,
        "table3": table3_throughput.run,
        "table45": table45_granularity.run,
        "fig5": fig5_layer_mse.run,
        "appendixB": appendixB_iterative.run,
        "roofline": roofline.run,
        "costmodel": roofline.run_costmodel,
        "scaleout": scaleout.run,
        "serving": serving.run,
        "chaos": chaos.run,
    }
    selected = sys.argv[1:] or list(suites)
    t0 = time.time()
    artifacts = []
    for name in selected:
        print(f"\n===== {name} =====")
        result = suites[name]()
        if isinstance(result, dict) and "bench_path" in result:
            artifacts.append(result["bench_path"])
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s")
    for path in artifacts:
        print(f"artifact: {path}")


if __name__ == "__main__":
    main()
