"""Paper Appendix B: iterative SFC for large kernels — mult accounting."""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.generator import generate_sfc
from repro.core.iterative import (iterative_conv1d, iterative_mult_count,
                                  large_kernel_report)


def run(log=print):
    t0 = time.time()
    log("kernel,outputs2d,direct_mults,nested_mults,ratio_pct")
    pairs = [
        (30, generate_sfc(6, 5, 5), generate_sfc(6, 6, 6)),   # ~29x29 paper ex.
        (9, generate_sfc(4, 3, 3), generate_sfc(6, 7, 3)),
        (24, generate_sfc(6, 4, 4), generate_sfc(6, 6, 6)),
    ]
    out = []
    for ksize, inner, outer in pairs:
        rep = large_kernel_report(ksize, inner, outer)
        out.append(rep)
        log(f"{rep['kernel']},{rep['outputs_2d']},{rep['direct_mults']},"
            f"{rep['nested_mults']},{rep['ratio_pct']:.2f}")
        # numeric exactness spot check (1-D)
        rng = np.random.RandomState(0)
        Rw, Mt = inner.R * outer.R, inner.M * outer.M
        x = jnp.asarray(rng.randn(Mt + Rw - 1), jnp.float32)
        w = jnp.asarray(rng.randn(Rw), jnp.float32)
        y = iterative_conv1d(x, w, inner, outer)
        yref = jnp.array([(x[m:m + Rw] * w).sum() for m in range(Mt)])
        err = float(jnp.abs(y - yref).max())
        assert err < 1e-3, err
    log(f"# appendixB done in {time.time()-t0:.1f}s "
        f"(paper reports ~3% for 29x29 with its uneven-split variant)")
    return out


if __name__ == "__main__":
    run()
