"""Paper Table 1: MSE / conditioning / arithmetic complexity per algorithm."""
import time

from repro.core.error_analysis import table1


def run(log=print):
    t0 = time.time()
    t = table1(trials=200)
    log("name,mse_measured,mse_paper,kappa_tile,amplification,"
        "mults2d,multsH,complexity_pct,complexity_pct_paper,int_transform")
    for name, row in t.items():
        paper = row["paper"] or (None, None, None)
        log(f"{name},{row['mse']:.2f},{paper[0]},{row['kappa_tile']:.2f},"
            f"{row['amplification']:.2f},{row['mults_2d']},"
            f"{row['mults_2d_hermitian']},"
            f"{row['complexity_pct_hermitian']:.2f},{paper[2]},"
            f"{row['integer_transform']}")
    log(f"# table1 done in {time.time()-t0:.1f}s")
    return t


if __name__ == "__main__":
    run()
