"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell:
    compute    = FLOPs_per_device / 197e12         (TPU v5e bf16 peak)
    memory     = bytes_per_device / 819e9          (HBM bandwidth)
    collective = coll_bytes_per_device / 50e9      (ICI per-link)

``cost_analysis`` on the SPMD-partitioned module reports *per-device*
flops/bytes (verified: whisper train_4k per-device flops x 256 == 6ND);
collective bytes are parsed from the compiled HLO (operand sums), also
per-device.  The dominant term is the bottleneck §Perf iterates on;
``model_flops / (hlo_flops * chips)`` flags remat/redundant compute.
"""
import json
import pathlib
import sys

PEAK = 197e12
HBM = 819e9
ICI = 50e9

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mesh_tag="pod1"):
    cells = {}
    for f in sorted(DRYRUN.glob(f"{mesh_tag}_*.json")):
        rec = json.loads(f.read_text())
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


def roofline_row(rec):
    # loop-aware (trip-count-corrected) per-device quantities; the raw
    # cost_analysis numbers count while bodies once (see hlo_analysis.py)
    flops = rec.get("la_flops") or rec["hlo_flops"] or 0.0
    byts = rec.get("la_traffic_bytes") or rec["hlo_bytes"] or 0.0
    coll = sum((rec.get("la_collective_bytes")
                or rec["collective_bytes"]).values())
    t_comp = flops / PEAK
    t_mem = byts / HBM
    t_coll = coll / ICI
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = rec["model_flops"] / (flops * rec["n_chips"]) if flops else 0.0
    # roofline fraction: useful model flops per chip-second at the bound
    frac = (rec["model_flops"] / rec["n_chips"] / PEAK) / bound if bound else 0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant, "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "collective_breakdown": rec.get("la_collective_bytes",
                                        rec["collective_bytes"]),
    }


def run(log=print, mesh_tag="pod1"):
    cells = load_cells(mesh_tag)
    if not cells:
        log("# no dry-run artifacts found — run repro.launch.dryrun first")
        return []
    log("arch,shape,t_compute_s,t_memory_s,t_collective_s,dominant,"
        "useful_flops_ratio,roofline_fraction")
    rows = []
    for (arch, shape), rec in sorted(cells.items()):
        r = roofline_row(rec)
        rows.append(r)
        log(f"{arch},{shape},{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
            f"{r['t_collective_s']:.3e},{r['dominant']},"
            f"{r['useful_flops_ratio']:.3f},{r['roofline_fraction']:.3f}")
    out = DRYRUN.parent / f"roofline_{mesh_tag}.json"
    out.write_text(json.dumps(rows, indent=1))
    log(f"# wrote {out}")
    return rows


if __name__ == "__main__":
    run(mesh_tag=sys.argv[1] if len(sys.argv) > 1 else "pod1")
