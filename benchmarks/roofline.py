"""Roofline analysis from the dry-run artifacts (deliverable g), plus
the cost-model validation cell (``run_costmodel``).

Per (arch x shape x mesh) cell:
    compute    = FLOPs_per_device / 197e12         (TPU v5e bf16 peak)
    memory     = bytes_per_device / 819e9          (HBM bandwidth)
    collective = coll_bytes_per_device / 50e9      (ICI per-link)

``cost_analysis`` on the SPMD-partitioned module reports *per-device*
flops/bytes (verified: whisper train_4k per-device flops x 256 == 6ND);
collective bytes are parsed from the compiled HLO (operand sums), also
per-device.  The dominant term is the bottleneck §Perf iterates on;
``model_flops / (hlo_flops * chips)`` flags remat/redundant compute.
Rows land under ``BENCH_conv.json["roofline"]`` with the same
merged-not-overwritten git-SHA ``trajectory[]`` convention as the other
suites, besides the per-mesh ``experiments/roofline_*.json`` file.

``run_costmodel`` validates ``repro.api.costmodel`` end to end: fit the
coefficients from the probe runs, then — per spec of the VGG/ResNet
sweep (interpret mode) — exhaustively measure every launchable
candidate and compare against the model's ranking.  Reported per spec
and in aggregate: Spearman rank correlation, strict top-1 agreement, a
noise-tolerant variant (the chosen config's measured time within 5% of
the exhaustive winner's), and the ``top_k=3`` autotune outcome (would
measuring only the model's top-3 have found the winner?).  Everything,
including the fitted coefficients and per-spec prediction error, lands
in ``BENCH_conv.json["costmodel"]``.
"""
import datetime
import json
import os
import pathlib
import subprocess
import sys

PEAK = 197e12
HBM = 819e9
ICI = 50e9

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
BENCH_PATH = os.environ.get("REPRO_BENCH_OUT", "BENCH_conv.json")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, check=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__))).stdout.strip()
    except Exception:
        return "unknown"


def _load_bench(bench_path: str) -> dict:
    bench = {}
    if os.path.exists(bench_path):
        try:
            with open(bench_path) as f:
                bench = json.load(f)
        except ValueError:
            bench = {}
    if not isinstance(bench, dict):
        bench = {}
    return bench


def _trajectory_entry(**fields) -> dict:
    import jax
    return {
        "ts": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "git_sha": _git_sha(),
        "platform": jax.default_backend(), "jax": jax.__version__,
        **fields,
    }


def load_cells(mesh_tag="pod1"):
    cells = {}
    for f in sorted(DRYRUN.glob(f"{mesh_tag}_*.json")):
        rec = json.loads(f.read_text())
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


def roofline_row(rec):
    # loop-aware (trip-count-corrected) per-device quantities; the raw
    # cost_analysis numbers count while bodies once (see hlo_analysis.py)
    flops = rec.get("la_flops") or rec["hlo_flops"] or 0.0
    byts = rec.get("la_traffic_bytes") or rec["hlo_bytes"] or 0.0
    coll = sum((rec.get("la_collective_bytes")
                or rec["collective_bytes"]).values())
    t_comp = flops / PEAK
    t_mem = byts / HBM
    t_coll = coll / ICI
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = rec["model_flops"] / (flops * rec["n_chips"]) if flops else 0.0
    # roofline fraction: useful model flops per chip-second at the bound
    frac = (rec["model_flops"] / rec["n_chips"] / PEAK) / bound if bound else 0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant, "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "collective_breakdown": rec.get("la_collective_bytes",
                                        rec["collective_bytes"]),
    }


def run(log=print, mesh_tag="pod1", bench_path=None):
    bench_path = bench_path or BENCH_PATH
    cells = load_cells(mesh_tag)
    if not cells:
        log("# no dry-run artifacts found — run repro.launch.dryrun first")
        return {"bench_path": bench_path, "rows": []}
    log("arch,shape,t_compute_s,t_memory_s,t_collective_s,dominant,"
        "useful_flops_ratio,roofline_fraction")
    rows = []
    for (arch, shape), rec in sorted(cells.items()):
        r = roofline_row(rec)
        rows.append(r)
        log(f"{arch},{shape},{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
            f"{r['t_collective_s']:.3e},{r['dominant']},"
            f"{r['useful_flops_ratio']:.3f},{r['roofline_fraction']:.3f}")
    out = DRYRUN.parent / f"roofline_{mesh_tag}.json"
    out.write_text(json.dumps(rows, indent=1))
    log(f"# wrote {out}")
    # merge, never overwrite: rows ride BENCH_conv.json["roofline"] next
    # to the other suites' keys, and the run stamps the shared trajectory
    bench = _load_bench(bench_path)
    bench.setdefault("roofline", {})[mesh_tag] = rows
    bench.setdefault("trajectory", []).append(_trajectory_entry(
        suite="roofline", mesh=mesh_tag, cells=len(rows),
        dominant={r["shape"]: r["dominant"] for r in rows}))
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    log(f"# bench_artifact,{bench_path} "
        f"(trajectory: {len(bench['trajectory'])} entries)")
    return {"bench_path": bench_path, "rows": rows}


# --------------------------------------------------------------------------
# cost-model validation cell
# --------------------------------------------------------------------------
def _spearman(a, b) -> float:
    """Spearman rank correlation, hand-rolled (no scipy in the image).
    Average ranks for ties; 1.0 for degenerate single-point inputs."""
    import numpy as np
    a, b = np.asarray(a, float), np.asarray(b, float)
    if len(a) < 2:
        return 1.0

    def ranks(v):
        order = np.argsort(v, kind="stable")
        r = np.empty(len(v))
        r[order] = np.arange(1, len(v) + 1)
        for val in np.unique(v):
            m = v == val
            r[m] = r[m].mean()
        return r

    ra, rb = ranks(a), ranks(b)
    ra, rb = ra - ra.mean(), rb - rb.mean()
    denom = np.sqrt((ra ** 2).sum() * (rb ** 2).sum())
    return float((ra * rb).sum() / denom) if denom else 1.0


def _sweep_specs(cap: int):
    """Deduped stride-1 conv specs of the VGG/ResNet benchmark sweep at
    the bench spatial cap (channels full — they decide the ranking)."""
    from benchmarks.table3_throughput import (RESNET_LOWERED_LAYERS,
                                              VGG_LAYERS, _scaled_layers)
    from repro.api import ConvSpec
    from repro.quant import INT8_FREQ
    specs, seen = [], set()
    for hw, cin, cout in _scaled_layers(cap):
        key = (hw, cin, cout)
        if key in seen:
            continue
        seen.add(key)
        specs.append((f"vgg{hw}x{hw}x{cin}->{cout}",
                      ConvSpec(kernel_size=3, in_channels=cin,
                               out_channels=cout, spatial=(hw, hw),
                               quant=INT8_FREQ)))
    for name, hw, cin, cout, r, stride, dw in RESNET_LOWERED_LAYERS:
        if stride != 1 or not dw:
            continue                 # fast-path pricing is stride-1 native
        hw_s = max(round(hw * cap / 224), 7) if cap < 224 else hw
        specs.append((f"resnet_{name}{hw_s}x{hw_s}",
                      ConvSpec(kernel_size=r, in_channels=cin,
                               out_channels=cout, spatial=(hw_s, hw_s),
                               depthwise=True, quant=INT8_FREQ)))
    assert VGG_LAYERS  # sweep source sanity
    return specs


def _dedup_key(spec, algo, cfg, batch):
    """Configs resolving identical launches are one candidate: e.g.
    k_block 128 vs 256 both clamp to one k-block at C_in=64, and timing
    both would turn top-1 agreement into a coin flip between aliases."""
    from repro.analysis import kernel_checks
    if cfg.datapath == "fused":
        H, W = spec.spatial
        return ("fused", kernel_checks.geometry_for(
            algo, cfg, batch, H, W, spec.in_channels, spec.out_channels,
            padding=spec.padding, depthwise=spec.depthwise))
    import math
    n_k = 1 if cfg.k_block is None \
        else math.ceil(spec.in_channels / cfg.k_block)
    return ("staged", cfg.tile_block, cfg.chan_block, n_k)


def run_costmodel(log=print, bench_path=None, backend="pallas",
                  interpret=True, top_k=3):
    """Fit the cost model, exhaustively measure the sweep, score the
    model's ranking, and write ``BENCH_conv.json["costmodel"]``."""
    from repro.analysis import kernel_checks, ranges
    from repro.api import costmodel, planner, registry, tuning

    bench_path = bench_path or BENCH_PATH
    reps = int(os.environ.get("REPRO_BENCH_REPS", "2"))
    cap = int(os.environ.get("REPRO_BENCH_SPATIAL_CAP", "28"))

    log("# fitting cost-model coefficients from probe runs")
    report = costmodel.fit_coefficients(backend=backend,
                                        interpret=interpret, reps=reps)
    for dp, vec in report["coefficients"].items():
        log(f"coefficients,{dp}," + ",".join(f"{c:.3e}" for c in vec))

    spec_rows = []
    for name, spec in _sweep_specs(cap):
        algo_name = planner.select_algorithm(spec)     # pure BOPs pick
        algo = registry.get_algorithm(algo_name)
        if algo is None:
            continue
        try:
            p0 = planner.plan(spec, backend=backend, algo=algo_name,
                              interpret=interpret)
        except ranges.AccumulatorOverflowError:
            continue
        if p0.path != "fast":
            continue
        x, w = tuning._synthetic_operands(spec)
        launchable, _ = kernel_checks.check_candidates(
            spec, algo, tuning.DEFAULT_CANDIDATES, batch=x.shape[0])
        uniq, seen = [], set()
        for cfg in launchable:
            k = _dedup_key(spec, algo, cfg, x.shape[0])
            if k in seen:
                continue
            seen.add(k)
            uniq.append(cfg)
        measured, predicted = [], []
        for cfg in uniq:
            t = tuning._measure_plan(p0.with_config(cfg), x, w, reps)
            pred = costmodel.predict_time(spec, algo, cfg,
                                          backend=backend,
                                          interpret=interpret,
                                          batch=x.shape[0])
            measured.append(t)
            predicted.append(pred)
        if not measured or any(p is None for p in predicted):
            continue
        best_meas = min(measured)
        i_meas = measured.index(best_meas)
        i_pred = predicted.index(min(predicted))
        # the autotune(top_k) outcome: measure only the model's top-k,
        # keep the fastest measured among them
        order = sorted(range(len(uniq)), key=lambda i: predicted[i])
        kept = order[:top_k]
        i_chosen = min(kept, key=lambda i: measured[i])
        row = {
            "spec": name, "algo": algo_name,
            "n_candidates": len(uniq),
            "spearman": _spearman(predicted, measured),
            "top1_strict": i_pred == i_meas,
            # noise tolerance: a pick within 5% of the winner's measured
            # time is an agreement — interpret-mode CPU timings jitter
            # more than the margin separating near-tied configs
            "top1_within5pct": measured[i_pred] <= 1.05 * best_meas,
            "topk_winner_found": i_chosen == i_meas,
            "topk_within5pct": measured[i_chosen] <= 1.05 * best_meas,
            "winner_measured_ms": best_meas * 1e3,
            "top1_measured_ms": measured[i_pred] * 1e3,
            "winner_pred_rel_err": abs(predicted[i_meas] - best_meas)
            / best_meas,
        }
        spec_rows.append(row)
        log(f"costmodel,{name},n={row['n_candidates']},"
            f"rho={row['spearman']:.2f},"
            f"top1={'Y' if row['top1_strict'] else 'n'}"
            f"({'Y' if row['top1_within5pct'] else 'n'}@5%),"
            f"top{top_k}={'Y' if row['topk_within5pct'] else 'n'}@5%,"
            f"win={row['winner_measured_ms']:.2f}ms")

    if not spec_rows:
        log("# costmodel: no sweep spec produced a fast-path plan")
        return {"bench_path": bench_path, "summary": {}}
    n = len(spec_rows)
    summary = {
        "n_specs": n, "top_k": top_k,
        "mean_spearman": sum(r["spearman"] for r in spec_rows) / n,
        "top1_strict_rate": sum(r["top1_strict"] for r in spec_rows) / n,
        "top1_within5pct_rate":
            sum(r["top1_within5pct"] for r in spec_rows) / n,
        "topk_winner_rate":
            sum(r["topk_winner_found"] for r in spec_rows) / n,
        "topk_within5pct_rate":
            sum(r["topk_within5pct"] for r in spec_rows) / n,
        "mean_winner_pred_rel_err":
            sum(r["winner_pred_rel_err"] for r in spec_rows) / n,
    }
    log(f"costmodel_summary,rho={summary['mean_spearman']:.2f},"
        f"top1={summary['top1_strict_rate']:.0%}"
        f"({summary['top1_within5pct_rate']:.0%}@5%),"
        f"top{top_k}={summary['topk_winner_rate']:.0%}"
        f"({summary['topk_within5pct_rate']:.0%}@5%)")

    bench = _load_bench(bench_path)
    bench["costmodel"] = {
        "coefficients": report["coefficients"],
        "fit": {k: report[k] for k in ("samples", "fit_error", "device")
                if k in report},
        "specs": spec_rows, "summary": summary,
        "spatial_cap": cap, "reps": reps, "interpret": interpret,
    }
    bench.setdefault("trajectory", []).append(_trajectory_entry(
        suite="costmodel", spatial_cap=cap, reps=reps, **summary))
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    log(f"# bench_artifact,{bench_path} "
        f"(trajectory: {len(bench['trajectory'])} entries)")
    return {"bench_path": bench_path, "summary": summary}


if __name__ == "__main__":
    arg = sys.argv[1] if len(sys.argv) > 1 else "pod1"
    if arg == "costmodel":
        run_costmodel()
    else:
        run(mesh_tag=arg)
