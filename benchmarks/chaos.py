"""Chaos benchmark: serving under injected faults, landing goodput /
SLO-attainment / recovery rows in ``BENCH_conv.json["chaos"]``.

  PYTHONPATH=src python -m benchmarks.run chaos
  PYTHONPATH=src python -m benchmarks.chaos --smoke       # the CI job

Methodology (EXPERIMENTS.md §Robustness): the PR 6 open-loop serving
workload (Poisson arrivals into the continuous-batching engine) is
re-driven with the fused kernel's injection site armed at 0%, 1%, and 5%
per-call fault rates (``repro.faults``).  Three claims are measured, not
asserted:

  * **resilience is free when healthy** — the 0% row runs the identical
    traffic through the full degradation chain (breaker lookup + try per
    apply) and must sit within noise of the PR 6 ``serving`` rows;
  * **transient faults are invisible** — at 1% / 5% every injected
    ``InjectedFault`` is absorbed by the fused->staged fallback (bit
    -identical by the PR 4 conformance invariant) or a dispatch retry:
    the row records ``request_errors`` (futures that resolved to a
    non-rejection error), which must stay 0;
  * **breakers recover** — a 100% fault burst trips the fused breaker
    (pinning the staged fallback), and once the burst ends the half-open
    probe re-closes it; ``recovery_s`` is the gap from the last injected
    fault to the recovered probe, measured against the configured
    cool-down.

Numbers are interpret-mode Pallas on CPU; they compare resilience
configurations and track the trajectory, they are not TPU latencies.
The artifact merge discipline matches every other suite: accumulate,
never overwrite.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.serving import BENCH_PATH, _build_engine, _git_sha

FAULT_RATES = (0.0, 0.01, 0.05)


def _drive_counted(eng, events, log) -> Dict:
    """Open-loop drive (benchmarks.serving discipline) that additionally
    classifies every future's resolution: deadline-met, rejected, or a
    request-visible error (the number that must stay zero)."""
    import jax.numpy as jnp

    from repro.serve import RejectedError

    rng = np.random.RandomState(42)
    xs = [jnp.asarray(rng.randn(h, w, 8), jnp.float32)
          for (h, w) in (e.shape for e in events)]
    eng.start()
    t0 = time.perf_counter()
    futures = []
    for ev, x in zip(events, xs):
        now = time.perf_counter() - t0
        if ev.t > now:
            time.sleep(ev.t - now)
        futures.append(eng.submit(x, ev.slo))
    eng.drain(timeout=600)
    wall_s = time.perf_counter() - t0
    eng.stop()

    good = rejected = errors = 0
    error_types: Dict[str, int] = {}
    for f in futures:
        try:
            r = f.result(timeout=0)
            good += int(r.deadline_met)
        except RejectedError:
            rejected += 1
        except Exception as e:               # the chaos headline number
            errors += 1
            name = type(e).__name__
            error_types[name] = error_types.get(name, 0) + 1
    snap = eng.snapshot()
    snap["wall_s"] = wall_s
    snap["goodput_rps"] = good / wall_s if wall_s > 0 else 0.0
    snap["rejected"] = rejected
    snap["request_errors"] = errors
    snap["request_error_types"] = error_types
    return snap


def _fault_row(rate: float, n: int, rate_hz: float, cap: int,
               max_batch: int, log) -> Dict:
    """One (fault-rate) cell: fresh engine, fresh breaker board, armed
    fused-apply faults at ``rate``, PR 6 Poisson traffic."""
    from repro import faults
    from repro.api import resilience
    from repro.serve import default_shape_mix, synthesize

    resilience.reset()
    eng, workload = _build_engine(cap, max_batch)   # warm-up runs clean
    events = synthesize(n, process="poisson", rate_hz=rate_hz,
                        mix=default_shape_mix(cap), seed=7)
    if rate > 0.0:
        with faults.inject({faults.APPLY_FUSED: faults.FaultSpec(p=rate)},
                           seed=11) as fp:
            snap = _drive_counted(eng, events, log)
        injected, site_hits = fp.injected(), fp.hits(faults.APPLY_FUSED)
    else:
        snap = _drive_counted(eng, events, log)
        injected = site_hits = 0
    c = snap["counters"]
    row = {
        "fault_rate": rate, "requests": n, "rate_hz": rate_hz,
        "injected": injected, "site_hits": site_hits,
        "wall_s": snap["wall_s"],
        "goodput_rps": snap["goodput_rps"],
        "slo_attainment": snap["slo_attainment"],
        "p50_ms": snap["e2e_ms"]["p50_ms"],
        "p95_ms": snap["e2e_ms"]["p95_ms"],
        "p99_ms": snap["e2e_ms"]["p99_ms"],
        "request_errors": snap["request_errors"],
        "request_error_types": snap["request_error_types"],
        "rejected": snap["rejected"],
        "fallback_staged": c.get("resilience_fallback_staged", 0),
        "fallback_reference": c.get("resilience_fallback_reference", 0),
        "breaker_trips": c.get("resilience_breaker_trip", 0),
        "breaker_skips": c.get("resilience_breaker_skip", 0),
        "dispatch_retries": c.get("dispatch_retries", 0),
        "quarantined": c.get("quarantined", 0),
        "shed": c.get("shed", 0),
        "workload": workload,
    }
    log(f"chaos fault={rate:.0%}: injected={injected}/{site_hits} "
        f"goodput={row['goodput_rps']:.1f}rps "
        f"slo={row['slo_attainment']:.2f} p50={row['p50_ms']:.0f}ms "
        f"p99={row['p99_ms']:.0f}ms errors={row['request_errors']} "
        f"fallbacks={row['fallback_staged']}+{row['fallback_reference']} "
        f"trips={row['breaker_trips']}")
    return row


def _recovery_cell(cooldown_s: float, log) -> Dict:
    """Trip the fused breaker with a 100% fault burst, end the burst, and
    time how long until the half-open probe re-closes it.  Driven at the
    plan tier (no engine) so the measured gap is breaker mechanics plus
    apply latency, not queueing."""
    import jax.numpy as jnp

    from repro import faults
    from repro.api import planner, resilience
    from repro.api.spec import ConvSpec
    from repro.quant import INT8_FREQ

    from repro.api.tuning import calibrate_act_scale

    rng = np.random.RandomState(3)
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=8, out_channels=16,
                    spatial=(14, 14), quant=INT8_FREQ)
    w = jnp.asarray(rng.randn(3, 3, 8, 16) * 0.1, jnp.float32)
    x = jnp.asarray(rng.randn(1, 14, 14, 8), jnp.float32)

    with resilience.configured(cooldown_s=cooldown_s):
        p = planner.plan(spec, backend="pallas")
        prep = p.prepare_weights(w, act_scale=calibrate_act_scale(
            x, p.algorithm, spec.quant, spec.padding))
        baseline = p.apply(x, prep)          # healthy reference answer
        with faults.inject(
                {faults.APPLY_FUSED: faults.FaultSpec(p=1.0)}) as fp:
            # burst: every fused attempt fails until the breaker opens
            # and pins the staged fallback (then the site stops being hit)
            trips = 0
            for _ in range(resilience.policy().failure_threshold + 2):
                y = p.apply(x, prep)
                assert bool(jnp.array_equal(y, baseline))   # bit-identical
                trips = resilience.stats().get(
                    "resilience_breaker_trip", 0)
            burst_end = fp.last_fire_t[faults.APPLY_FUSED]
        # burst over (faults disarmed): serve until the probe recovers
        recovered_t = None
        deadline = time.perf_counter() + 60.0
        while recovered_t is None and time.perf_counter() < deadline:
            p.apply(x, prep)
            if resilience.stats().get("resilience_breaker_recovered", 0):
                recovered_t = time.perf_counter()
            else:
                time.sleep(0.01)
        st = resilience.stats()
    recovery_s = (recovered_t - burst_end) if recovered_t else None
    cell = {
        "cooldown_s": cooldown_s,
        "burst_injected": fp.injected(faults.APPLY_FUSED),
        "breaker_trips": trips,
        "breaker_skips": st.get("resilience_breaker_skip", 0),
        "recovered": recovered_t is not None,
        "recovery_s": recovery_s,
    }
    log(f"chaos recovery: burst={cell['burst_injected']} faults, "
        f"trips={trips}, skips={cell['breaker_skips']}, "
        f"recovered in {recovery_s:.2f}s (cooldown {cooldown_s}s)"
        if recovered_t else
        f"chaos recovery: breaker did NOT recover within 60s")
    return cell


def _corrupt_cell(log) -> Dict:
    """Guardrail cell (full mode): NaN-poison the fused output and check
    the guardrail converts garbage into a staged fallback instead of a
    served answer."""
    import jax.numpy as jnp

    from repro import faults
    from repro.api import planner, resilience
    from repro.api.spec import ConvSpec
    from repro.quant import INT8_FREQ

    from repro.api.tuning import calibrate_act_scale

    rng = np.random.RandomState(5)
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=8, out_channels=16,
                    spatial=(14, 14), quant=INT8_FREQ)
    w = jnp.asarray(rng.randn(3, 3, 8, 16) * 0.1, jnp.float32)
    x = jnp.asarray(rng.randn(1, 14, 14, 8), jnp.float32)

    with resilience.configured(guardrail=resilience.Guardrail()):
        p = planner.plan(spec, backend="pallas")
        prep = p.prepare_weights(w, act_scale=calibrate_act_scale(
            x, p.algorithm, spec.quant, spec.padding))
        baseline = p.apply(x, prep)
        with faults.inject({faults.APPLY_FUSED: faults.FaultSpec(
                mode="corrupt", times=3)}) as fp:
            served_garbage = 0
            for _ in range(5):
                y = p.apply(x, prep)
                if not bool(jnp.all(jnp.isfinite(y))):
                    served_garbage += 1
        st = resilience.stats()
    cell = {
        "poisoned": fp.injected(faults.APPLY_FUSED),
        "served_garbage": served_garbage,
        "guardrail_trips": st.get("resilience_guardrail_trip", 0),
        "fallback_staged": st.get("resilience_fallback_staged", 0),
    }
    log(f"chaos guardrail: poisoned={cell['poisoned']} "
        f"garbage_served={served_garbage} "
        f"guardrail_trips={cell['guardrail_trips']} "
        f"fallbacks={cell['fallback_staged']}")
    return cell


def run(log=print, bench_path: Optional[str] = None, *,
        smoke: bool = False) -> Dict:
    import jax

    from repro.api import resilience

    bench_path = bench_path or BENCH_PATH
    cap = int(os.environ.get("REPRO_BENCH_SPATIAL_CAP", "28"))
    n = 32 if smoke else 96
    rate_hz = 200.0
    max_batch = 4 if smoke else 8

    # unrecorded warm-up cell at 100% fault rate: compiles BOTH the fused
    # path (engine warm-up) and the staged fallback (every dispatch falls
    # back), so neither the 0% row (compared against the PR 6 serving
    # baseline) nor a faulted row's first fallback is billed an XLA
    # compile that belongs to the process, not the fault
    _fault_row(1.0, n, rate_hz, cap, max_batch, lambda *a, **k: None)
    rows = [_fault_row(r, n, rate_hz, cap, max_batch, log)
            for r in FAULT_RATES]
    resilience.reset()
    recovery = _recovery_cell(cooldown_s=0.5, log=log)
    resilience.reset()
    guardrail = None if smoke else _corrupt_cell(log)
    resilience.reset()

    bench = {}
    if os.path.exists(bench_path):
        try:
            with open(bench_path) as f:
                bench = json.load(f)
        except ValueError:
            bench = {}
    if not isinstance(bench, dict):
        bench = {}
    bench["chaos"] = {
        "host": {"platform": jax.default_backend(), "jax": jax.__version__,
                 "interpret": True},
        "spatial_cap": cap, "smoke": smoke,
        "rows": rows, "recovery": recovery, "guardrail": guardrail,
    }
    entry = {
        "ts": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "git_sha": _git_sha(),
        "platform": jax.default_backend(), "jax": jax.__version__,
        "chaos": [{k: r[k] for k in
                   ("fault_rate", "injected", "goodput_rps",
                    "slo_attainment", "p50_ms", "p99_ms",
                    "request_errors", "fallback_staged",
                    "breaker_trips")}
                  for r in rows],
        "recovery_s": recovery.get("recovery_s"),
    }
    bench.setdefault("trajectory", []).append(entry)
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    log(f"bench_artifact,{bench_path} "
        f"(trajectory: {len(bench['trajectory'])} entries)")
    return {"bench_path": bench_path, "rows": rows, "recovery": recovery}


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small open-loop run (the CI chaos job)")
    ap.add_argument("--out", default=None, help="BENCH_conv.json path")
    args = ap.parse_args(argv)
    run(bench_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
