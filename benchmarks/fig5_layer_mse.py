"""Paper Fig. 5: per-layer MSE of accelerated vs fp32 layers under int8 PTQ.

Runs a trained smoke CNN, records per-conv-layer output MSE for each
algorithm; the claim: SFC layers sit near direct-quant MSE, Winograd
F(4x4) layers sit far above.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ConvSpec, plan
from repro.configs.resnet18 import SMOKE_CNN
from repro.data import ImagePipelineConfig, SyntheticImagePipeline
from repro.models.cnn import init_resnet
from repro.quant.fake_quant import QuantConfig


def run(log=print):
    t0 = time.time()
    rng = np.random.RandomState(0)
    pipe = SyntheticImagePipeline(ImagePipelineConfig(
        image_size=16, n_classes=10, global_batch=16, seed=3))
    params = init_resnet(jax.random.PRNGKey(0), SMOKE_CNN)
    x = jnp.asarray(pipe.batch(0)["images"])

    # probe each residual-block conv independently ("s<stage>b<block>";
    # the stem key also starts with 's' but has no conv2)
    import re as _re
    layers = [(k, v) for k, v in params.items()
              if _re.fullmatch(r"s\d+b\d+", k)]
    log("layer,algo,mse_ratio_vs_direct_int8")
    results = {}
    for lname, blk in layers:
        w = blk["conv2"]["w"]
        cin = w.shape[2]
        feat = jnp.asarray(np.maximum(
            rng.randn(4, 14, 14, cin), 0), jnp.float32)
        spec = ConvSpec.for_conv2d(feat.shape, w.shape)
        direct_plan = plan(spec, algo="direct")
        ref = direct_plan.apply(feat, w)

        def mse(algo_name, qc):
            if algo_name == "direct":
                from repro.quant.fake_quant import (fake_quant_activation,
                                                    fake_quant_weight)
                xq = fake_quant_activation(feat, 8, "tensor")
                wq = fake_quant_weight(w, 8, "channel")
                y = direct_plan.apply(xq, wq)
            else:
                y = plan(spec, algo=algo_name).apply(
                    feat, w, elementwise_hook=qc.hook())
            return float(jnp.mean((y - ref) ** 2))

        qc = QuantConfig(8, 8, "frequency", "channel+frequency")
        base = mse("direct", None)
        for algo_name in ("sfc6_6", "sfc6_7", "sfc4_4", "wino4"):
            r = mse(algo_name, qc) / (base + 1e-20)
            results.setdefault(algo_name, []).append(r)
            log(f"{lname},{algo_name},{r:.2f}")
    for algo_name, rs in results.items():
        log(f"# mean_ratio,{algo_name},{np.mean(rs):.2f}")
    assert np.mean(results["wino4"]) > np.mean(results["sfc6_6"])
    log(f"# fig5 done in {time.time()-t0:.1f}s")
    return results


if __name__ == "__main__":
    run()
