"""Open-loop serving benchmark: drive ``repro.serve.Engine`` with
synthetic traffic and land SLO rows in ``BENCH_conv.json["serving"]``.

  PYTHONPATH=src python -m benchmarks.run serving
  PYTHONPATH=src python -m benchmarks.serving --smoke       # the CI job

Methodology (EXPERIMENTS.md §Serving): arrivals are *open-loop* — a
Poisson (and a bursty Markov-modulated Poisson) process schedules submit
times independently of the engine's completions, so queueing delay and
the latency tail are measured rather than hidden.  Each row is one
(process, rate) cell: streaming-histogram p50/p95/p99 for queue wait,
service, and end-to-end latency, per-class SLO attainment, goodput
(deadline-met requests per second of wall clock), batch occupancy
(requests per dispatch AND images folded per fused grid step), serving
cache hit rate, and the pad-to-bucket waste fraction.

Two policy-comparison cell families ride along (ISSUE 9):

  * **scheduler** — the same bursty mixed INTERACTIVE/BATCH schedule
    (tight interactive deadline, calibrated so FCFS actually misses it
    under backlog) served once FCFS and once EDF + a short aging hold:
    the deadline-aware former must improve interactive SLO attainment
    and p99 at the same arrival rate without shedding more;
  * **aging** — a low-rate trickle served with aging off and on: the
    hold window folds near-coincident arrivals into one fused grid
    step, raising mean imgs-per-grid-step.

Numbers on this host are interpret-mode Pallas on CPU — they rank
serving policies (batching on/off, bucket tables, admission bounds,
schedulers) against each other and track the trajectory across PRs;
they are not TPU latencies.  The artifact is merged, never overwritten,
and a timestamped git-SHA entry rides ``trajectory`` like the
table3/scaleout suites.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import time
from typing import Dict, List, Optional

import numpy as np

BENCH_PATH = os.environ.get("REPRO_BENCH_OUT", "BENCH_conv.json")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, check=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__))).stdout.strip()
    except Exception:
        return "unknown"


def _build_engine(cap: int, max_batch: int, *, scheduler=None,
                  shed: bool = False):
    import jax.numpy as jnp

    from repro.quant import INT8_FREQ
    from repro.serve import BucketTable, Engine

    cin, cout = 8, 16
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(3, 3, cin, cout) * 0.1, jnp.float32)
    shapes = [(h, h) for h in (10, 14, 20, 28) if h <= cap]
    table = BucketTable.for_workload(shapes, kernel_size=3,
                                     in_channels=cin, out_channels=cout,
                                     quant=INT8_FREQ)
    # round_batches bounds the dispatch shapes to powers of two so
    # warm_compile can pre-trace ALL of them: live traffic never pays a
    # first-shape compile, and the measured tail is queueing, not XLA
    eng = Engine(w, table, max_batch=max_batch, round_batches=True,
                 warm_compile=True, scheduler=scheduler, shed_expired=shed)
    workload = {"kernel": 3, "cin": cin, "cout": cout, "quant": "int8",
                "buckets": [b.name for b in table.buckets],
                "max_batch": max_batch}
    return eng, workload


def _drive(eng, events, log) -> Dict:
    """Submit one traffic schedule open-loop; return the engine snapshot
    plus wall-clock goodput."""
    import jax.numpy as jnp

    from repro.serve import RejectedError, ShedError

    rng = np.random.RandomState(42)
    # inputs pre-generated so submit-time work is only the submit
    xs = [jnp.asarray(rng.randn(h, w, 8), jnp.float32)
          for (h, w) in (e.shape for e in events)]
    eng.start()
    t0 = time.perf_counter()
    futures = []
    for ev, x in zip(events, xs):
        now = time.perf_counter() - t0
        if ev.t > now:
            time.sleep(ev.t - now)
        futures.append((eng.submit(x, ev.slo), ev))
    eng.drain(timeout=600)
    wall_s = time.perf_counter() - t0
    eng.stop()

    good = rejected = shed = 0
    for f, ev in futures:
        try:
            r = f.result(timeout=0)
            good += int(r.deadline_met)
        except RejectedError:
            rejected += 1
        except ShedError:
            shed += 1                  # goodput-preserving deadline shed
    snap = eng.snapshot()
    snap["wall_s"] = wall_s
    snap["goodput_rps"] = good / wall_s if wall_s > 0 else 0.0
    snap["rejected"] = rejected
    return snap


def _row(process: str, rate_hz: float, n: int, snap: Dict) -> Dict:
    occ = snap["batch_occupancy"]
    int_e2e = snap["e2e_by_class"].get("interactive", {})
    return {
        "process": process, "rate_hz": rate_hz, "requests": n,
        "wall_s": snap["wall_s"],
        "p50_ms": snap["e2e_ms"]["p50_ms"],
        "p95_ms": snap["e2e_ms"]["p95_ms"],
        "p99_ms": snap["e2e_ms"]["p99_ms"],
        "queue_wait_p50_ms": snap["queue_wait_ms"]["p50_ms"],
        "service_p50_ms": snap["service_ms"]["p50_ms"],
        "goodput_rps": snap["goodput_rps"],
        "slo_attainment": snap["slo_attainment"],
        "slo": snap["slo"],
        "scheduler": snap["scheduler"],
        "interactive_p99_ms": int_e2e.get("p99_ms"),
        "shed": snap["counters"]["shed"],
        "aged_dispatches": snap["counters"]["aged_dispatches"],
        "hold_ms_mean": snap["hold_ms"]["mean_ms"],
        "occupancy_mean": occ["mean"], "occupancy_max": occ["max"],
        "imgs_per_step_mean": occ["imgs_per_step_mean"],
        "cache_hit_rate": snap["serving_cache"]["hit_rate"],
        "cache_evictions": snap["serving_cache"]["evictions"],
        "pad_waste_frac": snap["pad_waste_frac"],
        "rejected": snap["rejected"],
        "queue_depth_max": snap["queue_depth"]["max"],
    }


def run(log=print, bench_path: Optional[str] = None, *,
        smoke: bool = False) -> Dict:
    import jax

    from repro.serve import (SchedulerPolicy, SLOClass, default_shape_mix,
                             synthesize)

    bench_path = bench_path or BENCH_PATH
    cap = int(os.environ.get("REPRO_BENCH_SPATIAL_CAP", "28"))
    n = 24 if smoke else 48
    # rates chosen against interpret-mode service times (~2-40ms/dispatch
    # warm): the low rate measures the healthy regime, the high rate
    # pushes utilization past 1 so queueing, continuous-batch folding,
    # and SLO misses actually appear in the tail
    rates = [200.0] if smoke else [20.0, 200.0]
    low_rate = 20.0
    max_batch = 4 if smoke else 8
    mix = default_shape_mix(cap)

    def _cell(process, rate, row_n, *, cell, scheduler=None, shed=False,
              slo_mix=None, seed=7):
        # a fresh engine per cell: rows are independent measurements, and
        # warm (plan + calibrate + prepare) stays off the request path
        eng, workload = _build_engine(cap, max_batch, scheduler=scheduler,
                                      shed=shed)
        kw = {} if slo_mix is None else {"slo_mix": slo_mix}
        events = synthesize(row_n, process=process, rate_hz=rate, mix=mix,
                            seed=seed, **kw)
        snap = _drive(eng, events, log)
        row = _row(process, rate, row_n, snap)
        row["cell"] = cell
        sched = row["scheduler"]
        int_p99 = row["interactive_p99_ms"]
        log(f"serving[{cell}] {process}@{rate:.0f}rps "
            f"{sched['kind']}/hold={sched['max_hold_ms']:.0f}ms: "
            f"p50={row['p50_ms']:.0f}ms p95={row['p95_ms']:.0f}ms "
            f"p99={row['p99_ms']:.0f}ms goodput={row['goodput_rps']:.1f}rps "
            f"slo={row['slo_attainment']:.2f} "
            f"int_p99={int_p99 if int_p99 is None else round(int_p99)}ms "
            f"shed={row['shed']} "
            f"occ={row['occupancy_mean']:.2f} "
            f"imgs/step={row['imgs_per_step_mean']:.2f} "
            f"hit={row['cache_hit_rate']:.2f} "
            f"waste={row['pad_waste_frac']:.2f}")
        return row, workload

    rows: List[Dict] = []
    for process, rate in [("poisson", r) for r in rates] \
            + [("bursty", rates[-1])]:
        row, workload = _cell(process, rate, n, cell="baseline")
        rows.append(row)

    # ---- scheduler comparison: FCFS vs EDF(+aging) on mixed traffic ----
    # A 600rps burst of 8n requests queues several dispatches' worth of
    # backlog; the interactive deadline is calibrated to sit between
    # the EDF interactive tail (~40-50ms warm: urgent requests jump the
    # queue) and the FCFS makespan (~2-4x that: interactive requests
    # drain in arrival order behind batch-class peers), so it is met
    # only by serving out of arrival order.  Both cells see the
    # identical arrival schedule, and shedding is on: the backstop EDF
    # is supposed to make rare.
    tight_mix = ((SLOClass("interactive",
                           deadline_ms=45.0 if smoke else 150.0), 0.5),
                 (SLOClass("batch", deadline_ms=20_000.0), 0.5))
    for sched in (SchedulerPolicy(kind="fcfs"),
                  SchedulerPolicy(kind="edf", max_hold_ms=20.0)):
        row, workload = _cell("bursty", 600.0, 8 * n, cell="scheduler",
                              scheduler=sched, shed=True,
                              slo_mix=tight_mix, seed=7)
        rows.append(row)

    # ---- batch aging: fold a low-rate trickle into fused grid steps ----
    # At low rates the queue is usually length-0/1, so the pre-aging former
    # dispatched 1-image slivers; a hold window bounded by head slack
    # trades a little latency for fused-grid occupancy.
    for hold in (0.0, 75.0):
        row, workload = _cell(
            "poisson", low_rate, n, cell="aging",
            scheduler=SchedulerPolicy(kind="edf", max_hold_ms=hold),
            seed=11)
        rows.append(row)

    # accumulate, never overwrite: other suites' keys and the cross-PR
    # trajectory survive this run (same merge discipline as table3)
    bench = {}
    if os.path.exists(bench_path):
        try:
            with open(bench_path) as f:
                bench = json.load(f)
        except ValueError:
            bench = {}
    if not isinstance(bench, dict):
        bench = {}
    bench["serving"] = {
        "host": {"platform": jax.default_backend(), "jax": jax.__version__,
                 "interpret": True},
        "workload": workload, "spatial_cap": cap, "smoke": smoke,
        "rows": rows,
    }
    entry = {
        "ts": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "git_sha": _git_sha(),
        "platform": jax.default_backend(), "jax": jax.__version__,
        "serving": [{**{k: r[k] for k in
                        ("cell", "process", "rate_hz", "p50_ms", "p95_ms",
                         "p99_ms", "goodput_rps", "slo_attainment",
                         "interactive_p99_ms", "shed", "occupancy_mean",
                         "imgs_per_step_mean", "cache_hit_rate")},
                     "scheduler": f"{r['scheduler']['kind']}"
                                  f"+{r['scheduler']['max_hold_ms']:.0f}ms"}
                    for r in rows],
    }
    bench.setdefault("trajectory", []).append(entry)
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1)
    log(f"bench_artifact,{bench_path} "
        f"(trajectory: {len(bench['trajectory'])} entries)")
    return {"bench_path": bench_path, "rows": rows}


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small open-loop run (the CI serve job)")
    ap.add_argument("--out", default=None, help="BENCH_conv.json path")
    args = ap.parse_args(argv)
    run(bench_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
