"""Quantization: granularities, PTQ calibration, BOPs accounting."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conv2d_direct, fastconv2d, generate_sfc
from repro.core import conv2d as c2d
from repro.quant import (ConvWorkload, INT4_FREQ, INT8_FREQ, INT8_TENSOR,
                         PTQLayer, bops_reduction, direct_conv_bops,
                         fake_quant_activation, fake_quant_weight,
                         fastconv_bops, mse_scale_search)
from repro.quant.fake_quant import QuantConfig


def _setup():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 14, 14, 16), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 16, 32) * 0.1, jnp.float32)
    return x, w, generate_sfc(6, 6, 3)


def test_frequency_beats_tensor_granularity():
    """Paper §5/§6.3: frequency-wise scales -> lower error than tensor-wise."""
    x, w, algo = _setup()
    y_fp = conv2d_direct(x, w)

    def err(qc):
        y = fastconv2d(x, w, algo, elementwise_hook=qc.hook())
        return float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))

    assert err(INT8_FREQ) < err(INT8_TENSOR)
    assert err(INT8_FREQ) < 0.03
    assert err(INT4_FREQ) > err(INT8_FREQ)          # fewer bits, more error


def test_bits_monotonic():
    x, w, algo = _setup()
    y_fp = conv2d_direct(x, w)
    errs = []
    for bits in (4, 6, 8):
        qc = QuantConfig(bits, bits, "frequency", "channel+frequency")
        y = fastconv2d(x, w, algo, elementwise_hook=qc.hook())
        errs.append(float(jnp.linalg.norm(y - y_fp)))
    assert errs[0] > errs[1] > errs[2]


def test_fake_quant_roundtrip_levels():
    x = jnp.linspace(-1, 1, 257)[None, :]
    q = fake_quant_activation(x, 8, "tensor")
    assert len(np.unique(np.asarray(q))) <= 255
    q4 = fake_quant_activation(x, 4, "tensor")
    assert len(np.unique(np.asarray(q4))) <= 15


def test_mse_scale_search_improves():
    rng = np.random.RandomState(0)
    # heavy-tailed tensor: absmax scale is wasteful, search should win
    x = jnp.asarray(rng.standard_t(df=2, size=(64, 64)), jnp.float32)
    amax = jnp.abs(x).max() / 127
    s = mse_scale_search(x, 8, (0, 1))

    def qerr(scale):
        q = jnp.clip(jnp.round(x / scale), -127, 127) * scale
        return float(jnp.mean((q - x) ** 2))
    assert qerr(s) <= qerr(amax) + 1e-12


def test_ptq_layer_calibrate_then_deploy():
    x, w, algo = _setup()
    layer = PTQLayer(config=INT8_FREQ)
    # calibration pass observes transform-domain tensors
    fastconv2d(x, w, algo, elementwise_hook=layer.calibration_hook())
    y_fp = conv2d_direct(x, w)
    y_q = fastconv2d(x, w, algo, elementwise_hook=layer.quantized_hook())
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.03
    # deploys on unseen data too
    x2 = jnp.asarray(np.random.RandomState(7).randn(2, 14, 14, 16),
                     jnp.float32)
    y2 = fastconv2d(x2, w, algo, elementwise_hook=layer.quantized_hook())
    rel2 = float(jnp.linalg.norm(y2 - conv2d_direct(x2, w))
                 / jnp.linalg.norm(conv2d_direct(x2, w)))
    assert rel2 < 0.06


def test_bops_sfc_beats_direct():
    """Paper Fig. 4: SFC cuts BOPs 1.6-2.5x+ vs int8 direct convolution."""
    wl = ConvWorkload(H=56, W=56, C_in=64, C_out=64, R=3)
    for nmr in [(6, 6, 3), (6, 7, 3), (4, 4, 3)]:
        r = bops_reduction(wl, generate_sfc(*nmr))
        assert r > 1.6, (nmr, r)


def test_bops_accounting_sane():
    wl = ConvWorkload(H=28, W=28, C_in=32, C_out=32, R=3)
    algo = generate_sfc(6, 6, 3)
    assert fastconv_bops(wl, algo) < direct_conv_bops(wl)
    # transform cost is included: tiny channel counts favor direct
    wl_tiny = ConvWorkload(H=28, W=28, C_in=1, C_out=1, R=3)
    assert (fastconv_bops(wl_tiny, algo) / direct_conv_bops(wl_tiny)
            > fastconv_bops(wl, algo) / direct_conv_bops(wl))
