"""Plan-tier resilience tests: degradation chain, circuit breakers, and
the numerical guardrail (``repro.api.resilience``).

The acceptance invariant: under injected fused-kernel faults the served
answer stays BIT-IDENTICAL (fused and staged share one integer grid),
and a persistently-broken level is pinned out by its breaker instead of
re-crashing every request.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.api import planner, resilience
from repro.api.spec import ConvSpec
from repro.quant import INT8_FREQ

CIN, COUT = 4, 8


@pytest.fixture(autouse=True)
def _fresh_board():
    """Breaker board + counters are process-global: isolate every test."""
    resilience.reset()
    yield
    resilience.reset()


@pytest.fixture(scope="module")
def quantized():
    """One pallas int8 fast-path plan + prep + input + healthy baseline."""
    from repro.api.tuning import calibrate_act_scale
    rng = np.random.RandomState(0)
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=CIN,
                    out_channels=COUT, spatial=(8, 8), quant=INT8_FREQ)
    w = jnp.asarray(rng.randn(3, 3, CIN, COUT) * 0.2, jnp.float32)
    x = jnp.asarray(rng.randn(2, 8, 8, CIN), jnp.float32)
    p = planner.plan(spec, backend="pallas")
    scale = calibrate_act_scale(x, p.algorithm, spec.quant, spec.padding)
    prep = p.prepare_weights(w, act_scale=scale)
    assert prep.quantized                      # fused int8 datapath armed
    baseline = p.apply(x, prep)
    return p, prep, x, baseline


# ----------------------------------------------------------------------
# circuit breaker state machine (fake clock, no kernels)
# ----------------------------------------------------------------------
def test_breaker_state_machine():
    t = [0.0]
    br = resilience.CircuitBreaker(failure_threshold=3, cooldown_s=5.0,
                                   clock=lambda: t[0])
    assert br.state == resilience.CLOSED and br.allow()
    assert br.record_failure() is False
    assert br.record_failure() is False
    assert br.record_failure() is True         # threshold -> OPEN (tripped)
    assert br.state == resilience.OPEN
    assert not br.allow()                      # cooling down
    t[0] = 4.9
    assert not br.allow()
    t[0] = 5.0
    assert br.allow()                          # half-open: one probe
    assert br.state == resilience.HALF_OPEN
    assert not br.allow()                      # second probe refused
    assert br.record_failure() is True         # failed probe re-opens
    assert br.state == resilience.OPEN
    t[0] = 10.0
    assert br.allow()
    assert br.record_success() is True         # recovered
    assert br.state == resilience.CLOSED
    assert br.record_success() is False        # ordinary success
    assert br.snapshot() == {"state": "closed", "failures": 0}


def test_breaker_consecutive_not_cumulative_failures():
    br = resilience.CircuitBreaker(failure_threshold=3)
    br.record_failure()
    br.record_failure()
    br.record_success()                        # resets the streak
    br.record_failure()
    br.record_failure()
    assert br.state == resilience.CLOSED


def test_breaker_validation():
    with pytest.raises(ValueError, match="failure_threshold"):
        resilience.CircuitBreaker(failure_threshold=0)


# ----------------------------------------------------------------------
# the degradation chain
# ----------------------------------------------------------------------
def test_fused_fault_falls_back_bit_identical(quantized):
    """Acceptance: a fused-kernel crash is invisible — the staged
    fallback answer equals the healthy answer bit-for-bit."""
    p, prep, x, baseline = quantized
    with faults.inject({faults.APPLY_FUSED: faults.FaultSpec()}) as fp:
        y = p.apply(x, prep)
    assert fp.injected(faults.APPLY_FUSED) == 1
    assert np.array_equal(np.asarray(y), np.asarray(baseline))
    st = resilience.stats()
    assert st["resilience_fallback_staged"] == 1
    assert st["resilience_apply_failure"] == 1


def test_double_fault_falls_back_to_reference(quantized):
    p, prep, x, baseline = quantized
    with faults.inject({faults.APPLY_FUSED: faults.FaultSpec(),
                        faults.APPLY_STAGED: faults.FaultSpec()}):
        y = p.apply(x, prep)
    st = resilience.stats()
    assert st["resilience_fallback_reference"] == 1
    assert st["resilience_apply_failure"] == 2
    # reference is the int8 *simulation*: fp-epsilon close, not bit-equal
    np.testing.assert_allclose(np.asarray(y), np.asarray(baseline),
                               rtol=1e-4, atol=1e-4)


def test_total_failure_raises_last_error(quantized):
    p, prep, x, _ = quantized
    with faults.inject({faults.APPLY_FUSED: faults.FaultSpec(),
                        faults.APPLY_STAGED: faults.FaultSpec(),
                        faults.APPLY_REFERENCE: faults.FaultSpec()}):
        with pytest.raises(faults.InjectedFault):
            p.apply(x, prep)


def test_breaker_pins_fallback_under_persistent_faults(quantized):
    """After ``failure_threshold`` consecutive fused failures the fused
    level stops being ATTEMPTED: the injection site's hit count freezes
    while requests keep being served."""
    p, prep, x, baseline = quantized
    thr = resilience.policy().failure_threshold
    with faults.inject({faults.APPLY_FUSED: faults.FaultSpec()}) as fp:
        for _ in range(thr + 3):
            y = p.apply(x, prep)
            assert np.array_equal(np.asarray(y), np.asarray(baseline))
        assert fp.hits(faults.APPLY_FUSED) == thr      # pinned out
    st = resilience.stats()
    assert st["resilience_breaker_trip"] == 1
    assert st["resilience_breaker_skip"] == 3
    key = (p.spec, p.backend, "fused")
    assert resilience.breaker_for(key).state == resilience.OPEN


def test_breaker_recovers_after_cooldown(quantized):
    p, prep, x, baseline = quantized
    t = [0.0]
    with resilience.configured(cooldown_s=10.0, clock=lambda: t[0]):
        with faults.inject({faults.APPLY_FUSED: faults.FaultSpec()}):
            for _ in range(resilience.policy().failure_threshold):
                p.apply(x, prep)
        key = (p.spec, p.backend, "fused")
        assert resilience.breaker_for(key).state == resilience.OPEN
        # faults gone, but the cool-down has not elapsed: still skipped
        p.apply(x, prep)
        assert resilience.stats().get("resilience_breaker_recovered",
                                      0) == 0
        t[0] = 11.0                                    # cool-down elapsed
        y = p.apply(x, prep)                           # half-open probe
        assert np.array_equal(np.asarray(y), np.asarray(baseline))
        st = resilience.stats()
        assert st["resilience_breaker_probe"] == 1
        assert st["resilience_breaker_recovered"] == 1
        assert resilience.breaker_for(key).state == resilience.CLOSED


def test_disabled_policy_propagates_faults(quantized):
    p, prep, x, _ = quantized
    with resilience.configured(enabled=False):
        with faults.inject({faults.APPLY_FUSED: faults.FaultSpec()}):
            with pytest.raises(faults.InjectedFault):
                p.apply(x, prep)


def test_reference_backend_not_engaged():
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=CIN,
                    out_channels=COUT, spatial=(8, 8), quant=INT8_FREQ)
    assert not resilience.engaged(planner.plan(spec, backend="reference"))
    assert resilience.engaged(planner.plan(spec, backend="pallas"))


# ----------------------------------------------------------------------
# numerical guardrail
# ----------------------------------------------------------------------
def test_guardrail_converts_nan_output_into_fallback(quantized):
    """A silently-corrupted fused output (NaN poison) must never be
    served: the guardrail trips, the breaker counts it, staged serves."""
    p, prep, x, baseline = quantized
    with resilience.configured(guardrail=resilience.Guardrail()):
        with faults.inject({faults.APPLY_FUSED: faults.FaultSpec(
                mode="corrupt")}) as fp:
            y = p.apply(x, prep)
        assert fp.injected(faults.APPLY_FUSED) == 1
        assert np.array_equal(np.asarray(y), np.asarray(baseline))
        st = resilience.stats()
        assert st["resilience_guardrail_trip"] == 1
        assert st["resilience_fallback_staged"] == 1


def test_guardrail_saturation_probe_trips_on_miscalibrated_scales():
    """Scales calibrated on small activations + 100x larger live input:
    the transform-domain saturation rate blows past the bound on EVERY
    quantized level — served garbage becomes a loud failure."""
    from repro.api.tuning import calibrate_act_scale
    rng = np.random.RandomState(1)
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=CIN,
                    out_channels=COUT, spatial=(8, 8), quant=INT8_FREQ)
    w = jnp.asarray(rng.randn(3, 3, CIN, COUT) * 0.2, jnp.float32)
    xc = jnp.asarray(rng.randn(2, 8, 8, CIN) * 0.01, jnp.float32)
    p = planner.plan(spec, backend="pallas")
    scale = calibrate_act_scale(xc, p.algorithm, spec.quant, spec.padding)
    prep = p.prepare_weights(w, act_scale=scale)
    x = jnp.asarray(rng.randn(2, 8, 8, CIN), jnp.float32)  # 100x calib
    with resilience.configured(
            guardrail=resilience.Guardrail(max_sat_frac=0.05)):
        with pytest.raises(resilience.GuardrailViolation,
                           match="saturation"):
            p.apply(x, prep)
        assert resilience.stats()["resilience_guardrail_trip"] >= 2

    # and a healthy input under the same guardrail passes untouched
    resilience.reset()
    prep2 = p.prepare_weights(w, act_scale=calibrate_act_scale(
        x, p.algorithm, spec.quant, spec.padding))
    with resilience.configured(
            guardrail=resilience.Guardrail(max_sat_frac=0.05)):
        p.apply(x, prep2)
    assert "resilience_guardrail_trip" not in resilience.stats()


# ----------------------------------------------------------------------
# observability plumbing
# ----------------------------------------------------------------------
def test_metrics_sink_routes_events_to_caller(quantized):
    p, prep, x, _ = quantized
    seen = {}

    def inc(name, by=1):
        seen[name] = seen.get(name, 0) + by

    with resilience.metrics_sink(inc):
        with faults.inject({faults.APPLY_FUSED: faults.FaultSpec()}):
            p.apply(x, prep)
    assert seen["resilience_fallback_staged"] == 1
    assert seen["resilience_apply_failure"] == 1
    # global counters got the same events
    assert resilience.stats()["resilience_fallback_staged"] == 1
    # outside the sink, events no longer route to `seen`
    with faults.inject({faults.APPLY_FUSED: faults.FaultSpec()}):
        p.apply(x, prep)
    assert seen["resilience_fallback_staged"] == 1
    assert resilience.stats()["resilience_fallback_staged"] == 2


def test_board_snapshot_keys_are_readable(quantized):
    p, prep, x, _ = quantized
    with faults.inject({faults.APPLY_FUSED: faults.FaultSpec()}):
        p.apply(x, prep)
    snap = resilience.board_snapshot()
    assert any(k.endswith("|pallas|fused") for k in snap)
    assert all(v["state"] in (resilience.CLOSED, resilience.OPEN,
                              resilience.HALF_OPEN)
               for v in snap.values())


def test_configured_restores_previous_policy():
    before = resilience.policy()
    with resilience.configured(failure_threshold=99) as pol:
        assert pol.failure_threshold == 99
        assert resilience.policy() is pol
    assert resilience.policy() == before
