"""Fault-injection framework tests: determinism, schedules, modes,
nesting, and zero-footprint disarm."""
import numpy as np
import pytest

from repro import faults


def _drive(spec_kwargs, hits, seed=0, site=faults.APPLY_FUSED):
    """Hit one raise-mode site ``hits`` times; return the 0/1 firing
    pattern."""
    pattern = []
    with faults.inject({site: faults.FaultSpec(**spec_kwargs)},
                       seed=seed) as fp:
        for _ in range(hits):
            try:
                faults.maybe_fault(site)
                pattern.append(0)
            except faults.InjectedFault:
                pattern.append(1)
    return pattern, fp


def test_disarmed_hooks_are_noops():
    assert faults.active() is None
    faults.maybe_fault(faults.APPLY_FUSED)          # must not raise
    assert faults.maybe_corrupt(faults.APPLY_FUSED, 42) == 42


def test_p1_fires_every_hit():
    pattern, fp = _drive({"p": 1.0}, 5)
    assert pattern == [1] * 5
    assert fp.hits(faults.APPLY_FUSED) == 5
    assert fp.injected(faults.APPLY_FUSED) == 5
    assert fp.injected() == 5


def test_probability_schedule_is_deterministic_per_seed():
    a, _ = _drive({"p": 0.3}, 200, seed=123)
    b, _ = _drive({"p": 0.3}, 200, seed=123)
    c, _ = _drive({"p": 0.3}, 200, seed=124)
    assert a == b                          # same seed -> same pattern
    assert a != c                          # different seed -> different
    assert 0 < sum(a) < 200                # actually probabilistic
    # rate roughly honored (binomial, 200 draws)
    assert abs(sum(a) / 200 - 0.3) < 0.12


def test_per_site_streams_are_interleaving_independent():
    """The firing sequence at one site must not depend on traffic at
    another site."""
    s1, s2 = faults.APPLY_FUSED, faults.APPLY_STAGED
    spec = faults.FaultSpec(p=0.5)

    def fire_seq(interleave):
        seq = []
        with faults.inject({s1: spec, s2: spec}, seed=7):
            for i in range(100):
                if interleave:
                    try:
                        faults.maybe_fault(s2)
                    except faults.InjectedFault:
                        pass
                try:
                    faults.maybe_fault(s1)
                    seq.append(0)
                except faults.InjectedFault:
                    seq.append(1)
        return seq

    assert fire_seq(False) == fire_seq(True)


def test_times_bounds_the_burst():
    pattern, fp = _drive({"p": 1.0, "times": 3}, 10)
    assert pattern == [1, 1, 1] + [0] * 7
    assert fp.injected(faults.APPLY_FUSED) == 3
    assert fp.hits(faults.APPLY_FUSED) == 10


def test_after_skips_leading_hits():
    pattern, _ = _drive({"p": 1.0, "after": 4}, 7)
    assert pattern == [0] * 4 + [1] * 3


def test_when_predicate_gates_on_detail():
    site = faults.DISPATCH
    with faults.inject({site: faults.FaultSpec(
            when=lambda d: d == "poison")}) as fp:
        faults.maybe_fault(site, detail="clean")        # not eligible
        with pytest.raises(faults.InjectedFault):
            faults.maybe_fault(site, detail="poison")
    assert fp.injected(site) == 1
    assert fp.hits(site) == 1              # non-matching hits not counted


def test_corrupt_mode_rewrites_value_and_raise_hook_ignores_it():
    import jax.numpy as jnp
    site = faults.APPLY_FUSED
    with faults.inject({site: faults.FaultSpec(mode="corrupt")}) as fp:
        faults.maybe_fault(site)                        # wrong-mode: no-op
        y = faults.maybe_corrupt(site, jnp.ones((2, 2)))
        assert bool(jnp.all(jnp.isnan(y)))
    assert fp.injected(site) == 1


def test_custom_corrupt_and_exc():
    site = faults.APPLY_STAGED
    with faults.inject({site: faults.FaultSpec(
            mode="corrupt", corrupt=lambda v: -v)}):
        assert faults.maybe_corrupt(site, 5) == -5
    with faults.inject({site: faults.FaultSpec(exc=ValueError)}):
        with pytest.raises(ValueError):
            faults.maybe_fault(site)


def test_nesting_shadows_and_restores():
    outer = faults.FaultSpec(p=1.0)
    with faults.inject({faults.PLAN: outer}) as fp_outer:
        with faults.inject({faults.CACHE: faults.FaultSpec()}) as fp_inner:
            assert faults.active() is fp_inner
            faults.maybe_fault(faults.PLAN)             # outer shadowed
        assert faults.active() is fp_outer
        with pytest.raises(faults.InjectedFault):
            faults.maybe_fault(faults.PLAN)
    assert faults.active() is None


def test_unknown_site_rejected_unless_allowed():
    with pytest.raises(ValueError, match="unknown fault site"):
        faults.FaultPlan({"not-a-site": faults.FaultSpec()})
    fp = faults.FaultPlan({"not-a-site": faults.FaultSpec()},
                          allow_unknown_sites=True)
    assert fp.specs["not-a-site"].p == 1.0


def test_spec_validation():
    with pytest.raises(ValueError, match="p must be"):
        faults.FaultSpec(p=1.5)
    with pytest.raises(ValueError, match="mode must be"):
        faults.FaultSpec(mode="explode")


def test_sites_fire_inside_production_code():
    """The planted hooks in planner/plan/serving_cache actually raise."""
    import jax.numpy as jnp

    from repro.api import planner, serving_cache
    from repro.api.spec import ConvSpec

    spec = ConvSpec(rank=2, kernel_size=3, in_channels=4, out_channels=4,
                    spatial=(8, 8))
    w = jnp.zeros((3, 3, 4, 4), jnp.float32)
    with faults.inject({faults.PLAN: faults.FaultSpec()}):
        with pytest.raises(faults.InjectedFault):
            planner.plan(spec)
    with faults.inject({faults.PREPARE: faults.FaultSpec()}):
        with pytest.raises(faults.InjectedFault):
            planner.plan(spec).prepare_weights(w)
    with faults.inject({faults.CACHE: faults.FaultSpec()}):
        with pytest.raises(faults.InjectedFault):
            serving_cache.ServingCache().get(spec, w)


def test_last_fire_t_stamps_fires():
    import time
    t0 = time.perf_counter()
    _, fp = _drive({"p": 1.0, "times": 2}, 5)
    assert faults.APPLY_FUSED in fp.last_fire_t
    assert fp.last_fire_t[faults.APPLY_FUSED] >= t0
