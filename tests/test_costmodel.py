"""repro.api.costmodel: the calibrated analytic cost model.

Covers the ISSUE-10 contract: fit determinism, memory-feature
monotonicity, ``autotune(top_k=k)`` measuring exactly k launchable
candidates (and all of them when unfitted), the planner's
measured > model > BOPs precedence, the model-predicted config riding
cold plans, and coefficient persistence.
"""
import jax

from repro.api import ConvSpec, costmodel, plan, registry, tuning
from repro.api.planner import select_algorithm
from repro.api.tuning import KernelConfig
from repro.quant.fake_quant import INT8_FREQ


def _spec(cin=64, cout=128, hw=14):
    return ConvSpec(kernel_size=3, in_channels=cin, out_channels=cout,
                    spatial=(hw, hw), quant=INT8_FREQ)


def _algo(spec):
    return registry.get_algorithm(select_algorithm(spec))


def _patch_deterministic_measure(monkeypatch):
    """Replace ``tuning._measure_plan`` with a pseudo-latency that is a
    fixed linear function of the candidate's analytic features — nothing
    executes, rankings are deterministic, and the least-squares fit has
    an exact solution to recover."""
    def fake(p, x, w, reps):
        feats = costmodel.features_for(p.spec, p.algorithm, p.config,
                                       batch=x.shape[0])
        base = {"direct": 5e-3, "fused": 1e-3, "staged": 3e-3}
        return (base[feats.datapath] + feats.grid_steps * 1e-5
                + feats.roof_s * 2.0)
    monkeypatch.setattr(tuning, "_measure_plan", fake)
    return fake


def _full_coefs(fused=(1e-3, 1e-5, 2.0), staged=(3e-3, 1e-5, 2.0),
                direct=(5e-3, 2.0)):
    return {"fused": list(fused), "staged": list(staged),
            "direct": list(direct)}


# ---------------------------------------------------------------------------
# features
# ---------------------------------------------------------------------------
def test_memory_feature_monotone_in_cin():
    """More C_in k-blocks must never predict fewer memory cycles: the
    memory-seconds feature is non-decreasing in C_in at fixed config."""
    algo = _algo(_spec())
    cfg = tuning.DEFAULT_FUSED
    mem = [costmodel.features_for(_spec(cin=c), algo, cfg).memory_s
           for c in (32, 64, 128, 256, 512)]
    assert all(b >= a for a, b in zip(mem, mem[1:])), mem


def test_memory_feature_monotone_in_k_blocking():
    """Splitting the same C_in into more k-blocks never *reduces* the
    modelled HBM traffic (per-step bytes shrink but steps grow — the
    total is invariant or larger, never smaller)."""
    spec = _spec(cin=256)
    algo = _algo(spec)
    full = costmodel.features_for(spec, algo,
                                  KernelConfig(k_block=None))
    blocked = costmodel.features_for(spec, algo,
                                     KernelConfig(k_block=64))
    assert blocked.memory_s >= full.memory_s


def test_unfitted_model_predicts_nothing():
    spec = _spec()
    assert not costmodel.is_fitted()
    assert costmodel.predict_time(spec, _algo(spec),
                                  tuning.DEFAULT_FUSED) is None
    assert costmodel.best_config(spec, "pallas", "sfc4_4") is None
    assert costmodel.select_algorithm(
        spec, [registry.DIRECT, "sfc4_4"], "pallas") is None


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------
def test_fit_determinism(monkeypatch):
    """Same probes -> same coefficients, bit-for-bit."""
    _patch_deterministic_measure(monkeypatch)
    r1 = costmodel.fit_coefficients(persist=False)
    costmodel.clear()
    r2 = costmodel.fit_coefficients(persist=False)
    assert r1["coefficients"] == r2["coefficients"]
    assert set(r1["coefficients"]) >= {"fused", "direct"}


def test_fit_recovers_linear_pseudo_latency(monkeypatch):
    """The fit must reproduce the (linear, noise-free) pseudo-latency it
    measured: predictions equal measurements on the probe set."""
    fake = _patch_deterministic_measure(monkeypatch)
    report = costmodel.fit_coefficients(persist=False)
    for dp, errs in report["fit_error"].items():
        assert errs["max_rel"] < 1e-6, (dp, errs)
    # and end-to-end: predict_time matches the fake for a fresh spec
    spec = _spec(cin=128, cout=128, hw=10)
    x, w = tuning._synthetic_operands(spec)
    p = plan(spec, backend="pallas", algo=select_algorithm(spec))
    for cfg in (tuning.DEFAULT_FUSED, tuning.DEFAULT_STAGED):
        pred = costmodel.predict_time(spec, p.algorithm, cfg)
        want = fake(p.with_config(cfg), x, w, 1)
        assert abs(pred - want) / want < 1e-6


def test_coefficients_persist_across_reload():
    coefs = _full_coefs()
    costmodel.set_coefficients(coefs, "pallas", interpret=True)
    path = costmodel.cache_path()
    # a fresh process == dropping the in-memory store and reloading
    costmodel.set_cache_path(path)
    assert costmodel.coefficients("pallas", True) == coefs
    # keyed per backend/interpret: other keys stay unfitted
    assert costmodel.coefficients("pallas", False) is None
    assert costmodel.coefficients("reference", True) is None


# ---------------------------------------------------------------------------
# autotune top-k
# ---------------------------------------------------------------------------
def _count_measures(monkeypatch):
    counted = []

    def fake(p, x, w, reps):
        counted.append(p.config)
        feats = costmodel.features_for(p.spec, p.algorithm, p.config,
                                       batch=x.shape[0])
        base = {"direct": 5e-3, "fused": 1e-3, "staged": 3e-3}
        return (base[feats.datapath] + feats.grid_steps * 1e-5
                + feats.roof_s * 2.0)

    monkeypatch.setattr(tuning, "_measure_plan", fake)
    return counted


def test_autotune_topk_measures_exactly_k(monkeypatch):
    from repro.analysis import kernel_checks
    spec = _spec()
    algo = _algo(spec)
    launchable, _ = kernel_checks.check_candidates(
        spec, algo, tuning.DEFAULT_CANDIDATES, batch=1)
    assert len(launchable) > 3          # the truncation is observable
    costmodel.set_coefficients(_full_coefs())
    counted = _count_measures(monkeypatch)
    results = tuning.autotune(spec, algos=[select_algorithm(spec)],
                              include_direct=False, top_k=2)
    assert len(counted) == 2
    name = select_algorithm(spec)
    # predicted-vs-measured self-validation rides the cache entry
    assert "predicted_s" in results[name]
    assert "predicted_s" in tuning.lookup(spec, "pallas")[name]


def test_autotune_unfitted_measures_every_launchable(monkeypatch):
    """Behaviour preservation: with no fitted model, top_k is a no-op
    and the sweep stays exhaustive."""
    from repro.analysis import kernel_checks
    spec = _spec()
    algo = _algo(spec)
    launchable, _ = kernel_checks.check_candidates(
        spec, algo, tuning.DEFAULT_CANDIDATES, batch=1)
    assert not costmodel.is_fitted()
    counted = _count_measures(monkeypatch)
    tuning.autotune(spec, algos=[select_algorithm(spec)],
                    include_direct=False, top_k=3)
    assert len(counted) == len(launchable)


# ---------------------------------------------------------------------------
# planner precedence: measured > model > BOPs
# ---------------------------------------------------------------------------
def test_planner_precedence_measured_over_model_over_bops():
    spec = _spec()
    bops_best = select_algorithm(spec)          # no backend: pure BOPs
    assert bops_best != registry.DIRECT
    # tier 3 — unfitted, untimed: BOPs governs
    assert select_algorithm(spec, backend="pallas") == bops_best
    # tier 2 — a fitted model that prices direct as near-free overrides
    # the BOPs ranking
    costmodel.set_coefficients(_full_coefs(
        fused=(10.0, 0.0, 0.0), staged=(10.0, 0.0, 0.0),
        direct=(1e-6, 0.0)))
    assert select_algorithm(spec, backend="pallas") == registry.DIRECT
    # tier 1 — measured wall-clock beats the model
    tuning.record(spec, "pallas", bops_best, 1e-4)
    tuning.record(spec, "pallas", registry.DIRECT, 5e-4)
    assert select_algorithm(spec, backend="pallas") == bops_best


def test_model_predicted_config_rides_cold_plan():
    """With no timing entry, a fitted model supplies the plan's kernel
    config (the serve engine's cold-bucket warm-up path)."""
    spec = _spec()
    # price per grid step only: the rows_per_step=None single-step grid
    # wins, and staged (many steps) loses
    costmodel.set_coefficients(_full_coefs(
        fused=(0.0, 1e-5, 0.0), staged=(0.0, 1e-5, 0.0),
        direct=(5e-3, 0.0)))
    name = select_algorithm(spec)
    p = plan(spec, backend="pallas", algo=name)
    assert p.config is not None
    assert p.config == costmodel.best_config(spec, "pallas", name)
    assert p.config.datapath == "fused" and p.config.rows_per_step is None
    # measured config takes over once recorded
    cfg = KernelConfig(datapath="fused", k_block=None)
    tuning.record(spec, "pallas", name, 1e-4, cfg)
    assert plan(spec, backend="pallas", algo=name).config == cfg


def test_rank_candidates_orders_by_prediction():
    spec = _spec()
    algo = _algo(spec)
    costmodel.set_coefficients(_full_coefs())
    ranked = costmodel.rank_candidates(spec, algo)
    assert ranked is not None and len(ranked) >= 3
    preds = [t for _, t in ranked]
    assert preds == sorted(preds)
    for cfg, t in ranked:
        assert abs(costmodel.predict_time(spec, algo, cfg) - t) < 1e-12


def test_engine_warm_source_accounting():
    """Cold buckets under a fitted model warm as 'model'; timed buckets
    as 'measured'; the snapshot exposes the provenance."""
    import numpy as np
    from repro.serve.bucketing import BucketTable
    from repro.serve.engine import Engine

    rng = np.random.RandomState(0)
    w = rng.randn(3, 3, 8, 8).astype("float32") * 0.1
    table = BucketTable.for_workload([(10, 10)], kernel_size=3,
                                     in_channels=8, out_channels=8,
                                     quant=INT8_FREQ)
    costmodel.set_coefficients(_full_coefs())
    eng = Engine(w, table, interpret=True)
    b = table.buckets[0]
    src = eng.warm_sources[b.name]
    snap = eng.snapshot()
    assert snap["warm_config_sources"][b.name] == src
    if eng._plan(b).path == "fast":
        assert src == "model"
        assert snap["counters"]["warm_config_model"] >= 1
    # a timing entry flips the bucket to 'measured' on a fresh engine
    tuning.record(b.spec, "pallas", select_algorithm(b.spec), 1e-4,
                  KernelConfig())
    eng2 = Engine(w, table, interpret=True)
    assert eng2.warm_sources[b.name] == "measured"
