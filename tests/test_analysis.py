"""repro.analysis.ranges: static overflow/bit-width verification.

Covers the golden certificate table, the exactness of the safe-C_in
bound (a real int8 x int8 -> int32 contraction wraps one past it and is
exact at it), the plan-time pre-flight on integer-datapath backends, the
tightness of the 2-D transform bound, and (slow tier) a hypothesis fuzz
of observed vs predicted transform-domain ranges.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import ranges
from repro.api import plan, registry
from repro.api.spec import ConvSpec
from repro.core import conv2d as c2d
from repro.quant.fake_quant import QuantConfig

Q88 = QuantConfig(enabled=True, bits_act=8, bits_weight=8)


# --------------------------------------------------------------------------
# golden certificate table (derived from the exact Fraction matrices;
# a generator change that alters transform growth must show up here)
# --------------------------------------------------------------------------
GOLDEN = {
    # name: (M, R, t, bt_l1, transform_hi, transform_bits, at_l1, integer)
    "sfc4_4":    (4, 3, 7, 4.0, 2032, 12, 3.0, True),
    "sfc4_4_r2": (4, 2, 6, 4.0, 2032, 12, 2.5, True),
    "sfc4_5_r2": (5, 2, 7, 4.0, 2032, 12, 2.5, True),
    "sfc6_6":    (6, 3, 10, 6.0, 4572, 14, 8 / 3, True),
    "sfc6_6_r4": (6, 4, 12, 6.0, 4572, 14, 11 / 3, True),
    "sfc6_7":    (7, 3, 12, 6.0, 4572, 14, 11 / 3, True),
    "sfc6_7_r2": (7, 2, 10, 6.0, 4572, 14, 8 / 3, True),
    "wino2":     (2, 3, 4, 2.0, 508, 10, 3.0, False),
    "wino4":     (4, 3, 6, 10.0, 12700, 15, 19.0, False),
}


def test_certificate_golden_table():
    certs = ranges.all_certificates()
    assert set(certs) == set(GOLDEN), "registry/golden table drifted"
    for name, (M, R, t, l1, hi, bits, at_l1, integer) in GOLDEN.items():
        c = certs[name]
        assert (c.M, c.R, c.t) == (M, R, t), name
        assert c.bt_row_l1 == pytest.approx(l1), name
        assert c.transform_hi == hi, name
        assert c.transform_bits == bits, name
        assert c.at_row_l1 == pytest.approx(at_l1), name
        assert c.integer_transform is integer, name
        # shared stage-3/4 facts at 8/8 bits
        assert c.product_hi == 127 * 127
        assert c.safe_cin == ranges.safe_cin_bound() == 133144
        assert c.acc_bits_at_safe_cin == 32
        assert c.dequant_exact_cin == 2 ** 24 // (127 * 127) == 1040
        # 2-D growth is the separable square of the 1-D row norm
        assert c.transform_growth_2d == pytest.approx(l1 * l1)


def test_certificate_headroom_and_json_roundtrip():
    c = ranges.certificate(registry.get_algorithm("sfc4_4"))
    assert c.headroom_bits(64) > 0
    assert c.headroom_bits(c.safe_cin) == 0
    assert c.headroom_bits(c.safe_cin + 1) <= 0
    j = c.to_json()
    assert j["safe_cin"] == c.safe_cin and j["algo"] == c.algo


def test_transform_bits_matches_historical_bops_formula():
    # the shared helper must stay bit-identical to the expression the
    # BOPs model inlined historically — rankings must not move
    for e in registry.entries():
        algo = registry.get_algorithm(e.name)
        row_l1 = max(int(sum(abs(v) for v in row)) for row in algo.BT)
        legacy = 8 + max(1, math.ceil(math.log2(max(row_l1, 2))))
        assert ranges.transform_bits_1d(algo, 8) == legacy, e.name


# --------------------------------------------------------------------------
# the bound is exact: the real accumulator wraps one past it
# --------------------------------------------------------------------------
def _int8_contraction(k: int) -> int:
    """Worst-case K-length int8 x int8 contraction through the same
    primitive/accumulator the kernels use (lax dot, int32 preferred)."""
    a = jnp.full((1, k), 127, dtype=jnp.int8)
    b = jnp.full((k, 1), 127, dtype=jnp.int8)
    out = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return int(out[0, 0])


def test_safe_cin_bound_is_exact():
    bound = ranges.safe_cin_bound()
    assert bound == (2 ** 31 - 1) // (127 * 127)
    # at the bound: exact
    assert _int8_contraction(bound) == bound * 127 * 127
    # one past the bound: the int32 accumulator actually wraps —
    # this is the mis-accumulation the plan() pre-flight prevents
    wrapped = _int8_contraction(bound + 1)
    assert wrapped != (bound + 1) * 127 * 127
    assert wrapped < 0


def test_check_contraction_error_names_bound():
    with pytest.raises(ranges.AccumulatorOverflowError) as ei:
        ranges.check_contraction(ranges.safe_cin_bound() + 1, 8, 8,
                                 context=" (unit test)")
    msg = str(ei.value)
    assert str(ranges.safe_cin_bound()) in msg
    assert "unit test" in msg


# --------------------------------------------------------------------------
# plan-time pre-flight
# --------------------------------------------------------------------------
def _overflow_spec(cin: int = 200_000) -> ConvSpec:
    return ConvSpec(kernel_size=3, in_channels=cin, out_channels=8,
                    spatial=(8, 8), quant=Q88)


def test_plan_rejects_overflow_spec_on_integer_backends():
    for backend in ("pallas", "pallas_spmd"):
        with pytest.raises(ranges.AccumulatorOverflowError) as ei:
            plan(_overflow_spec(), backend=backend, algo="sfc4_4")
        assert str(ranges.safe_cin_bound()) in str(ei.value)


def test_plan_allows_overflow_spec_on_reference_backend():
    # the reference backend fake-quantizes in f32 — no int32 to wrap
    p = plan(_overflow_spec(), backend="reference", algo="sfc4_4")
    assert p.path == "fast" and p.algo_name == "sfc4_4"


def test_plan_boundary_cases_on_pallas():
    bound = ranges.safe_cin_bound()
    ok = plan(_overflow_spec(bound), backend="pallas", algo="sfc4_4")
    assert ok.algo_name == "sfc4_4"
    with pytest.raises(ranges.AccumulatorOverflowError):
        plan(_overflow_spec(bound + 1), backend="pallas", algo="sfc4_4")
    # unquantized, depthwise (K=1), and grouped-under-bound specs pass
    assert plan(ConvSpec(kernel_size=3, in_channels=bound + 1,
                         out_channels=8, spatial=(8, 8)),
                backend="pallas", algo="sfc4_4").spec.in_channels \
        == bound + 1
    dw = ConvSpec(kernel_size=3, depthwise=True, in_channels=bound + 8,
                  out_channels=bound + 8, spatial=(8, 8), quant=Q88)
    assert plan(dw, backend="pallas", algo="sfc4_4") is not None


def test_autotune_skips_overflowing_algorithm(deterministic_time_fn):
    # autotune over a spec no integer algorithm may run: every fast algo
    # is skipped with a logged reason, only direct is measured
    from repro.api import tuning
    spec = ConvSpec(kernel_size=3, in_channels=ranges.safe_cin_bound() + 1,
                    out_channels=8, spatial=(4, 4), quant=Q88)
    msgs = []
    res = tuning.autotune(spec, backend="pallas", algos=["sfc4_4"],
                          reps=1, persist=False, log=msgs.append)
    assert list(res) == ["direct"]
    assert any("skipped" in m and "sfc4_4" in m for m in msgs)


# --------------------------------------------------------------------------
# tightness of the transform bound
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["sfc4_4", "sfc6_6", "wino4"])
def test_transform_bound_is_achieved(name):
    # X = qmax * sign(outer(bt_u, bt_u)) drives frequency (u, u) to
    # exactly qmax * ||B^T_u||_1^2 — the certificate's transform_hi
    algo = registry.get_algorithm(name)
    cert = ranges.certificate(algo)
    bt = np.array([[float(v) for v in row] for row in algo.BT])
    u = int(np.argmax(np.abs(bt).sum(axis=1)))
    x = 127.0 * np.sign(np.outer(bt[u], bt[u]))
    x = x[None, :, :, None]                       # (1, L, L, 1)
    tx = np.einsum("ti,bijc,uj->btuc",
                   bt, x, bt)
    peak = float(np.abs(tx).max())
    assert peak == pytest.approx(cert.transform_hi, rel=1e-6)
    # and nothing exceeds the bound
    assert peak <= cert.transform_hi * (1 + 1e-9)


def test_transform_interval_contains_random_inputs():
    rng = np.random.default_rng(0)
    for name in ("sfc4_4", "sfc6_7", "wino2"):
        algo = registry.get_algorithm(name)
        hi = ranges.transform_interval_hi(algo, 127.0)
        x = rng.integers(-127, 128,
                         size=(2, algo.L, algo.L, 3)).astype(np.float32)
        tx, _ = c2d.transform_input_2d(jnp.asarray(x), algo,
                                       padding="VALID")
        assert float(jnp.max(jnp.abs(tx))) <= hi + 1e-4


# --------------------------------------------------------------------------
# prepare-time transform-matrix cache (the hoisted call-time cast)
# --------------------------------------------------------------------------
def test_transform_matrices_cached_and_frozen():
    algo = registry.get_algorithm("sfc4_4")
    a = c2d.transform_matrices(algo, "float32")
    b = c2d.transform_matrices(algo, "float32")
    assert all(x is y for x, y in zip(a, b))      # one entry per (algo, dtype)
    assert a[0].dtype == jnp.float32
    bt16 = c2d.transform_matrices(algo, "bfloat16")[0]
    assert bt16.dtype == jnp.bfloat16
    # the exact-matrix memo on the algorithm itself is immutable
    f64 = algo.bt()
    assert f64 is algo.bt()
    with pytest.raises(ValueError):
        f64[0, 0] = 99.0


def test_cached_matrices_bit_identical_to_call_time_cast():
    # the sfc_transform kernels used to cast bt at every call
    # (bt.astype(tiles.dtype)); the hoist must be bit-identical
    from repro.kernels.sfc_transform import sfc_transform
    algo = registry.get_algorithm("sfc6_6")
    rng = np.random.default_rng(1)
    tiles = jnp.asarray(rng.standard_normal((5, algo.L, algo.L, 3)),
                        dtype=jnp.float32)
    bt_cached = c2d.transform_matrices(algo, "float32")[0]
    bt_fresh = jnp.asarray(np.asarray(algo.bt()), jnp.float32)
    out_cached = sfc_transform(tiles, bt_cached)
    out_fresh = sfc_transform(tiles, bt_fresh)
    assert jnp.array_equal(out_cached, out_fresh)
    # and the fp reference path agrees with itself across dtypes handed in
    tx_a, _ = c2d.transform_input_2d(tiles, algo, padding="VALID")
    tx_b, _ = c2d.transform_input_2d(tiles, algo, padding="VALID")
    assert jnp.array_equal(tx_a, tx_b)


@pytest.mark.slow
def test_transform_range_fuzz_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=50, deadline=None)
    @hyp.given(st.data())
    def run(data):
        name = data.draw(st.sampled_from(sorted(GOLDEN)))
        algo = registry.get_algorithm(name)
        cert = ranges.certificate(algo)
        vals = data.draw(st.lists(
            st.integers(min_value=-127, max_value=127),
            min_size=algo.L * algo.L, max_size=algo.L * algo.L))
        x = np.array(vals, dtype=np.float64).reshape(algo.L, algo.L)
        bt = np.array([[float(v) for v in row] for row in algo.BT])
        tx = bt @ x @ bt.T
        assert np.abs(tx).max() <= cert.transform_hi + 1e-6

    run()
