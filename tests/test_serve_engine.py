"""Deterministic engine tests: the serving subsystem's acceptance surface.

  * continuous batching actually folds: >=2 concurrent requests ride ONE
    fused grid step (batch occupancy and imgs_per_step both > 1);
  * engine-batched answers are BIT-IDENTICAL to per-request dispatch
    (and the bucket specs run under repro.testing.assert_conv_conformance);
  * the request path never re-prepares: cache ``prepares`` stays at the
    bucket count under load;
  * admission control rejects (queue bound, no-bucket-fits) by resolving
    the future with RejectedError;
  * SLO accounting is exact under an injected clock;
  * round_batches pads dispatches up to warm shapes without changing
    real outputs; warm_compile leaves the metrics untouched.

All tests drive ``Engine.step()`` synchronously — no dispatch thread, no
timing dependence.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.serving_cache import ServingCache
from repro.quant import INT8_FREQ
from repro.serve import (AdmissionPolicy, BucketTable, Engine, INTERACTIVE,
                         BATCH, RejectedError, results)

CIN, COUT = 4, 8


def _weights(seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(3, 3, CIN, COUT) * 0.2, jnp.float32)


def _table(shapes=((8, 8), (12, 12)), quant=INT8_FREQ):
    return BucketTable.for_workload(shapes, kernel_size=3, in_channels=CIN,
                                    out_channels=COUT, quant=quant)


def _imgs(shapes, seed=1):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.randn(h, w, CIN), jnp.float32)
            for h, w in shapes]


@pytest.fixture(scope="module")
def shared_cache():
    """One prepared-weights cache for the module: every engine warms the
    same keyed ("serve", bucket) entries, so plan+transform+quantize cost
    is paid once (and bit-identity tests share the exact prep objects)."""
    return ServingCache()


# ----------------------------------------------------------------------
# the tentpole: continuous batching folds into the fused grid
# ----------------------------------------------------------------------
def test_batch_occupancy_folds_multiple_requests(shared_cache):
    """Acceptance: >=2 concurrent requests fold into ONE fused grid step
    — asserted deterministically by queueing 3 submits before a single
    step()."""
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache)
    futs = [eng.submit(x) for x in _imgs([(12, 12)] * 3)]
    served = eng.step()
    assert served == 3
    occ = eng.snapshot()["batch_occupancy"]
    assert occ["dispatches"] == 1
    assert occ["max"] == 3 and occ["max"] > 1
    assert occ["imgs_per_step_max"] == 3      # whole batch in one grid step
    for r in results(futs):
        assert r.batch_size == 3 and r.imgs_per_step == 3
        assert r.y.shape == (12, 12, COUT)


def test_batched_bit_identical_to_per_request(shared_cache):
    """Acceptance: the batched engine answer equals per-request dispatch
    bit-for-bit — ragged shapes, pad-to-bucket, fold and crop included."""
    shapes = [(11, 10), (8, 8), (12, 12), (7, 5)]
    xs = _imgs(shapes, seed=3)
    eng_b = Engine(_weights(), _table(), max_batch=4, cache=shared_cache)
    eng_s = Engine(_weights(), _table(), max_batch=1, cache=shared_cache)

    def serve_all(eng):
        futs = [eng.submit(x) for x in xs]
        while eng.step() > 0:
            pass
        return results(futs)

    rb, rs = serve_all(eng_b), serve_all(eng_s)
    for b, s, (h, w) in zip(rb, rs, shapes):
        assert b.y.shape == s.y.shape
        assert np.array_equal(np.asarray(b.y), np.asarray(s.y)), \
            f"batched != per-request for shape ({h}, {w})"
    # the batched engine really batched; the single one really did not
    assert eng_b.snapshot()["batch_occupancy"]["max"] > 1
    assert eng_s.snapshot()["batch_occupancy"]["max"] == 1


def test_bucket_specs_conform():
    """The specs the table plans are ordinary fused-kernel workloads:
    every fused grouping must stay bit-identical to staged on them."""
    from repro.testing import assert_conv_conformance
    b = _table().by_name("b8x8")
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(2, 8, 8, CIN), jnp.float32)
    assert_conv_conformance(x, _weights(), b.spec)


def test_heterogeneous_queue_batches_per_bucket(shared_cache):
    """Mixed-shape traffic never mixes buckets inside one dispatch."""
    eng = Engine(_weights(), _table(), max_batch=8, cache=shared_cache)
    xs = _imgs([(8, 8), (12, 12), (8, 8), (12, 12)], seed=7)
    futs = [eng.submit(x) for x in xs]
    assert eng.step() == 2                    # both b8x8 (FCFS head bucket)
    assert eng.step() == 2                    # then both b12x12
    rs = results(futs)
    assert [r.bucket_name for r in rs] == ["b8x8", "b12x12"] * 2
    assert all(r.batch_size == 2 for r in rs)


# ----------------------------------------------------------------------
# cache accounting: the request path never prepares
# ----------------------------------------------------------------------
def test_request_path_never_reprepares():
    cache = ServingCache()
    eng = Engine(_weights(), _table(), max_batch=4, cache=cache)
    warm = cache.stats()
    assert warm["prepares"] == len(eng.buckets.buckets)
    futs = [eng.submit(x) for x in _imgs([(8, 8), (12, 12)] * 4, seed=9)]
    while eng.step() > 0:
        pass
    results(futs)
    after = cache.stats()
    assert after["prepares"] == warm["prepares"]      # warm-only
    assert after["evictions"] == 0
    assert after["hits"] > warm["hits"]
    # 2 warm misses + 1 hit per dispatch: rate climbs toward 1 with load
    assert eng.snapshot()["serving_cache"]["hit_rate"] >= 0.5


# ----------------------------------------------------------------------
# admission control
# ----------------------------------------------------------------------
def test_admission_rejects_on_queue_bound(shared_cache):
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 admission=AdmissionPolicy(max_queue_depth=2))
    xs = _imgs([(8, 8)] * 3, seed=11)
    f1, f2, f3 = (eng.submit(x) for x in xs)
    with pytest.raises(RejectedError, match="queue depth"):
        f3.result(timeout=0)
    assert eng.step() == 2                    # the admitted two still serve
    assert f1.result(timeout=0).deadline_met
    c = eng.snapshot()["counters"]
    assert c["submitted"] == 3 and c["admitted"] == 2 and c["rejected"] == 1


def test_admission_rejects_shape_no_bucket_fits(shared_cache):
    eng = Engine(_weights(), _table(), cache=shared_cache)
    f = eng.submit(jnp.zeros((40, 40, CIN), jnp.float32))
    with pytest.raises(RejectedError, match="no bucket fits"):
        f.result(timeout=0)
    assert eng.queue.depth() == 0             # nothing queued


# ----------------------------------------------------------------------
# SLO accounting under an injected clock
# ----------------------------------------------------------------------
class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_slo_accounting_is_exact_under_injected_clock(shared_cache):
    clk = _FakeClock()
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 clock=clk)
    x = _imgs([(8, 8)], seed=13)[0]
    fi = eng.submit(x, INTERACTIVE)           # 2s deadline
    fb = eng.submit(x, BATCH)                 # 20s deadline
    clk.t = 10.0                              # 10s stuck in the queue
    assert eng.step() == 2
    ri, rb = fi.result(timeout=0), fb.result(timeout=0)
    assert ri.e2e_ms == pytest.approx(10_000.0)
    assert ri.queue_wait_ms == pytest.approx(10_000.0)
    assert not ri.deadline_met and rb.deadline_met
    snap = eng.snapshot()
    assert eng.metrics.slo_attainment("interactive") == 0.0
    assert eng.metrics.slo_attainment("batch") == 1.0
    assert snap["slo_attainment"] == 0.5
    assert snap["slo"]["interactive"]["missed"] == 1


# ----------------------------------------------------------------------
# batch-shape rounding + warm compile
# ----------------------------------------------------------------------
def test_round_batches_pads_without_changing_outputs(shared_cache):
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 round_batches=True)
    ref = Engine(_weights(), _table(), max_batch=1, cache=shared_cache)
    xs = _imgs([(12, 12)] * 3, seed=15)
    futs = [eng.submit(x) for x in xs]
    assert eng.step() == 3                    # dispatched as B=4 (1 zero img)
    snap = eng.snapshot()
    assert snap["counters"]["batch_pad_imgs"] == 1
    assert snap["batch_occupancy"]["max"] == 3    # real requests only
    for r, x in zip(results(futs), xs):
        f = ref.submit(x)
        ref.step()
        assert np.array_equal(np.asarray(r.y),
                              np.asarray(f.result(timeout=0).y))


def test_batch_sizes_powers_of_two():
    eng_cfg = Engine.__new__(Engine)          # _batch_sizes is pure config
    eng_cfg.round_batches, eng_cfg.max_batch = True, 6
    assert eng_cfg._batch_sizes() == [1, 2, 4, 6]
    assert eng_cfg._round_batch(3) == 4 and eng_cfg._round_batch(5) == 6
    eng_cfg.round_batches = False
    assert eng_cfg._batch_sizes() == [1, 2, 3, 4, 5, 6]
    assert eng_cfg._round_batch(3) == 3


def test_warm_compile_leaves_metrics_untouched():
    cache = ServingCache()
    eng = Engine(_weights(), _table(shapes=((8, 8),)), max_batch=2,
                 cache=cache, round_batches=True, warm_compile=True)
    snap = eng.snapshot()
    assert snap["counters"]["completed"] == 0
    assert snap["batch_occupancy"]["dispatches"] == 0
    assert cache.stats()["prepares"] == 1     # warm dispatches only hit


# ----------------------------------------------------------------------
# async surface
# ----------------------------------------------------------------------
def test_dispatch_thread_serves_and_drains(shared_cache):
    with Engine(_weights(), _table(), max_batch=4,
                cache=shared_cache) as eng:
        futs = [eng.submit(x) for x in _imgs([(8, 8), (12, 12)] * 3,
                                             seed=17)]
        assert eng.drain(timeout=60)
        rs = results(futs)
    assert len(rs) == 6 and all(r.y.ndim == 3 for r in rs)
    assert eng.snapshot()["counters"]["completed"] == 6
