"""Unified conv front-end: ConvSpec -> plan -> ConvPlan.apply.

Covers the acceptance surface of the API: reference-vs-pallas parity for
fp32 and int8, cost-model auto-selection, graceful direct degradation,
prepared-weight caching, the thread-safe registry, and the deprecation
shims over the legacy entry points.
"""
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ConvSpec, PreparedWeights, get_algorithm,
                       list_algorithms, list_backends, plan,
                       register_algorithm, select_algorithm)
from repro.core import conv2d as c2d
from repro.quant.fake_quant import INT8_FREQ
from repro.quant.ptq import PTQLayer


@pytest.fixture(autouse=True, scope="module")
def _registry_isolation():
    """Restore the process-wide registry after this module's mutations."""
    from repro.api import planner, registry as reg
    with reg._LOCK:
        entries, instances = dict(reg._ENTRIES), dict(reg._INSTANCES)
    yield
    with reg._LOCK:
        reg._ENTRIES.clear()
        reg._ENTRIES.update(entries)
        reg._INSTANCES.clear()
        reg._INSTANCES.update(instances)
    planner._plan_cached.cache_clear()


def _data(cout=8, cin=8, hw=12, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(2, hw, hw, cin), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, cin, cout) * 0.2, jnp.float32)
    return x, w


# ----------------------------------------------------------------------
# (a) reference vs pallas parity through ConvPlan.apply
# ----------------------------------------------------------------------
def test_parity_fp32_reference_vs_pallas():
    x, w = _data()
    spec = ConvSpec.for_conv2d(x.shape, w.shape)
    y_ref = plan(spec, backend="reference", algo="sfc6_6").apply(x, w)
    y_pal = plan(spec, backend="pallas", algo="sfc6_6").apply(x, w)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-5, atol=1e-5)
    # both must agree with the direct oracle
    y_direct = plan(spec, algo="direct").apply(x, w)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_direct),
                               rtol=1e-4, atol=1e-4)


def test_parity_int8_reference_vs_pallas():
    x, w = _data(seed=1)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)
    p_ref = plan(spec, backend="reference", algo="sfc6_6")
    p_pal = plan(spec, backend="pallas", algo="sfc6_6")
    algo = p_ref.algorithm
    tx, _ = c2d.transform_input_2d(x, algo)
    act_scale = jnp.abs(tx).max(axis=(0, 1, 2, 5)) / 127 + 1e-9
    y_ref = p_ref.apply(x, p_ref.prepare_weights(w, act_scale=act_scale))
    y_pal = p_pal.apply(x, p_pal.prepare_weights(w, act_scale=act_scale))
    # same integer grid on both backends; only accumulation order differs
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-4, atol=1e-4)
    # and the int8 path stays close to the fp oracle (paper's accuracy claim)
    y_fp = plan(ConvSpec.for_conv2d(x.shape, w.shape),
                algo="direct").apply(x, w)
    rel = float(jnp.linalg.norm(y_ref - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.05


def test_int8_via_ptq_calibration():
    """PTQLayer calibration -> static scales -> both backends agree."""
    x, w = _data(seed=2)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)
    p_ref = plan(spec, algo="sfc6_6")
    layer = PTQLayer(config=INT8_FREQ)
    p_ref.apply(x, w, elementwise_hook=layer.calibration_hook())
    p_pal = plan(spec, backend="pallas", algo="sfc6_6")
    y_ref = p_ref.apply(x, layer.prepare(p_ref, w))
    y_pal = p_pal.apply(x, layer.prepare(p_pal, w))
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-4, atol=1e-4)


def test_conv1d_depthwise_parity():
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(2, 37, 16), jnp.float32)
    w = jnp.asarray(rng.randn(4, 16) * 0.3, jnp.float32)
    spec = ConvSpec.for_conv1d_depthwise(x.shape, w.shape)
    p = plan(spec, algo="auto")
    assert p.algo_name == "sfc6_6_r4"
    y = p.apply(x, w)
    y_ref = c2d.conv1d_depthwise_causal_direct(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # pallas backend falls back to the same reference impl for rank 1
    y_pal = plan(spec, backend="pallas", algo="auto").apply(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_pal),
                               rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------------------
# (b) auto algorithm selection
# ----------------------------------------------------------------------
def test_auto_picks_sfc_for_3x3_stride1_int8():
    spec = ConvSpec(rank=2, kernel_size=3, stride=1, in_channels=64,
                    out_channels=64, spatial=(56, 56), quant=INT8_FREQ)
    p = plan(spec, algo="auto")
    assert p.algorithm is not None and p.algorithm.kind == "sfc"
    assert p.cost < plan(spec, algo="direct").cost


def test_auto_picks_fast_for_fp32():
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=64,
                    out_channels=64, spatial=(56, 56))
    assert plan(spec, algo="auto").path == "fast"


def test_auto_lowers_stride2_and_keeps_1x1_direct():
    # stride-2 now LOWERS onto polyphase SFC sub-convs (the cost model
    # confirms the composite beats strided direct at this shape); 1x1
    # stays direct — there is nothing to transform
    s2 = ConvSpec(rank=2, kernel_size=3, stride=2, in_channels=64,
                  out_channels=64, spatial=(56, 56), quant=INT8_FREQ)
    p1x1 = ConvSpec(rank=2, kernel_size=1, in_channels=64,
                    out_channels=64, spatial=(56, 56), quant=INT8_FREQ)
    p = plan(s2, algo="auto")
    assert p.path == "lowered" and p.algorithm is None
    assert p.cost < plan(s2, algo="direct").cost
    assert plan(p1x1, algo="auto").path == "direct"
    # native (non-lowered) selection still degrades strided specs
    assert select_algorithm(s2) == "direct"


def test_explicit_algo_lowers_or_degrades_gracefully():
    # stride-2 with an explicit fast algorithm lowers (the honest reading
    # of "run this on the fast path"); tap mismatch still resolves to
    # direct, as each call site used to hand-roll
    s2 = ConvSpec(rank=2, kernel_size=3, stride=2)
    assert plan(s2, algo="sfc6_6").path == "lowered"
    r7 = ConvSpec(rank=2, kernel_size=7)
    assert plan(r7, algo="sfc6_6").path == "direct"
    with pytest.raises(KeyError):
        plan(ConvSpec(rank=2, kernel_size=3), algo="nope")
    # a typo'd name must raise even when the spec would degrade to direct
    with pytest.raises(KeyError):
        plan(ConvSpec(rank=2, kernel_size=3, stride=2), algo="nope")


def test_direct_path_executes_stride2_and_1x1():
    x, _ = _data()
    rng = np.random.RandomState(4)
    w2 = jnp.asarray(rng.randn(3, 3, 8, 8) * 0.2, jnp.float32)
    w1 = jnp.asarray(rng.randn(1, 1, 8, 8) * 0.2, jnp.float32)
    y2 = plan(ConvSpec.for_conv2d(x.shape, w2.shape, stride=2)).apply(x, w2)
    y1 = plan(ConvSpec.for_conv2d(x.shape, w1.shape)).apply(x, w1)
    assert y2.shape == (2, 6, 6, 8)
    assert y1.shape == (2, 12, 12, 8)


# ----------------------------------------------------------------------
# (c) prepared-weight caching
# ----------------------------------------------------------------------
def test_prepared_weights_cached_and_identical():
    x, w = _data(seed=5)
    spec = ConvSpec.for_conv2d(x.shape, w.shape)
    p = plan(spec, algo="sfc6_7")
    prep1 = p.prepare_weights(w)
    prep2 = p.prepare_weights(w)
    assert prep1 is prep2                      # memoized per weight array
    assert isinstance(prep1, PreparedWeights)
    y_cached = p.apply(x, prep1)
    y_uncached = p.apply(x, w)
    assert bool(jnp.all(y_cached == y_uncached))


def test_prepare_inside_jit_does_not_cache_tracers():
    x, w = _data(seed=6)
    spec = ConvSpec.for_conv2d(x.shape, w.shape)
    p = plan(spec, algo="sfc6_6")
    before = len(p._prep)
    y = jax.jit(lambda x, w: p.apply(x, w))(x, w)
    assert len(p._prep) == before              # tracers never cached
    np.testing.assert_allclose(np.asarray(y), np.asarray(p.apply(x, w)),
                               rtol=1e-5, atol=1e-5)


def test_plan_memoized_on_spec():
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=8, out_channels=8,
                    spatial=(12, 12))
    assert plan(spec, algo="sfc6_6") is plan(spec, algo="sfc6_6")


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
def test_registry_lists_defaults_and_registers_new():
    names = list_algorithms()
    for expected in ("sfc6_7", "sfc6_6", "sfc4_4", "wino4", "direct"):
        assert expected in names
    assert "sfc6_6" in list_algorithms(taps=3)
    assert "sfc6_6_r4" not in list_algorithms(taps=3)
    from repro.core.generator import generate_sfc
    register_algorithm("sfc4_5_test", lambda: generate_sfc(4, 5, 3),
                       taps=3, kind="sfc", overwrite=True)
    assert "sfc4_5_test" in list_algorithms(taps=3)
    assert get_algorithm("sfc4_5_test").M == 5
    with pytest.raises(ValueError):
        register_algorithm("sfc4_5_test", lambda: generate_sfc(4, 5, 3),
                           taps=3, kind="sfc")


def test_register_algorithm_invalidates_auto_plans():
    """Newly registered algorithms become visible to memoized auto plans."""
    from repro.core.generator import generate_sfc
    spec = ConvSpec(rank=2, kernel_size=5, in_channels=8, out_channels=8,
                    spatial=(20, 20))
    assert plan(spec, algo="auto").path == "direct"   # no 5-tap algo yet
    register_algorithm("sfc6_4_r5_test", lambda: generate_sfc(6, 4, 5),
                       taps=5, kind="sfc", overwrite=True)
    assert plan(spec, algo="auto").algo_name == "sfc6_4_r5_test"


def test_registry_threadsafe_memoization():
    results = []

    def worker():
        results.append(get_algorithm("sfc6_7"))

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(a is results[0] for a in results)   # one shared instance


def test_backends_listed():
    assert "reference" in list_backends()
    assert "pallas" in list_backends()
    assert "pallas_spmd" in list_backends()


def test_register_backend_roundtrip_and_plan_invalidation():
    """The extension seam: a custom backend object registers by name, is
    resolved by ``plan(..., backend='myback')``, receives the apply
    dispatch, and (re-)registration invalidates memoized plans."""
    from repro.api import backends as be
    from repro.api import register_backend

    class RecordingBackend:
        name = "myback"

        def __init__(self):
            self.calls = 0

        def apply(self, plan_, x, prep, *, bias=None, elementwise_hook=None):
            self.calls += 1
            return be.get_backend("reference").apply(
                plan_, x, prep, bias=bias, elementwise_hook=elementwise_hook)

    x, w = _data(seed=11)
    spec = ConvSpec.for_conv2d(x.shape, w.shape)
    with pytest.raises(KeyError):
        plan(spec, backend="myback", algo="sfc6_6")
    mine = RecordingBackend()
    register_backend("myback", mine)
    try:
        p1 = plan(spec, backend="myback", algo="sfc6_6")
        y = p1.apply(x, w)
        assert mine.calls == 1                    # dispatched to our object
        y_ref = plan(spec, backend="reference", algo="sfc6_6").apply(x, w)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-6, atol=1e-6)
        with pytest.raises(ValueError):           # no silent overwrite
            register_backend("myback", RecordingBackend())
        # overwrite drops memoized plans: the stale plan object must not
        # keep serving a name that now resolves to a different backend
        register_backend("myback", RecordingBackend(), overwrite=True)
        p2 = plan(spec, backend="myback", algo="sfc6_6")
        assert p2 is not p1
    finally:
        del be._BACKENDS["myback"]
        from repro.api import planner
        planner.invalidate_plan_cache()


# ----------------------------------------------------------------------
# (d) deprecation shims
# ----------------------------------------------------------------------
def test_deprecation_shims_resolve_and_match():
    import repro.core as core
    import repro.kernels as kernels
    x, w = _data(seed=7)
    algo = get_algorithm("sfc6_6")
    spec = ConvSpec.for_conv2d(x.shape, w.shape)
    y_api = plan(spec, algo="sfc6_6").apply(x, w)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        y_legacy = core.fastconv2d(x, w, algo)
        y_kernel = kernels.fastconv2d_fp(x, w, algo)
    assert any(issubclass(r.category, DeprecationWarning) for r in rec)
    np.testing.assert_allclose(np.asarray(y_api), np.asarray(y_legacy),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_api), np.asarray(y_kernel),
                               rtol=1e-5, atol=1e-5)
    # models shim: conv_algo resolves through the registry
    from repro.models.cnn import conv_algo
    assert conv_algo("sfc6_6") is algo
    assert conv_algo("direct") is None


# ----------------------------------------------------------------------
# misc API contracts
# ----------------------------------------------------------------------
def test_spec_validation():
    with pytest.raises(ValueError):
        ConvSpec(rank=3)
    with pytest.raises(ValueError):
        ConvSpec(rank=1, depthwise=False)
    with pytest.raises(ValueError):
        ConvSpec(rank=2, padding="CAUSAL")
    ConvSpec(rank=2, depthwise=True)      # 2-D depthwise is supported now
    with pytest.raises(ValueError):   # stride-1 only: no strided 1-D path
        ConvSpec(rank=1, kernel_size=4, stride=2, depthwise=True,
                 padding="CAUSAL")
    with pytest.raises(ValueError):   # channels must divide into groups
        ConvSpec(rank=2, groups=3, in_channels=8, out_channels=8)
    with pytest.raises(ValueError):   # depthwise already means groups == C
        ConvSpec(rank=2, depthwise=True, groups=2)
    with pytest.raises(ValueError):   # grouped conv is rank-2 only
        ConvSpec(rank=1, kernel_size=4, depthwise=True, padding="CAUSAL",
                 groups=2)
    with pytest.raises(ValueError):   # depthwise: out == in channels
        ConvSpec(rank=2, depthwise=True, in_channels=8, out_channels=16)


def test_hook_rejected_on_rank1_fast_path():
    rng = np.random.RandomState(9)
    x = jnp.asarray(rng.randn(2, 20, 8), jnp.float32)
    w = jnp.asarray(rng.randn(4, 8), jnp.float32)
    p = plan(ConvSpec.for_conv1d_depthwise(x.shape, w.shape), algo="auto")
    assert p.path == "fast"
    with pytest.raises(NotImplementedError):
        p.apply(x, w, elementwise_hook=lambda tx, tw: (tx, tw))


def test_hook_rejected_on_static_int8_and_pallas():
    x, w = _data(seed=8)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)
    p = plan(spec, algo="sfc6_6")
    algo = p.algorithm
    tx, _ = c2d.transform_input_2d(x, algo)
    act_scale = jnp.abs(tx).max(axis=(0, 1, 2, 5)) / 127 + 1e-9
    prep = p.prepare_weights(w, act_scale=act_scale)
    with pytest.raises(ValueError):
        p.apply(x, prep, elementwise_hook=lambda tx, tw: (tx, tw))
    p_pal = plan(spec, backend="pallas", algo="sfc6_6")
    with pytest.raises(ValueError):
        p_pal.apply(x, w, elementwise_hook=lambda tx, tw: (tx, tw))
