"""Pallas kernels (interpret mode) vs ref.py oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import generate_sfc, conv2d_direct
from repro.core import conv2d as c2d
from repro.kernels import (fastconv2d_fp, quantize_weights,
                           quantized_fastconv2d, ref, sfc_inverse,
                           sfc_transform, sfc_transform_quantize, tdmm_int8)

ALGO_SET = [(4, 4, 3), (6, 6, 3), (6, 7, 3)]


@pytest.mark.parametrize("nmr", ALGO_SET)
@pytest.mark.parametrize("n_tiles,channels", [(1, 1), (5, 19), (16, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_transform_kernel_sweep(nmr, n_tiles, channels, dtype):
    algo = generate_sfc(*nmr)
    rng = np.random.RandomState(0)
    tiles = jnp.asarray(rng.randn(n_tiles, algo.L, algo.L, channels), dtype)
    bt = jnp.asarray(algo.bt(), dtype)
    out = sfc_transform(tiles, bt)
    want = ref.sfc_transform_ref(tiles, bt)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-2)


@pytest.mark.parametrize("nmr", ALGO_SET)
def test_transform_quantize_kernel_bitexact(nmr):
    algo = generate_sfc(*nmr)
    rng = np.random.RandomState(1)
    tiles = jnp.asarray(rng.randn(7, algo.L, algo.L, 33), jnp.float32)
    bt = jnp.asarray(algo.bt(), jnp.float32)
    scale = jnp.abs(ref.sfc_transform_ref(tiles, bt)).max(
        axis=(0, 3)) / 127 + 1e-9
    out = sfc_transform_quantize(tiles, bt, scale)
    want = ref.sfc_transform_quantize_ref(tiles, bt, scale)
    assert out.dtype == jnp.int8
    assert bool(jnp.all(out == want))


@pytest.mark.parametrize("P,T,K,N", [(4, 8, 16, 8), (7, 33, 19, 21),
                                     (9, 130, 64, 130), (1, 1, 1, 1)])
def test_tdmm_kernel_sweep(P, T, K, N):
    rng = np.random.RandomState(2)
    xq = jnp.asarray(rng.randint(-127, 128, (P, T, K)), jnp.int8)
    wq = jnp.asarray(rng.randint(-127, 128, (P, K, N)), jnp.int8)
    sx = jnp.asarray(rng.rand(P), jnp.float32)
    sw = jnp.asarray(rng.rand(P, N), jnp.float32)
    out = tdmm_int8(xq, wq, sx, sw)
    want = ref.tdmm_int8_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("nmr", ALGO_SET)
def test_inverse_kernel(nmr):
    algo = generate_sfc(*nmr)
    rng = np.random.RandomState(3)
    ty = jnp.asarray(rng.randn(5, algo.t, algo.t, 21), jnp.float32)
    at = jnp.asarray(algo.at(), jnp.float32)
    out = sfc_inverse(ty, at)
    want = ref.sfc_inverse_ref(ty, at)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_end_to_end_quantized_conv_kernel():
    """Full Pallas pipeline == ref oracle (bit-exact) and ~int8-close to fp."""
    algo = generate_sfc(6, 6, 3)
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(2, 13, 13, 16), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 16, 8) * 0.2, jnp.float32)
    tx, _ = c2d.transform_input_2d(x, algo)
    act_scale = jnp.abs(tx).max(axis=(0, 1, 2, 5)) / 127
    tw = c2d.transform_weights_2d(w, algo)
    w_scale = jnp.abs(tw).max(axis=2) / 127
    wq = quantize_weights(w, algo, w_scale)
    y = quantized_fastconv2d(x, wq, act_scale, w_scale, algo)
    yref = ref.quantized_fastconv2d_ref(x, w, algo, act_scale, w_scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-6, atol=1e-6)
    yfp = conv2d_direct(x, w)
    rel = float(jnp.linalg.norm(y - yfp) / jnp.linalg.norm(yfp))
    assert rel < 0.03


def test_fp_kernel_path():
    algo = generate_sfc(6, 7, 3)
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(1, 14, 14, 8), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 8, 4), jnp.float32)
    y = fastconv2d_fp(x, w, algo)
    yfp = conv2d_direct(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yfp),
                               rtol=1e-4, atol=1e-4)
