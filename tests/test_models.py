"""Per-arch smoke tests (reduced configs): shapes, finiteness, decode parity,
gradients, SFC-conv1d integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build
from repro.models import moe as moe_mod


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.RandomState(seed)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.randn(B, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.randn(B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one grad step on CPU: output shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss), arch
    memory = batch.get("vision", batch.get("frames"))
    logits = model.forward(params, batch["tokens"], memory)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode == full forward (lossless MoE capacity)."""
    cfg = get_smoke_config(arch)
    cfg = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32"})
    # lossless MoE so prefill and decode see identical dispatch
    orig = moe_mod.moe_block
    moe_mod.moe_block = lambda p, c, x, capacity_factor=None: orig(
        p, c, x, capacity_factor=c.n_experts / max(c.n_experts_active, 1))
    import repro.models.transformer as tfm
    tfm.moe.moe_block = moe_mod.moe_block
    try:
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S = 2, 12
        batch = _batch(cfg, B, S)
        memory = batch.get("vision", batch.get("frames"))
        full = model.forward(params, batch["tokens"], memory)
        cache = model.init_cache(params, B, S, memory)
        outs = []
        for t in range(S):
            lg, cache = model.decode_step(
                params, cache, batch["tokens"][:, t:t + 1],
                jnp.full((B,), t, jnp.int32))
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, axis=1)
        err = float(jnp.abs(full - dec).max())
        assert err < 1e-3, (arch, err)
    finally:
        moe_mod.moe_block = orig
        tfm.moe.moe_block = orig


def test_full_config_values():
    """The full (assigned) configs carry the exact published dimensions."""
    c = get_config("qwen2.5-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (64, 5120, 40, 8, 27648, 152064)
    c = get_config("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_experts,
            c.n_experts_active) == (61, 7168, 128, 256, 8)
    assert c.use_mla and c.mtp_depth == 1
    c = get_config("mamba2-1.3b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (48, 2048, 128)
    assert c.padded_vocab % 16 == 0
    c = get_config("mixtral-8x7b")
    assert c.sliding_window == 4096 and c.n_experts == 8
    # ~param-count sanity (within 15% of the nominal sizes)
    assert abs(get_config("deepseek-v3-671b").param_count() - 671e9) \
        < 0.15 * 671e9
    assert abs(get_config("mixtral-8x7b").param_count() - 46.7e9) \
        < 0.15 * 46.7e9


def test_mamba_sfc_conv_equals_direct_path():
    """cfg.use_sfc_conv flips the conv1d to the paper's fast path — same math."""
    cfg = get_smoke_config("mamba2-1.3b")
    cfg32 = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32"})
    cfg_direct = cfg32.__class__(**{**cfg32.__dict__, "use_sfc_conv": False})
    m1, m2 = build(cfg32), build(cfg_direct)
    params = m1.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.RandomState(0).randint(0, 64, (2, 32)),
                       jnp.int32)
    y1 = m1.forward(params, toks)
    y2 = m2.forward(params, toks)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_sliding_window_restricts_context():
    """Single layer: the receptive field is exactly the window (deeper
    stacks legitimately widen it through the residual stream; MoE archs
    additionally couple tokens through capacity-limited dispatch, so a
    dense arch isolates the attention mask)."""
    cfg = get_smoke_config("qwen3-14b")
    cfg = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32",
                           "sliding_window": 4, "n_layers": 1})
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    t1 = jnp.asarray(rng.randint(0, 64, (1, 12)), jnp.int32)
    t2 = t1.at[0, 0].set((int(t1[0, 0]) + 1) % 64)   # differ far in the past
    l1 = model.forward(params, t1)
    l2 = model.forward(params, t2)
    # final position attends only to the last 4 tokens -> logits identical
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-4, atol=1e-4)
    # ...while a within-window change does alter them
    t3 = t1.at[0, 11].set((int(t1[0, 11]) + 1) % 64)
    l3 = model.forward(params, t3)
    assert float(jnp.abs(l1[0, -1] - l3[0, -1]).max()) > 1e-4
