"""Optimizers + gradient compression invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")    # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.optim import (AdamW, compress_with_feedback, cosine_schedule,
                         global_norm, init_residuals)


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=100.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(200):
        g = {"x": 2 * (params["x"] - target)}
        params, state, _ = opt.apply(params, g, state)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_bf16_params_f32_moments():
    opt = AdamW(lr=0.01)
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.float32
    params, state, _ = opt.apply(params, {"w": jnp.ones((4,), jnp.bfloat16)},
                                 state)
    assert params["w"].dtype == jnp.bfloat16


def test_clip_norm():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros((3,))}
    state = opt.init(params)
    _, _, metrics = opt.apply(params, {"x": jnp.full((3,), 100.0)}, state)
    assert float(metrics["grad_norm"]) > 100


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-3)


def test_error_feedback_unbiased_accumulation():
    """Sum of compressed grads + final residual == sum of true grads."""
    rng = np.random.RandomState(0)
    params = {"w": jnp.zeros((32,))}
    res = init_residuals(params)
    total_true = np.zeros(32)
    total_sent = np.zeros(32)
    key = jax.random.PRNGKey(0)
    for i in range(20):
        g = {"w": jnp.asarray(rng.randn(32), jnp.float32)}
        key, sub = jax.random.split(key)
        cg, res = compress_with_feedback(g, res, sub)
        total_true += np.asarray(g["w"])
        total_sent += np.asarray(cg["w"])
    gap = np.abs(total_true - (total_sent + np.asarray(res["w"])))
    assert gap.max() < 1e-4


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_compression_bounded_error(seed):
    rng = np.random.RandomState(seed)
    g = {"w": jnp.asarray(rng.randn(64), jnp.float32)}
    res = init_residuals(g)
    cg, new_res = compress_with_feedback(g, res, jax.random.PRNGKey(seed))
    scale = float(jnp.abs(g["w"]).max()) / 127
    assert float(jnp.abs(new_res["w"]).max()) <= scale + 1e-6


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
