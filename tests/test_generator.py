"""SFC/Winograd generator: exactness, paper multiplication counts, structure."""
from fractions import Fraction

import numpy as np
import pytest

pytest.importorskip("hypothesis")    # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.generator import (direct_algorithm, generate_sfc,
                                  generate_winograd, paper_algorithms)

ALGOS = paper_algorithms()


@pytest.mark.parametrize("name", list(ALGOS))
def test_exact_rational(name):
    """A^T((Gw) . (B^T x)) == correlation, exactly (zero rational error)."""
    algo = ALGOS[name]
    rng = np.random.RandomState(42)
    for _ in range(5):
        x = [Fraction(int(v), int(d)) for v, d in zip(
            rng.randint(-99, 100, algo.L), rng.randint(1, 9, algo.L))]
        w = [Fraction(int(v)) for v in rng.randint(-99, 100, algo.R)]
        got = algo.conv1d_exact(x, w)
        want = [sum(x[m + r] * w[r] for r in range(algo.R))
                for m in range(algo.M)]
        assert got == want


def test_paper_multiplication_counts():
    """Table 1 / appendix counts: 49, 100, 144, 196 (separable form)."""
    assert generate_sfc(4, 4, 3).mults_2d == 49
    assert generate_sfc(6, 6, 3).mults_2d == 100
    assert generate_sfc(6, 7, 3).mults_2d == 144
    assert generate_sfc(6, 6, 5).mults_2d == 196


def test_paper_hermitian_complexity():
    """Paper's arithmetic-complexity column (full-Hermitian counts)."""
    from repro.core.error_analysis import table1
    t = table1(trials=8)
    assert abs(t["SFC-4(4x4,3x3)"]["complexity_pct_hermitian"] - 31.94) < 0.01
    assert abs(t["SFC-6(6x6,3x3)"]["complexity_pct_hermitian"] - 27.16) < 0.01
    assert abs(t["SFC-6(7x7,3x3)"]["complexity_pct_hermitian"] - 29.93) < 0.01
    assert abs(t["SFC-6(6x6,5x5)"]["complexity_pct_hermitian"] - 20.44) < 0.01


def test_sfc_transforms_are_integer():
    """The additions-only claim: B^T and G contain only integers."""
    for name, algo in ALGOS.items():
        if algo.kind == "sfc":
            assert algo.is_integer_transform(), name
            for row in algo.BT:
                assert all(abs(v) <= 2 for v in row), name


def test_winograd_vs_sfc_conditioning():
    """SFC condition numbers stay O(1) while Winograd's grow with N."""
    sfc_k = [ALGOS[n].condition_number_at() for n in ALGOS
             if ALGOS[n].kind == "sfc"]
    wino_big = ALGOS["Wino(4x4,3x3)"].condition_number_at()
    assert max(sfc_k) < 4.0
    assert wino_big > 2 * max(sfc_k)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([(4, 1, 3), (4, 2, 3), (4, 4, 3), (6, 2, 3),
                        (6, 6, 3), (6, 7, 3), (6, 3, 4), (6, 6, 4),
                        (6, 4, 5), (6, 6, 5), (6, 4, 7), (3, 2, 2),
                        (6, 8, 3), (6, 5, 4), (4, 5, 3)]),
       st.integers(0, 2 ** 31 - 1))
def test_sfc_property_random_nm_r(nmr, seed):
    """Property: every generatable SFC-N(M,R) is exact on random ints."""
    N, M, R = nmr
    algo = generate_sfc(N, M, R)
    rng = np.random.RandomState(seed)
    x = [Fraction(int(v)) for v in rng.randint(-50, 51, algo.L)]
    w = [Fraction(int(v)) for v in rng.randint(-50, 51, algo.R)]
    got = algo.conv1d_exact(x, w)
    want = [sum(x[m + r] * w[r] for r in range(R)) for m in range(M)]
    assert got == want


def test_unsupported_dft_points_raise():
    with pytest.raises(ValueError):
        generate_sfc(8, 4, 3)


def test_direct_algorithm_is_identity():
    d = direct_algorithm(3)
    assert d.mults_2d == 9
    assert d.condition_number_at() == pytest.approx(1.0, abs=1e-9)
