"""Fault-tolerant trainer: convergence, fault injection + auto-resume,
straggler accounting, microbatch accumulation, grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import SyntheticTokenPipeline, TokenPipelineConfig
from repro.models import build
from repro.optim.optimizers import AdamW
from repro.train import Trainer, TrainerConfig, TransientError


def _make(tmp_path, arch="stablelm-3b", **kw):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    pipe = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))

    def batches(i):
        b = pipe.batch(i)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    tc = TrainerConfig(checkpoint_dir=str(tmp_path), **kw)
    return model, batches, tc


def test_loss_decreases(tmp_path):
    model, batches, tc = _make(tmp_path, total_steps=30,
                               checkpoint_every=10, log_every=1000)
    trainer = Trainer(model, AdamW(lr=1e-2), tc)
    rep = trainer.run(batches, jax.random.PRNGKey(0))
    assert rep.steps_run == 30
    assert np.mean(rep.losses[-5:]) < np.mean(rep.losses[:5]) - 0.1


def test_fault_injection_and_resume(tmp_path):
    """A transient failure mid-run rolls back to the last checkpoint and
    completes; the loss stream stays consistent."""
    fail_at = {17}

    def fault_hook(step):
        if step in fail_at:
            fail_at.discard(step)     # fail exactly once
            raise TransientError("injected node failure")

    model, batches, tc = _make(tmp_path, total_steps=25, checkpoint_every=5,
                               log_every=1000)
    trainer = Trainer(model, AdamW(lr=1e-2), tc, fault_hook=fault_hook)
    rep = trainer.run(batches, jax.random.PRNGKey(0))
    assert rep.restarts == 1
    assert rep.steps_run >= 25 - 15   # resumed from step 15 checkpoint
    assert trainer.ckpt.latest_step() == 25


def test_repeated_failure_aborts(tmp_path):
    def always_fail(step):
        raise TransientError("dead node")
    model, batches, tc = _make(tmp_path, total_steps=10, max_retries=2,
                               log_every=1000)
    trainer = Trainer(model, AdamW(lr=1e-2), tc, fault_hook=always_fail)
    with pytest.raises(RuntimeError, match="giving up"):
        trainer.run(batches, jax.random.PRNGKey(0))


def test_restart_process_resumes_from_checkpoint(tmp_path):
    """Simulated preemption: a fresh Trainer on the same dir continues
    from the saved step instead of restarting from scratch."""
    model, batches, tc = _make(tmp_path, total_steps=10, checkpoint_every=5,
                               log_every=1000)
    Trainer(model, AdamW(lr=1e-2), tc).run(batches, jax.random.PRNGKey(0))
    tc2 = TrainerConfig(checkpoint_dir=str(tmp_path), total_steps=20,
                        checkpoint_every=5, log_every=1000)
    t2 = Trainer(model, AdamW(lr=1e-2), tc2)
    state, step = t2.init_or_restore(jax.random.PRNGKey(0))
    assert step == 10
    rep = t2.run(batches, jax.random.PRNGKey(0))
    assert rep.steps_run == 10        # only the remaining steps


def test_microbatch_accumulation_matches_full_batch(tmp_path):
    cfg = get_smoke_config("stablelm-3b")
    cfg = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32"})
    model = build(cfg)
    from repro.train.steps import init_train_state, make_train_step
    opt = AdamW(lr=1e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, 64, (4, 32)), jnp.int32),
             "labels": jnp.asarray(rng.randint(0, 64, (4, 32)), jnp.int32)}
    s1, m1 = make_train_step(model, opt)(state, batch)
    s2, m2 = make_train_step(model, opt, microbatches=2)(state, batch)
    w1 = jax.tree_util.tree_leaves(s1.params)[0]
    w2 = jax.tree_util.tree_leaves(s2.params)[0]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2),
                               rtol=2e-3, atol=2e-5)


def test_grad_compression_still_converges(tmp_path):
    model, batches, tc = _make(tmp_path, total_steps=30, checkpoint_every=50,
                               log_every=1000, grad_compression=True)
    trainer = Trainer(model, AdamW(lr=1e-2), tc)
    rep = trainer.run(batches, jax.random.PRNGKey(0))
    assert np.mean(rep.losses[-5:]) < np.mean(rep.losses[:5]) - 0.05
