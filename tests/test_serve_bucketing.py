"""Bucket table semantics + the output-exactness claim behind serving:
zero-padding a request to its bucket and cropping the output recovers
the unbucketed conv answer (stride-1 SAME and VALID)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConvSpec, plan
from repro.quant.fake_quant import FP32
from repro.serve import Bucket, BucketTable


def _table(**kw):
    return BucketTable.for_workload([(8, 8), (16, 12), (4, 4)],
                                    kernel_size=3, in_channels=4,
                                    out_channels=8, **kw)


# ----------------------------------------------------------------------
# table semantics
# ----------------------------------------------------------------------
def test_sorted_smallest_first_and_first_fit():
    t = _table()
    assert [b.name for b in t.buckets] == ["b4x4", "b8x8", "b16x12"]
    assert t.bucket_for(3, 3).name == "b4x4"
    assert t.bucket_for(5, 4).name == "b8x8"      # smallest that fits
    assert t.bucket_for(9, 12).name == "b16x12"
    assert t.bucket_for(17, 1) is None            # h exceeds every bucket
    assert t.bucket_for(1, 13) is None


def test_duplicate_shapes_dedup_and_names():
    t = BucketTable.for_workload([(8, 8), (8, 8)], kernel_size=3,
                                 in_channels=4, out_channels=8)
    assert len(t.buckets) == 1
    assert t.by_name("b8x8").spec.spatial == (8, 8)
    with pytest.raises(KeyError):
        t.by_name("b9x9")


def test_request_larger_than_every_bucket_is_rejected():
    """A shape exceeding every bucket (either dimension) maps to None —
    the admission path turns that into a RejectedError rather than
    truncating, and pad_to refuses it outright as the backstop."""
    t = _table()
    assert t.bucket_for(17, 13) is None            # both dims exceed
    assert t.bucket_for(17, 12) is None            # h alone exceeds
    assert t.bucket_for(16, 13) is None            # w alone exceeds
    assert t.bucket_for(16, 12) is not None        # exact largest fits
    with pytest.raises(ValueError, match="exceeds bucket"):
        BucketTable.pad_to(jnp.ones((17, 13, 4)), t.buckets[-1])


def test_empty_table_rejected():
    with pytest.raises(ValueError):
        BucketTable([])


def test_duplicate_names_rejected():
    spec = ConvSpec(rank=2, kernel_size=3, stride=1, padding="SAME",
                    in_channels=4, out_channels=8, spatial=(8, 8))
    with pytest.raises(ValueError, match="duplicate"):
        BucketTable([Bucket("b", 8, 8, spec), Bucket("b", 8, 8, spec)])


def test_waste_fraction():
    b = _table().by_name("b8x8")
    assert b.waste(8, 8) == 0.0
    assert b.waste(4, 4) == pytest.approx(1 - 16 / 64)


# ----------------------------------------------------------------------
# pad / crop
# ----------------------------------------------------------------------
def test_pad_to_shapes_and_bounds():
    b = _table().by_name("b8x8")
    x = jnp.ones((5, 6, 4))
    xp = BucketTable.pad_to(x, b)
    assert xp.shape == (8, 8, 4)
    assert float(jnp.sum(xp)) == float(jnp.sum(x))      # zero fill
    exact = jnp.ones((8, 8, 4))
    assert BucketTable.pad_to(exact, b) is exact        # no-op passthrough
    with pytest.raises(ValueError, match="exceeds bucket"):
        BucketTable.pad_to(jnp.ones((9, 3, 4)), b)


def _y(x, spec):
    p = plan(spec, backend="reference", algo="direct")
    return p.apply(x[None], p.prepare_weights(_W))[0]


_RNG = np.random.RandomState(0)
_W = jnp.asarray(_RNG.randn(3, 3, 4, 8) * 0.3, jnp.float32)


@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_pad_then_crop_is_output_exact(padding):
    """The serving invariant: bucket-padded conv + crop == unbucketed
    conv, because the pad region is exactly the zero border the conv
    itself would synthesize (SAME) or never touches (VALID)."""
    h, w = 5, 7
    x = jnp.asarray(_RNG.randn(h, w, 4), jnp.float32)
    t = BucketTable.for_workload([(8, 8)], kernel_size=3, in_channels=4,
                                 out_channels=8, padding=padding,
                                 quant=FP32)
    b = t.buckets[0]
    y_bucket = _y(BucketTable.pad_to(x, b), b.spec)
    y_crop = BucketTable.crop_output(y_bucket, h, w, b)
    small = ConvSpec(rank=2, kernel_size=3, stride=1, padding=padding,
                     in_channels=4, out_channels=8, spatial=(h, w))
    y_direct = _y(x, small)
    assert y_crop.shape == y_direct.shape
    np.testing.assert_allclose(np.asarray(y_crop), np.asarray(y_direct),
                               rtol=1e-5, atol=1e-5)


def test_crop_output_stride_aware():
    spec2 = ConvSpec(rank=2, kernel_size=3, stride=2, padding="SAME",
                     in_channels=4, out_channels=8, spatial=(8, 8))
    b = Bucket("b8x8s2", 8, 8, spec2)
    y = jnp.zeros((4, 4, 8))                   # bucket output at stride 2
    assert BucketTable.crop_output(y, 5, 7, b).shape == (3, 4, 8)
    specv = ConvSpec(rank=2, kernel_size=3, stride=1, padding="VALID",
                     in_channels=4, out_channels=8, spatial=(8, 8))
    bv = Bucket("b8x8v", 8, 8, specv)
    yv = jnp.zeros((6, 6, 8))
    assert BucketTable.crop_output(yv, 5, 7, bv).shape == (3, 5, 8)
