"""End-to-end behaviour of the full system (paper pipeline on a real model).

Covers the deployment story: train a small CNN -> PTQ-calibrate SFC int8
convs -> accuracy parity; and the LM side: train, checkpoint, serve with
the production decode path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.resnet18 import SMOKE_CNN
from repro.data import (ImagePipelineConfig, SyntheticImagePipeline,
                        SyntheticTokenPipeline, TokenPipelineConfig)
from repro.models import build
from repro.models.cnn import cnn_loss, init_resnet, resnet_forward
from repro.optim.optimizers import AdamW
from repro.train import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def trained_cnn():
    """Train the smoke CNN on structured synthetic images until it beats
    chance comfortably."""
    cfg = SMOKE_CNN
    pipe = SyntheticImagePipeline(ImagePipelineConfig(
        image_size=cfg.image_size, n_classes=cfg.n_classes, global_batch=32,
        seed=3))
    params = init_resnet(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=3e-3, weight_decay=1e-4)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, metrics), g = jax.value_and_grad(
            lambda p: cnn_loss(p, cfg, batch), has_aux=True)(params)
        params, state, _ = opt.apply(params, g, state)
        return params, state, metrics

    for i in range(160):
        b = pipe.batch(i)
        batch = {"images": jnp.asarray(b["images"]),
                 "labels": jnp.asarray(b["labels"])}
        params, state, metrics = step(params, state, batch)
    return cfg, params, pipe


def _accuracy(cfg, params, pipe, n_batches=4, start=1000):
    correct = total = 0
    for i in range(start, start + n_batches):
        b = pipe.batch(i)
        logits = resnet_forward(params, cfg, jnp.asarray(b["images"]))
        correct += int((np.argmax(np.asarray(logits), -1)
                        == b["labels"]).sum())
        total += len(b["labels"])
    return correct / total


def test_sfc_int8_preserves_accuracy(trained_cnn):
    """The paper's claim end-to-end: swapping direct fp32 convs for
    quantized SFC convs keeps accuracy (±small delta)."""
    cfg, params, pipe = trained_cnn
    acc_fp = _accuracy(cfg, params, pipe)
    assert acc_fp > 0.5, f"baseline failed to learn: {acc_fp}"
    cfg_sfc8 = dataclasses.replace(cfg, conv_algo="sfc6_6", quant="int8")
    acc_sfc8 = _accuracy(cfg_sfc8, params, pipe)
    assert acc_sfc8 > acc_fp - 0.05, (acc_fp, acc_sfc8)


def test_winograd_int8_degrades_more_than_sfc(trained_cnn):
    """Relative claim of Table 2: Wino F(4x4) int8 degrades more than
    SFC int8 (tensor-granularity quantization to stress the difference)."""
    cfg, params, pipe = trained_cnn
    sfc = dataclasses.replace(cfg, conv_algo="sfc6_6", quant="int6",
                              act_granularity="tensor",
                              weight_granularity="channel")
    win = dataclasses.replace(cfg, conv_algo="wino4", quant="int6",
                              act_granularity="tensor",
                              weight_granularity="channel")
    acc_sfc = _accuracy(sfc, params, pipe)
    acc_win = _accuracy(win, params, pipe)
    assert acc_sfc >= acc_win, (acc_sfc, acc_win)


def test_lm_train_checkpoint_serve(tmp_path):
    """LM end-to-end: train w/ checkpoints -> reload -> batched serving."""
    cfg = get_smoke_config("qwen3-14b")
    model = build(cfg)
    pipe = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))

    def batches(i):
        b = pipe.batch(i)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    trainer = Trainer(model, AdamW(lr=5e-3), TrainerConfig(
        total_steps=20, checkpoint_every=10, checkpoint_dir=str(tmp_path),
        log_every=1000))
    rep = trainer.run(batches, jax.random.PRNGKey(0))
    assert rep.losses[-1] < rep.losses[0]

    # reload into a fresh process-level state and serve greedily
    state, step = trainer.init_or_restore(jax.random.PRNGKey(0))
    assert step == 20
    B, prompt_len, gen_len = 4, 8, 8
    prompt = batches(99)["tokens"][:, :prompt_len]
    cache = model.init_cache(state.params, B, prompt_len + gen_len)
    tok = prompt[:, 0:1]
    generated = []
    for t in range(prompt_len + gen_len - 1):
        logits, cache = model.decode_step(
            state.params, cache, tok, jnp.full((B,), t, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        tok = prompt[:, t + 1:t + 2] if t + 1 < prompt_len else nxt
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    assert out.shape == (B, prompt_len + gen_len - 1)
    assert bool(jnp.all((out >= 0) & (out < cfg.padded_vocab)))
