"""Lowering pass: polyphase stride-2, grouped, and 2-D depthwise convs.

tier-1 keeps the deterministic unit corpus (geometry laws, plan-shape
assertions, small conformance cases, cost-model honesty in both
directions); the exhaustive cross-shape sweep rides the ``kernels``
marker job like the rest of the conformance suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConvSpec, CompositePlan, plan
from repro.api.lowering import disabled, phase_taps, strided_lo_out
from repro.quant.fake_quant import INT8_FREQ
from repro.testing import assert_conv_conformance

# narrow fused sweep for composite cases: every sub-conv runs per variant,
# so the tier-1 corpus checks the default grid, a ragged k-block, and the
# batched+double-buffered grid (the full default sweep is the kernels job)
FAST_VARIANTS = (
    dict(k_block=128, cout_block=128, rows_per_step=1),
    dict(k_block=64, cout_block=128, rows_per_step=2, double_buffer=True),
)


def _data(hw=12, cin=8, cout=8, r=3, seed=0, cin_w=None, batch=2):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(batch, hw, hw, cin), jnp.float32)
    w = jnp.asarray(rng.randn(r, r, cin_w or cin, cout) * 0.2, jnp.float32)
    return x, w


# ----------------------------------------------------------------------
# geometry laws
# ----------------------------------------------------------------------
def test_phase_taps_partition_kernel():
    # the phases partition the R taps exactly, for every (R, stride)
    for R in range(1, 9):
        for s in (2, 3, 4):
            assert sum(phase_taps(R, a, s) for a in range(s)) == R


def test_strided_lo_out_matches_lax():
    # the polyphase pad/out geometry must agree with XLA's convention
    rng = np.random.RandomState(0)
    for size, R, s, pad in [(14, 3, 2, "SAME"), (15, 3, 2, "SAME"),
                            (14, 3, 2, "VALID"), (17, 7, 2, "VALID"),
                            (224, 7, 2, "SAME"), (9, 5, 3, "SAME")]:
        x = jnp.asarray(rng.randn(1, size, size, 1), jnp.float32)
        w = jnp.ones((R, R, 1, 1), jnp.float32)
        out = jax.lax.conv_general_dilated(
            x, w, (s, s), pad, dimension_numbers=("NHWC", "HWIO", "NHWC"))
        assert strided_lo_out(size, R, s, pad)[1] == out.shape[1], \
            (size, R, s, pad)


# ----------------------------------------------------------------------
# plan shapes: what lowers, what doesn't
# ----------------------------------------------------------------------
def test_resnet_stage_transitions_lower_and_beat_direct():
    """Acceptance: every ResNet-18 (224) stride-2 3x3 stage transition and
    the stride-2 7x7 stem plan onto SFC sub-convs, and the BOPs model
    prices the composite below strided direct."""
    shapes = [(56, 64, 128), (28, 128, 256), (14, 256, 512)]
    for hw, cin, cout in shapes:
        for quant in (INT8_FREQ, None):
            kw = {"quant": quant} if quant else {}
            spec = ConvSpec(rank=2, kernel_size=3, stride=2,
                            in_channels=cin, out_channels=cout,
                            spatial=(hw, hw), **kw)
            p = plan(spec, algo="auto")
            assert p.path == "lowered", spec
            assert any(sp.path == "fast" for sp in p.sub_plans)
            assert all(sp.algorithm is None or sp.algorithm.kind == "sfc"
                       for sp in p.sub_plans)
            assert p.cost < plan(spec, algo="direct").cost
    stem = ConvSpec(rank=2, kernel_size=7, stride=2, in_channels=3,
                    out_channels=64, spatial=(224, 224), quant=INT8_FREQ)
    ps = plan(stem, algo="auto")
    assert ps.path == "lowered"
    assert ps.cost < plan(stem, algo="direct").cost
    # the 7x7 phases are 4- and 3-tap sub-kernels
    assert sorted({m[2] for m in ps.sub_meta}) == [3, 4]


def test_cost_model_honest_when_lowering_loses():
    """Auto must NOT lower when the composite loses: tiny-channel stride-2
    (transform overhead dominates) and strided depthwise (per-channel
    transforms with no C_out amortization) stay direct."""
    tiny = ConvSpec(rank=2, kernel_size=3, stride=2, in_channels=4,
                    out_channels=4, spatial=(12, 12), quant=INT8_FREQ)
    assert plan(tiny, algo="auto").path == "direct"
    dw2 = ConvSpec(rank=2, kernel_size=3, stride=2, depthwise=True,
                   in_channels=256, out_channels=256, spatial=(28, 28),
                   quant=INT8_FREQ)
    assert plan(dw2, algo="auto").path == "direct"
    # 2-tap stride-2 lowers to four pointwise subs: no fast sub at all
    r2 = ConvSpec(rank=2, kernel_size=2, stride=2, in_channels=64,
                  out_channels=64, spatial=(16, 16))
    assert plan(r2, algo="auto").path == "direct"


def test_explicit_algo_forces_lowering():
    spec = ConvSpec(rank=2, kernel_size=3, stride=2, in_channels=4,
                    out_channels=4, spatial=(10, 10))
    p = plan(spec, algo="sfc6_7_r2")
    assert p.path == "lowered"
    # the explicitly requested 2-tap algorithm serves the 2-tap phases
    assert any(sp.algo_name == "sfc6_7_r2" for sp in p.sub_plans)


def test_disabled_restores_pre_lowering_behaviour():
    spec = ConvSpec(rank=2, kernel_size=3, stride=2, in_channels=64,
                    out_channels=128, spatial=(56, 56), quant=INT8_FREQ)
    assert plan(spec, algo="auto").path == "lowered"
    with disabled():
        assert plan(spec, algo="auto").path == "direct"
    assert plan(spec, algo="auto").path == "lowered"


def test_grouped_subplans_shared():
    spec = ConvSpec(rank=2, kernel_size=3, groups=4, in_channels=32,
                    out_channels=32, spatial=(12, 12))
    p = plan(spec, algo="sfc6_6")
    assert isinstance(p, CompositePlan) and p.kind == "grouped"
    assert len(p.sub_plans) == 4
    # one memoized sub-plan object serves every group (one prepared
    # -weight layout)
    assert all(sp is p.sub_plans[0] for sp in p.sub_plans)


def test_depthwise_plans_native_fast():
    spec = ConvSpec(rank=2, kernel_size=3, depthwise=True, in_channels=64,
                    out_channels=64, spatial=(28, 28), quant=INT8_FREQ)
    p = plan(spec, algo="auto")
    assert p.path == "fast" and p.algorithm.kind == "sfc"


# ----------------------------------------------------------------------
# conformance: every lowering bit-checks against the direct reference
# ----------------------------------------------------------------------
def test_stride2_conformance_fp32_and_int8():
    x, w = _data(hw=14, cin=8, cout=8, seed=1)
    for quant in (None, INT8_FREQ):
        kw = {"quant": quant} if quant else {}
        spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2, **kw)
        y = assert_conv_conformance(x, w, spec, "sfc4_4_r2",
                                    variants=FAST_VARIANTS)
        # and the whole composite equals the strided direct oracle
        y_direct = plan(spec, algo="direct").apply(x, w)
        tol = 1e-4 if quant is None else 0.08
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_direct),
                                   rtol=tol, atol=tol * float(
                                       jnp.abs(y_direct).max()))


def test_stride2_valid_padding_conformance():
    x, w = _data(hw=13, cin=8, cout=8, seed=2)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2, padding="VALID",
                               quant=INT8_FREQ)
    assert_conv_conformance(x, w, spec, "sfc4_4_r2", variants=FAST_VARIANTS)


def test_stem_7x7_stride2_conformance():
    x, w = _data(hw=18, cin=3, cout=8, r=7, seed=3, batch=1)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2, quant=INT8_FREQ)
    p = plan(spec, backend="pallas", algo="sfc6_6_r4")
    assert p.path == "lowered"
    assert_conv_conformance(x, w, spec, "sfc6_6_r4", variants=FAST_VARIANTS)


def test_grouped_conformance_fp32_and_int8():
    x, w = _data(hw=12, cin=16, cout=16, cin_w=4, seed=4)
    for quant in (None, INT8_FREQ):
        kw = {"quant": quant} if quant else {}
        spec = ConvSpec.for_conv2d(x.shape, w.shape, groups=4, **kw)
        y = assert_conv_conformance(x, w, spec, "sfc6_6",
                                    variants=FAST_VARIANTS)
        y_direct = plan(spec, algo="direct").apply(x, w)
        tol = 1e-4 if quant is None else 0.08
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_direct),
                                   rtol=tol, atol=tol * float(
                                       jnp.abs(y_direct).max()))


def test_depthwise_conformance_fp32_and_int8():
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(2, 12, 12, 16), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 1, 16) * 0.3, jnp.float32)
    for quant in (None, INT8_FREQ):
        kw = {"quant": quant} if quant else {}
        spec = ConvSpec.for_conv2d_depthwise(x.shape, w.shape, **kw)
        y = assert_conv_conformance(x, w, spec, "sfc6_6")
        y_direct = plan(spec, algo="direct").apply(x, w)
        tol = 1e-4 if quant is None else 0.08
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_direct),
                                   rtol=tol, atol=tol * float(
                                       jnp.abs(y_direct).max()))


def test_depthwise_stride2_polyphase_recursion():
    """A strided depthwise spec composes both mechanisms: polyphase into
    stride-1 depthwise sub-specs running the elementwise path."""
    rng = np.random.RandomState(6)
    x = jnp.asarray(rng.randn(1, 14, 14, 8), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 1, 8) * 0.3, jnp.float32)
    spec = ConvSpec.for_conv2d_depthwise(x.shape, w.shape, stride=2)
    p = plan(spec, algo="sfc4_4_r2")
    assert p.path == "lowered" and p.kind == "polyphase"
    assert all(sp.spec.depthwise for sp in p.sub_plans)
    y = p.apply(x, w)
    y_direct = plan(spec, algo="direct").apply(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_direct),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------------
# composite plan mechanics
# ----------------------------------------------------------------------
def test_composite_prepare_weights_cached():
    x, w = _data(hw=14, seed=7)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2)
    p = plan(spec, algo="sfc4_4_r2")
    prep1 = p.prepare_weights(w)
    prep2 = p.prepare_weights(w)
    assert prep1 is prep2
    assert len(prep1.subs) == len(p.sub_plans)
    y1 = p.apply(x, prep1)
    y2 = p.apply(x, w)
    assert bool(jnp.all(y1 == y2))


def test_composite_prepare_skips_tracers():
    x, w = _data(hw=14, seed=8)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2)
    p = plan(spec, algo="sfc4_4_r2")
    before = len(p._prep)
    y = jax.jit(lambda x, w: p.apply(x, w))(x, w)
    assert len(p._prep) == before
    np.testing.assert_allclose(np.asarray(y), np.asarray(p.apply(x, w)),
                               rtol=1e-5, atol=1e-5)


def test_composite_hook_reaches_subconvs():
    """elementwise_hook is forwarded to every sub-plan with a transform
    domain; direct subs (the 1x1 centre phase) are skipped."""
    x, w = _data(hw=14, seed=9)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2)
    p = plan(spec, backend="reference", algo="sfc4_4_r2")
    n_fast = sum(1 for sp in p.sub_plans if sp.path != "direct")
    calls = []

    def hook(tx, tw):
        calls.append(tx.shape)
        return tx, tw

    p.apply(x, w, elementwise_hook=hook)
    assert len(calls) == n_fast > 0


def test_serving_cache_serves_lowered_plans():
    from repro.api import serving_cache
    cache = serving_cache.ServingCache(maxsize=8)
    x, w = _data(hw=14, seed=10)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2)
    p1, prep1 = cache.get(spec, w, algo="sfc4_4_r2")
    p2, prep2 = cache.get(spec, w, algo="sfc4_4_r2")
    assert p1 is p2 and prep1 is prep2
    assert cache.stats()["hits"] == 1 and cache.stats()["prepares"] == 1


def test_measured_latency_overrides_lowering_decision():
    """Measured wall-clock takes precedence over the BOPs lower-vs-direct
    verdict (the planner-wide contract), in both directions — but only
    once BOTH sides have been timed on this host (partial-sweep rule)."""
    from repro.api import tuning
    spec = ConvSpec(rank=2, kernel_size=3, stride=2, in_channels=64,
                    out_channels=128, spatial=(56, 56), quant=INT8_FREQ)
    assert plan(spec, algo="auto").path == "lowered"   # BOPs verdict
    # host measured the composite slower than strided direct -> direct
    tuning.record(spec, "reference", "direct", 1e-3)
    tuning.record(spec, "reference", "sfc6_6", 5e-3)
    assert plan(spec, algo="auto").path == "direct"
    # re-tuned the other way round -> lowered again
    tuning.record(spec, "reference", "sfc6_6", 5e-4)
    assert plan(spec, algo="auto").path == "lowered"
    # one-sided measurements leave the analytic verdict in charge
    tuning.clear()
    tuning.record(spec, "reference", "direct", 1e-9)
    assert plan(spec, algo="auto").path == "lowered"


def test_measured_config_rides_lowered_plan():
    """The autotuned winning KernelConfig measured for the ORIGINAL
    strided spec rides the composite: every sub-plan executes it (same
    contract as a native plan carrying its measured config)."""
    from repro.api import tuning
    from repro.api.tuning import KernelConfig
    spec = ConvSpec(rank=2, kernel_size=3, stride=2, in_channels=64,
                    out_channels=128, spatial=(56, 56), quant=INT8_FREQ)
    cfg = KernelConfig(datapath="fused", rows_per_step=4,
                       double_buffer=True)
    tuning.record(spec, "reference", "sfc6_6", 1e-3, cfg)
    p = plan(spec, algo="auto")
    assert p.path == "lowered"
    assert p.config == cfg
    assert all(sp.config == cfg for sp in p.sub_plans)


def test_ptq_prepare_rejects_composite_plans():
    """PTQLayer holds ONE (t, t) scale state; a lowered plan has one
    transform domain per sub-conv — prepare must fail loudly instead of
    silently returning unquantized weights."""
    from repro.quant.ptq import PTQLayer
    x, w = _data(hw=14, seed=12)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2, quant=INT8_FREQ)
    p = plan(spec, backend="pallas", algo="sfc4_4_r2")
    assert p.path == "lowered"
    with pytest.raises(NotImplementedError):
        PTQLayer(config=spec.quant).prepare(p, w)
    # the supported composite static-int8 path
    prep = p.prepare_weights(w, act_scale=p.calibrate(x))
    assert prep.quantized


def test_composite_gradients_flow():
    x, w = _data(hw=14, seed=11)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2)
    p = plan(spec, algo="sfc4_4_r2")
    g = jax.grad(lambda w: jnp.sum(p.apply(x, w) ** 2))(w)
    assert g.shape == w.shape
    assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.abs(g).sum()) > 0


# ----------------------------------------------------------------------
# exhaustive sweep — kernels marker job
# ----------------------------------------------------------------------
@pytest.mark.kernels
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("hw,cin,cout,r,algo", [
    (14, 8, 16, 3, "auto_force"), (15, 16, 8, 3, "auto_force"),
    (16, 8, 8, 3, "sfc4_4_r2"), (17, 4, 4, 5, "auto_force"),
    (18, 3, 8, 7, "sfc6_6_r4"), (13, 8, 8, 4, "auto_force"),
])
def test_lowering_sweep_stride2(hw, cin, cout, r, algo, padding):
    """Exhaustive polyphase conformance: odd/even extents, every phase
    layout (R = 3, 4, 5, 7), both paddings, fp32 + int8, full fused
    variant sweep per sub-conv."""
    x, w = _data(hw=hw, cin=cin, cout=cout, r=r, seed=hw)
    if algo == "auto_force":
        # force lowering independently of shape profitability: request a
        # registered algorithm whose taps match one of the phases
        algo = "sfc4_4_r2" if phase_taps(r, 0, 2) == 2 else "sfc6_6_r4"
    for quant in (None, INT8_FREQ):
        kw = {"quant": quant} if quant else {}
        spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2,
                                   padding=padding, **kw)
        p = plan(spec, backend="pallas", algo=algo)
        assert p.path == "lowered", spec
        y = assert_conv_conformance(x, w, spec, algo)
        y_direct = plan(spec, algo="direct").apply(x, w)
        tol = 2e-4 if quant is None else 0.1
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_direct), rtol=tol,
            atol=tol * float(jnp.abs(y_direct).max()))


@pytest.mark.kernels
@pytest.mark.parametrize("cin,groups", [(16, 2), (24, 3), (32, 8)])
def test_lowering_sweep_grouped(cin, groups):
    x, w = _data(hw=12, cin=cin, cout=cin, cin_w=cin // groups, seed=cin)
    for quant in (None, INT8_FREQ):
        kw = {"quant": quant} if quant else {}
        spec = ConvSpec.for_conv2d(x.shape, w.shape, groups=groups, **kw)
        assert_conv_conformance(x, w, spec, "sfc6_6")


@pytest.mark.kernels
@pytest.mark.parametrize("hw,c", [(12, 8), (17, 24), (9, 128)])
def test_lowering_sweep_depthwise(hw, c):
    rng = np.random.RandomState(hw + c)
    x = jnp.asarray(rng.randn(2, hw, hw, c), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 1, c) * 0.3, jnp.float32)
    for quant in (None, INT8_FREQ):
        kw = {"quant": quant} if quant else {}
        spec = ConvSpec.for_conv2d_depthwise(x.shape, w.shape, **kw)
        assert_conv_conformance(x, w, spec, "sfc6_6")
