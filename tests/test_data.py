"""Data pipeline: determinism, host sharding, prefetch, learnability signal."""
import numpy as np

from repro.data import (ImagePipelineConfig, Prefetcher,
                        SyntheticImagePipeline, SyntheticTokenPipeline,
                        TokenPipelineConfig)


def test_deterministic_restart():
    cfg = TokenPipelineConfig(vocab_size=100, seq_len=16, global_batch=4)
    p1, p2 = SyntheticTokenPipeline(cfg), SyntheticTokenPipeline(cfg)
    for i in (0, 3, 17):
        np.testing.assert_array_equal(p1.batch(i)["tokens"],
                                      p2.batch(i)["tokens"])


def test_host_sharding_partitions_batch():
    base = TokenPipelineConfig(vocab_size=100, seq_len=8, global_batch=8)
    full = SyntheticTokenPipeline(base)
    h0 = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=100, seq_len=8, global_batch=8, host_index=0,
        host_count=2))
    assert h0.host_batch == 4
    assert full.batch(0)["tokens"].shape == (8, 8)
    assert h0.batch(0)["tokens"].shape == (4, 8)
    # different hosts draw different data
    h1 = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=100, seq_len=8, global_batch=8, host_index=1,
        host_count=2))
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])


def test_labels_are_next_tokens():
    p = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=50, seq_len=12, global_batch=2))
    b = p.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure_learnable():
    """Bigram structure exists: successor entropy << unigram entropy."""
    p = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=64, seq_len=256, global_batch=8, markov_weight=0.9))
    b = p.batch(0)
    toks = b["tokens"]
    # P(next in successor table | current) should be high
    hits = 0
    total = 0
    for row in toks:
        for t in range(len(row) - 1):
            hits += row[t + 1] in p._succ[row[t]]
            total += 1
    assert hits / total > 0.5


def test_image_pipeline_class_structure():
    cfg = ImagePipelineConfig(image_size=16, n_classes=4, global_batch=8)
    p = SyntheticImagePipeline(cfg)
    b = p.batch(0)
    assert b["images"].shape == (8, 16, 16, 3)
    assert b["labels"].max() < 4
    # same-class images correlate more than cross-class
    b2 = p.batch(1)
    same = cross = 0
    n_same = n_cross = 0
    for i in range(8):
        for j in range(8):
            c = np.corrcoef(b["images"][i].ravel(),
                            b2["images"][j].ravel())[0, 1]
            if b["labels"][i] == b2["labels"][j]:
                same += c
                n_same += 1
            else:
                cross += c
                n_cross += 1
    assert same / max(n_same, 1) > cross / max(n_cross, 1)


def test_prefetcher():
    p = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=32, seq_len=8, global_batch=2))
    pf = Prefetcher(p, depth=2)
    b0 = pf.next()
    np.testing.assert_array_equal(b0["tokens"], p.batch(0)["tokens"])
    b1 = pf.next()
    np.testing.assert_array_equal(b1["tokens"], p.batch(1)["tokens"])
    pf.close()
