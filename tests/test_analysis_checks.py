"""repro.analysis.kernel_checks: static Pallas launch verification.

The checker consumes the same FusedGeometry the kernel launches from, so
these tests assert three things: real geometries are clean and match the
kernel's own arithmetic, corrupted geometries (dataclasses.replace) trip
the right finding codes, and the autotune/batcher integrations actually
consult the checker (tiny monkeypatched VMEM limit changes behaviour).
"""
import dataclasses

import pytest

from repro.analysis import kernel_checks as kc
from repro.api import plan, registry, tuning
from repro.api.spec import ConvSpec
from repro.kernels import sfc_fused as sf
from repro.quant.fake_quant import QuantConfig

Q88 = QuantConfig(enabled=True, bits_act=8, bits_weight=8)
ALGO = registry.get_algorithm("sfc4_4")


def test_real_geometries_are_clean():
    for args in [(2, 12, 12, 16, 24), (1, 28, 28, 64, 128),
                 (4, 7, 7, 130, 48)]:
        geom = sf.fused_geometry(ALGO, *args)
        assert kc.check_geometry(geom) == [], args
    dw = sf.fused_geometry(ALGO, 2, 8, 8, 20, 20, depthwise=True)
    assert kc.check_geometry(dw) == []
    # auto rows + double buffer resolve to a clean launch too
    auto = sf.fused_geometry(ALGO, 4, 32, 32, 64, 64, rows_per_step=None,
                             double_buffer=True)
    assert kc.check_geometry(auto) == []


def test_geometry_matches_kernel_docstring_values():
    # hand-derived reference launch from the sfc_fused docstring/smoke:
    # B=2 12x12 16->24 with sfc4_4 (M=4, t=7)
    geom = sf.fused_geometry(ALGO, 2, 12, 12, 16, 24)
    assert geom.grid == (6, 1, 1)
    assert geom.strip_shape == (1, 6, 14, 16)
    assert geom.vmem_bytes() == 51536
    assert geom.scratch_shapes() == (("acc", (49, 3, 24), "int32"),)
    assert geom.rmw_axis == 2
    dw = sf.fused_geometry(ALGO, 2, 8, 8, 20, 20, depthwise=True)
    assert dw.grid == (4, 1)
    assert dw.kb == dw.cb == 24 and dw.n_k == 1
    assert dw.scratch_shapes() == ()
    assert dw.rmw_axis is None


def test_kc001_vmem_limit():
    geom = sf.fused_geometry(ALGO, 2, 12, 12, 16, 24)
    findings = kc.check_geometry(geom, vmem_limit=100)
    assert [f.code for f in findings] == ["KC001"]
    assert str(geom.vmem_bytes()) in findings[0].message


def test_kc002_strip_and_blocking_corruptions():
    geom = sf.fused_geometry(ALGO, 2, 12, 12, 16, 24)
    # under-tiled C_in: channels silently dropped
    assert "KC002" in {f.code for f in kc.check_geometry(
        dataclasses.replace(geom, n_k=0))}
    # over-tiled C_out
    assert "KC002" in {f.code for f in kc.check_geometry(
        dataclasses.replace(geom, n_o=geom.n_o + 1))}
    # strip group taller than the padded input: out-of-bounds read
    assert "KC002" in {f.code for f in kc.check_geometry(
        dataclasses.replace(geom, x_rows=geom.x_rows - 1))}
    # grouped images not covering the batch
    assert "KC002" in {f.code for f in kc.check_geometry(
        dataclasses.replace(geom, g_b=geom.g_b + 1, B=geom.B + 1))}


def test_kc003_dma_slot_aliasing():
    geom = sf.fused_geometry(ALGO, 2, 12, 12, 16, 24)
    # double-buffer prefetch landing in the in-flight slot
    aliased = dataclasses.replace(geom, double_buffer=True,
                                  db_prefetch_distance=2)
    assert [f.code for f in kc.check_geometry(aliased)] == ["KC003"]

    # an RMW axis that is not innermost leaves scratch accumulation
    # order undefined across grid dims
    class BadRmw(sf.FusedGeometry):
        @property
        def rmw_axis(self):
            return 0
    bad = BadRmw(**{f.name: getattr(geom, f.name)
                    for f in dataclasses.fields(geom)})
    assert any(f.code == "KC003" for f in kc.check_geometry(bad))


def test_kc003_leaky_out_index():
    # a 2-k-block geometry whose out_index leaks the k axis must trip
    # KC003; the uncorrupted counterpart is clean
    geom = sf.fused_geometry(ALGO, 2, 12, 12, 256, 24, k_block=128)
    assert geom.n_k == 2 and kc.check_geometry(geom) == []

    class LeakyGeom(sf.FusedGeometry):
        def out_index(self, i, j, k):
            return (i // self.g_h, i % self.g_h, k, j)
    leaky = LeakyGeom(**{f.name: getattr(geom, f.name)
                         for f in dataclasses.fields(geom)})
    assert any(f.code == "KC003" for f in kc.check_geometry(leaky))


def test_default_candidates_clean_on_representative_specs():
    assert kc.default_candidate_report() == []


def test_check_candidates_partitions_on_tiny_limit():
    spec = ConvSpec(kernel_size=3, in_channels=64, out_channels=64,
                    spatial=(14, 14), quant=Q88)
    ok, rejected = kc.check_candidates(spec, ALGO,
                                       tuning.DEFAULT_CANDIDATES)
    assert len(ok) == len(tuning.DEFAULT_CANDIDATES) and not rejected
    ok2, rej2 = kc.check_candidates(spec, ALGO, tuning.DEFAULT_CANDIDATES,
                                    vmem_limit=1000)
    # every fused candidate fails the budget; staged ones pass vacuously
    assert all(c.datapath == "staged" for c in ok2)
    assert all(any(f.code == "KC001" for f in errs) for _, errs in rej2)
    assert {c.datapath for c, _ in rej2} == {"fused"}


def test_autotune_preflight_skips_unlaunchable_candidates(
        deterministic_time_fn, monkeypatch):
    # with a tiny VMEM limit every fused candidate is rejected before
    # timing, so the measured winner must be a staged config
    monkeypatch.setattr(sf, "VMEM_LIMIT_BYTES", 1000)
    spec = ConvSpec(kernel_size=3, in_channels=16, out_channels=16,
                    spatial=(8, 8), quant=Q88)
    msgs = []
    res = tuning.autotune(spec, backend="pallas", algos=["sfc4_4"],
                          reps=1, persist=False, log=msgs.append,
                          include_direct=False)
    assert res["sfc4_4"]["config"]["datapath"] == "staged"
    assert any("rejected by pre-flight" in m and "KC001" in m
               for m in msgs)
    # and no fused candidate was ever timed
    assert not any("fused(" in m and "ms" in m for m in msgs)


def test_batcher_fold_uses_checker(monkeypatch):
    from repro.serve import batcher
    spec = ConvSpec(kernel_size=3, in_channels=64, out_channels=64,
                    spatial=(14, 14), quant=Q88)
    p = plan(spec, backend="pallas", algo="sfc4_4")
    # normal limit: whole batch folds into one grid step
    rps, imgs, rows = batcher.fold_rows_per_step(p, 4)
    assert (rps, imgs, rows) == (16, 4, 4)
    # choked limit: the fold shrinks — proof the batcher consults the
    # checker's geometry rather than private kernel arithmetic.  At 200kB
    # even the ungrouped step is over budget (the int8 weight block alone
    # is 49 * 64 * 64 B), so the fold falls back to the trivial group.
    monkeypatch.setattr(sf, "VMEM_LIMIT_BYTES", 200_000)
    assert batcher.fold_rows_per_step(p, 4) == (1, 1, 1)
    assert not kc.fold_fits(ALGO, p.config or tuning.DEFAULT_FUSED, 4,
                            14, 14, 64, 64, rows_per_step=1)


def test_fold_fits_matches_geometry_budget():
    cfg = tuning.DEFAULT_FUSED
    geom = sf.fused_geometry(ALGO, 2, 28, 28, 64, 64,
                             k_block=cfg.k_block,
                             cout_block=cfg.cout_block, rows_per_step=4,
                             double_buffer=cfg.double_buffer)
    assert kc.fold_fits(ALGO, cfg, 2, 28, 28, 64, 64, rows_per_step=4) \
        == (geom.vmem_bytes() <= sf.VMEM_LIMIT_BYTES)
