"""Loop-aware HLO cost analyzer: trip counts, nested loops, dot flops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, normalize_cost_analysis


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    x = jnp.ones((64, 64))

    def body(c, _):
        return c @ x, None

    def f(c):
        out, _ = jax.lax.scan(body, c, None, length=10)
        return out

    s = analyze(_compiled_text(f, x))
    assert s.flops == pytest.approx(10 * 2 * 64 ** 3, rel=1e-6)
    assert 10 in s.loop_trips.values()


def test_nested_scan():
    x = jnp.ones((32, 32))

    def inner(c, _):
        return c @ x, None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, None, length=5)
        return c, None

    def f(c):
        out, _ = jax.lax.scan(outer, c, None, length=3)
        return out

    s = analyze(_compiled_text(f, x))
    assert s.flops == pytest.approx(15 * 2 * 32 ** 3, rel=1e-6)
    assert sorted(s.loop_trips.values()) == [3, 5]


def test_cost_analysis_undercounts_loops():
    """The motivating observation: XLA cost_analysis counts a while body
    once; the analyzer corrects it."""
    x = jnp.ones((64, 64))

    def f(c):
        out, _ = jax.lax.scan(lambda c, _: (c @ x, None), c, None, length=8)
        return out

    compiled = jax.jit(f).lower(x).compile()
    # cost_analysis() returns a list on some JAX versions, a dict on others
    raw = normalize_cost_analysis(compiled.cost_analysis())["flops"]
    corrected = analyze(compiled.as_text()).flops
    assert corrected == pytest.approx(8 * 2 * 64 ** 3, rel=1e-6)
    assert corrected > 5 * raw          # raw counted the body ~once


def test_normalize_cost_analysis_shapes():
    assert normalize_cost_analysis(None) == {}
    assert normalize_cost_analysis([]) == {}
    assert normalize_cost_analysis({"flops": 3.0}) == {"flops": 3.0}
    assert normalize_cost_analysis([{"flops": 3.0}]) == {"flops": 3.0}


def test_traffic_nonzero_and_param_bytes():
    a = jnp.ones((128, 128))

    def f(a):
        return jnp.tanh(a @ a) @ a

    s = analyze(_compiled_text(f, a))
    assert s.flops == pytest.approx(2 * 2 * 128 ** 3, rel=1e-6)
    assert s.traffic_bytes > 0
    assert s.param_bytes == 128 * 128 * 4


def test_model_train_step_flops_scale_with_layers():
    """End-to-end: a 4-layer smoke model reports ~2x the flops of 2-layer."""
    from repro.configs import get_smoke_config
    from repro.models import build
    from repro.optim.optimizers import AdamW
    from repro.train.steps import abstract_train_state, make_train_step

    flops = {}
    for L in (2, 4):
        cfg = get_smoke_config("stablelm-3b")
        cfg = cfg.__class__(**{**cfg.__dict__, "n_layers": L})
        model = build(cfg)
        opt = AdamW(lr=1e-3)
        state = abstract_train_state(model, opt)
        batch = {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((2, 64), jnp.int32)}
        step = make_train_step(model, opt)
        text = jax.jit(step).lower(state, batch).compile().as_text()
        flops[L] = analyze(text).flops
    # embed/lm_head are layer-independent; per-layer part must double
    assert flops[4] > 1.5 * flops[2] - (flops[2] * 0.5)
    assert flops[4] / flops[2] > 1.3
