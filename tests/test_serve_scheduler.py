"""Deadline-aware batch scheduling (EDF) + batch aging, and the
serving-path bugfix regressions that ride with them:

  * EDF formation picks the bucket of the most urgent request and fills
    it with same-bucket peers in deadline order — the SLO classes become
    *scheduling*, not just accounting;
  * batch aging holds an underfull batch for ``max_hold_ms`` (bounded by
    the head request's slack) so co-batchable arrivals fold into ONE
    fused grid step; hold decisions are pure functions of the injected
    clock, asserted deterministically;
  * batched answers stay BIT-IDENTICAL to per-request dispatch under the
    new formation order (the PR 6 invariant re-proven under EDF);
  * same-bucket matching is by equality, not identity (two equal
    ``Bucket`` objects co-batch);
  * ``BatchQueue.put_if_below`` enforces the admission depth bound
    atomically (no TOCTOU overshoot under concurrent submitters);
  * sub-kernel VALID shapes are rejected at admission, and
    ``crop_output`` raises instead of silently serving an empty tensor;
  * warm-compile dispatches do not consume an armed fault budget;
  * ``stop(raise_on_error=True)`` does not re-raise a stale loop error
    from a previous run.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.api.serving_cache import ServingCache
from repro.quant import INT8_FREQ
from repro.serve import (BATCH, EDF, INTERACTIVE, AdmissionPolicy,
                         BatchQueue, Bucket, BucketTable, Engine,
                         RejectedError, SchedulerPolicy, ShedError,
                         SLOClass, results)
from repro.serve.types import Request

CIN, COUT = 4, 8


def _weights(seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(3, 3, CIN, COUT) * 0.2, jnp.float32)


def _table(shapes=((8, 8), (12, 12)), quant=INT8_FREQ, **kw):
    return BucketTable.for_workload(shapes, kernel_size=3, in_channels=CIN,
                                    out_channels=COUT, quant=quant, **kw)


def _imgs(shapes, seed=1):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.randn(h, w, CIN), jnp.float32)
            for h, w in shapes]


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def shared_cache():
    return ServingCache()


# ----------------------------------------------------------------------
# policy + request helpers
# ----------------------------------------------------------------------
def test_scheduler_policy_validation():
    assert SchedulerPolicy().kind == "fcfs"          # default unchanged
    assert EDF.kind == "edf" and EDF.max_hold_ms == 0.0
    with pytest.raises(ValueError, match="kind"):
        SchedulerPolicy(kind="lifo")
    with pytest.raises(ValueError, match="max_hold_ms"):
        SchedulerPolicy(max_hold_ms=-1.0)


def test_request_deadline_and_slack():
    r = Request(x=jnp.zeros((8, 8, CIN)), slo=INTERACTIVE, arrival_t=10.0)
    assert r.deadline_t == pytest.approx(12.0)       # 2s interactive SLO
    assert r.slack_ms(10.0) == pytest.approx(2_000.0)
    assert r.slack_ms(13.0) == pytest.approx(-1_000.0)


# ----------------------------------------------------------------------
# EDF formation
# ----------------------------------------------------------------------
def test_edf_dispatches_most_urgent_bucket_first(shared_cache):
    """A slack-rich BATCH request at the head of the queue must not delay
    an INTERACTIVE request queued behind it in another bucket."""
    clk = _FakeClock()
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 clock=clk, scheduler=EDF)
    x12, x8 = _imgs([(12, 12), (8, 8)], seed=2)
    fb = eng.submit(x12, BATCH)                # arrives first, 20s deadline
    fi = eng.submit(x8, INTERACTIVE)           # arrives second, 2s deadline
    assert eng.step() == 1
    assert fi.done() and not fb.done()         # urgent bucket jumped ahead
    assert fi.result(timeout=0).bucket_name == "b8x8"
    assert eng.step() == 1
    assert fb.result(timeout=0).bucket_name == "b12x12"


def test_fcfs_default_is_head_of_line(shared_cache):
    """The same arrival order under the default policy serves the head
    bucket first — the pre-scheduler behavior is preserved."""
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache)
    x12, x8 = _imgs([(12, 12), (8, 8)], seed=2)
    fb = eng.submit(x12, BATCH)
    fi = eng.submit(x8, INTERACTIVE)
    assert eng.step() == 1
    assert fb.done() and not fi.done()
    eng.step()
    results([fb, fi])


def test_edf_fills_same_bucket_in_deadline_order(shared_cache):
    """Within the chosen bucket, peers ride in deadline order: with
    max_batch=1 the later-arriving INTERACTIVE request dispatches before
    the earlier BATCH one."""
    clk = _FakeClock()
    eng = Engine(_weights(), _table(), max_batch=1, cache=shared_cache,
                 clock=clk, scheduler=EDF)
    xs = _imgs([(8, 8)] * 2, seed=3)
    fb = eng.submit(xs[0], BATCH)
    fi = eng.submit(xs[1], INTERACTIVE)
    assert eng.step() == 1
    assert fi.done() and not fb.done()
    eng.step()
    results([fb, fi])


def test_edf_expired_request_flows_to_shed_not_starvation(shared_cache):
    """An already-expired request is maximally urgent under EDF: it is
    taken (and shed) immediately instead of starving unresolved behind
    still-viable work."""
    clk = _FakeClock()
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 clock=clk, scheduler=EDF, shed_expired=True)
    x8, x12 = _imgs([(8, 8), (12, 12)], seed=4)
    fi = eng.submit(x8, INTERACTIVE)
    clk.t = 5.0                                # interactive now expired
    fb = eng.submit(x12, BATCH)                # viable, different bucket
    assert eng.step() == 1                     # expired one taken first...
    with pytest.raises(ShedError):
        fi.result(timeout=0)                   # ...and resolved by shed
    assert eng.snapshot()["counters"]["shed"] == 1
    assert eng.step() == 1
    assert fb.result(timeout=0).deadline_met


def test_edf_batched_bit_identical_to_per_request(shared_cache):
    """The acceptance invariant re-proven under the new formation order:
    EDF-batched answers equal per-request dispatch bit-for-bit."""
    shapes = [(11, 10), (8, 8), (12, 12), (7, 5)]
    slos = [BATCH, INTERACTIVE, INTERACTIVE, BATCH]
    xs = _imgs(shapes, seed=5)
    eng_e = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                   scheduler=EDF)
    eng_s = Engine(_weights(), _table(), max_batch=1, cache=shared_cache)

    def serve_all(eng):
        futs = [eng.submit(x, slo) for x, slo in zip(xs, slos)]
        while eng.step() > 0:
            pass
        return results(futs)

    re_, rs = serve_all(eng_e), serve_all(eng_s)
    for b, s, (h, w) in zip(re_, rs, shapes):
        assert b.y.shape == s.y.shape
        assert np.array_equal(np.asarray(b.y), np.asarray(s.y)), \
            f"EDF-batched != per-request for shape ({h}, {w})"
    assert eng_e.snapshot()["batch_occupancy"]["max"] > 1


# ----------------------------------------------------------------------
# batch aging
# ----------------------------------------------------------------------
def test_aging_holds_underfull_batch_then_folds_arrival(shared_cache):
    clk = _FakeClock()
    eng = Engine(_weights(), _table(), max_batch=2, cache=shared_cache,
                 clock=clk,
                 scheduler=SchedulerPolicy(kind="edf", max_hold_ms=50.0))
    xs = _imgs([(8, 8)] * 2, seed=6)
    f1 = eng.submit(xs[0], BATCH)
    assert eng.step(timeout=0) == 0            # held: window open, underfull
    assert eng.queue.depth() == 1              # nothing was taken
    f2 = eng.submit(xs[1], BATCH)
    assert eng.step(timeout=0) == 2            # full batch ends the hold
    r1, r2 = results([f1, f2])
    assert r1.batch_size == 2 and r1.imgs_per_step == 2
    assert r2.batch_size == 2


def test_aging_window_expiry_dispatches_singleton(shared_cache):
    clk = _FakeClock()
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 clock=clk,
                 scheduler=SchedulerPolicy(kind="edf", max_hold_ms=50.0))
    f = eng.submit(_imgs([(8, 8)], seed=7)[0], BATCH)
    assert eng.step(timeout=0) == 0            # held
    clk.t = 0.06                               # past the 50ms window
    assert eng.step(timeout=0) == 1
    assert f.result(timeout=0).batch_size == 1
    snap = eng.snapshot()
    assert snap["counters"]["aged_dispatches"] == 1
    assert snap["hold_ms"]["max_ms"] == pytest.approx(50.0)   # clamped


def test_aging_hold_bounded_by_head_slack(shared_cache):
    """A huge max_hold_ms never holds past the head request's deadline:
    the tight-deadline request dispatches as soon as its slack runs out,
    while a slack-rich one is still being held."""
    clk = _FakeClock()
    tight = SLOClass("rt", deadline_ms=100.0)
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 clock=clk,
                 scheduler=SchedulerPolicy(kind="edf",
                                           max_hold_ms=10_000.0))
    f = eng.submit(_imgs([(8, 8)], seed=8)[0], tight)
    assert eng.step(timeout=0) == 0            # inside the 100ms slack
    clk.t = 0.2                                # slack exhausted << 10s hold
    assert eng.step(timeout=0) == 1
    assert f.result(timeout=0).deadline_met is False
    fb = eng.submit(_imgs([(8, 8)], seed=9)[0], BATCH)
    assert eng.step(timeout=0) == 0            # 20s slack: still held
    clk.t = 31.0                               # past hold AND deadline
    assert eng.step(timeout=0) == 1
    assert fb.done()


def test_aging_zero_hold_is_immediate_dispatch(shared_cache):
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 scheduler=EDF)                # max_hold_ms=0
    f = eng.submit(_imgs([(8, 8)], seed=10)[0], BATCH)
    assert eng.step(timeout=0) == 1
    assert f.result(timeout=0).batch_size == 1
    assert eng.snapshot()["counters"]["aged_dispatches"] == 0


def test_aging_blocking_take_wakes_on_completing_arrival():
    """In blocking mode the hold waits inside take_batch and an arrival
    that completes the batch ends it early (real clock, generous window
    so the assertion is on completion, not timing)."""
    q = BatchQueue()
    spec = _table().by_name("b8x8").spec
    b = Bucket("b8x8", 8, 8, spec)
    now = time.perf_counter()
    q.put(Request(x=jnp.zeros((8, 8, CIN)), slo=BATCH, arrival_t=now), b)
    got = {}

    def taker():
        got["batch"] = q.take_batch(
            2, timeout=5.0,
            policy=SchedulerPolicy(kind="fcfs", max_hold_ms=5_000.0))

    th = threading.Thread(target=taker)
    th.start()
    time.sleep(0.05)
    q.put(Request(x=jnp.zeros((8, 8, CIN)), slo=BATCH,
                  arrival_t=time.perf_counter()), b)
    th.join(timeout=5.0)
    assert not th.is_alive()
    assert got["batch"] is not None and len(got["batch"]) == 2


# ----------------------------------------------------------------------
# bucket matching by equality (not identity)
# ----------------------------------------------------------------------
def test_equal_but_distinct_buckets_cobatch():
    spec = _table().by_name("b8x8").spec
    b1 = Bucket("b8x8", 8, 8, spec)
    b2 = Bucket("b8x8", 8, 8, spec)            # equal, distinct object
    assert b1 == b2 and b1 is not b2
    q = BatchQueue()
    q.put(Request(x=jnp.zeros((8, 8, CIN)), slo=BATCH, arrival_t=0.0), b1)
    q.put(Request(x=jnp.zeros((8, 8, CIN)), slo=BATCH, arrival_t=0.0), b2)
    batch = q.take_batch(4, timeout=0)
    assert batch is not None and len(batch) == 2   # no occupancy loss
    assert q.depth() == 0


# ----------------------------------------------------------------------
# atomic admission (TOCTOU)
# ----------------------------------------------------------------------
def test_put_if_below_bound_atomic_under_threads():
    q = BatchQueue()
    spec = _table().by_name("b8x8").spec
    b = Bucket("b8x8", 8, 8, spec)
    bound, n_threads, per_thread = 32, 16, 8
    admitted = []

    def submitter():
        for _ in range(per_thread):
            r = Request(x=jnp.zeros((8, 8, CIN)), slo=BATCH, arrival_t=0.0)
            if q.put_if_below(r, b, bound):
                admitted.append(r.id)

    threads = [threading.Thread(target=submitter) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert q.depth() == bound                  # never overshot
    assert len(admitted) == bound


def test_engine_submit_never_overshoots_queue_bound(shared_cache):
    bound = 8
    eng = Engine(_weights(), _table(shapes=((8, 8),)), max_batch=4,
                 cache=shared_cache,
                 admission=AdmissionPolicy(max_queue_depth=bound))
    x = _imgs([(8, 8)], seed=11)[0]
    futs, lock = [], threading.Lock()

    def submitter():
        for _ in range(6):
            f = eng.submit(x)
            with lock:
                futs.append(f)

    threads = [threading.Thread(target=submitter) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert eng.queue.depth() == bound
    c = eng.snapshot()["counters"]
    assert c["admitted"] == bound
    assert c["rejected"] == len(futs) - bound
    rejected = [f for f in futs if f.done()]
    with pytest.raises(RejectedError, match="queue depth"):
        rejected[0].result(timeout=0)
    while eng.step() > 0:                      # admitted ones still serve
        pass
    assert eng.drain(timeout=5.0)


# ----------------------------------------------------------------------
# sub-kernel VALID shapes: reject at admission, raise on empty crop
# ----------------------------------------------------------------------
def test_subkernel_valid_request_rejected_at_admission(shared_cache):
    eng = Engine(_weights(), _table(shapes=((8, 8),), padding="VALID"),
                 cache=shared_cache)
    f = eng.submit(jnp.zeros((2, 5, CIN), jnp.float32))
    with pytest.raises(RejectedError, match="smaller than the 3x3 kernel"):
        f.result(timeout=0)
    assert eng.queue.depth() == 0
    # the same shape under SAME padding is a legitimate request
    eng2 = Engine(_weights(), _table(shapes=((8, 8),)), cache=shared_cache)
    f2 = eng2.submit(jnp.zeros((2, 5, CIN), jnp.float32))
    eng2.step()
    assert f2.result(timeout=0).y.shape == (2, 5, COUT)


def test_crop_output_raises_on_empty_instead_of_truncating():
    spec = _table(shapes=((8, 8),), padding="VALID").buckets[0].spec
    b = Bucket("b8x8v", 8, 8, spec)
    y = jnp.zeros((6, 6, COUT))
    with pytest.raises(ValueError, match="empty output crop"):
        BucketTable.crop_output(y, 2, 5, b)
    assert BucketTable.crop_output(y, 5, 5, b).shape == (3, 3, COUT)


# ----------------------------------------------------------------------
# warm-compile must not consume an armed fault budget
# ----------------------------------------------------------------------
def test_warm_compile_does_not_consume_fault_budget():
    with faults.inject({faults.DISPATCH: faults.FaultSpec(times=1)}) as fp:
        eng = Engine(_weights(), _table(shapes=((8, 8),)), max_batch=2,
                     cache=ServingCache(), round_batches=True,
                     warm_compile=True)
        assert fp.injected(faults.DISPATCH) == 0   # warm-up did not fire it
        f = eng.submit(_imgs([(8, 8)], seed=12)[0])
        assert eng.step() == 1
        assert fp.injected(faults.DISPATCH) == 1   # burst spent under load
        assert f.result(timeout=0).y.shape == (8, 8, COUT)
    assert eng.snapshot()["counters"]["dispatch_retries"] == 1


# ----------------------------------------------------------------------
# stale loop error must not survive a restart
# ----------------------------------------------------------------------
def test_stop_does_not_reraise_stale_loop_error(shared_cache, monkeypatch):
    eng = Engine(_weights(), _table(), max_batch=2, cache=shared_cache)
    orig = eng.queue.take_batch

    def boom(*a, **k):
        raise RuntimeError("transient formation failure")

    monkeypatch.setattr(eng.queue, "take_batch", boom)
    eng.start()
    deadline = time.perf_counter() + 5.0
    while eng.snapshot()["loop_errors"] == 0 \
            and time.perf_counter() < deadline:
        time.sleep(0.01)
    eng.stop()                                 # run 1 absorbed an error
    assert eng.last_loop_error is not None
    monkeypatch.setattr(eng.queue, "take_batch", orig)
    eng.start()                                # run 2 is clean
    f = eng.submit(_imgs([(8, 8)], seed=13)[0])
    assert eng.drain(timeout=10.0)
    assert f.result(timeout=1.0).y.shape == (8, 8, COUT)
    eng.stop(raise_on_error=True)              # must NOT re-raise run 1's
