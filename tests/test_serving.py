"""Serving-path edge cases: ring-buffer wrap, long-context state decode,
batched position vectors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build


def test_sliding_window_ring_buffer_wrap():
    """Decoding past the window length must match a full-cache model that
    applies the same window mask (the ring buffer holds exactly the last
    `window` keys)."""
    cfg = get_smoke_config("mixtral-8x7b")
    window = 8
    cfg = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32",
                           "sliding_window": window, "n_layers": 2,
                           "n_experts": 2, "n_experts_active": 2})
    # full-cache reference: same arch but cache length = seq (window mask
    # still applied inside decode via flash/window logic in forward)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 20                                   # > 2x window: buffer wraps
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, S)), jnp.int32)

    # teacher-forced decode through the ring buffer
    cache = model.init_cache(params, 1, S)
    assert cache["layers"]["k"].shape[2] == window  # ring, not full length
    ring_logits = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.full((1,), t, jnp.int32))
        ring_logits.append(lg[:, 0])
    ring = jnp.stack(ring_logits, axis=1)

    # reference: full forward (flash attention applies the window mask)
    full = model.forward(params, tokens)
    err = float(jnp.abs(full - ring).max())
    assert err < 1e-3, err


def test_mamba_long_decode_constant_memory():
    """SSM decode state is O(1): decoding 200 tokens keeps identical cache
    shapes and matches the chunked forward."""
    cfg = get_smoke_config("mamba2-1.3b")
    cfg = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32"})
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 200
    rng = np.random.RandomState(1)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, S)), jnp.int32)
    cache = model.init_cache(params, 1, S)
    shapes0 = jax.tree_util.tree_map(lambda a: a.shape, cache)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.full((1,), t, jnp.int32))
        outs.append(lg[:, 0])
    assert jax.tree_util.tree_map(lambda a: a.shape, cache) == shapes0
    full = model.forward(params, tokens)
    # compare a suffix (chunked SSD vs sequential recurrence, fp32)
    err = float(jnp.abs(full[:, -8:] - jnp.stack(outs[-8:], 1)).max())
    assert err < 5e-3, err


def test_batched_ragged_positions():
    """Per-sequence positions (continuous batching): sequences at different
    offsets decode exactly as they would alone."""
    cfg = get_smoke_config("qwen3-14b")
    cfg = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32"})
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    S = 10
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, S)), jnp.int32)

    # sequence 0 alone
    cache1 = model.init_cache(params, 1, S)
    solo = []
    for t in range(S):
        lg, cache1 = model.decode_step(params, cache1, toks[0:1, t:t + 1],
                                       jnp.full((1,), t, jnp.int32))
        solo.append(lg[0, 0])

    # batched with a second sequence offset by staggered starts
    cache2 = model.init_cache(params, 2, S)
    batched = []
    for t in range(S):
        lg, cache2 = model.decode_step(
            params, cache2, toks[:, t:t + 1],
            jnp.asarray([t, t], jnp.int32))
        batched.append(lg[0, 0])
    err = float(jnp.abs(jnp.stack(solo) - jnp.stack(batched)).max())
    assert err < 1e-4, err
