"""repro.analysis.lint: architecture-invariant linter."""
import pathlib
import textwrap

import pytest

from repro.analysis import lint


def _codes(findings):
    return [f.code for f in findings]


def test_installed_tree_is_clean():
    findings = lint.run_lint(lint.source_root())
    assert findings == [], [str(f) for f in findings]


def test_arch001_kernel_import_outside_allowlist():
    src = "from repro.kernels import sfc_fused as sf\n"
    assert _codes(lint.lint_source(src, "serve/batcher.py")) == ["ARCH001"]
    assert _codes(lint.lint_source(src, "train/trainer.py")) == ["ARCH001"]
    assert _codes(lint.lint_source(
        "import repro.kernels.ops\n", "models/cnn.py")) == ["ARCH001"]
    assert _codes(lint.lint_source(
        "from repro.distributed.conv_spmd import SpmdPallasBackend\n",
        "serve/engine.py")) == ["ARCH001"]
    # allowlisted layers may
    for ok in ("api/backends.py", "kernels/ops.py",
               "analysis/kernel_checks.py", "distributed/conv_spmd.py",
               "testing.py"):
        assert lint.lint_source(src, ok) == [], ok
    # importing the sanctioned seams is fine anywhere
    assert lint.lint_source("from repro.api import plan\n",
                            "serve/engine.py") == []
    assert lint.lint_source("from repro.distributed import sharding\n",
                            "train/trainer.py") == []


def test_time001_wall_clock_on_serving_paths():
    src = "import time\nt0 = time.time()\n"
    assert _codes(lint.lint_source(src, "serve/engine.py")) == ["TIME001"]
    # perf_counter is the sanctioned clock; non-serve paths may wall-clock
    assert lint.lint_source("import time\nt = time.perf_counter()\n",
                            "serve/engine.py") == []
    assert lint.lint_source(src, "train/trainer.py") == []


def test_exc001_bare_except():
    src = textwrap.dedent("""
        try:
            x = 1
        except:
            pass
    """)
    assert _codes(lint.lint_source(src, "quant/ptq.py")) \
        == ["EXC001"]


def test_exc002_silent_broad_except():
    silent = textwrap.dedent("""
        try:
            x = 1
        except Exception:
            pass
    """)
    assert _codes(lint.lint_source(silent, "serve/engine.py")) == ["EXC002"]
    # logging the failure is allowed
    loud = textwrap.dedent("""
        try:
            x = 1
        except Exception:
            log("absorbed")
    """)
    assert lint.lint_source(loud, "serve/engine.py") == []
    # narrow handlers are allowed even when silent
    narrow = textwrap.dedent("""
        try:
            x = 1
        except KeyError:
            pass
    """)
    assert lint.lint_source(narrow, "serve/engine.py") == []


def test_reg001_registration_outside_seams():
    src = "register_algorithm('x', make)\n"
    assert _codes(lint.lint_source(src, "models/cnn.py")) == ["REG001"]
    assert _codes(lint.lint_source(
        "registry.register_backend('gpu', b)\n",
        "launch/serve.py")) == ["REG001"]
    assert lint.lint_source(src, "api/registry.py") == []
    assert lint.lint_source("register_backend('pallas', b)\n",
                            "api/backends.py") == []


def test_cost001_costmodel_geometry_surface():
    # the sanctioned surface: FusedGeometry/fused_geometry only
    ok = "from repro.kernels.sfc_fused import fused_geometry\n"
    assert lint.lint_source(ok, "api/costmodel.py") == []
    assert lint.lint_source(
        "from repro.kernels.sfc_fused import FusedGeometry\n",
        "api/costmodel.py") == []
    # kernel-internal resource helpers are banned inside costmodel.py
    assert _codes(lint.lint_source(
        "from repro.kernels.sfc_fused import fused_vmem_bytes\n",
        "api/costmodel.py")) == ["COST001"]
    assert _codes(lint.lint_source(
        "import repro.kernels.sfc_fused\n",
        "api/costmodel.py")) == ["COST001"]
    assert _codes(lint.lint_source(
        "b = sf.VMEM_LIMIT_BYTES\n", "api/costmodel.py")) == ["COST001"]
    assert _codes(lint.lint_source(
        "r = auto_rows_per_step(g)\n", "api/costmodel.py")) == ["COST001"]
    # the rule is scoped to costmodel.py: other api files may (they are
    # already ARCH-allowlisted and not the cost model)
    assert lint.lint_source(
        "b = sf.VMEM_LIMIT_BYTES\n", "api/backends.py") == []


def test_syntax_error_is_reported_not_raised():
    findings = lint.lint_source("def broken(:\n", "core/x.py")
    assert _codes(findings) == ["LNT000"]


def test_run_lint_over_tmp_tree(tmp_path):
    pkg = tmp_path / "repro"
    (pkg / "serve").mkdir(parents=True)
    (pkg / "api").mkdir()
    (pkg / "serve" / "bad.py").write_text(
        "import time\nfrom repro.kernels import ops\nt = time.time()\n")
    (pkg / "api" / "good.py").write_text(
        "from repro.kernels import ops\n")
    findings = lint.run_lint(tmp_path)
    assert sorted(_codes(findings)) == ["ARCH001", "TIME001"]
    assert all(f.where.startswith("serve/bad.py") for f in findings)


def test_finding_str_has_code_and_location():
    f = lint.lint_source("x = time.time()\n", "serve/a.py")[0]
    s = str(f)
    assert "TIME001" in s and "serve/a.py:1" in s and "ERROR" in s
