"""Checkpointing: atomic round-trip, corruption detection, retention,
elastic restore across device layouts."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "params": {"w": jnp.asarray(rng.randn(8, 16), jnp.float32),
                   "b": jnp.asarray(rng.randn(16), jnp.bfloat16)},
        "opt": {"mu": {"w": jnp.asarray(rng.randn(8, 16), jnp.float32)}},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(7, tree, blocking=True)
    restored, step = ck.restore(tree)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    assert ck.latest_step() == 4
    kept = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(kept) == 2 and kept[-1].endswith("4".zfill(12))


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=True)
    d = next(pathlib.Path(tmp_path).glob("step_*"))
    blob = (d / "arrays.npz").read_bytes()
    (d / "arrays.npz").write_bytes(b"CORR" + blob[4:])
    with pytest.raises(IOError):
        ck.restore(_tree())


def test_elastic_restore_resharding(tmp_path):
    """Save replicated, restore sharded onto the host mesh (different
    layout) — values identical."""
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(3, tree, blocking=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    shardings = jax.tree_util.tree_map(
        lambda a: NamedSharding(mesh, P(*([None] * a.ndim))), tree)
    restored, step = ck.restore(tree, shardings=shardings)
    np.testing.assert_array_equal(
        np.asarray(tree["params"]["w"]), np.asarray(restored["params"]["w"]))


def test_resume_from_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        Checkpointer(str(tmp_path)).restore(_tree())
