"""SPMD conv backend parity: ``pallas_spmd`` vs single-device ``pallas``.

Every test asserts BIT-identity (``==``, not allclose): the sharding
layout (batch over 'data', C_out over 'model') introduces no cross-shard
reduction, so not a single float may accumulate in a different order.

Needs >= 2 devices — the tier-1 single-device run skips this module; CI
runs it under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConvSpec, get_backend, plan
from repro.api.tuning import (DEFAULT_STAGED, KernelConfig,
                              calibrate_act_scale)
from repro.launch.mesh import make_forced_host_mesh
from repro.quant.fake_quant import INT8_FREQ

N_DEV = len(jax.devices())
pytestmark = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# (data, model): exercise both axes when the host has enough devices
MESH = (2, 2) if N_DEV >= 4 else (2, 1)


@pytest.fixture
def spmd():
    backend = get_backend("pallas_spmd")

    def use(shape=MESH):
        backend.set_mesh(make_forced_host_mesh(shape))
        return backend

    yield use
    backend.set_mesh(None)


def _data(b=4, hw=12, cin=16, cout=32, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(b, hw, hw, cin), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, cin, cout) * 0.2, jnp.float32)
    return x, w


def _int8_plans(x, w, padding="SAME", algo="sfc6_6"):
    spec = ConvSpec.for_conv2d(x.shape, w.shape, padding=padding,
                               quant=INT8_FREQ)
    p_s = plan(spec, backend="pallas_spmd", algo=algo)
    p_1 = plan(spec, backend="pallas", algo=algo)
    act = calibrate_act_scale(x, p_1.algorithm, spec.quant, padding)
    return p_s, p_1, act


@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_int8_fused_parity(spmd, padding):
    """Fused int8 datapath, batch+C_out sharded, SAME and VALID."""
    spmd()
    x, w = _data()
    p_s, p_1, act = _int8_plans(x, w, padding)
    y_s = p_s.apply(x, p_s.prepare_weights(w, act_scale=act))
    y_1 = p_1.apply(x, p_1.prepare_weights(w, act_scale=act))
    assert y_s.shape == y_1.shape
    assert bool(jnp.all(y_s == y_1))


def test_int8_staged_parity(spmd):
    """The staged three-kernel pipeline shards identically (a measured
    KernelConfig riding the plan must not break SPMD dispatch)."""
    spmd()
    x, w = _data(seed=1)
    p_s, p_1, act = _int8_plans(x, w)
    p_s = dataclasses.replace(p_s, config=DEFAULT_STAGED)
    p_1 = dataclasses.replace(p_1, config=DEFAULT_STAGED)
    y_s = p_s.apply(x, p_s.prepare_weights(w, act_scale=act))
    y_1 = p_1.apply(x, p_1.prepare_weights(w, act_scale=act))
    assert bool(jnp.all(y_s == y_1))


def test_fp_fast_parity(spmd):
    """fp transform-domain path (no quantization), both axes sharded."""
    spmd()
    x, w = _data(seed=2)
    spec = ConvSpec.for_conv2d(x.shape, w.shape)
    y_s = plan(spec, backend="pallas_spmd", algo="sfc6_6").apply(x, w)
    y_1 = plan(spec, backend="pallas", algo="sfc6_6").apply(x, w)
    assert bool(jnp.all(y_s == y_1))


def test_bias_sharded_with_cout(spmd):
    spmd()
    x, w = _data(seed=3)
    bias = jnp.arange(w.shape[-1], dtype=jnp.float32)
    p_s, p_1, act = _int8_plans(x, w)
    y_s = p_s.apply(x, p_s.prepare_weights(w, act_scale=act), bias=bias)
    y_1 = p_1.apply(x, p_1.prepare_weights(w, act_scale=act), bias=bias)
    assert bool(jnp.all(y_s == y_1))


@pytest.mark.skipif(N_DEV < 4, reason="needs a >1 model axis")
def test_nondivisible_axes_sanitized(spmd):
    """B=3 on a 2-way data axis and C_out=18 on a 4-way model axis: both
    drop to replication (sanitize_pspec) instead of erroring, and the
    result stays bit-identical."""
    backend = spmd((2, 4) if N_DEV >= 8 else (1, 4))
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(3, 10, 10, 16), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 16, 18) * 0.2, jnp.float32)
    p_s, p_1, act = _int8_plans(x, w)
    prep_s = p_s.prepare_weights(w, act_scale=act)
    # 18 % 4 != 0: the prepared weights must have degraded to replication
    assert prep_s.wq.sharding.is_fully_replicated
    y_s = p_s.apply(x, prep_s)
    y_1 = p_1.apply(x, p_1.prepare_weights(w, act_scale=act))
    assert bool(jnp.all(y_s == y_1))
    assert backend.mesh.shape["model"] == 4


def test_direct_path_parity(spmd):
    """1x1 stride-2 (a ResNet projection shortcut) stays on the direct
    path, still sharded (batch + output channels of the XLA conv are
    independent)."""
    spmd()
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(4, 12, 12, 16), jnp.float32)
    w = jnp.asarray(rng.randn(1, 1, 16, 32) * 0.2, jnp.float32)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2)
    p_s = plan(spec, backend="pallas_spmd")
    p_1 = plan(spec, backend="pallas")
    assert p_s.path == "direct"
    y_s = p_s.apply(x, w)
    y_1 = p_1.apply(x, w)
    assert bool(jnp.all(y_s == y_1))


def test_lowered_polyphase_parity(spmd):
    """A lowered stride-2 plan on ``pallas_spmd``: every polyphase
    sub-plan inherits the backend, so each sub-conv is its own
    shard_map'd fused kernel — bit-identical to the single-device
    composite (the phase sum adds floats in the same order)."""
    spmd()
    x, w = _data(seed=9)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=2, quant=INT8_FREQ)
    p_s = plan(spec, backend="pallas_spmd", algo="sfc4_4_r2")
    p_1 = plan(spec, backend="pallas", algo="sfc4_4_r2")
    assert p_s.path == "lowered" == p_1.path
    assert all(sp.backend == "pallas_spmd" for sp in p_s.sub_plans)
    y_s = p_s.apply(x, p_s.prepare_weights(w, act_scale=p_s.calibrate(x)))
    y_1 = p_1.apply(x, p_1.prepare_weights(w, act_scale=p_1.calibrate(x)))
    assert y_s.shape == y_1.shape
    assert bool(jnp.all(y_s == y_1))


def test_depthwise_channel_sharded_parity(spmd):
    """2-D depthwise shards its single channel axis over 'model' on the
    input AND the weights (elementwise path: no contraction to split) —
    bit-identical to single-device for int8 and fp."""
    spmd()
    rng = np.random.RandomState(10)
    x = jnp.asarray(rng.randn(4, 12, 12, 16), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 1, 16) * 0.3, jnp.float32)
    for quant in (INT8_FREQ, None):
        kw = {"quant": quant} if quant else {}
        spec = ConvSpec.for_conv2d_depthwise(x.shape, w.shape, **kw)
        p_s = plan(spec, backend="pallas_spmd", algo="sfc6_6")
        p_1 = plan(spec, backend="pallas", algo="sfc6_6")
        assert p_s.path == "fast"
        if quant:
            act = calibrate_act_scale(x, p_1.algorithm, spec.quant, "SAME")
            y_s = p_s.apply(x, p_s.prepare_weights(w, act_scale=act))
            y_1 = p_1.apply(x, p_1.prepare_weights(w, act_scale=act))
        else:
            y_s = p_s.apply(x, w)
            y_1 = p_1.apply(x, w)
        assert bool(jnp.all(y_s == y_1))


@pytest.mark.skipif(MESH[1] < 2, reason="needs a >1 model axis")
def test_prepared_weights_device_sharded(spmd):
    """prepare_weights places wq/w_scale C_out-sharded on the mesh (the
    offline half of the SPMD story); scales stay replicated per shard."""
    spmd()
    x, w = _data()
    p_s, _, act = _int8_plans(x, w)
    prep = p_s.prepare_weights(w, act_scale=act)
    cout = w.shape[-1]
    shard = prep.wq.addressable_shards[0].data
    assert shard.shape[-1] == cout // MESH[1]
    assert prep.w_scale.addressable_shards[0].data.shape[-1] \
        == cout // MESH[1]
    assert prep.act_scale.sharding.is_fully_replicated
    # memoized: the placed copy is returned on re-prepare
    assert p_s.prepare_weights(w, act_scale=act) is prep


def test_rank1_depthwise_delegates(spmd):
    """rank-1 depthwise falls through to the (replicated) reference impl."""
    spmd()
    rng = np.random.RandomState(6)
    x = jnp.asarray(rng.randn(2, 24, 8), jnp.float32)
    w = jnp.asarray(rng.randn(4, 8) * 0.3, jnp.float32)
    spec = ConvSpec.for_conv1d_depthwise(x.shape, w.shape)
    y_s = plan(spec, backend="pallas_spmd", algo="auto").apply(x, w)
    y_1 = plan(spec, backend="pallas", algo="auto").apply(x, w)
    assert bool(jnp.all(y_s == y_1))


def test_batched_double_buffered_config_rides_shards(spmd):
    """A KernelConfig with the batched multi-tile-row grid and DMA
    double-buffering rides the plan through shard_map: each shard runs
    the grouped kernel on its local batch, bit-identical to the
    ungrouped single-device fused path."""
    spmd()
    x, w = _data(b=4, hw=8, seed=8)          # nH=2: shards fold images
    p_s, p_1, act = _int8_plans(x, w)
    cfg = KernelConfig(datapath="fused", rows_per_step=4,
                       double_buffer=True)
    p_s = dataclasses.replace(p_s, config=cfg)
    y_s = p_s.apply(x, p_s.prepare_weights(w, act_scale=act))
    y_1 = p_1.apply(x, p_1.prepare_weights(w, act_scale=act))
    assert bool(jnp.all(y_s == y_1))


def test_spmd_under_jit(spmd):
    """The sharded apply composes with an outer jit (the serving shape)."""
    spmd()
    x, w = _data(seed=7)
    p_s, p_1, act = _int8_plans(x, w)
    prep = p_s.prepare_weights(w, act_scale=act)
    y_jit = jax.jit(lambda a: p_s.apply(a, prep))(x)
    y_1 = p_1.apply(x, p_1.prepare_weights(w, act_scale=act))
    assert bool(jnp.all(y_jit == y_1))
