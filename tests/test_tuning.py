"""Tuning-cache concurrency + fused full-K config plumbing.

Regression coverage for two PR-3 fixes: (a) ``record()`` used to fetch
the store and mutate/save it under *separate* lock acquisitions, so a
concurrent ``clear()``/``set_cache_path()`` left it mutating an orphaned
dict the save never persisted; (b) the fused backend path used to coerce
``KernelConfig.k_block=None`` ("full K") to 128, so autotuned full-K
configs silently ran k-blocked.
"""
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConvSpec, plan, tuning
from repro.api.tuning import KernelConfig, calibrate_act_scale
from repro.quant.fake_quant import INT8_FREQ


def test_record_survives_concurrent_clear(monkeypatch):
    """A completed record() must always be on disk, whatever clear()
    interleaving happened — the load->mutate->snapshot span is atomic.

    The patched spec_key widens the historical race window (store fetched,
    then a sleep, then the mutation) to make the old bug near-certain."""
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=8, out_channels=8,
                    spatial=(12, 12))
    real_key = tuning.spec_key

    def slow_key(*a, **k):
        time.sleep(0.03)
        return real_key(*a, **k)

    monkeypatch.setattr(tuning, "spec_key", slow_key)
    stop = threading.Event()

    def clearer():
        while not stop.is_set():
            tuning.clear()
            time.sleep(0.003)

    t = threading.Thread(target=clearer)
    t.start()
    try:
        for i in range(4):
            tuning.record(spec, "pallas", f"warm{i}", 0.5)
        tuning.record(spec, "pallas", "final", 1.25,
                      KernelConfig(datapath="fused", k_block=None))
    finally:
        stop.set()
        t.join()
    with open(tuning.cache_path()) as f:
        persisted = json.load(f)
    entries = {}
    for per_spec in persisted.values():
        entries.update(per_spec)
    # the last record can never be lost to a concurrent clear (clear only
    # drops the in-memory store; the file write snapshots the mutation)
    assert entries["final"]["time_s"] == 1.25
    assert entries["final"]["config"]["k_block"] is None


def test_record_roundtrips_config_and_lookup():
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=16, out_channels=16,
                    spatial=(10, 10), quant=INT8_FREQ)
    cfg = KernelConfig(datapath="fused", k_block=None, cout_block=64)
    tuning.record(spec, "pallas", "sfc4_4", 2e-3, cfg)
    got = tuning.get_config(spec, "pallas", "sfc4_4")
    assert got == cfg and got.k_block is None
    assert tuning.lookup(spec, "pallas")["sfc4_4"]["time_s"] == 2e-3


def test_old_cache_entries_survive_new_spec_fields(tmp_path):
    """Timing-cache entries written before the lowering PR (no ``groups``
    field, no 2-D ``depthwise``, configs without the newer knobs, plus
    unknown future keys) must keep loading and resolving for the specs
    they keyed — the ``KernelConfig.from_json`` tolerance pattern, now
    extended to ``spec_key`` (non-default-only tokens)."""
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=16, out_channels=16,
                    spatial=(10, 10), quant=INT8_FREQ)
    # the pre-PR key literally had no groups/depthwise tokens: today's
    # key for a default (dense, groups=1) spec must be identical
    old_key = (f"r2k3s1pSAMEci16co16sp(10, 10)"
               f"qa8w8frequency-channel+frequency|pallas"
               f"|{jax.default_backend()}|i1")
    assert tuning.spec_key(spec, "pallas") == old_key
    old_cache = {old_key: {
        # config written by PR 2: no rows_per_step/double_buffer fields,
        # plus a key from some future version
        "sfc4_4": {"time_s": 1.5e-3,
                   "config": {"datapath": "staged", "tile_block": 8,
                              "chan_block": 128, "k_block": 64,
                              "cout_block": 128, "future_knob": True}},
        "sfc6_6": {"time_s": 2.5e-3},
        "direct": {"time_s": 3.0e-3},
    }}
    path = tmp_path / "old_tuning.json"
    path.write_text(json.dumps(old_cache))
    tuning.set_cache_path(str(path))
    try:
        assert tuning.lookup(spec, "pallas")["sfc4_4"]["time_s"] == 1.5e-3
        cfg = tuning.get_config(spec, "pallas", "sfc4_4")
        assert cfg.datapath == "staged" and cfg.k_block == 64
        # missing knobs default, unknown knobs drop
        assert cfg.rows_per_step == KernelConfig().rows_per_step
        assert cfg.double_buffer is False
        # the measured entry governs planning, as before the refactor
        assert plan(spec, backend="pallas", algo="auto").algo_name == "sfc4_4"
        # non-default new fields key DIFFERENTLY (no false sharing with
        # old entries): grouped/depthwise specs miss this cache entry
        import dataclasses as dc
        g = dc.replace(spec, groups=2)
        dw = dc.replace(spec, depthwise=True, groups=1)
        assert tuning.spec_key(g, "pallas") != old_key
        assert tuning.spec_key(dw, "pallas") != old_key
        assert tuning.lookup(g, "pallas") == {}
        assert tuning.lookup(dw, "pallas") == {}
    finally:
        tuning.set_cache_path(None)


def _int8_case(cin=24, cout=8, hw=10, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(1, hw, hw, cin), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, cin, cout) * 0.2, jnp.float32)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)
    return x, w, spec


def test_full_k_fused_config_reaches_kernel(monkeypatch):
    """An autotuned k_block=None config must reach sfc_fused_conv2d as
    None (full K), not be coerced back to the default block size."""
    import repro.kernels.sfc_fused as sf
    x, w, spec = _int8_case()
    tuning.record(spec, "pallas", "sfc4_4", 1e-3,
                  KernelConfig(datapath="fused", k_block=None))
    p = plan(spec, backend="pallas", algo="sfc4_4")
    assert p.config is not None and p.config.k_block is None
    calls = []
    real = sf.sfc_fused_conv2d

    def spy(*args, **kwargs):
        calls.append(kwargs)
        return real(*args, **kwargs)

    monkeypatch.setattr(sf, "sfc_fused_conv2d", spy)
    act = calibrate_act_scale(x, p.algorithm, spec.quant)
    y = p.apply(x, p.prepare_weights(w, act_scale=act))
    assert calls and calls[0]["k_block"] is None
    # full-K execution matches the reference int8 simulation exactly
    p_ref = plan(spec, backend="reference", algo="sfc4_4")
    y_ref = p_ref.apply(x, p_ref.prepare_weights(w, act_scale=act))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_kernel_full_k_matches_blocked():
    """k_block=None (single k-block) is bit-exact vs the k-blocked grid."""
    from repro.api import get_algorithm
    from repro.kernels.sfc_fused import sfc_fused_conv2d
    x, w, spec = _int8_case(seed=1)
    p = plan(spec, backend="reference", algo="sfc4_4")
    algo = get_algorithm("sfc4_4")
    act = calibrate_act_scale(x, algo, spec.quant)
    prep = p.prepare_weights(w, act_scale=act)
    y_full = sfc_fused_conv2d(x, prep.wq, prep.act_scale, prep.w_scale,
                              algo, k_block=None)
    y_blocked = sfc_fused_conv2d(x, prep.wq, prep.act_scale, prep.w_scale,
                                 algo, k_block=8)
    assert bool(jnp.all(y_full == y_blocked))


def test_write_failure_warns_once_and_store_still_serves(monkeypatch):
    """Regression: ``_write`` used to swallow OSError silently — a
    read-only host re-tuned from scratch every process with no trace.
    Now the first failed persist warns (exactly once, not per record),
    the in-memory store keeps serving, and a later successful write
    re-arms the warning."""
    import os
    import warnings

    spec = ConvSpec(rank=2, kernel_size=3, in_channels=8, out_channels=8,
                    spatial=(12, 12))
    monkeypatch.setattr(tuning, "_WRITE_WARNED", False)
    real_replace = os.replace
    fail = [True]

    def maybe_deny(src, dst):
        if fail[0]:
            raise OSError(30, "Read-only file system")
        return real_replace(src, dst)

    monkeypatch.setattr(tuning.os, "replace", maybe_deny)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        tuning.record(spec, "pallas", "a1", 1.0)
        tuning.record(spec, "pallas", "a2", 2.0)   # second failure: silent
    hits = [w for w in caught if issubclass(w.category, RuntimeWarning)
            and "not persisted" in str(w.message)]
    assert len(hits) == 1
    # the in-memory store still serves every recorded measurement
    assert tuning.lookup(spec, "pallas")["a1"]["time_s"] == 1.0
    assert tuning.lookup(spec, "pallas")["a2"]["time_s"] == 2.0
    assert not os.path.exists(tuning.cache_path())  # nothing reached disk
    # a successful write re-arms the warning for the NEXT failure
    fail[0] = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        tuning.record(spec, "pallas", "a3", 3.0)
        fail[0] = True
        tuning.record(spec, "pallas", "a4", 4.0)
    hits = [w for w in caught if issubclass(w.category, RuntimeWarning)
            and "not persisted" in str(w.message)]
    assert len(hits) == 1
    assert os.path.exists(tuning.cache_path())      # the a3 write landed


# ---------------------------------------------------------------------------
# adaptive timing protocol
# ---------------------------------------------------------------------------
def _counting_fn(calls):
    def fn():
        calls[0] += 1
        return jnp.zeros(())
    return fn


def test_time_fn_fixed_protocol_when_floor_disabled():
    """min_total_s=0 restores the historical protocol exactly: one
    warmup call plus ``reps`` timed calls."""
    calls = [0]
    tuning.time_fn(_counting_fn(calls), reps=3, min_total_s=0.0)
    assert calls[0] == 1 + 3


def test_time_fn_adaptive_batches_cap_at_max_reps():
    """A near-instant fn can never reach the floor; the doubling batches
    must stop exactly at max_reps timed calls (warmup excluded)."""
    calls = [0]
    tuning.time_fn(_counting_fn(calls), reps=3, min_total_s=1e9,
                   max_reps=17)
    assert calls[0] == 1 + 17          # batches 3+3+6+5, capped


def test_time_fn_stops_once_floor_crossed():
    """A slow fn that crosses the floor in its first batch is not timed
    again — the adaptive loop only extends *fast* kernels."""
    calls = [0]
    counting = _counting_fn(calls)

    def slow():
        time.sleep(0.004)
        return counting()

    t = tuning.time_fn(slow, reps=3, min_total_s=0.01)
    assert calls[0] == 1 + 3
    assert t >= 0.003                  # mean per-call, not total
