"""Attention unit tests: flash custom-VJP vs naive, windows, head padding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.attention import (expand_kv_padded, flash_attention,
                                    padded_heads)


def naive(qg, k, v, causal=True, window=0):
    B, Sq, Hkv, g, D = qg.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq) if causal else \
        jnp.ones((Sq, Sk), bool)
    if window:
        qpos = jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqhgk,bkhd->bqhgd", p, v)


@pytest.mark.parametrize("Hkv,g,Dv,window,chunk", [
    (2, 2, 16, 0, 8), (4, 1, 8, 0, 16), (2, 2, 16, 7, 8), (1, 4, 32, 0, 33)])
def test_flash_matches_naive_fwd_and_grad(Hkv, g, Dv, window, chunk):
    rng = np.random.RandomState(0)
    B, S, D = 2, 33, 16
    qg = jnp.asarray(rng.randn(B, S, Hkv, g, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Hkv, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Hkv, Dv), jnp.float32)

    def f(qg, k, v):
        return jnp.sum(jnp.sin(flash_attention(
            qg, k, v, causal=True, window=window, chunk=chunk)))

    def fn(qg, k, v):
        return jnp.sum(jnp.sin(naive(qg, k, v, causal=True, window=window)))

    np.testing.assert_allclose(float(f(qg, k, v)), float(fn(qg, k, v)),
                               rtol=1e-4)
    g1 = jax.grad(f, argnums=(0, 1, 2))(qg, k, v)
    g2 = jax.grad(fn, argnums=(0, 1, 2))(qg, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_padded_heads_arithmetic():
    class C:
        pass
    assert padded_heads(C(), 40) == 48
    assert padded_heads(C(), 24) == 32
    assert padded_heads(C(), 32) == 32
    assert padded_heads(C(), 6) == 6      # below one shard: replicated


def test_padded_heads_do_not_change_output():
    """A model whose head count pads (phi4: 24 -> 32) computes the same
    function as one with no padding (the zero wo rows kill dead heads)."""
    cfg = get_smoke_config("phi4-mini-3.8b")
    cfg = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32"})
    from repro.models.attention import attention_block, init_attention
    p = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.RandomState(0).randn(2, 16, cfg.d_model),
                    jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    out = attention_block(p, cfg, x, pos)
    # reference: strip padding and compute densely
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dkh->bskh", x, p["wq"][:, :hq])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    from repro.models.layers import apply_rope
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    g = hq // hkv
    o = naive(q.reshape(2, 16, hkv, g, hd), k, v, causal=True)
    ref = jnp.einsum("bskh,khd->bsd", o.reshape(2, 16, hq, hd),
                     p["wo"][:hq])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
