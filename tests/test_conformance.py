"""Cross-backend differential conformance suite for the conv kernel zoo.

Every test routes through the one shared oracle
(``repro.testing.assert_conv_conformance``): int8 paths must be
bit-identical across the staged pipeline and every fused-kernel
configuration (k-blocking, C_out blocking, the batched multi-tile-row
grid, DMA double-buffering), and fp-close to the reference backend's int8
simulation; fp paths are held to the API epsilon.

Three tiers:

  * a small deterministic corpus (tier-1: runs on every ``pytest -q``) —
    the regression net for the shapes that have bitten before (ragged
    channels, odd spatial, VALID, image folding);
  * an exhaustive deterministic sweep marked ``kernels`` (CI's kernel
    job; minutes of interpret-mode wall-clock);
  * a ``hypothesis`` fuzz layer marked ``slow`` that samples the full
    ConvSpec space — H/W 3..33, ragged C_in/C_out, batch 1..4, every
    registered algorithm, SAME/VALID, k_block/rows_per_step grids.

The VMEM-budget helper that sizes the batched grid is regression-tested
here against the numbers documented in ``sfc_fused.py``'s docstring.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import ConvSpec
from repro.core.generator import generate_sfc
from repro.kernels import sfc_fused as sf
from repro.quant.fake_quant import FP32, INT4_FREQ, INT8_FREQ
from repro.testing import assert_conv_conformance

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # container without the test extra: the
    HAVE_HYPOTHESIS = False   # deterministic corpus still runs

ALGOS = ["sfc4_4", "sfc6_6", "sfc6_7"]


def _case(b, h, w_, cin, cout, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(b, h, w_, cin), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, cin, cout) * 0.2, jnp.float32)
    return x, w


# ---------------------------------------------------------------------------
# tier-1 deterministic corpus (fast: one algo/variant slice per case)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("algo_name", ALGOS)
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_conformance_core(algo_name, padding):
    """The PR-2 parity matrix, now through the shared oracle (batched +
    double-buffered variants included)."""
    x, w = _case(2, 13, 13, 16, 8)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, padding=padding,
                               quant=INT8_FREQ)
    assert_conv_conformance(x, w, spec, algo_name,
                            variants=(dict(k_block=128, rows_per_step=1),
                                      dict(k_block=64, rows_per_step=2),
                                      dict(rows_per_step=None,
                                           double_buffer=True)))


@pytest.mark.parametrize("shape,cout,rps", [
    ((1, 9, 11, 5), 7, 2),      # odd spatial, tiny ragged channels
    ((1, 17, 13, 19), 21, 4),   # C_in/C_out not block multiples
    ((4, 7, 7, 3), 5, 4),       # nH < rows_per_step: folds whole images
    ((3, 6, 6, 9), 4, 8),       # group exceeds B*nH: clamps to divisors
])
def test_conformance_ragged_and_folded(shape, cout, rps):
    x, w = _case(*shape, cout, seed=1)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)
    assert_conv_conformance(
        x, w, spec, "sfc6_6",
        variants=(dict(k_block=None, rows_per_step=rps),
                  dict(k_block=8, cout_block=16, rows_per_step=rps,
                       double_buffer=True)))


def test_conformance_fp_and_direct_paths():
    """fp spec (no shared integer grid: epsilon only) and a stride-2 spec
    that degrades to the direct path on both backends."""
    x, w = _case(2, 12, 12, 8, 6, seed=2)
    assert_conv_conformance(x, w, ConvSpec.for_conv2d(x.shape, w.shape,
                                                      quant=FP32), "sfc6_6")
    assert_conv_conformance(
        x, w, ConvSpec.for_conv2d(x.shape, w.shape, stride=2,
                                  quant=INT8_FREQ), allow_degraded=True)


def test_conformance_int4_policy():
    """Sub-int8 policies clip on their own grid across every variant."""
    x, w = _case(1, 12, 12, 12, 6, seed=3)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT4_FREQ)
    assert_conv_conformance(x, w, spec, "sfc6_6",
                            variants=(dict(rows_per_step=2),
                                      dict(rows_per_step=None,
                                           double_buffer=True)))


def test_conformance_xq_cache_disabled(monkeypatch):
    """Batched + double-buffered with the strip cache too small to use:
    the every-step DMA consumption schedule must stay bit-identical."""
    monkeypatch.setattr(sf, "XQ_CACHE_BYTES", 0)
    x, w = _case(1, 10, 16, 70, 48, seed=4)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)
    assert_conv_conformance(
        x, w, spec, "sfc6_6",
        variants=(dict(k_block=32, cout_block=16, rows_per_step=2,
                       double_buffer=True),))


# ---------------------------------------------------------------------------
# VMEM budget helper vs the documented worst case
# ---------------------------------------------------------------------------
def test_vmem_budget_matches_docstring_worst_case():
    """fused_vmem_bytes reproduces the sfc_fused.py budget table: VGG-16
    224x224 with SFC-6(7x7,3x3) at default blocks stays under 16 MiB."""
    algo = generate_sfc(6, 7, 3)         # SFC-6(7x7,3x3): t=12, M=7, L=9
    assert (algo.t, algo.M, algo.L) == (12, 7, 9)
    nW, Wp, kb, cb, n_k = 32, 226, 128, 128, 4      # 224x224, C_in 512
    total = sf.fused_vmem_bytes(algo, nW, Wp, kb, cb, n_k=n_k,
                                cache_xq=True)
    # the docstring's itemized terms
    strip = 9 * 226 * 128 * 4
    row_xform = 12 * 226 * 128 * 4
    xq = 144 * 32 * 128
    xq_cache = 4 * 144 * 32 * 128
    weights = 144 * 128 * 128
    acc = 144 * 32 * 128 * 4
    out = 7 * 7 * 32 * 128 * 4
    assert total == (strip + row_xform + xq + xq_cache + weights + acc
                     + out)
    assert total <= sf.VMEM_LIMIT_BYTES
    assert xq_cache <= sf.XQ_CACHE_BYTES
    # double-buffering adds one extra strip slot and still fits
    assert sf.fused_vmem_bytes(algo, nW, Wp, kb, cb, n_k=n_k,
                               cache_xq=True, double_buffer=True) \
        == total + strip <= sf.VMEM_LIMIT_BYTES


def test_auto_rows_never_exceeds_budget():
    """auto_rows_per_step's pick always fits; small images batch up,
    the 224x224 worst case does not blow the ceiling."""
    algo = generate_sfc(6, 7, 3)
    for (B, nH, nW, Wp) in [(1, 1, 1, 9), (1, 2, 2, 16), (4, 2, 2, 16),
                            (1, 32, 32, 226), (8, 32, 32, 226)]:
        g = sf.auto_rows_per_step(algo, B, nH, nW, Wp, 128, 128, n_k=4,
                                  n_o=4)
        imgs, rows = sf.grouping(B, nH, g)
        cols = imgs * rows * nW
        cache = sf.cache_fits(4, 4, algo.t ** 2, cols, 128)
        assert sf.fused_vmem_bytes(
            algo, nW, Wp, 128, 128, n_k=4, rows=rows, imgs=imgs,
            cache_xq=cache) <= sf.VMEM_LIMIT_BYTES
        if nH <= 2 and B == 1:
            assert g >= 2, "small images must batch tile-rows"


def test_grouping_folds_only_divisor_images():
    assert sf.grouping(4, 2, 1) == (1, 1)
    assert sf.grouping(4, 2, 2) == (1, 2)       # rows first
    assert sf.grouping(4, 2, 4) == (2, 2)       # then whole images
    assert sf.grouping(4, 2, 8) == (4, 2)
    assert sf.grouping(3, 1, 4) == (3, 1)       # divisor of B only
    assert sf.grouping(3, 2, 8) == (3, 2)
    assert sf.grouping(5, 1, 4) == (1, 1)       # 5 has no divisor <= 4 but 1
    assert sf.grouping(1, 3, 8) == (1, 3)       # rows clamp to nH


# ---------------------------------------------------------------------------
# exhaustive deterministic sweep (CI kernels job)
# ---------------------------------------------------------------------------
@pytest.mark.kernels
@pytest.mark.parametrize("algo_name", ALGOS)
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("b,h,w_,cin,cout", [
    (1, 3, 3, 1, 1), (1, 5, 33, 3, 2), (2, 33, 5, 2, 3),
    (3, 15, 21, 40, 24), (4, 8, 8, 130, 70), (1, 24, 24, 260, 140),
])
def test_conformance_sweep(algo_name, padding, b, h, w_, cin, cout):
    x, w = _case(b, h, w_, cin, cout, seed=h * w_ + cin)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, padding=padding,
                               quant=INT8_FREQ)
    assert_conv_conformance(x, w, spec, algo_name)


# ---------------------------------------------------------------------------
# hypothesis fuzz layer (slow; CI kernels job, skipped without hypothesis)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    conv_specs = st.tuples(
        st.integers(1, 4),                      # batch
        st.integers(3, 33), st.integers(3, 33),  # H, W (ragged included)
        st.integers(1, 140),                    # C_in (non-multiples of 128)
        st.integers(1, 140),                    # C_out
        st.sampled_from(ALGOS),
        st.sampled_from(["SAME", "VALID"]),
        st.sampled_from([None, 64, 128]),       # k_block
        st.sampled_from([1, 2, 4]),             # rows_per_step
        st.booleans(),                          # double_buffer
        st.integers(0, 2 ** 31 - 1),            # data seed
    )

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(conv_specs)
    def test_conformance_fuzz(params):
        (b, h, w_, cin, cout, algo_name, padding, k_block, rps, db,
         seed) = params
        x, w = _case(b, h, w_, cin, cout, seed=seed)
        spec = ConvSpec.for_conv2d(x.shape, w.shape, padding=padding,
                                   quant=INT8_FREQ)
        assert_conv_conformance(
            x, w, spec, algo_name,
            variants=(dict(k_block=k_block, rows_per_step=rps,
                           double_buffer=db),
                      dict(k_block=k_block, rows_per_step=1)))
else:
    @pytest.mark.slow
    def test_conformance_fuzz():
        pytest.skip("hypothesis not installed (pip install -e '.[test]')")
