"""ConvSpec-keyed serving cache + serve-launcher CLI coverage.

Acceptance surface: repeated serve-path hits on one ConvSpec re-use one
cached plan and one PreparedWeights (no re-preparation), stacked-layer
weights stay cached across re-slicing via stable keys, tracers bypass the
cache, and the ``--smoke/--no-smoke`` CLI reaches both config branches.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConvSpec, serving_cache
from repro.api.serving_cache import ServingCache
from repro.core import conv2d as c2d


@pytest.fixture(autouse=True)
def _fresh_cache():
    serving_cache.clear()
    yield
    serving_cache.clear()


def _conv1d_data(c=8, t=20, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(2, t, c), jnp.float32)
    w = jnp.asarray(rng.randn(4, c) * 0.3, jnp.float32)
    return x, w


# ----------------------------------------------------------------------
# cache semantics
# ----------------------------------------------------------------------
def test_same_spec_reuses_plan_and_prep():
    x, w = _conv1d_data()
    spec = ConvSpec.for_conv1d_depthwise(x.shape, w.shape)
    p1, prep1 = serving_cache.get(spec, w, algo="auto")
    p2, prep2 = serving_cache.get(spec, w, algo="auto")
    assert p1 is p2 and prep1 is prep2
    s = serving_cache.stats()
    assert s["prepares"] == 1 and s["hits"] == 1 and s["size"] == 1


def test_keyed_entries_survive_reslicing():
    """Stacked layer params are sliced fresh every call — a stable key
    must keep one prepared entry alive across id churn."""
    _, w0 = _conv1d_data(seed=1)
    _, w1 = _conv1d_data(seed=2)
    stacked = jnp.stack([w0, w1])
    spec = ConvSpec.for_conv1d_depthwise((2, 20, 8), w0.shape)
    for _ in range(3):                       # new slice objects every pass
        for i in range(2):
            serving_cache.get(spec, stacked[i], key=("blocks", "conv_w", i))
    s = serving_cache.stats()
    assert s["prepares"] == 2 and s["hits"] == 4 and s["size"] == 2


def test_distinct_weights_same_spec_coexist():
    x, wa = _conv1d_data(seed=3)
    _, wb = _conv1d_data(seed=4)
    spec = ConvSpec.for_conv1d_depthwise(x.shape, wa.shape)
    _, prep_a = serving_cache.get(spec, wa, algo="auto")
    _, prep_b = serving_cache.get(spec, wb, algo="auto")
    assert prep_a is not prep_b
    _, again_a = serving_cache.get(spec, wa, algo="auto")
    assert again_a is prep_a                  # not evicted by wb
    assert serving_cache.stats()["prepares"] == 2


def test_lru_eviction_bound():
    cache = ServingCache(maxsize=2)
    spec = ConvSpec.for_conv1d_depthwise((2, 20, 8), (4, 8))
    ws = [jnp.asarray(np.random.RandomState(s).randn(4, 8), jnp.float32)
          for s in range(3)]
    for w in ws:
        cache.get(spec, w)
    assert cache.stats()["size"] == 2
    # ws[0] was evicted (LRU): re-getting prepares again
    cache.get(spec, ws[0])
    assert cache.stats()["prepares"] == 4


def test_eviction_counter_counts_capacity_pops_only():
    """Regression: ``evictions`` counts capacity-driven LRU pops — a
    replaced (invalidated) same-key entry must NOT count, and the key
    being replaced must never be the one popped."""
    from repro.api import planner
    cache = ServingCache(maxsize=2)
    spec = ConvSpec.for_conv1d_depthwise((2, 20, 8), (4, 8))
    ws = [jnp.asarray(np.random.RandomState(s).randn(4, 8), jnp.float32)
          for s in range(3)]
    cache.get(spec, ws[0], key="a")
    cache.get(spec, ws[1], key="b")
    assert cache.stats()["evictions"] == 0
    cache.get(spec, ws[2], key="c")                   # capacity: pops "a"
    assert cache.stats() == {"hits": 0, "misses": 3, "prepares": 3,
                             "evictions": 1, "size": 2}
    # plan invalidation forces a same-key REPLACEMENT at full capacity:
    # size and evictions must not move, and "c" must survive as MRU
    planner.invalidate_plan_cache()
    cache.get(spec, ws[1], key="b")
    s = cache.stats()
    assert s["evictions"] == 1 and s["size"] == 2 and s["prepares"] == 4
    cache.get(spec, ws[2], key="c")
    assert cache.stats()["prepares"] == 5             # replaced, not popped
    assert cache.stats()["evictions"] == 1
    cache.clear()
    assert cache.stats()["evictions"] == 0


def test_maxsize_env_var(monkeypatch):
    """REPRO_SERVING_CACHE_SIZE sizes default-constructed caches; invalid
    values fall back to the built-in default; an explicit maxsize wins."""
    from repro.api.serving_cache import default_maxsize
    monkeypatch.setenv("REPRO_SERVING_CACHE_SIZE", "1")
    assert default_maxsize() == 1
    cache = ServingCache()
    spec = ConvSpec.for_conv1d_depthwise((2, 20, 8), (4, 8))
    ws = [jnp.asarray(np.random.RandomState(s).randn(4, 8), jnp.float32)
          for s in range(2)]
    cache.get(spec, ws[0], key="a")
    cache.get(spec, ws[1], key="b")
    assert cache.stats()["size"] == 1
    assert cache.stats()["evictions"] == 1
    assert ServingCache(maxsize=4)._maxsize == 4      # explicit arg wins
    for bad in ("not-a-number", "0", "-3"):
        monkeypatch.setenv("REPRO_SERVING_CACHE_SIZE", bad)
        assert default_maxsize() == 256
    monkeypatch.delenv("REPRO_SERVING_CACHE_SIZE")
    assert default_maxsize() == 256
    with pytest.raises(ValueError):
        ServingCache(maxsize=0)


def test_tracers_bypass_cache():
    x, w = _conv1d_data(seed=5)
    spec_of = ConvSpec.for_conv1d_depthwise

    def fn(xx, ww):
        p, prep = serving_cache.get(spec_of(xx.shape, ww.shape), ww,
                                    algo="auto")
        return p.apply(xx, prep)

    y_jit = jax.jit(fn)(x, w)
    assert serving_cache.stats()["size"] == 0          # nothing cached
    y_eager = fn(x, w)
    assert serving_cache.stats()["size"] == 1
    np.testing.assert_allclose(np.asarray(y_jit), np.asarray(y_eager),
                               rtol=1e-5, atol=1e-5)


def test_algo_flip_invalidates_entry():
    """A cached prep must not outlive the algorithm it was prepared under:
    registering an algorithm re-resolves 'auto', and the next get() must
    re-prepare instead of pairing the fast-path plan with a direct prep."""
    from repro.api import register_algorithm
    from repro.api import planner, registry as reg
    from repro.core.generator import generate_sfc
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(2, 12, 12, 8), jnp.float32)
    w = jnp.asarray(rng.randn(5, 5, 8, 8) * 0.2, jnp.float32)
    spec = ConvSpec.for_conv2d(x.shape, w.shape)          # 5-tap: no algo
    p1, prep1 = serving_cache.get(spec, w, algo="auto")
    assert p1.path == "direct" and prep1.tw is None
    with reg._LOCK:
        saved = dict(reg._ENTRIES), dict(reg._INSTANCES)
    try:
        register_algorithm("sfc6_4_r5_cache_test",
                           lambda: generate_sfc(6, 4, 5), taps=5,
                           kind="sfc", overwrite=True)
        p2, prep2 = serving_cache.get(spec, w, algo="auto")
        assert p2.path == "fast" and prep2.tw is not None
        assert serving_cache.stats()["prepares"] == 2
        y = p2.apply(x, prep2)                            # must not crash
        y_ref = p1.apply(x, prep1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
    finally:
        with reg._LOCK:
            reg._ENTRIES.clear(), reg._ENTRIES.update(saved[0])
            reg._INSTANCES.clear(), reg._INSTANCES.update(saved[1])
        planner.invalidate_plan_cache()


# ----------------------------------------------------------------------
# serve-path wiring
# ----------------------------------------------------------------------
def test_ssm_conv_routes_through_serving_cache():
    from repro.models.ssm import _causal_conv1d
    x, w = _conv1d_data(seed=6)
    b = jnp.zeros((8,), jnp.float32)
    y1 = _causal_conv1d(x, w, b, use_sfc=True)
    y2 = _causal_conv1d(x, w, b, use_sfc=True)
    s = serving_cache.stats()
    assert s["prepares"] == 1 and s["hits"] == 1
    assert bool(jnp.all(y1 == y2))
    ref = jax.nn.silu(c2d.conv1d_depthwise_causal_direct(x, w) + b)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_serve_warm_no_repreparation():
    """Acceptance: repeated serve-path hits on the same ConvSpec re-use
    one cached plan + prepared weights — the second warm pass must not
    prepare anything."""
    from repro.configs import get_smoke_config
    from repro.launch.serve import warm_conv_plans
    from repro.models.registry import build
    cfg = get_smoke_config("mamba2-1.3b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    first = warm_conv_plans(cfg, params, batch=2, seq=16)
    assert first["size"] > 0 and first["prepares"] == first["size"]
    assert first["hits"] == 0
    second = warm_conv_plans(cfg, params, batch=2, seq=16)
    assert second["prepares"] == first["prepares"]      # no re-preparation
    assert second["hits"] == first["size"]
    assert second["size"] == first["size"]


# ----------------------------------------------------------------------
# serve CLI
# ----------------------------------------------------------------------
def test_serve_smoke_flag_both_branches():
    from repro.configs import get_config, get_smoke_config
    from repro.launch.serve import parse_args, resolve_config
    on = parse_args(["--arch", "qwen3-14b"])
    assert on.smoke is True
    assert resolve_config(on) == get_smoke_config("qwen3-14b")
    off = parse_args(["--arch", "qwen3-14b", "--no-smoke"])
    assert off.smoke is False
    full = resolve_config(off)
    assert full == get_config("qwen3-14b")
    assert full.d_model > get_smoke_config("qwen3-14b").d_model
    # and --smoke still parses explicitly
    assert parse_args(["--smoke"]).smoke is True
