"""Engine self-healing tests: transparent fault absorption, bounded
retry, poison-request quarantine, deadline shedding, and loop-error
surfacing.

The serve-tier acceptance claim (ISSUE 7): under transient injected
faults ZERO request-visible errors occur and the degraded answers stay
bit-identical to a healthy engine's.  All tests drive ``Engine.step()``
synchronously unless the dispatch *thread* itself is under test.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.api import resilience
from repro.api.serving_cache import ServingCache
from repro.quant import INT8_FREQ
from repro.serve import (BucketTable, Engine, INTERACTIVE, BATCH,
                         QuarantinedError, ShedError, results)

CIN, COUT = 4, 8


@pytest.fixture(autouse=True)
def _fresh_board():
    resilience.reset()
    yield
    resilience.reset()


@pytest.fixture(scope="module")
def shared_cache():
    return ServingCache()


def _weights(seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(3, 3, CIN, COUT) * 0.2, jnp.float32)


def _table(shapes=((8, 8), (12, 12))):
    return BucketTable.for_workload(shapes, kernel_size=3, in_channels=CIN,
                                    out_channels=COUT, quant=INT8_FREQ)


def _imgs(shapes, seed=1):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.randn(h, w, CIN), jnp.float32)
            for h, w in shapes]


def _serve_all(eng, xs, slo=BATCH):
    futs = [eng.submit(x, slo) for x in xs]
    while eng.step() > 0:
        pass
    return futs


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ----------------------------------------------------------------------
# transparent absorption: the acceptance test
# ----------------------------------------------------------------------
def test_transient_faults_invisible_and_bit_identical(shared_cache):
    """Under a 30% fused-apply fault rate every future resolves with a
    RESULT (zero request-visible errors) and each answer equals the
    healthy engine's bit-for-bit."""
    shapes = [(8, 8), (11, 10), (12, 12), (8, 8), (7, 7), (12, 12)] * 2
    xs = _imgs(shapes, seed=3)
    clean = Engine(_weights(), _table(), max_batch=4, cache=shared_cache)
    expect = [r.y for r in results(_serve_all(clean, xs))]

    faulty = Engine(_weights(), _table(), max_batch=4, cache=shared_cache)
    with faults.inject({faults.APPLY_FUSED: faults.FaultSpec(p=0.3)},
                       seed=9) as fp:
        futs = _serve_all(faulty, xs)
    assert fp.injected() > 0                   # faults actually happened
    got = results(futs)                        # raises if ANY errored
    for g, e in zip(got, expect):
        assert np.array_equal(np.asarray(g.y), np.asarray(e))
    c = faulty.snapshot()["counters"]
    # plan-level events landed in THIS engine's registry via the sink
    assert c.get("resilience_fallback_staged", 0) \
        + c.get("resilience_breaker_skip", 0) >= fp.injected()


def test_dispatch_fault_retried_and_counted(shared_cache):
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 retry_backoff_s=0.0)
    xs = _imgs([(8, 8)] * 3)
    with faults.inject({faults.DISPATCH: faults.FaultSpec(times=1)}) as fp:
        futs = _serve_all(eng, xs)
    assert fp.injected(faults.DISPATCH) == 1
    rs = results(futs)
    assert len(rs) == 3
    c = eng.snapshot()["counters"]
    assert c["dispatch_retries"] == 1
    assert c["quarantined"] == 0 and c["batch_bisections"] == 0


def test_poison_request_quarantined_peers_served(shared_cache):
    """A request whose presence persistently kills its batch is isolated
    by bisection and quarantined; every co-batched peer is served."""
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 max_dispatch_retries=1, retry_backoff_s=0.0)
    xs = _imgs([(8, 8)] * 4, seed=5)
    poison_x = xs[1]                           # identified by input identity
    futs = [eng.submit(x) for x in xs]
    with faults.inject({faults.DISPATCH: faults.FaultSpec(
            when=lambda b: any(r.x is poison_x
                               for r in b.requests))}) as fp:
        while eng.step() > 0:
            pass
    assert fp.injected(faults.DISPATCH) >= 2   # batch + bisected halves
    for i, f in enumerate(futs):
        if i != 1:
            f.result(timeout=0)                # peers all served
    with pytest.raises(QuarantinedError) as ei:
        futs[1].result(timeout=0)
    assert isinstance(ei.value.__cause__, faults.InjectedFault)
    c = eng.snapshot()["counters"]
    assert c["quarantined"] == 1
    assert c["batch_bisections"] >= 1


# ----------------------------------------------------------------------
# deadline shedding
# ----------------------------------------------------------------------
def test_expired_requests_shed_before_dispatch(shared_cache):
    clock = _FakeClock()
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 clock=clock, shed_expired=True)
    x8, x12 = _imgs([(8, 8), (12, 12)], seed=6)
    f_late = eng.submit(x8, INTERACTIVE)       # 2s deadline
    f_ok = eng.submit(x8, BATCH)               # 20s deadline
    clock.t = 3.0                              # interactive now expired
    assert eng.step() == 2                     # both resolved: 1 shed, 1 served
    with pytest.raises(ShedError):
        f_late.result(timeout=0)
    assert f_ok.result(timeout=0).y.shape == (8, 8, COUT)
    snap = eng.snapshot()
    assert snap["counters"]["shed"] == 1
    assert snap["slo"]["interactive"]["missed"] == 1
    # shedding off (the default): the same late request is served
    eng2 = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                  clock=clock)
    f = eng2.submit(x12, INTERACTIVE)
    clock.t = 9.0
    eng2.step()
    assert f.result(timeout=0).deadline_met is False


def test_all_shed_batch_completes_inflight_accounting(shared_cache):
    clock = _FakeClock()
    eng = Engine(_weights(), _table(), max_batch=4, cache=shared_cache,
                 clock=clock, shed_expired=True)
    futs = [eng.submit(x, INTERACTIVE) for x in _imgs([(8, 8)] * 3)]
    clock.t = 100.0
    assert eng.step() == 3
    for f in futs:
        with pytest.raises(ShedError):
            f.result(timeout=0)
    assert eng.drain(timeout=1.0) is True      # inflight went back to 0


# ----------------------------------------------------------------------
# dispatch-loop error surfacing (the silent `except: pass` satellite)
# ----------------------------------------------------------------------
def test_loop_errors_counted_retained_and_reraised(shared_cache,
                                                   monkeypatch):
    eng = Engine(_weights(), _table(), max_batch=2, cache=shared_cache)

    def boom(*a, **k):
        raise RuntimeError("batch formation exploded")

    monkeypatch.setattr(eng.queue, "take_batch", boom)
    eng.start()
    deadline = time.perf_counter() + 5.0
    while eng.snapshot()["loop_errors"] == 0 \
            and time.perf_counter() < deadline:
        time.sleep(0.01)
    with pytest.raises(RuntimeError, match="batch formation exploded"):
        eng.stop(raise_on_error=True)
    snap = eng.snapshot()
    assert snap["loop_errors"] >= 1
    assert snap["counters"]["loop_errors"] >= 1
    assert "batch formation exploded" in snap["last_loop_error"]
    # plain stop() after the fact does not raise
    eng.stop()


# ----------------------------------------------------------------------
# drain vs concurrent submit (satellite)
# ----------------------------------------------------------------------
def test_drain_not_true_while_admitted_request_unresolved(shared_cache):
    eng = Engine(_weights(), _table(), max_batch=2, cache=shared_cache)
    f = eng.submit(_imgs([(8, 8)])[0])
    assert eng.drain(timeout=0.05) is False    # admitted, not yet served
    assert not f.done()
    eng.step()
    assert eng.drain(timeout=1.0) is True
    assert f.done()


def test_drain_races_concurrent_submits(shared_cache):
    """drain() returning True must imply every previously-submitted
    request resolved, even with submits racing the dispatch thread."""
    eng = Engine(_weights(), _table(), max_batch=4,
                 cache=shared_cache).start()
    xs = _imgs([(8, 8)] * 12, seed=8)
    futs = []

    def submitter():
        for x in xs:
            futs.append(eng.submit(x))
            time.sleep(0.002)

    th = threading.Thread(target=submitter)
    th.start()
    th.join()
    assert eng.drain(timeout=60) is True
    assert all(f.done() for f in futs)
    eng.stop(raise_on_error=True)
    for r in results(futs):
        assert r.y.shape == (8, 8, COUT)
