"""MoE grouped dispatch: routing semantics, capacity, shard-local grouping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.moe import init_moe, moe_block


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("mixtral-8x7b")
    cfg = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32"})
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.RandomState(0).randn(2, 16, cfg.d_model),
                    jnp.float32)
    return cfg, p, x


def test_lossless_capacity_matches_dense_reference(setup):
    """At capacity == T the grouped dispatch equals the explicit per-token
    dense mixture."""
    cfg, p, x = setup
    y, _ = moe_block(p, cfg, x,
                     capacity_factor=cfg.n_experts / cfg.n_experts_active)
    # dense reference
    T = x.shape[0] * x.shape[1]
    xt = x.reshape(T, -1)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gv, ei = jax.lax.top_k(probs, cfg.n_experts_active)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        ye = h @ p["w_down"][e]
        w = jnp.sum(jnp.where(ei == e, gv, 0.0), axis=-1)
        ref = ref + ye * w[:, None]
    np.testing.assert_allclose(np.asarray(y.reshape(T, -1)), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_batch_consistency(setup):
    """Full batch == per-token application under lossless capacity."""
    cfg, p, x = setup
    cf = cfg.n_experts / cfg.n_experts_active
    full, _ = moe_block(p, cfg, x, capacity_factor=cf)
    per = jnp.concatenate(
        [moe_block(p, cfg, x[:, t:t + 1], capacity_factor=cf)[0]
         for t in range(x.shape[1])], axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(per),
                               rtol=1e-5, atol=1e-5)


def test_capacity_drops_tokens(setup):
    """Tight capacity changes outputs (GShard dropping) but stays finite."""
    cfg, p, x = setup
    tight, _ = moe_block(p, cfg, x, capacity_factor=0.25)
    loose, _ = moe_block(p, cfg, x, capacity_factor=8.0)
    assert bool(jnp.all(jnp.isfinite(tight)))
    assert float(jnp.abs(tight - loose).max()) > 0


def test_aux_loss_balanced_router(setup):
    """A uniform router gives aux ~ 1 (the balanced optimum of E*sum(f*p))."""
    cfg, p, x = setup
    p_bal = dict(p)
    p_bal["router"] = jnp.zeros_like(p["router"])
    _, aux = moe_block(p_bal, cfg, x)
    assert abs(float(aux) - 1.0) < 0.05


def test_shard_local_grouping_matches_global():
    """The data-shard-local dispatch (§Perf hillclimb 2) is numerically
    identical to single-shard dispatch under lossless capacity."""
    from repro.distributed import act_sharding as acts
    cfg = get_smoke_config("deepseek-v3-671b")
    cfg = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32"})
    p = init_moe(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jnp.asarray(np.random.RandomState(1).randn(4, 8, cfg.d_model),
                    jnp.float32)
    cf = cfg.n_experts / cfg.n_experts_active
    y1, _ = moe_block(p, cfg, x, capacity_factor=cf)   # ds = 1 (no rules)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    try:
        acts.install(mesh, ("data",))
        y2, _ = moe_block(p, cfg, x, capacity_factor=cf)
    finally:
        acts.clear()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
