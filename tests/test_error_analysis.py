"""Paper §5 / Table 1 reproduction claims."""
import numpy as np
import pytest

from repro.core.error_analysis import simulate_mse, table1
from repro.core.generator import paper_algorithms


@pytest.fixture(scope="module")
def t1():
    return table1(trials=120)


def test_sfc_mse_near_direct(t1):
    """SFC error stays within ~4x of direct conv (paper: 2.4-3.6)."""
    for name, row in t1.items():
        if name.startswith("SFC"):
            assert row["mse"] < 5.0, (name, row["mse"])


def test_winograd_mse_grows(t1):
    """Winograd F(4x4,3x3) error >> SFC (paper: 10.5 vs 2.4-2.6)."""
    assert t1["Wino(4x4,3x3)"]["mse"] > 3 * t1["SFC-6(6x6,3x3)"]["mse"]
    assert t1["Wino(2x2,7x7)"]["mse"] > t1["Wino(2x2,3x3)"]["mse"]


def test_sfc_faster_than_winograd_at_matched_error(t1):
    """The headline: 3.68x mult reduction (SFC-6(6,3), Hermitian count 88)
    vs Winograd's 2.25x at comparable (direct-like) error."""
    sfc = t1["SFC-6(6x6,3x3)"]
    wino = t1["Wino(2x2,3x3)"]
    sfc_speedup = 324 / sfc["mults_2d_hermitian"]
    wino_speedup = 144 / wino["mults_2d"] * (324 / 144)  # normalize per out
    assert sfc["mults_2d_hermitian"] == 88
    assert abs(sfc_speedup - 3.68) < 0.01
    assert sfc["mse"] < 2 * wino["mse"]


def test_mse_correlates_with_amplification(t1):
    """Paper: 'numerical error is highly correlated to kappa(A^T)'.  Our
    analytic amplification factor (which kappa proxies) must track the
    measured MSE across all algorithms."""
    names = [n for n in t1 if t1[n]["paper"]]
    k = np.array([t1[n]["amplification"] for n in names])
    m = np.array([t1[n]["mse"] for n in names])
    r = np.corrcoef(np.log(k + 1e-9), np.log(m + 1e-9))[0, 1]
    assert r > 0.8, r


def test_per_frequency_quant_reduces_intn_error():
    algos = paper_algorithms()
    a = algos["SFC-6(6x6,3x3)"]
    base = simulate_mse(a, fmt="int6", trials=60, per_frequency=False)
    freq = simulate_mse(a, fmt="int6", trials=60, per_frequency=True)
    assert freq < base
