"""Shared test config.

The suite jit-compiles hundreds of programs in one process; compiled
executables otherwise accumulate until LLVM hits the container's memory
ceiling ("LLVM compilation error: Cannot allocate memory").  Clearing the
jax caches at module boundaries keeps the footprint flat.

NOTE: deliberately no XLA_FLAGS here — smoke tests and benches must see
the host's single device (the 512-device override belongs exclusively to
repro/launch/dryrun*.py).
"""
import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
    gc.collect()


@pytest.fixture
def deterministic_time_fn(monkeypatch):
    """Replace ``tuning.time_fn`` with a call-order timer.

    The datapath under test still executes once (compile errors and
    numerical crashes surface), but the reported "latency" is the call
    index — so tests asserting on autotune *rankings* (fastest-measured
    wins) are deterministic instead of flaking on host-load noise.
    Returns the log of (reported time, fn) entries.
    """
    from repro.api import tuning
    log = []

    def fake_time_fn(fn, *args, reps=3):
        jax.block_until_ready(fn(*args))
        log.append(((len(log) + 1) * 1e-3, fn))
        return log[-1][0]

    monkeypatch.setattr(tuning, "time_fn", fake_time_fn)
    return log


@pytest.fixture(autouse=True, scope="session")
def _isolated_caches_for_session(tmp_path_factory):
    """Session-wide hermetic tuning/cost-model caches.

    Module-scoped fixtures (e.g. test_resilience's ``quantized``)
    instantiate BEFORE function-scoped autouse fixtures, so without this
    outer layer they would plan against the host's real
    ``~/.cache/repro`` stores — a host-fitted cost model flips their
    ``plan(backend=...)`` selections (the model tier honestly prefers
    direct over interpret-mode fused on CPU)."""
    from repro.api import costmodel, tuning
    d = tmp_path_factory.mktemp("caches")
    tuning.set_cache_path(str(d / "tuning.json"))
    costmodel.set_cache_path(str(d / "costmodel.json"))
    yield
    tuning.set_cache_path(None)
    costmodel.set_cache_path(None)


@pytest.fixture(autouse=True)
def _isolated_tuning_cache(tmp_path):
    """Hermetic measured-latency cache for every test.

    ``plan(..., algo="auto")`` consults the tuning cache ahead of the BOPs
    model, so without this a prior ``autotune`` run on the host (or a test
    that records measurements) would change other tests' auto-selections.
    """
    from repro.api import tuning
    prev = tuning.cache_path()      # the session-scoped hermetic path —
    tuning.set_cache_path(str(tmp_path / "tuning.json"))
    yield
    tuning.set_cache_path(prev)     # NOT None: a module-scoped fixture
    # instantiating between tests must never see the host's real cache


@pytest.fixture(autouse=True)
def _isolated_costmodel_cache(tmp_path):
    """Hermetic cost-model coefficient store for every test.

    The planner consults ``repro.api.costmodel`` between measured timings
    and BOPs, and ``autotune(top_k=...)`` truncates its sweep when the
    model is fitted — a coefficient fit persisted on the host must not
    leak into tests (each test starts unfitted unless it fits/installs
    coefficients itself).
    """
    from repro.api import costmodel
    prev = costmodel.cache_path()
    costmodel.set_cache_path(str(tmp_path / "costmodel.json"))
    yield
    costmodel.set_cache_path(prev)
