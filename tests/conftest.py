"""Shared test config.

The suite jit-compiles hundreds of programs in one process; compiled
executables otherwise accumulate until LLVM hits the container's memory
ceiling ("LLVM compilation error: Cannot allocate memory").  Clearing the
jax caches at module boundaries keeps the footprint flat.

NOTE: deliberately no XLA_FLAGS here — smoke tests and benches must see
the host's single device (the 512-device override belongs exclusively to
repro/launch/dryrun*.py).
"""
import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
    gc.collect()


@pytest.fixture
def deterministic_time_fn(monkeypatch):
    """Replace ``tuning.time_fn`` with a call-order timer.

    The datapath under test still executes once (compile errors and
    numerical crashes surface), but the reported "latency" is the call
    index — so tests asserting on autotune *rankings* (fastest-measured
    wins) are deterministic instead of flaking on host-load noise.
    Returns the log of (reported time, fn) entries.
    """
    from repro.api import tuning
    log = []

    def fake_time_fn(fn, *args, reps=3):
        jax.block_until_ready(fn(*args))
        log.append(((len(log) + 1) * 1e-3, fn))
        return log[-1][0]

    monkeypatch.setattr(tuning, "time_fn", fake_time_fn)
    return log


@pytest.fixture(autouse=True)
def _isolated_tuning_cache(tmp_path):
    """Hermetic measured-latency cache for every test.

    ``plan(..., algo="auto")`` consults the tuning cache ahead of the BOPs
    model, so without this a prior ``autotune`` run on the host (or a test
    that records measurements) would change other tests' auto-selections.
    """
    from repro.api import tuning
    tuning.set_cache_path(str(tmp_path / "tuning.json"))
    yield
    tuning.set_cache_path(None)
