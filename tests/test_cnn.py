"""Paper CNNs: forward shapes, algorithm/quant selection, trainability."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.resnet18 import CIFAR_RESNET18, SMOKE_CNN, VGG16, CNNConfig
from repro.models.cnn import (cnn_loss, init_resnet, init_vgg,
                              resnet_forward, vgg_forward)


def test_resnet_forward_shapes():
    cfg = SMOKE_CNN
    p = init_resnet(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((2, cfg.image_size, cfg.image_size, 3))
    logits = resnet_forward(p, cfg, x)
    assert logits.shape == (2, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("algo", ["direct", "sfc6_6", "sfc6_7", "sfc4_4",
                                  "wino4"])
def test_algorithms_agree_fp32(algo):
    """All conv algorithms compute the same network function in fp32."""
    base = dataclasses.replace(SMOKE_CNN, conv_algo="direct")
    var = dataclasses.replace(SMOKE_CNN, conv_algo=algo)
    p = init_resnet(jax.random.PRNGKey(0), base)
    x = jnp.asarray(np.random.RandomState(0).randn(2, 16, 16, 3),
                    jnp.float32)
    y0 = resnet_forward(p, base, x)
    y1 = resnet_forward(p, var, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-3, atol=1e-3)


def test_quantized_sfc_close_to_fp():
    base = dataclasses.replace(SMOKE_CNN, conv_algo="sfc6_6")
    q = dataclasses.replace(SMOKE_CNN, conv_algo="sfc6_6", quant="int8")
    p = init_resnet(jax.random.PRNGKey(0), base)
    x = jnp.asarray(np.random.RandomState(0).randn(2, 16, 16, 3),
                    jnp.float32)
    y0 = resnet_forward(p, base, x)
    y1 = resnet_forward(p, q, x)
    rel = float(jnp.linalg.norm(y1 - y0) / (jnp.linalg.norm(y0) + 1e-9))
    assert rel < 0.15


def test_vgg_forward():
    cfg = dataclasses.replace(
        VGG16, stages=(1, 1), widths=(8, 16), image_size=16, n_classes=10)
    p = init_vgg(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((2, 16, 16, 3))
    assert vgg_forward(p, cfg, x).shape == (2, 10)


def _resnet_conv_specs(cfg):
    """(name, spec) for every conv the forward pass plans, mirroring
    ``resnet_forward``'s shape evolution."""
    from repro.api import ConvSpec
    specs = []
    hw = cfg.image_size
    stem_stride = 2 if cfg.image_size >= 128 else 1
    specs.append(("stem", ConvSpec(
        rank=2, kernel_size=cfg.stem_kernel, stride=stem_stride,
        in_channels=3, out_channels=cfg.widths[0], spatial=(hw, hw))))
    hw = -(-hw // stem_stride)
    if cfg.image_size >= 128:
        hw = -(-hw // 2)                       # stem max-pool
    cin = cfg.widths[0]
    for si, (n_blocks, width) in enumerate(zip(cfg.stages, cfg.widths)):
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            specs.append((f"s{si}b{bi}.conv1", ConvSpec(
                rank=2, kernel_size=3, stride=stride, in_channels=cin,
                out_channels=width, spatial=(hw, hw))))
            hw_out = -(-hw // stride)
            specs.append((f"s{si}b{bi}.conv2", ConvSpec(
                rank=2, kernel_size=3, in_channels=width,
                out_channels=width, spatial=(hw_out, hw_out))))
            if stride != 1 or cin != width:
                specs.append((f"s{si}b{bi}.proj", ConvSpec(
                    rank=2, kernel_size=1, stride=stride, in_channels=cin,
                    out_channels=width, spatial=(hw, hw))))
            hw, cin = hw_out, width
    return specs


def test_resnet_stride2_layers_lower_end_to_end():
    """Every stride-2 conv (stage transitions AND the stride-2 stem) now
    resolves to a lowered fast plan — not direct — and the forward pass
    matches the pre-refactor forward (lowering disabled: stride-2 layers
    direct, stride-1 layers fast) to fp32 epsilon, and the int8 config
    stays within the conformance envelope of the fp32 forward."""
    from repro.api import lowering, plan
    cfg = dataclasses.replace(
        SMOKE_CNN, name="stem-smoke", image_size=128, stem_kernel=7,
        conv_algo="sfc6_6")
    p = init_resnet(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.RandomState(0).randn(1, 128, 128, 3),
                    jnp.float32)
    y = resnet_forward(p, cfg, x)
    strided = [(n, s) for n, s in _resnet_conv_specs(cfg) if s.stride == 2]
    assert any(n == "stem" for n, _ in strided)
    for name, spec in strided:
        pl_ = plan(spec, backend="reference", algo=cfg.conv_algo)
        if spec.kernel_size == 1:
            assert pl_.path == "direct", name       # 1x1 projections
        else:
            assert pl_.path == "lowered", \
                f"{name} still degrades to {pl_.path}"
    # stride-1 layers plan exactly as before (identical memoized plans),
    # so the delta vs the lowering-disabled forward isolates the strided
    # layers: direct vs polyphase arithmetic of the same convolution
    with lowering.disabled():
        for name, spec in _resnet_conv_specs(cfg):
            if spec.stride == 2 and spec.kernel_size > 1:
                assert plan(spec, backend="reference",
                            algo=cfg.conv_algo).path == "direct"
        y_pre = resnet_forward(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_pre),
                               rtol=1e-3, atol=1e-3)
    # int8: transform-domain fake quant now reaches the lowered layers too
    qcfg = dataclasses.replace(cfg, quant="int8")
    yq = resnet_forward(p, qcfg, x)
    rel = float(jnp.linalg.norm(yq - y) / (jnp.linalg.norm(y) + 1e-9))
    assert rel < 0.15


def test_cnn_gradients():
    cfg = dataclasses.replace(SMOKE_CNN, conv_algo="sfc6_6", quant="int8")
    p = init_resnet(jax.random.PRNGKey(0), cfg)
    batch = {"images": jnp.asarray(
        np.random.RandomState(0).randn(2, 16, 16, 3), jnp.float32),
        "labels": jnp.asarray([0, 1], jnp.int32)}
    loss, metrics = cnn_loss(p, cfg, batch)
    assert jnp.isfinite(loss)
    g = jax.grad(lambda p: cnn_loss(p, cfg, batch)[0])(p)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in
             jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0   # STE keeps grads flowing
