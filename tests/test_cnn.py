"""Paper CNNs: forward shapes, algorithm/quant selection, trainability."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.resnet18 import CIFAR_RESNET18, SMOKE_CNN, VGG16, CNNConfig
from repro.models.cnn import (cnn_loss, init_resnet, init_vgg,
                              resnet_forward, vgg_forward)


def test_resnet_forward_shapes():
    cfg = SMOKE_CNN
    p = init_resnet(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((2, cfg.image_size, cfg.image_size, 3))
    logits = resnet_forward(p, cfg, x)
    assert logits.shape == (2, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("algo", ["direct", "sfc6_6", "sfc6_7", "sfc4_4",
                                  "wino4"])
def test_algorithms_agree_fp32(algo):
    """All conv algorithms compute the same network function in fp32."""
    base = dataclasses.replace(SMOKE_CNN, conv_algo="direct")
    var = dataclasses.replace(SMOKE_CNN, conv_algo=algo)
    p = init_resnet(jax.random.PRNGKey(0), base)
    x = jnp.asarray(np.random.RandomState(0).randn(2, 16, 16, 3),
                    jnp.float32)
    y0 = resnet_forward(p, base, x)
    y1 = resnet_forward(p, var, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-3, atol=1e-3)


def test_quantized_sfc_close_to_fp():
    base = dataclasses.replace(SMOKE_CNN, conv_algo="sfc6_6")
    q = dataclasses.replace(SMOKE_CNN, conv_algo="sfc6_6", quant="int8")
    p = init_resnet(jax.random.PRNGKey(0), base)
    x = jnp.asarray(np.random.RandomState(0).randn(2, 16, 16, 3),
                    jnp.float32)
    y0 = resnet_forward(p, base, x)
    y1 = resnet_forward(p, q, x)
    rel = float(jnp.linalg.norm(y1 - y0) / (jnp.linalg.norm(y0) + 1e-9))
    assert rel < 0.15


def test_vgg_forward():
    cfg = dataclasses.replace(
        VGG16, stages=(1, 1), widths=(8, 16), image_size=16, n_classes=10)
    p = init_vgg(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((2, 16, 16, 3))
    assert vgg_forward(p, cfg, x).shape == (2, 10)


def test_cnn_gradients():
    cfg = dataclasses.replace(SMOKE_CNN, conv_algo="sfc6_6", quant="int8")
    p = init_resnet(jax.random.PRNGKey(0), cfg)
    batch = {"images": jnp.asarray(
        np.random.RandomState(0).randn(2, 16, 16, 3), jnp.float32),
        "labels": jnp.asarray([0, 1], jnp.int32)}
    loss, metrics = cnn_loss(p, cfg, batch)
    assert jnp.isfinite(loss)
    g = jax.grad(lambda p: cnn_loss(p, cfg, batch)[0])(p)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in
             jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0   # STE keeps grads flowing
