"""Pipeline parallelism + sharding rules on a multi-device host mesh.

These run in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count
because the parent pytest process has already locked jax to 1 CPU device.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=420, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_gpipe_matches_sequential():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_apply, stack_params_for_stages
    mesh = jax.make_mesh((4,), ("stage",))
    L, d = 8, 16
    rng = np.random.RandomState(0)
    Ws = jnp.asarray(rng.randn(L, d, d) * 0.2, jnp.float32)

    def layer(w, x):
        return jnp.tanh(x @ w)

    def stage_fn(stage_params, x):
        def body(x, w):
            return layer(w, x), None
        x, _ = jax.lax.scan(body, x, stage_params)
        return x

    x = jnp.asarray(rng.randn(8, d), jnp.float32)
    # sequential reference
    ref = x
    for l in range(L):
        ref = layer(Ws[l], ref)
    staged = stack_params_for_stages({"w": Ws}, 4)["w"]
    y = pipeline_apply(stage_fn, staged, x, n_micro=4, mesh=mesh,
                       axis="stage")
    err = float(jnp.abs(y - ref).max())
    assert err < 1e-5, err
    print("gpipe ok", err)
    """)


def test_sharding_rules_lower_small_mesh():
    """Sharded train_step lowers+compiles on a host 2x4 mesh (reduced cfg)."""
    _run("""
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.distributed import sharding as shd
    from repro.models.registry import build
    from repro.optim.optimizers import AdamW
    from repro.train import steps as steps_lib
    from repro.configs.base import ShapeConfig

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for arch in ["qwen3-14b", "mixtral-8x7b", "mamba2-1.3b"]:
        cfg = get_smoke_config(arch)
        model = build(cfg)
        with mesh:
            opt = AdamW(lr=1e-3)
            state_abs = steps_lib.abstract_train_state(model, opt)
            pspecs = shd.params_pspecs(state_abs.params, cfg, mesh)
            state_pspecs = steps_lib.TrainState(
                params=pspecs,
                opt=shd.opt_state_pspecs(state_abs.opt, pspecs),
                rng=jax.sharding.PartitionSpec())
            state_shard = shd.sanitized_shardings(state_pspecs, state_abs, mesh)
            shape = ShapeConfig("t", 32, 4, "train")
            batch_abs = model.batch_specs(shape)
            b_shard = shd.sanitized_shardings(
                shd.batch_pspecs(batch_abs, mesh), batch_abs, mesh)
            step = steps_lib.make_train_step(model, opt)
            compiled = jax.jit(step, in_shardings=(state_shard, b_shard),
                               out_shardings=(state_shard, None),
                               donate_argnums=(0,)).lower(
                                   state_abs, batch_abs).compile()
            assert compiled.cost_analysis() is not None
        print(arch, "compiled ok")
    """, devices=8)


def test_sharded_train_step_executes():
    """Not just compiles: run 3 real sharded steps, loss finite+decreasing."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.distributed import sharding as shd
    from repro.models.registry import build
    from repro.optim.optimizers import AdamW
    from repro.train import steps as steps_lib
    from repro.data import SyntheticTokenPipeline, TokenPipelineConfig

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_smoke_config("qwen3-14b")
    model = build(cfg)
    opt = AdamW(lr=5e-3)
    with mesh:
        state = steps_lib.init_train_state(model, opt, jax.random.PRNGKey(0))
        pspecs = shd.params_pspecs(state.params, cfg, mesh)
        state_pspecs = steps_lib.TrainState(
            params=pspecs, opt=shd.opt_state_pspecs(state.opt, pspecs),
            rng=jax.sharding.PartitionSpec())
        state_shard = shd.sanitized_shardings(state_pspecs, state, mesh)
        state = jax.device_put(state, state_shard)
        pipe = SyntheticTokenPipeline(TokenPipelineConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
        step_fn = jax.jit(steps_lib.make_train_step(model, opt),
                          donate_argnums=(0,))
        losses = []
        for i in range(6):
            b = pipe.batch(i)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0] + 0.1, losses
        print("sharded exec ok", losses[0], "->", losses[-1])
    """, devices=8)
