"""Full-2D-Hermitian SFC: executable algorithms at the paper's '/88' counts."""
from fractions import Fraction

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.generator2d import generate_sfc_2d_hermitian


@pytest.mark.parametrize("nmr,expected_t", [
    ((4, 4, 3), 46), ((6, 6, 3), 88), ((6, 7, 3), 132), ((6, 6, 5), 184)])
def test_hermitian_counts_match_paper(nmr, expected_t):
    algo = generate_sfc_2d_hermitian(*nmr)
    assert algo.t == expected_t


def test_hermitian_exact_rational():
    algo = generate_sfc_2d_hermitian(6, 6, 3)
    rng = np.random.RandomState(7)
    x = [[Fraction(int(v), int(d)) for v, d in zip(r1, r2)]
         for r1, r2 in zip(rng.randint(-20, 21, (algo.L, algo.L)),
                           rng.randint(1, 5, (algo.L, algo.L)))]
    w = [[Fraction(int(v)) for v in row]
         for row in rng.randint(-20, 21, (algo.R, algo.R))]
    got = algo.conv2d_exact(x, w)
    for mr in range(algo.M):
        for mc in range(algo.M):
            want = sum(x[mr + a][mc + b] * w[a][b]
                       for a in range(algo.R) for b in range(algo.R))
            assert got[mr][mc] == want


def test_hermitian_numeric_float():
    """Float64 execution through the flat matrices stays exact to 1e-9."""
    algo = generate_sfc_2d_hermitian(6, 6, 3)
    rng = np.random.RandomState(0)
    x = rng.randn(algo.L, algo.L)
    w = rng.randn(algo.R, algo.R)
    tx = algo.bt() @ x.reshape(-1)
    tw = algo.g() @ w.reshape(-1)
    y = (algo.at() @ (tx * tw)).reshape(algo.M, algo.M)
    ref = np.array([[np.sum(x[mr:mr + 3, mc:mc + 3] * w)
                     for mc in range(algo.M)] for mr in range(algo.M)])
    np.testing.assert_allclose(y, ref, rtol=1e-8, atol=1e-8)


def test_headline_368x():
    """The paper's 3.68x multiplication reduction, now executed: 324/88."""
    algo = generate_sfc_2d_hermitian(6, 6, 3)
    direct = algo.M ** 2 * algo.R ** 2
    assert direct / algo.t == pytest.approx(3.6818, abs=1e-3)
