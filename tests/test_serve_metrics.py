"""Streaming metrics: histogram percentile accuracy (the <10% geometric
-bucket error bound), SLO attainment accounting, the snapshot the
serving benchmark rows come from, and the histogram-mutation lock
discipline (every record happens under the registry lock — unlocked
records race and lose observations)."""
import threading

import numpy as np
import pytest

from repro.serve import LatencyHistogram, MetricsRegistry


# ----------------------------------------------------------------------
# histogram
# ----------------------------------------------------------------------
def test_percentiles_within_bucket_error_bound():
    h = LatencyHistogram()
    xs = np.linspace(1.0, 1000.0, 2000)       # known order statistics
    for x in xs:
        h.record(float(x))
    assert h.count == 2000
    assert h.max == pytest.approx(1000.0)
    assert h.mean == pytest.approx(float(np.mean(xs)), rel=1e-6)
    for p in (50, 95, 99):
        exact = float(np.percentile(xs, p))
        assert h.percentile(p) == pytest.approx(exact, rel=0.10), \
            f"p{p} outside the 10% geometric-bucket bound"
    assert h.percentile(50) < h.percentile(95) < h.percentile(99)


def test_empty_and_single_sample():
    h = LatencyHistogram()
    assert h.percentile(99) == 0.0 and h.mean == 0.0
    h.record(5.0)
    assert h.percentile(50) <= h.max == 5.0
    assert h.percentile(50) == pytest.approx(5.0, rel=0.10)


def test_overflow_clamps_to_observed_max():
    h = LatencyHistogram()
    h.record(1e9)                             # far past the last bound
    h.record(2.0)
    assert h.percentile(100) == 1e9           # clamped to max, not a bound
    assert h.summary()["max_ms"] == 1e9


def test_negative_input_clamped():
    h = LatencyHistogram()
    h.record(-3.0)
    assert h.count == 1 and h.max == 0.0


def test_summary_keys():
    h = LatencyHistogram()
    h.record(1.0)
    assert set(h.summary()) == {"count", "mean_ms", "p50_ms", "p95_ms",
                                "p99_ms", "max_ms"}


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
def test_slo_attainment_per_class_and_overall():
    m = MetricsRegistry()
    assert m.slo_attainment() == 1.0          # nothing finished: no misses
    for met in (True, True, False):
        m.record_slo("interactive", met)
    m.record_slo("batch", True)
    assert m.slo_attainment("interactive") == pytest.approx(2 / 3)
    assert m.slo_attainment("batch") == 1.0
    assert m.slo_attainment() == pytest.approx(3 / 4)
    assert m.slo_attainment("unknown") == 1.0


def test_occupancy_and_queue_depth_tracking():
    m = MetricsRegistry()
    m.record_dispatch(occupancy=3, imgs_per_step=3, queue_depth=2,
                      service_ms=4.0)
    m.record_dispatch(occupancy=1, imgs_per_step=1, queue_depth=0,
                      service_ms=2.0)
    occ = m.batch_occupancy()
    assert occ["dispatches"] == 2
    assert occ["mean"] == 2.0 and occ["max"] == 3
    assert occ["imgs_per_step_mean"] == 2.0 and occ["imgs_per_step_max"] == 3
    snap = m.snapshot()
    assert snap["queue_depth"] == {"mean": 1.0, "max": 2}
    assert snap["service_ms"]["count"] == 2


def test_request_recording_and_pad_waste():
    m = MetricsRegistry()
    m.record_request(queue_wait_ms=1.0, e2e_ms=5.0, slo_name="batch",
                     met=True, real_px=64, padded_px=144)
    m.record_request(queue_wait_ms=2.0, e2e_ms=6.0, slo_name="batch",
                     met=True, real_px=144, padded_px=144)
    snap = m.snapshot()
    assert snap["counters"]["completed"] == 2
    assert snap["pad_waste_frac"] == pytest.approx((288 - 208) / 288)
    assert snap["e2e_ms"]["count"] == 2
    assert snap["slo"]["batch"]["met"] == 2
    assert snap["slo"]["batch"]["attainment"] == 1.0


def test_custom_counters():
    m = MetricsRegistry()
    m.inc("batch_pad_imgs", 3)
    m.inc("batch_pad_imgs")
    assert m.snapshot()["counters"]["batch_pad_imgs"] == 4


def test_empty_snapshot_is_complete():
    snap = MetricsRegistry().snapshot()
    assert snap["pad_waste_frac"] == 0.0
    assert snap["slo_attainment"] == 1.0
    assert snap["batch_occupancy"]["dispatches"] == 0
    assert snap["queue_depth"] == {"mean": 0.0, "max": 0}
    assert snap["hold_ms"]["count"] == 0
    assert snap["queue_wait_by_class"] == {}
    assert snap["e2e_by_class"] == {}


def test_per_class_latency_histograms():
    m = MetricsRegistry()
    m.record_request(queue_wait_ms=10.0, e2e_ms=15.0, slo_name="interactive",
                     met=True, real_px=1, padded_px=1)
    m.record_request(queue_wait_ms=100.0, e2e_ms=120.0, slo_name="batch",
                     met=True, real_px=1, padded_px=1)
    snap = m.snapshot()
    assert snap["queue_wait_by_class"]["interactive"]["count"] == 1
    assert snap["e2e_by_class"]["batch"]["count"] == 1
    # per-class splits partition the global histogram
    assert snap["queue_wait_ms"]["count"] == 2
    assert snap["e2e_by_class"]["interactive"]["max_ms"] == 15.0
    assert snap["e2e_by_class"]["batch"]["max_ms"] == 120.0


def test_hold_recording_counts_aged_dispatches():
    m = MetricsRegistry()
    m.record_hold(0.0)                         # immediate dispatch
    m.record_hold(12.5)                        # aged
    snap = m.snapshot()
    assert snap["hold_ms"]["count"] == 2
    assert snap["hold_ms"]["max_ms"] == 12.5
    assert snap["counters"]["aged_dispatches"] == 1


def test_threaded_recording_loses_no_observations():
    """Regression for the histogram lock races: ``record_dispatch``
    recorded ``service_ms`` outside the registry lock and
    ``record_request`` recorded ``queue_wait_ms``/``e2e_ms`` with no lock
    at all — ``LatencyHistogram.record`` is a non-atomic
    read-modify-write, so concurrent threads silently lost observations
    and the ``count == completed`` ledger drifted."""
    m = MetricsRegistry()
    n_threads, per_thread = 8, 400

    def worker(k):
        for i in range(per_thread):
            m.record_request(queue_wait_ms=float(i % 7), e2e_ms=float(i),
                             slo_name="interactive" if i % 2 else "batch",
                             met=True, real_px=1, padded_px=2)
            m.record_dispatch(occupancy=1, imgs_per_step=1, queue_depth=0,
                              service_ms=float(i % 5))

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = n_threads * per_thread
    snap = m.snapshot()
    assert snap["counters"]["completed"] == total
    assert snap["queue_wait_ms"]["count"] == total     # ledger holds
    assert snap["e2e_ms"]["count"] == total
    assert snap["service_ms"]["count"] == total
    by_class = snap["queue_wait_by_class"]
    assert (by_class["interactive"]["count"]
            + by_class["batch"]["count"]) == total
    assert m.e2e_ms.sum == pytest.approx(
        n_threads * sum(range(per_thread)))            # no lost updates
