"""Fast-conv execution vs direct oracle: 2-D, 1-D depthwise, iterative."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")    # property tests skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import (conv1d_depthwise_causal_direct, conv2d_direct,
                        fastconv1d_depthwise_causal, fastconv2d,
                        generate_sfc, generate_winograd, paper_algorithms)
from repro.core.iterative import iterative_conv1d, large_kernel_report

ALGOS = {n: a for n, a in paper_algorithms().items() if a.kind != "direct"}


@pytest.mark.parametrize("name", list(ALGOS))
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_fastconv2d_matches_direct(name, padding):
    algo = ALGOS[name]
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 13, 15, 4), jnp.float32)
    w = jnp.asarray(rng.randn(algo.R, algo.R, 4, 6), jnp.float32)
    y = fastconv2d(x, w, algo, padding=padding)
    yref = conv2d_direct(x, w, padding=padding)
    assert y.shape == yref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(5, 23), st.integers(5, 23),
       st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
def test_fastconv2d_property_shapes(b, h, w_, c, seed):
    algo = ALGOS["SFC-6(6x6,3x3)"]
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(b, h, w_, c), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, c, 3), jnp.float32)
    y = fastconv2d(x, w, algo, padding="SAME")
    yref = conv2d_direct(x, w, padding="SAME")
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("nmr", [(6, 3, 4), (6, 6, 4), (4, 4, 3), (6, 7, 3)])
def test_fastconv1d_depthwise(nmr):
    N, M, R = nmr
    algo = generate_sfc(N, M, R)
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(2, 37, 8), jnp.float32)
    w = jnp.asarray(rng.randn(R, 8), jnp.float32)
    y = fastconv1d_depthwise_causal(x, w, algo)
    yref = conv1d_depthwise_causal_direct(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=2e-4, atol=2e-4)


def test_iterative_large_kernel():
    """App. B: nested SFC for a 30-tap kernel, exact + ~5% of direct."""
    inner = generate_sfc(6, 5, 5)
    outer = generate_sfc(6, 6, 6)
    rng = np.random.RandomState(0)
    Rw, Mt = inner.R * outer.R, inner.M * outer.M
    x = jnp.asarray(rng.randn(Mt + Rw - 1), jnp.float64)
    w = jnp.asarray(rng.randn(Rw), jnp.float64)
    y = iterative_conv1d(x, w, inner, outer)
    yref = jnp.array([(x[m:m + Rw] * w).sum() for m in range(Mt)])
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-4, atol=1e-4)
    rep = large_kernel_report(30, inner, outer)
    assert rep["ratio_pct"] < 8.0     # paper: ~3% with its uneven split


def test_iterative_alignment_check():
    with pytest.raises(ValueError):
        iterative_conv1d(jnp.zeros(30), jnp.zeros(12),
                         generate_sfc(6, 6, 4), generate_sfc(6, 3, 3))
