"""Synthetic traffic determinism + distribution sanity.

Every generator is seeded (np.random.RandomState), so the assertions on
means/variability are exact reruns of one fixed draw — no statistical
flakiness, the tolerances just document what the fixed draw looks like.
"""
import numpy as np
import pytest

from repro.serve import (PromptStream, ShapeMix, SLO_CLASSES, TrafficEvent,
                         bursty_arrivals, default_shape_mix,
                         poisson_arrivals, synthesize)


# ----------------------------------------------------------------------
# arrival processes
# ----------------------------------------------------------------------
def test_poisson_deterministic_and_monotone():
    a = poisson_arrivals(50.0, 200, seed=4)
    b = poisson_arrivals(50.0, 200, seed=4)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, poisson_arrivals(50.0, 200, seed=5))
    assert np.all(np.diff(a) > 0)
    assert a.shape == (200,)


def test_poisson_mean_rate():
    a = poisson_arrivals(100.0, 4000, seed=0)
    # 4000 arrivals at 100 Hz span ~40s; the fixed draw is within 10%
    assert a[-1] == pytest.approx(40.0, rel=0.1)


def test_poisson_rejects_bad_rate():
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 10)


def test_bursty_deterministic_keeps_average_rate():
    a = bursty_arrivals(100.0, 4000, seed=0)
    assert np.array_equal(a, bursty_arrivals(100.0, 4000, seed=0))
    assert np.all(np.diff(a) > 0)
    # MMPP compensates the burst phase: long-run average stays ~rate_hz
    assert a[-1] == pytest.approx(40.0, rel=0.25)


def test_bursty_clumps_more_than_poisson():
    """The point of the bursty process: gap variability well above the
    exponential's CV of 1 (same seed, same average rate)."""
    gp = np.diff(poisson_arrivals(100.0, 4000, seed=0))
    gb = np.diff(bursty_arrivals(100.0, 4000, seed=0))
    cv = lambda g: np.std(g) / np.mean(g)
    assert cv(gb) > 1.3 * cv(gp)


def test_bursty_rejects_bad_duty():
    with pytest.raises(ValueError):
        bursty_arrivals(10.0, 5, duty=1.0)


# ----------------------------------------------------------------------
# shape / SLO mixes
# ----------------------------------------------------------------------
def test_shape_mix_weights_validated_and_respected():
    with pytest.raises(ValueError):
        ShapeMix(shapes=((4, 4), (8, 8)), weights=(1.0,))
    mix = ShapeMix(shapes=((4, 4), (8, 8)), weights=(0.0, 1.0))
    rng = np.random.RandomState(0)
    assert all(mix.sample(rng) == (8, 8) for _ in range(20))


def test_default_shape_mix_respects_cap():
    assert all(h <= 12 and w <= 12
               for h, w in default_shape_mix(12).shapes)
    assert (28, 28) in default_shape_mix(28).shapes


def test_synthesize_deterministic_schedule():
    ev1 = synthesize(50, process="poisson", rate_hz=20.0, seed=9)
    ev2 = synthesize(50, process="poisson", rate_hz=20.0, seed=9)
    assert ev1 == ev2
    assert len(ev1) == 50
    assert all(isinstance(e, TrafficEvent) for e in ev1)
    assert [e.t for e in ev1] == sorted(e.t for e in ev1)
    mix = set(default_shape_mix().shapes)
    assert all(e.shape in mix for e in ev1)
    names = {e.slo.name for e in ev1}
    assert names <= set(SLO_CLASSES) and len(names) == 2


def test_synthesize_shapes_independent_of_arrival_gaps():
    """Same seed, different process: the shape/SLO stream must not shift
    when only the arrival times change."""
    a = synthesize(30, process="poisson", rate_hz=20.0, seed=2)
    b = synthesize(30, process="bursty", rate_hz=20.0, seed=2)
    assert [e.shape for e in a] == [e.shape for e in b]
    assert [e.slo for e in a] == [e.slo for e in b]
    assert [e.t for e in a] != [e.t for e in b]


# ----------------------------------------------------------------------
# prompt stream
# ----------------------------------------------------------------------
def test_prompt_stream_uniform_range():
    ps = PromptStream(100, lengths=(4, 16), seed=1)
    lens = [len(ps.next_prompt()) for _ in range(200)]
    assert min(lens) >= 4 and max(lens) <= 15
    assert len(set(lens)) > 5                 # actually a distribution
    ids = [t for _ in range(20) for t in ps.next_prompt()]
    assert all(0 <= t < 100 for t in ids)


def test_prompt_stream_deterministic():
    a = PromptStream(100, lengths=(4, 16), seed=7)
    b = PromptStream(100, lengths=(4, 16), seed=7)
    assert [a.next_prompt() for _ in range(10)] == \
        [b.next_prompt() for _ in range(10)]


def test_prompt_stream_explicit_lengths_and_weights():
    ps = PromptStream(100, lengths=[3, 30], weights=[1.0, 0.0], seed=0)
    assert all(len(ps.next_prompt()) == 3 for _ in range(20))
    bimodal = PromptStream(100, lengths=[3, 30], seed=0)
    assert {len(bimodal.next_prompt()) for _ in range(50)} == {3, 30}


@pytest.mark.parametrize("kwargs", [
    dict(vocab=0),
    dict(vocab=10, lengths=(8, 4)),
    dict(vocab=10, lengths=[4, 0]),
    dict(vocab=10, lengths=[4, 8], weights=[1.0]),
])
def test_prompt_stream_validation(kwargs):
    with pytest.raises(ValueError):
        PromptStream(**kwargs)
