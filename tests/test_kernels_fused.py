"""Fused single-pass Pallas SFC kernel + measured-latency planner tests.

Parity contract: the fused kernel must match the ``reference`` backend's
static-int8 simulation to the API's existing epsilon (rtol/atol 1e-4) and
the staged Pallas pipeline bit-for-bit (identical integer grid + scales).
The parity matrix itself lives in the shared oracle
(``repro.testing.assert_conv_conformance``) that
``tests/test_conformance.py`` fuzzes; the cases here pin the specific
shapes this kernel has regressed on plus the planner plumbing.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConvSpec, plan, select_algorithm, tuning
from repro.core import conv2d as c2d
from repro.core.generator import generate_sfc
from repro.kernels import ops, ref
from repro.kernels.sfc_fused import sfc_fused_conv2d
from repro.kernels.sfc_tdmm import tdmm_int8
from repro.quant.fake_quant import INT4_FREQ, INT8_FREQ
from repro.testing import assert_conv_conformance

REGISTRY_ALGOS = ["sfc4_4", "sfc6_6", "sfc6_7"]

# hermetic tuning cache: the autouse fixture in conftest.py points
# REPRO's timing cache at a per-test tmp path

# tier-1 keeps one cheap variant slice per case; the conformance suite
# (and its CI job) covers the full variant grid
FAST_VARIANTS = (dict(k_block=128, rows_per_step=1),)


# ---------------------------------------------------------------------------
# fused kernel vs reference backend / staged pipeline (shared oracle)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("algo_name", REGISTRY_ALGOS)
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_fused_backend_parity(algo_name, padding):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 13, 13, 16), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 16, 8) * 0.2, jnp.float32)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, padding=padding,
                               quant=INT8_FREQ)
    assert (plan(spec, backend="pallas", algo=algo_name).config
            or tuning.DEFAULT_FUSED).datapath == "fused"
    assert_conv_conformance(x, w, spec, algo_name, variants=FAST_VARIANTS)


@pytest.mark.parametrize("shape,cout", [
    ((1, 9, 11, 5), 7),        # odd spatial, tiny ragged channels
    ((1, 17, 13, 19), 21),     # odd spatial, C_in/C_out not block multiples
])
def test_fused_odd_shapes_and_ragged_channels(shape, cout):
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(*shape), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, shape[-1], cout) * 0.2, jnp.float32)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)
    assert_conv_conformance(x, w, spec, "sfc6_6", variants=FAST_VARIANTS)


def test_fused_sub8bit_policy_uses_spec_bits():
    """INT4 policy must clip on the +/-7 grid, not the int8 carrier's."""
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(1, 12, 12, 12), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 12, 6) * 0.2, jnp.float32)
    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT4_FREQ)
    assert_conv_conformance(x, w, spec, "sfc6_6", variants=FAST_VARIANTS)


def test_fused_xq_cache_disabled_recompute_path(monkeypatch):
    """Multiple C_out blocks with the strip cache too small to use."""
    import repro.kernels.sfc_fused as sf
    monkeypatch.setattr(sf, "XQ_CACHE_BYTES", 0)
    rng = np.random.RandomState(8)
    algo = generate_sfc(6, 6, 3)
    x = jnp.asarray(rng.randn(1, 10, 16, 70), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 70, 48) * 0.1, jnp.float32)
    tx, _ = c2d.transform_input_2d(x, algo)
    act = jnp.abs(tx).max(axis=(0, 1, 2, 5)) / 127 + 1e-9
    tw = c2d.transform_weights_2d(w, algo)
    w_scale = jnp.abs(tw).max(axis=2) / 127 + 1e-12
    wq = ops.quantize_weights(w, algo, w_scale)
    want = ref.quantized_fastconv2d_ref(x, w, algo, act, w_scale)
    got = sfc_fused_conv2d(x, wq, act, w_scale, algo,
                           k_block=32, cout_block=16)
    assert bool(jnp.all(got == want))


def test_fused_large_cin_kblocked_accumulation():
    """C_in beyond one k block: int32 scratch accumulates across k steps."""
    rng = np.random.RandomState(2)
    algo = generate_sfc(6, 6, 3)
    x = jnp.asarray(rng.randn(1, 12, 12, 300), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 300, 40) * 0.05, jnp.float32)
    tx, _ = c2d.transform_input_2d(x, algo)
    act = jnp.abs(tx).max(axis=(0, 1, 2, 5)) / 127 + 1e-9
    tw = c2d.transform_weights_2d(w, algo)
    w_scale = jnp.abs(tw).max(axis=2) / 127 + 1e-12
    wq = ops.quantize_weights(w, algo, w_scale)
    want = ref.quantized_fastconv2d_ref(x, w, algo, act, w_scale)
    # 3 k-steps (300 -> 128+128+44-pad) and a ragged C_out block
    got = sfc_fused_conv2d(x, wq, act, w_scale, algo,
                           k_block=128, cout_block=32)
    assert bool(jnp.all(got == want))   # same integer grid: bit-exact
    # the batched grid accumulates the identical k-step sequence per strip
    batched = sfc_fused_conv2d(x, wq, act, w_scale, algo,
                               k_block=128, cout_block=32, rows_per_step=2)
    assert bool(jnp.all(batched == want))


@pytest.mark.parametrize("algo_name", ["sfc6_6"])
def test_fused_bitexact_vs_staged(algo_name):
    """Fused and staged pipelines share scales/grid: identical outputs."""
    rng = np.random.RandomState(3)
    algo = generate_sfc(6, 6, 3)
    x = jnp.asarray(rng.randn(2, 11, 14, 24), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 24, 10) * 0.2, jnp.float32)
    tx, _ = c2d.transform_input_2d(x, algo)
    act = jnp.abs(tx).max(axis=(0, 1, 2, 5)) / 127 + 1e-9
    tw = c2d.transform_weights_2d(w, algo)
    w_scale = jnp.abs(tw).max(axis=2) / 127 + 1e-12
    wq = ops.quantize_weights(w, algo, w_scale)
    y_fused = sfc_fused_conv2d(x, wq, act, w_scale, algo)
    y_staged = ops.quantized_fastconv2d(x, wq, act, w_scale, algo)
    assert bool(jnp.all(y_fused == y_staged))


# ---------------------------------------------------------------------------
# k-blocked tdmm + single-gather extract_tiles (staged-path satellites)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("P,T,K,N,kb", [(4, 8, 16, 8, 8),
                                        (7, 33, 19, 21, 7),
                                        (5, 40, 300, 24, 128)])
def test_tdmm_kblock_parity(P, T, K, N, kb):
    rng = np.random.RandomState(4)
    xq = jnp.asarray(rng.randint(-127, 128, (P, T, K)), jnp.int8)
    wq = jnp.asarray(rng.randint(-127, 128, (P, K, N)), jnp.int8)
    sx = jnp.asarray(rng.rand(P), jnp.float32)
    sw = jnp.asarray(rng.rand(P, N), jnp.float32)
    want = ref.tdmm_int8_ref(xq, wq, sx, sw)
    got = tdmm_int8(xq, wq, sx, sw, k_block=kb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_extract_tiles_single_gather_parity():
    """Single-gather tiling == the transform_input_2d tiling (oracle)."""
    rng = np.random.RandomState(5)
    algo = generate_sfc(6, 7, 3)
    x = jnp.asarray(rng.randn(2, 13, 17, 5), jnp.float32)
    for padding in ("SAME", "VALID"):
        tiles, geom = ops.extract_tiles(x, algo, padding)
        bt = jnp.asarray(algo.bt(), jnp.float32)
        got = jnp.einsum("ti,nijc,uj->ntuc", bt, tiles, bt)
        want, _ = c2d.transform_input_2d(x, algo, padding)
        want = want.reshape(-1, algo.t, algo.t, x.shape[-1])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# measured-latency planner: timing cache overrides the BOPs ranking
# ---------------------------------------------------------------------------
def test_seeded_timing_cache_overrides_bops_ranking():
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=64, out_channels=64,
                    spatial=(56, 56), quant=INT8_FREQ)
    bops_pick = select_algorithm(spec)
    assert bops_pick != "direct"            # BOPs favors a fast algorithm
    assert select_algorithm(spec, "pallas") == bops_pick  # nothing measured
    # seed measurements saying direct is fastest on this host
    tuning.record(spec, "pallas", bops_pick, 5e-3)
    tuning.record(spec, "pallas", "direct", 1e-4)
    assert select_algorithm(spec, "pallas") == "direct"
    p = plan(spec, backend="pallas", algo="auto")
    assert p.algo_name == "direct"
    # the reference backend has no measurements: BOPs ranking still applies
    assert select_algorithm(spec, "reference") == bops_pick
    assert plan(spec, backend="reference", algo="auto").algo_name == bops_pick


def test_tuned_config_rides_the_plan():
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=32, out_channels=32,
                    spatial=(24, 24), quant=INT8_FREQ)
    cfg = tuning.KernelConfig(datapath="staged", k_block=64)
    tuning.record(spec, "pallas", "sfc6_6", 2e-3, cfg)
    p = plan(spec, backend="pallas", algo="sfc6_6")
    assert p.config == cfg
    # staged-config plans execute (and agree with the fused default)
    rng = np.random.RandomState(6)
    x = jnp.asarray(rng.randn(1, 24, 24, 32), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 32, 32) * 0.1, jnp.float32)
    tx, _ = c2d.transform_input_2d(x, p.algorithm)
    act = jnp.abs(tx).max(axis=(0, 1, 2, 5)) / 127 + 1e-9
    prep = p.prepare_weights(w, act_scale=act)
    y_staged = p.apply(x, prep)
    y_fused = dataclasses.replace(p, config=tuning.DEFAULT_FUSED).apply(
        x, prep)
    assert bool(jnp.all(y_staged == y_fused))


def test_autotune_records_and_planner_consumes(deterministic_time_fn):
    # deterministic_time_fn (conftest) replaces wall-clock with call-order
    # ranks: direct is measured first, so it "wins" reproducibly and the
    # ranking assertion below cannot flake on host-load noise
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=8, out_channels=8,
                    spatial=(12, 12), quant=INT8_FREQ)
    bops_pick = select_algorithm(spec)
    res = tuning.autotune(
        spec, "pallas", algos=["sfc6_6", bops_pick], reps=1,
        candidates=(tuning.DEFAULT_FUSED,))
    assert "sfc6_6" in res and "direct" in res
    measured = tuning.lookup(spec, "pallas")
    assert measured["sfc6_6"]["time_s"] > 0
    # the BOPs-best candidate was timed, so the measured ranking governs
    picked = select_algorithm(spec, "pallas")
    assert picked == min(measured, key=lambda n: measured[n]["time_s"])
    assert picked == "direct"          # measured first => lowest fake time


def test_partial_timing_cache_falls_back_to_bops():
    """A sweep that never timed the BOPs-best candidate must not hide it."""
    spec = ConvSpec(rank=2, kernel_size=3, in_channels=128, out_channels=128,
                    spatial=(28, 28), quant=INT8_FREQ)
    bops_pick = select_algorithm(spec)
    assert bops_pick != "direct"
    tuning.record(spec, "pallas", "direct", 1e-6)   # bops_pick never timed
    assert select_algorithm(spec, "pallas") == bops_pick
