"""Deterministic coverage of the decode slot loop's admission state
machine (extracted from launch/serve.py into repro.serve.slots).

No model, no jax: ``step_fn`` is a pure-numpy stub, prompt lengths are
pinned through PromptStream's explicit-length mode, and the previously
untested branches are pinned down:

  * drain: once the admission budget is spent, finished slots deactivate
    and the loop ends with exactly ``requests`` served;
  * KV wrap: a sequence hitting ``max_len - 1`` is truncated, counted as
    served AND wrapped, and its replacement honors the same budget.
"""
import numpy as np
import pytest

from repro.serve import PromptStream, SlotLoop


def _stream(length, vocab=50, seed=0):
    """Prompt source with every prompt exactly ``length`` tokens."""
    return PromptStream(vocab, lengths=[length], seed=seed)


def _echo_step(tok, pos):
    return np.full(tok.shape[0], 7, np.int32)


def test_serves_exact_budget_and_token_accounting():
    # prompt L=5, gen G=3: each request costs exactly L+G slot-steps
    loop = SlotLoop(batch=2, gen=3, max_len=64, requests=5,
                    prompts=_stream(5))
    stats = loop.run(_echo_step)
    assert stats.served == 5
    assert stats.wrapped == 0
    assert stats.tokens == 5 * (5 + 3)
    assert stats.latency_ms.count == 5
    assert stats.tok_per_s > 0


def test_drain_surplus_slots_idle_from_start():
    """requests < batch: only ``requests`` slots ever activate, and the
    loop still terminates with the budget served."""
    loop = SlotLoop(batch=4, gen=2, max_len=64, requests=2,
                    prompts=_stream(3))
    stats = loop.run(_echo_step)
    assert stats.served == 2
    # two active slots, running in lockstep: tokens from them alone
    assert stats.tokens == 2 * (3 + 2)
    assert stats.steps == 3 + 2               # lockstep: one pass each


def test_drain_after_budget_reached():
    """batch=2, requests=3: one slot swaps in the third prompt, the other
    drains; loop ends at exactly 3 served (never over-serves)."""
    loop = SlotLoop(batch=2, gen=2, max_len=64, requests=3,
                    prompts=_stream(4))
    stats = loop.run(_echo_step)
    assert stats.served == 3
    assert stats.wrapped == 0
    assert stats.tokens == 3 * (4 + 2)


def test_kv_wrap_counts_and_readmits_within_budget():
    """The pos >= max_len - 1 safety wrap: prompt 4 + gen 100 overruns a
    6-token KV cache, so every request truncates at pos 5 — served AND
    wrapped, replacements admitted under the same budget."""
    loop = SlotLoop(batch=1, gen=100, max_len=6, requests=3,
                    prompts=_stream(4))
    stats = loop.run(_echo_step)
    assert stats.served == 3
    assert stats.wrapped == 3
    # each request: pos walks 1..5 -> 5 steps, truncated at max_len-1
    assert stats.tokens == 3 * 5
    assert stats.latency_ms.count == 3        # wrap path records latency


def test_kv_wrap_mixed_with_normal_completion():
    """gen budget small enough to finish BEFORE the wrap: no truncation,
    even with a tight max_len."""
    loop = SlotLoop(batch=1, gen=2, max_len=8, requests=2,
                    prompts=_stream(4))
    stats = loop.run(_echo_step)
    assert stats.served == 2 and stats.wrapped == 0


def test_prompt_consumption_ignores_predictions():
    """While consuming the prompt the loop must feed prompt tokens, not
    step_fn predictions; predictions only enter during generation."""
    seen = []

    def recording_step(tok, pos):
        seen.append(int(tok[0, 0]))
        return np.full(tok.shape[0], 7, np.int32)

    prompts = _stream(4, seed=3)
    expect = PromptStream(50, lengths=[4], seed=3).next_prompt()
    loop = SlotLoop(batch=1, gen=2, max_len=64, requests=1, prompts=prompts)
    stats = loop.run(recording_step)
    assert stats.served == 1
    # steps feed prompt[0..3], then the model's own prediction (7) twice
    assert seen == expect + [7, 7]


def test_max_steps_safety_bound():
    loop = SlotLoop(batch=1, gen=100, max_len=1024, requests=1,
                    prompts=_stream(4))
    stats = loop.run(_echo_step, max_steps=5)
    assert stats.steps == 5 and stats.served == 0


@pytest.mark.parametrize("kwargs", [
    dict(batch=0, gen=1, max_len=4, requests=1),
    dict(batch=1, gen=0, max_len=4, requests=1),
    dict(batch=1, gen=1, max_len=1, requests=1),
    dict(batch=1, gen=1, max_len=4, requests=0),
])
def test_invalid_args_raise(kwargs):
    with pytest.raises(ValueError):
        SlotLoop(prompts=_stream(4), **kwargs)
