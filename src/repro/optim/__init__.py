"""Optimizers + schedules + gradient compression."""
from repro.optim.optimizers import (AdamW, AdamWState, SGD, cosine_schedule,
                                    global_norm)
from repro.optim.grad_compression import (compress_with_feedback,
                                          compressed_psum, init_residuals)

__all__ = ["AdamW", "AdamWState", "SGD", "cosine_schedule", "global_norm",
           "compress_with_feedback", "compressed_psum", "init_residuals"]
