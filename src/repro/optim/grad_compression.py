"""Gradient compression with error feedback (distributed-optimization trick).

int8 stochastic quantization of gradients before the cross-replica
all-reduce cuts gradient-sync bytes 4x (f32) / 2x (bf16); the residual is
fed back into the next step so the *accumulated* update is unbiased
(error-feedback SGD, Seide et al. / Karimireddy et al.).

Inside ``shard_map`` use ``compressed_psum``; under plain GSPMD jit the
quantize/dequantize pair still shrinks the all-reduce operand (XLA reduces
the int8 tensor).  Convergence is covered by tests/test_optim.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stochastic-rounding symmetric int8; returns (q, scale)."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    y = x / scale
    noise = jax.random.uniform(key, x.shape) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, residuals, key):
    """grads+residual -> (int8-roundtripped grads, new residuals).

    The returned grads have passed through the int8 bottleneck; residuals
    carry the quantization error to the next step.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = jax.tree_util.tree_leaves(residuals)
    keys = jax.random.split(key, len(leaves))
    new_g, new_r = [], []
    for g, r, k in zip(leaves, res_leaves, keys):
        target = g.astype(jnp.float32) + r
        q, s = quantize_int8(target, k)
        deq = dequantize_int8(q, s)
        new_g.append(deq.astype(g.dtype))
        new_r.append(target - deq)
    return (jax.tree_util.tree_unflatten(treedef, new_g),
            jax.tree_util.tree_unflatten(treedef, new_r))


def init_residuals(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jnp.ndarray, axis_name: str, key) -> jnp.ndarray:
    """int8 quantize -> psum -> dequant (for explicit shard_map pipelines)."""
    q, s = quantize_int8(x, key)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    smax = jax.lax.pmax(s, axis_name)
    return total.astype(jnp.float32) * smax
