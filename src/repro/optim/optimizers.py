"""Native optimizers (optax is not available offline): AdamW + SGD,
cosine/linear schedules, global-norm clipping.

Moments are kept in f32 regardless of param dtype (bf16-param training);
update math runs in f32 and casts back to the param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(zeros, params),
                          nu=jax.tree_util.tree_map(zeros, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def apply(self, params, grads, state: AdamWState
              ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        step = state.step + 1
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            g = g * scale
            m_new = self.b1 * m + (1 - self.b1) * g
            v_new = self.b2 * v + (1 - self.b2) * g * g
            mhat = m_new / b1c
            vhat = v_new / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if jnp.issubdtype(p.dtype, jnp.floating):
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
                m_new, v_new

        out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                            is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step, new_mu, new_nu), \
            {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(peak_lr: float, warmup: int, total: int
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * peak_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float = 0.1
    momentum: float = 0.9

    def init(self, params):
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
            nu=())

    def apply(self, params, grads, state):
        def upd(p, g, m):
            m_new = self.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * m_new
                    ).astype(p.dtype), m_new
        out = jax.tree_util.tree_map(upd, params, grads, state.mu)
        new_params = jax.tree_util.tree_map(
            lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree_util.tree_map(
            lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(state.step + 1, new_mu, ()), {}
