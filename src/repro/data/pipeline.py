"""Deterministic synthetic data pipelines (offline container — no datasets).

Production-shaped: host-sharded (each host materializes only its slice of
the global batch), seeded/stateless (batch i is a pure function of (seed,
i) so restarts and elastic rescales reproduce the stream), with background
prefetch.  Token streams follow a Zipf unigram + Markov bigram mixture so
models actually have structure to learn (losses fall; used by the
end-to-end examples and convergence tests).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    zipf_a: float = 1.2
    markov_weight: float = 0.5      # fraction of tokens from bigram chain


class SyntheticTokenPipeline:
    """batch(i) -> {'tokens': (B_host, S), 'labels': (B_host, S)} int32."""

    def __init__(self, cfg: TokenPipelineConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.host_count
        rng = np.random.RandomState(cfg.seed)
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self._unigram = (ranks ** -cfg.zipf_a)
        self._unigram /= self._unigram.sum()
        # sparse deterministic bigram successor table
        self._succ = rng.randint(0, V, size=(V, 4))

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + index * 65_537 + cfg.host_index)
            % (2 ** 31))
        B, S, V = self.host_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.randint(0, V, size=B)
        uni = rng.choice(V, size=(B, S), p=self._unigram)
        use_markov = rng.rand(B, S) < cfg.markov_weight
        pick = rng.randint(0, self._succ.shape[1], size=(B, S))
        for t in range(S):
            succ = self._succ[toks[:, t], pick[:, t]]
            toks[:, t + 1] = np.where(use_markov[:, t], succ, uni[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


@dataclasses.dataclass(frozen=True)
class ImagePipelineConfig:
    image_size: int
    n_classes: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1


class SyntheticImagePipeline:
    """Class-conditional structured images (learnable, CNN benchmarks).

    Each class is a fixed random low-frequency template; samples are
    template + noise, so accuracy above chance is meaningful and PTQ
    degradation is measurable.
    """

    def __init__(self, cfg: ImagePipelineConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.host_count
        rng = np.random.RandomState(cfg.seed)
        s = cfg.image_size
        base = rng.randn(cfg.n_classes, s // 4 + 1, s // 4 + 1, 3)
        templates = np.stack([
            np.kron(base[c], np.ones((4, 4, 1)))[:s, :s, :]
            for c in range(cfg.n_classes)])
        self._templates = (templates /
                           np.abs(templates).max()).astype(np.float32)

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + index * 65_537 + cfg.host_index)
            % (2 ** 31))
        B = self.host_batch
        labels = rng.randint(0, cfg.n_classes, size=B)
        imgs = self._templates[labels] + \
            0.35 * rng.randn(B, cfg.image_size, cfg.image_size, 3
                             ).astype(np.float32)
        return {"images": imgs.astype(np.float32),
                "labels": labels.astype(np.int32)}

    def __iter__(self):
        i = 0
        while True:
            yield self.batch(i)
            i += 1


class Prefetcher:
    """Background-thread prefetch (bounded queue) around any pipeline."""

    def __init__(self, pipeline, depth: int = 2, start_index: int = 0):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            i = start_index
            while not self._stop.is_set():
                try:
                    self._q.put(pipeline.batch(i), timeout=0.5)
                    i += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
