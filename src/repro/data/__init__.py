"""Deterministic synthetic data pipelines."""
from repro.data.pipeline import (ImagePipelineConfig, Prefetcher,
                                 SyntheticImagePipeline,
                                 SyntheticTokenPipeline,
                                 TokenPipelineConfig)

__all__ = ["TokenPipelineConfig", "SyntheticTokenPipeline",
           "ImagePipelineConfig", "SyntheticImagePipeline", "Prefetcher"]
