"""Pallas TPU kernel: SFC input tile transform (+ fused quantization).

Computes TX[n, :, :, c] = B^T @ X[n, :, :, c] @ B for a block of tiles and
channels per grid step.  The transform matrices are {-1, 0, 1} integer
matrices (the paper's additions-only SFT), so on TPU this lowers to cheap
VPU/MXU work; the fused variant also applies static per-frequency scales and
emits int8, saving an HBM round-trip of the f32 transform-domain tensor
(the dominant memory term of the SFC pipeline — see EXPERIMENTS.md §Perf).

VMEM budget per grid step (defaults TILE_BLOCK=8, CHAN_BLOCK=128, L<=14):
  in  : 8 * 14 * 14 * 128 * 4B   = 0.8 MiB
  out : 8 * 14 * 14 * 128 * 1..4B <= 0.8 MiB            (fits 16 MiB VMEM)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_BLOCK = 8
CHAN_BLOCK = 128


def _transform_kernel(bt_ref, x_ref, o_ref):
    bt = bt_ref[...]                                  # (t, L)
    x = x_ref[...]                                    # (TB, L, L, CB)
    y = jnp.einsum("ti,nijc->ntjc", bt, x,
                   preferred_element_type=jnp.float32)
    y = jnp.einsum("uj,ntjc->ntuc", bt, y,
                   preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _transform_quant_kernel(bt_ref, scale_ref, x_ref, o_ref, *, bits: int):
    bt = bt_ref[...]
    x = x_ref[...]
    y = jnp.einsum("ti,nijc->ntjc", bt, x,
                   preferred_element_type=jnp.float32)
    y = jnp.einsum("uj,ntjc->ntuc", bt, y,
                   preferred_element_type=jnp.float32)
    qmax = 2 ** (bits - 1) - 1
    s = scale_ref[...]                                # (t, t)
    q = jnp.clip(jnp.round(y / s[None, :, :, None]), -qmax, qmax)
    o_ref[...] = q.astype(o_ref.dtype)


def _as_operand_dtype(mat: jnp.ndarray, dtype) -> jnp.ndarray:
    """No-op when ``mat`` already matches the operand dtype.

    Callers on the hot path (``repro.kernels.ops``, ``repro.api.backends``)
    pass prepare-time matrices from ``repro.core.conv2d.transform_matrices``
    so this never casts there; the fallback cast only covers direct callers
    handing a mismatched matrix, preserving the old call-time behaviour
    bit for bit.
    """
    return mat if mat.dtype == jnp.dtype(dtype) else mat.astype(dtype)


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    width = [(0, 0)] * x.ndim
    width[axis] = (0, pad)
    return jnp.pad(x, width), pad


@functools.partial(jax.jit, static_argnames=("interpret", "tile_block",
                                             "chan_block"))
def sfc_transform(tiles: jnp.ndarray, bt: jnp.ndarray, *,
                  interpret: bool = True,
                  tile_block: int = TILE_BLOCK,
                  chan_block: int = CHAN_BLOCK) -> jnp.ndarray:
    """tiles (nT, L, L, C) f32 -> (nT, t, t, C) f32."""
    nT, L, _, C = tiles.shape
    t = bt.shape[0]
    tiles, pad_n = _pad_to(tiles, 0, tile_block)
    tiles, pad_c = _pad_to(tiles, 3, chan_block)
    nTp, Cp = tiles.shape[0], tiles.shape[3]
    out = pl.pallas_call(
        _transform_kernel,
        grid=(nTp // tile_block, Cp // chan_block),
        in_specs=[
            pl.BlockSpec((t, L), lambda i, j: (0, 0)),
            pl.BlockSpec((tile_block, L, L, chan_block),
                         lambda i, j: (i, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_block, t, t, chan_block),
                               lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nTp, t, t, Cp), tiles.dtype),
        interpret=interpret,
    )(_as_operand_dtype(bt, tiles.dtype), tiles)
    return out[:nT, :, :, :C]


@functools.partial(jax.jit, static_argnames=("bits", "interpret",
                                             "tile_block", "chan_block"))
def sfc_transform_quantize(tiles: jnp.ndarray, bt: jnp.ndarray,
                           scale: jnp.ndarray, *, bits: int = 8,
                           interpret: bool = True,
                           tile_block: int = TILE_BLOCK,
                           chan_block: int = CHAN_BLOCK) -> jnp.ndarray:
    """tiles (nT, L, L, C) f32 -> int8 (nT, t, t, C), fused static quant."""
    nT, L, _, C = tiles.shape
    t = bt.shape[0]
    tiles, _ = _pad_to(tiles, 0, tile_block)
    tiles, _ = _pad_to(tiles, 3, chan_block)
    nTp, Cp = tiles.shape[0], tiles.shape[3]
    kern = functools.partial(_transform_quant_kernel, bits=bits)
    out = pl.pallas_call(
        kern,
        grid=(nTp // tile_block, Cp // chan_block),
        in_specs=[
            pl.BlockSpec((t, L), lambda i, j: (0, 0)),
            pl.BlockSpec((t, t), lambda i, j: (0, 0)),
            pl.BlockSpec((tile_block, L, L, chan_block),
                         lambda i, j: (i, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_block, t, t, chan_block),
                               lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nTp, t, t, Cp), jnp.int8),
        interpret=interpret,
    )(_as_operand_dtype(bt, tiles.dtype), _as_operand_dtype(scale, tiles.dtype),
      tiles)
    return out[:nT, :, :, :C]
