"""Pallas TPU kernel: the int8 SFC convolution as ONE fused ``pallas_call``.

The staged pipeline (``repro.kernels.ops.quantized_fastconv2d``) runs three
kernels with two full HBM round-trips of the transform-domain tensor in
between — t^2/M^2 times the input footprint (3.06x for SFC-4(4x4,3x3),
2.78x for SFC-6(6x6,3x3)) — and feeds the first kernel a materialized tile
tensor that duplicates every input element L^2/M^2 times (2.25x / 1.78x).
This kernel keeps the whole pipeline on-chip (EXPERIMENTS.md §Perf):

  grid = (B * nH, C_out blocks, C_in k-blocks), k innermost

Per grid step it
  * reads one overlapping (L, W_padded, k_block) input strip straight from
    HBM via an Unblocked BlockSpec index map at row stride M — tiles are
    never materialized;
  * applies the additions-only B^T X B transform per tile column and the
    fused per-frequency intN quantization in VMEM/registers; the quantized
    int8 strips are cached in a VMEM scratch across C_out blocks (bounded
    by ``XQ_CACHE_BYTES``; recomputed per block when they do not fit), so
    the transform runs once per (tile-row, k-block), not once per output
    block;
  * runs the t^2-position int8 MXU matmuls against the matching weight
    k-block and accumulates into an int32 VMEM scratch that persists across
    the C_in k-blocks — so full-K VMEM residency (which caps the staged
    ``tdmm_int8`` near C_in ~ 2048) is never required;
  * on the last k-block dequantizes with the static per-frequency scales
    and applies the correction-term inverse A^T Y A, writing one spatial
    (M, nW*M) output strip.

The transform-domain tensor therefore never touches HBM.

VMEM budget per grid step (f32 in, defaults K_BLOCK=COUT_BLOCK=128, the
VGG-16 224x224 worst case with SFC-6(7x7,3x3): L=9, t=12, nW=32, Wp=226):
  input strip : 9 * 226 * 128 * 4B          = 1.0 MiB
  row xform   : 12 * 226 * 128 * 4B         = 1.4 MiB
  xq cache    : <= XQ_CACHE_BYTES           = 4.0 MiB
  weights     : 144 * 128 * 128 * 1B        = 2.3 MiB
  int32 acc   : 144 * 32 * 128 * 4B         = 2.3 MiB
  out strip   : 7 * 224 * 128 * 4B          = 0.8 MiB    (~12 MiB < 16 MiB)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import conv2d as c2d
from repro.core.generator import BilinearAlgorithm

K_BLOCK = 128
COUT_BLOCK = 128
# cap on the quantized-strip cache that amortizes the input transform
# across C_out blocks (full-K int8 residency of ONE tile-row strip)
XQ_CACHE_BYTES = 4 * 1024 * 1024


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _fused_kernel(bt_ref, at_ref, sx_ref, sw_ref, x_ref, w_ref, o_ref,
                  acc_ref, *scratch, n_w: int, M: int, L: int, bits: int,
                  n_k: int, cache_xq: bool):
    """One (tile-row, C_out block, C_in block) step of the fused pipeline.

    ``scratch`` holds the quantized-strip cache ref only when ``cache_xq``
    (the wrapper allocates it conditionally).
    """
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bt = bt_ref[...]                               # (t, L)
    t = bt.shape[0]
    s = sx_ref[...]                                # (t, t)
    qmax = 2 ** (bits - 1) - 1

    def _quantized_strip():
        x = x_ref[0]                               # (L, Wp, kb) f32
        # row transform once for the whole strip; every tile column
        # reuses it
        rows = jnp.einsum("ti,iwc->twc", bt, x,
                          preferred_element_type=jnp.float32)
        q_cols = []
        for jj in range(n_w):                      # static unroll: tile cols
            tx = jnp.einsum("uj,tjc->tuc", bt, rows[:, jj * M:jj * M + L, :],
                            preferred_element_type=jnp.float32)
            q = jnp.clip(jnp.round(tx / s[:, :, None]), -qmax, qmax)
            q_cols.append(q.reshape(t * t, -1))    # (P, kb)
        return jnp.stack(q_cols, axis=1).astype(jnp.int8)   # (P, nW, kb)

    if cache_xq:
        # strips depend on (tile-row, k) only: compute on the first C_out
        # block, replay from VMEM for the rest
        xq_ref, = scratch

        @pl.when(j == 0)
        def _fill_cache():
            xq_ref[k] = _quantized_strip()
        xq = xq_ref[k]
    else:
        xq = _quantized_strip()
    w = w_ref[...]                                     # (P, kb, cb) int8
    acc_ref[...] += jax.lax.dot_general(
        xq, w, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)              # (P, nW, cb)

    @pl.when(k == n_k - 1)
    def _finalize():
        at = at_ref[...]                           # (M, t)
        sw = sw_ref[...]                           # (P, cb)
        scale = s.reshape(t * t)[:, None, None] * sw[:, None, :]
        y = acc_ref[...].astype(jnp.float32) * scale   # (P, nW, cb)
        ty = y.reshape(t, t, n_w, -1)
        z = jnp.einsum("mt,tunc->munc", at, ty,
                       preferred_element_type=jnp.float32)
        z = jnp.einsum("pu,munc->mnpc", at, z,
                       preferred_element_type=jnp.float32)  # (M, nW, M, cb)
        o_ref[0] = z.reshape(M, n_w * M, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("algo", "padding", "bits",
                                             "interpret", "k_block",
                                             "cout_block"))
def sfc_fused_conv2d(x: jnp.ndarray, wq: jnp.ndarray,
                     act_scale: jnp.ndarray, w_scale: jnp.ndarray,
                     algo: BilinearAlgorithm, *,
                     padding: str = "SAME", bits: int = 8,
                     interpret: bool = True,
                     k_block: Optional[int] = K_BLOCK,
                     cout_block: int = COUT_BLOCK) -> jnp.ndarray:
    """int8 SFC convolution in one ``pallas_call``.

    x (B, H, W, Cin) f32; wq (t^2, Cin, Cout) int8; act_scale (t, t);
    w_scale (t, t, Cout) -> (B, H', W', Cout) f32.  Numerically identical
    to the staged ``quantized_fastconv2d`` (same integer grid and scales).
    ``bits`` sets the activation clipping grid (sub-int8 policies run on
    the int8 carrier).  ``k_block=None`` means full K: the whole C_in
    reduction in a single k-block (``n_k = 1``) — the autotuner's
    "no reduction grid dim" candidate, same convention as the staged
    ``tdmm_int8``.
    """
    B, H, W, C = x.shape
    t, M, R, L = algo.t, algo.M, algo.R, algo.L
    P = t * t
    assert wq.shape[0] == P and wq.shape[1] == C, (wq.shape, P, C)
    Cout = wq.shape[2]
    lo_h, hi_h, out_h = c2d.pad_amounts(H, M, R, padding)
    lo_w, hi_w, out_w = c2d.pad_amounts(W, M, R, padding)
    xp = jnp.pad(x, ((0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    nH = (xp.shape[1] - (R - 1)) // M
    nW = (xp.shape[2] - (R - 1)) // M
    Wp = xp.shape[2]

    # channel blocking (both dims padded with zeros; zero channels quantize
    # to zero / carry zero scales, so they contribute nothing)
    kb = _round_up(C, 8) if k_block is None else min(k_block, _round_up(C, 8))
    Cp = _round_up(C, kb)
    cb = min(cout_block, _round_up(Cout, 8))
    Op = _round_up(Cout, cb)
    n_k = Cp // kb
    n_o = Op // cb
    xp = jnp.pad(xp, ((0, 0), (0, 0), (0, 0), (0, Cp - C)))
    wqp = jnp.pad(wq, ((0, 0), (0, Cp - C), (0, Op - Cout)))
    sw = jnp.pad(w_scale.reshape(P, Cout).astype(jnp.float32),
                 ((0, 0), (0, Op - Cout)))

    cache_xq = n_o > 1 and n_k * P * nW * kb <= XQ_CACHE_BYTES
    kern = functools.partial(_fused_kernel, n_w=nW, M=M, L=L, bits=bits,
                             n_k=n_k, cache_xq=cache_xq)
    out = pl.pallas_call(
        kern,
        grid=(B * nH, n_o, n_k),
        in_specs=[
            pl.BlockSpec((t, L), lambda i, j, k: (0, 0)),
            pl.BlockSpec((M, t), lambda i, j, k: (0, 0)),
            pl.BlockSpec((t, t), lambda i, j, k: (0, 0)),
            pl.BlockSpec((P, cb), lambda i, j, k: (0, j)),
            # overlapping (L, Wp) input strips at row stride M, straight
            # from HBM — element-offset (Unblocked) index map
            pl.BlockSpec((1, L, Wp, kb),
                         lambda i, j, k, _nH=nH: (i // _nH, (i % _nH) * M,
                                                  0, k * kb),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((P, kb, cb), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((1, M, nW * M, cb),
                               lambda i, j, k, _nH=nH: (i // _nH, i % _nH,
                                                        0, j)),
        out_shape=jax.ShapeDtypeStruct((B, nH * M, nW * M, Op), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, nW, cb), jnp.int32)] + (
            [pltpu.VMEM((n_k, P, nW, kb), jnp.int8)] if cache_xq else []),
        interpret=interpret,
    )(jnp.asarray(algo.bt(), jnp.float32), jnp.asarray(algo.at(), jnp.float32),
      act_scale.astype(jnp.float32), sw, xp, wqp)
    return out[:, :out_h, :out_w, :Cout]
