"""Pallas TPU kernel: the int8 SFC convolution as ONE fused ``pallas_call``.

The staged pipeline (``repro.kernels.ops.quantized_fastconv2d``) runs three
kernels with two full HBM round-trips of the transform-domain tensor in
between — t^2/M^2 times the input footprint (3.06x for SFC-4(4x4,3x3),
2.78x for SFC-6(6x6,3x3)) — and feeds the first kernel a materialized tile
tensor that duplicates every input element L^2/M^2 times (2.25x / 1.78x).
This kernel keeps the whole pipeline on-chip (EXPERIMENTS.md §Perf):

  grid = (ceil(B/imgs) * ceil(nH/rows), C_out blocks, C_in k-blocks),
  k innermost

Per grid step it
  * reads one overlapping (imgs, span, W_padded, k_block) input strip
    group — ``rows`` consecutive tile-rows (span = (rows-1)*M + L) of
    ``imgs`` images — straight from HBM, either via an Unblocked BlockSpec
    index map at row stride rows*M, or (``double_buffer``) via a manual
    ``pltpu.make_async_copy`` DMA into a two-slot VMEM scratch so the next
    strip's HBM read overlaps the current strip's transform + matmul;
  * applies the additions-only B^T X B transform per tile column and the
    fused per-frequency intN quantization in VMEM/registers; the quantized
    int8 strips are cached in a VMEM scratch across C_out blocks (bounded
    by ``XQ_CACHE_BYTES``; recomputed per block when they do not fit), so
    the transform runs once per (strip group, k-block), not once per
    output block;
  * runs the t^2-position int8 MXU matmuls against the matching weight
    k-block — the LHS stacks all imgs*rows*nW tile columns of the group,
    so small images (nW*M = 7..14) still feed the 128-lane MXU a full
    batch of rows instead of a sliver — and accumulates into an int32
    VMEM scratch that persists across the C_in k-blocks, so full-K VMEM
    residency (which caps the staged ``tdmm_int8`` near C_in ~ 2048) is
    never required;
  * on the last k-block dequantizes with the static per-frequency scales
    and applies the correction-term inverse A^T Y A, writing one spatial
    (imgs, rows*M, nW*M) output strip group.

The transform-domain tensor therefore never touches HBM.

Grouping (``rows_per_step``): ``rows = min(rows_per_step, nH)`` tile-rows
of one image fold into a step; when ``rows_per_step >= nH`` the leftover
factor folds whole images (``imgs = rows_per_step // nH``, clamped to a
divisor of B so no padded images are computed).  ``rows_per_step=None``
resolves via :func:`auto_rows_per_step`, the largest candidate whose
per-step footprint (:func:`fused_vmem_bytes`, the budget math below) fits
``VMEM_LIMIT_BYTES``.  All groupings are bit-identical to
``rows_per_step=1``: the per-strip transform arithmetic and the per-column
matmul contraction are unchanged, only the grid batching differs.

VMEM budget per grid step (f32 in, defaults K_BLOCK=COUT_BLOCK=128, the
VGG-16 224x224 worst case with SFC-6(7x7,3x3): L=9, t=12, nW=32, Wp=226,
rows=1):
  input strip : 9 * 226 * 128 * 4B          = 1.0 MiB   (x2 double_buffer)
  row xform   : 12 * 226 * 128 * 4B         = 1.4 MiB
  xq cache    : <= XQ_CACHE_BYTES           = 4.0 MiB
  weights     : 144 * 128 * 128 * 1B        = 2.3 MiB
  int32 acc   : 144 * 32 * 128 * 4B         = 2.3 MiB
  out strip   : 7 * 224 * 128 * 4B          = 0.8 MiB    (~12 MiB < 16 MiB)
:func:`fused_vmem_bytes` reproduces exactly these terms (scaled by the
grouping) and is regression-tested against them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import conv2d as c2d
from repro.core.generator import BilinearAlgorithm

K_BLOCK = 128
COUT_BLOCK = 128
# cap on the quantized-strip cache that amortizes the input transform
# across C_out blocks (full-K int8 residency of ONE strip group)
XQ_CACHE_BYTES = 4 * 1024 * 1024
# per-step VMEM ceiling the batching helper packs against (v5e: 16 MiB
# usable VMEM per core; the budget math is documented in the module
# docstring and regression-tested in tests/test_conformance.py)
VMEM_LIMIT_BYTES = 16 * 1024 * 1024
# candidate group sizes auto_rows_per_step tries, largest first
AUTO_ROWS_CANDIDATES = (8, 4, 2, 1)


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def cache_fits(n_o: int, n_k: int, P: int, cols: int, kb: int) -> bool:
    """Whether the quantized-strip cache is worth allocating: multiple
    C_out blocks to amortize over, and full-K residency of one strip
    group's int8 strips under ``XQ_CACHE_BYTES``.  The ONE predicate both
    the VMEM-budget helper and the kernel wrapper consult — if they
    disagreed, ``auto_rows_per_step`` would budget a scratch the kernel
    does (or does not) allocate."""
    return n_o > 1 and n_k * P * cols * kb <= XQ_CACHE_BYTES


def grouping(B: int, nH: int, rows_per_step: int) -> Tuple[int, int]:
    """Resolve ``rows_per_step`` into ``(imgs, rows)`` folded per step.

    ``rows`` tile-rows of one image always come first; only when the
    requested group exceeds one image's tile-rows does the remainder fold
    whole images — and only divisors of B, so no zero-padded image is
    ever computed.
    """
    g = max(1, rows_per_step)
    rows = min(g, nH)
    imgs = 1
    if g >= nH and B > 1:
        cap = min(B, g // nH)
        imgs = max(d for d in range(1, cap + 1) if B % d == 0)
    return imgs, rows


def _vmem_bytes(t: int, M: int, L: int, n_w: int, w_padded: int,
                kb: int, cb: int, *, n_k: int, rows: int, imgs: int,
                cache_xq: bool, double_buffer: bool) -> int:
    P = t * t
    span = (rows - 1) * M + L
    cols = imgs * rows * n_w               # tile columns folded per step
    strip = imgs * span * w_padded * kb * 4
    if double_buffer:
        strip *= 2
    row_xform = t * w_padded * kb * 4      # one strip at a time
    xq = P * cols * kb                     # int8
    xq_cache = n_k * P * cols * kb if cache_xq else 0
    weights = P * kb * cb                  # int8
    acc = P * cols * cb * 4                # int32
    out = imgs * rows * M * n_w * M * cb * 4
    return strip + row_xform + xq + xq_cache + weights + acc + out


def fused_vmem_bytes(algo: BilinearAlgorithm, n_w: int, w_padded: int,
                     kb: int, cb: int, *, n_k: int = 1, rows: int = 1,
                     imgs: int = 1, cache_xq: bool = False,
                     double_buffer: bool = False) -> int:
    """Per-grid-step VMEM footprint of the fused kernel, in bytes.

    Reproduces the module docstring's budget table term by term, scaled
    by the (imgs, rows) grouping: input strip group (doubled when
    double-buffered), the per-strip row-transform intermediate, the int8
    quantized-strip matmul LHS, the optional full-K xq cache, the weight
    k-block, the int32 accumulator, and the output strip group.
    """
    return _vmem_bytes(algo.t, algo.M, algo.L, n_w, w_padded, kb, cb,
                       n_k=n_k, rows=rows, imgs=imgs, cache_xq=cache_xq,
                       double_buffer=double_buffer)


def auto_rows_per_step(algo: BilinearAlgorithm, B: int, nH: int, n_w: int,
                       w_padded: int, kb: int, cb: int, *, n_k: int = 1,
                       n_o: int = 1, double_buffer: bool = False) -> int:
    """Largest AUTO_ROWS_CANDIDATES group whose step fits the VMEM budget.

    Falls back to 1 (the ungrouped grid, which the docstring's worst case
    shows fits at the default block sizes).
    """
    for g in AUTO_ROWS_CANDIDATES:
        imgs, rows = grouping(B, nH, g)
        cols = imgs * rows * n_w
        cache = cache_fits(n_o, n_k, algo.t ** 2, cols, kb)
        if fused_vmem_bytes(algo, n_w, w_padded, kb, cb, n_k=n_k,
                            rows=rows, imgs=imgs, cache_xq=cache,
                            double_buffer=double_buffer) \
                <= VMEM_LIMIT_BYTES:
            return g
    return 1


@dataclasses.dataclass(frozen=True)
class FusedGeometry:
    """The complete static launch geometry of one fused-kernel call.

    This is THE description of the grid, blocking, strip reads, and
    scratch allocations — derived once by :func:`fused_geometry` and
    consumed both by :func:`sfc_fused_conv2d` (to build the launch) and
    by the static resource checker (``repro.analysis.kernel_checks``) and
    the serving batcher, so out-of-kernel consumers never re-derive (and
    silently diverge from) the kernel's own arithmetic.

    Shapes are post-padding: ``x_rows``/``w_padded`` are the padded input
    extents the strip index maps read against, ``Cp``/``Op`` the padded
    channel extents.  ``rows_per_step`` is the *resolved* grouping (never
    None).  For depthwise launches ``n_k == 1``, ``kb == cb`` (the shared
    channel block), and ``cache_xq``/``double_buffer`` are forced off —
    there is no reduction to block and no cross-block strip reuse.
    """

    # algorithm tile geometry
    t: int
    M: int
    L: int
    # problem extents (padding already applied where noted)
    B: int
    C: int
    Cout: int
    nH: int                  # tile rows per image
    nW: int                  # tile cols per image
    out_h: int               # unpadded output extents
    out_w: int
    x_rows: int              # padded input rows incl. grouped-grid pad
    w_padded: int            # padded input cols (Wp)
    depthwise: bool
    # channel blocking
    kb: int                  # C_in k-block (== cb for depthwise)
    Cp: int                  # C padded to a multiple of kb
    n_k: int
    cb: int                  # C_out block
    Op: int                  # Cout padded to a multiple of cb
    n_o: int
    # grid batching
    rows_per_step: int       # resolved grouping request
    imgs: int                # whole images folded per step
    rows: int                # tile-rows folded per step
    g_h: int                 # strip groups per image column
    g_b: int                 # image groups (B // imgs)
    nH_p: int                # g_h * rows
    span: int                # input rows read per strip group
    grid0: int               # g_b * g_h
    # features
    cache_xq: bool
    double_buffer: bool
    # double-buffer pipeline constants (the kernel's two-slot DMA scheme)
    db_slots: int = 2
    db_prefetch_distance: int = 1

    # ---- derived ----
    @property
    def P(self) -> int:
        return self.t * self.t

    @property
    def cols(self) -> int:
        """Tile columns stacked into the matmul LHS per grid step."""
        return self.imgs * self.rows * self.nW

    @property
    def grid(self) -> Tuple[int, ...]:
        return (self.grid0, self.n_o) if self.depthwise \
            else (self.grid0, self.n_o, self.n_k)

    @property
    def rmw_axis(self) -> Optional[int]:
        """Grid axis allowed to read-modify-write the int32 accumulator
        scratch (the innermost C_in reduction axis); None when the launch
        carries no accumulator (depthwise)."""
        return None if self.depthwise else len(self.grid) - 1

    def vmem_bytes(self) -> int:
        """Per-grid-step VMEM footprint of THIS geometry (same terms as
        :func:`fused_vmem_bytes`, evaluated on the resolved fields)."""
        return _vmem_bytes(self.t, self.M, self.L, self.nW, self.w_padded,
                           self.kb, self.cb, n_k=self.n_k, rows=self.rows,
                           imgs=self.imgs, cache_xq=self.cache_xq,
                           double_buffer=self.double_buffer)

    # ---- strip reads (the Unblocked index map / manual DMA source) ----
    @property
    def strip_shape(self) -> Tuple[int, int, int, int]:
        return (self.imgs, self.span, self.w_padded, self.kb)

    def strip_offset(self, i: int, k: int = 0
                     ) -> Tuple[int, int, int, int]:
        """Element offsets of grid step (i, ·, k)'s input strip group —
        the same arithmetic as the kernel's Unblocked index map and its
        manual-DMA ``_coords`` helper."""
        return ((i // self.g_h) * self.imgs,
                (i % self.g_h) * self.rows * self.M, 0, k * self.kb)

    @property
    def x_extents(self) -> Tuple[int, int, int, int]:
        """HBM extents of the padded input the strip reads index into."""
        return (self.B, self.x_rows, self.w_padded, self.Cp)

    def out_index(self, i: int, j: int, k: int = 0
                  ) -> Tuple[int, int, int, int]:
        """Output BlockSpec block index for grid step (i, j, k).  Must be
        independent of ``k``: the int32 accumulator spans all k-blocks and
        only the last one writes the block."""
        del k
        return (i // self.g_h, i % self.g_h, 0, j)

    def db_slot(self, s_idx: int) -> int:
        """DMA landing slot of strip-sequence entry ``s_idx``."""
        return s_idx % self.db_slots

    # ---- workload accounting (the analytic cost model's inputs) ----
    # These accessors are the ONE place the kernel's per-launch work is
    # counted: repro.api.costmodel prices candidates from them and must
    # never re-derive strip/blocking arithmetic (lint rule COST001).
    @property
    def grid_steps(self) -> int:
        """Total grid steps of the launch (the per-step overhead quanta)."""
        n = 1
        for g in self.grid:
            n *= g
        return n

    @property
    def input_consuming_steps(self) -> int:
        """Grid steps that read their input strip group from HBM.

        With the quantized-strip cache only the first C_out block of each
        (strip group, k-block) touches the input; every other step replays
        from VMEM.  The double-buffer DMA path issues exactly one copy per
        consuming step, so the count is the same either way."""
        if self.depthwise:
            return self.grid0 * self.n_o
        if self.cache_xq:
            return self.grid0 * self.n_k
        return self.grid0 * self.n_o * self.n_k

    @property
    def transform_invocations(self) -> int:
        """How many times the B^T X B transform + quantize runs (equals
        :attr:`input_consuming_steps`: strips are transformed exactly when
        they are read, cached strips replay the quantized result)."""
        return self.input_consuming_steps

    def hbm_bytes(self) -> Dict[str, int]:
        """Per-launch HBM traffic of this geometry, bytes by stream.

        input   — f32 strip-group reads, one per consuming step (the
                  overlapping spans are re-read per strip group; the xq
                  cache removes the per-C_out-block re-reads);
        weights — the int8 weight block every step fetches;
        output  — the f32 spatial strip groups the last k-block writes.
        """
        strip = self.imgs * self.span * self.w_padded * self.kb * 4
        inp = self.input_consuming_steps * strip
        if self.depthwise:
            wgt = self.grid0 * self.n_o * self.P * self.cb
        else:
            wgt = self.grid0 * self.n_o * self.n_k * self.P * self.kb \
                * self.cb
        out = self.grid0 * self.n_o \
            * self.imgs * self.rows * self.M * self.nW * self.M * self.cb * 4
        return {"input": inp, "weights": wgt, "output": out,
                "total": inp + wgt + out}

    def compute_ops(self) -> Dict[str, int]:
        """Per-launch arithmetic of this geometry, ops by execution unit.

        mxu_macs    — int8 MXU multiply-accumulates of the t^2 transform-
                      domain matmuls (zero for depthwise);
        vpu_ew      — the depthwise transform-domain elementwise products;
        vpu_transform — f32 VPU work of the separable B^T X B transform +
                      per-frequency quantize, once per consuming step;
        vpu_inverse — dequant + A^T Y A correction inverse per finalize.
        """
        cols = self.cols
        if self.depthwise:
            mxu = 0
            ew = self.grid0 * self.n_o * self.P * cols * self.cb
        else:
            mxu = self.grid0 * self.n_o * self.n_k * self.P * cols \
                * self.kb * self.cb
            ew = 0
        # per consuming step: row transform (t x L against the full strip
        # width), per-column col transform, per-frequency quantize
        per_step = self.imgs * self.rows * self.kb * (
            self.t * self.L * self.w_padded
            + self.nW * self.t * self.t * self.L
            + self.nW * self.P)
        transform = self.transform_invocations * per_step
        # per finalize: dequant scale (P x cols) + the two inverse einsums
        inverse = self.grid0 * self.n_o * cols * self.cb * (
            self.P + self.M * self.t * self.t + self.M * self.M * self.t)
        return {"mxu_macs": mxu, "vpu_ew": ew, "vpu_transform": transform,
                "vpu_inverse": inverse}

    def scratch_shapes(self) -> Tuple[Tuple[str, Tuple[int, ...], str], ...]:
        """(name, shape, dtype) of every VMEM scratch the launch allocates,
        in ``pallas_call`` order."""
        out = []
        if not self.depthwise:
            out.append(("acc", (self.P, self.cols, self.cb), "int32"))
        if self.cache_xq:
            out.append(("xq_cache", (self.n_k, self.P, self.cols, self.kb),
                        "int8"))
        if self.double_buffer:
            out.append(("db_buf", (self.db_slots, self.imgs, self.span,
                                   self.w_padded, self.kb), "float32"))
        return tuple(out)


def fused_geometry(algo: BilinearAlgorithm, B: int, H: int, W: int,
                   C: int, Cout: int, *, padding: str = "SAME",
                   k_block: Optional[int] = K_BLOCK,
                   cout_block: int = COUT_BLOCK,
                   rows_per_step: Optional[int] = 1,
                   double_buffer: bool = False,
                   depthwise: bool = False) -> FusedGeometry:
    """Resolve the launch geometry :func:`sfc_fused_conv2d` will use.

    Pure integer arithmetic on static shapes — safe to call from the
    planner, the autotuner's pre-flight checker, and the serving batcher
    without touching jax.  ``rows_per_step=None`` resolves through
    :func:`auto_rows_per_step` exactly as the kernel wrapper does.
    """
    t, M, R, L = algo.t, algo.M, algo.R, algo.L
    lo_h, hi_h, out_h = c2d.pad_amounts(H, M, R, padding)
    lo_w, hi_w, out_w = c2d.pad_amounts(W, M, R, padding)
    xp_h = H + lo_h + hi_h
    Wp = W + lo_w + hi_w
    nH = (xp_h - (R - 1)) // M
    nW = (Wp - (R - 1)) // M
    if depthwise:
        cb = min(cout_block, _round_up(C, 8))
        Cp = _round_up(C, cb)
        kb, n_k = cb, 1
        Op, n_o = Cp, Cp // cb
        cache_xq = double_buffer = False
        if rows_per_step is None:
            rows_per_step = auto_rows_per_step(algo, B, nH, nW, Wp, cb, cb,
                                               n_k=1, n_o=n_o)
    else:
        kb = _round_up(C, 8) if k_block is None \
            else min(k_block, _round_up(C, 8))
        Cp = _round_up(C, kb)
        cb = min(cout_block, _round_up(Cout, 8))
        Op = _round_up(Cout, cb)
        n_k = Cp // kb
        n_o = Op // cb
        if rows_per_step is None:
            rows_per_step = auto_rows_per_step(
                algo, B, nH, nW, Wp, kb, cb, n_k=n_k, n_o=n_o,
                double_buffer=double_buffer)
    imgs, rows = grouping(B, nH, rows_per_step)
    g_h = -(-nH // rows)
    nH_p = g_h * rows
    g_b = B // imgs                        # imgs divides B by construction
    span = (rows - 1) * M + L
    cache_xq = False if depthwise \
        else cache_fits(n_o, n_k, t * t, imgs * rows * nW, kb)
    return FusedGeometry(
        t=t, M=M, L=L, B=B, C=C, Cout=Cout, nH=nH, nW=nW,
        out_h=out_h, out_w=out_w,
        x_rows=max(xp_h, (nH_p - 1) * M + L), w_padded=Wp,
        depthwise=depthwise, kb=kb, Cp=Cp, n_k=n_k, cb=cb, Op=Op, n_o=n_o,
        rows_per_step=rows_per_step, imgs=imgs, rows=rows, g_h=g_h,
        g_b=g_b, nH_p=nH_p, span=span, grid0=g_b * g_h,
        cache_xq=cache_xq, double_buffer=double_buffer)


def _quantize_strip_group(xg, bt, s, qmax, *, imgs: int, rows: int,
                          n_w: int, M: int, L: int):
    """Transform + per-frequency quantize one (imgs, span, Wp, cb) strip
    group into the (P, imgs*rows*nW, cb) int8 matmul LHS.  Shared by the
    dense and depthwise fused kernels so their integer grids are
    bit-identical by construction."""
    t = bt.shape[0]
    q_cols = []
    for im in range(imgs):                     # static unroll: strips
        for r in range(rows):
            xs = xg[im, r * M:r * M + L]       # (L, Wp, cb) f32
            # row transform once for the whole strip; every tile
            # column reuses it
            rws = jnp.einsum("ti,iwc->twc", bt, xs,
                             preferred_element_type=jnp.float32)
            for jj in range(n_w):              # static unroll: cols
                tx = jnp.einsum("uj,tjc->tuc", bt,
                                rws[:, jj * M:jj * M + L, :],
                                preferred_element_type=jnp.float32)
                q = jnp.clip(jnp.round(tx / s[:, :, None]), -qmax, qmax)
                q_cols.append(q.reshape(t * t, -1))    # (P, cb)
    # (P, imgs*rows*nW, cb)
    return jnp.stack(q_cols, axis=1).astype(jnp.int8)


def _dequant_inverse_strip_group(y, at, t, *, imgs: int, rows: int,
                                 n_w: int, M: int):
    """(P, cols, cb) dequantized f32 -> (imgs, rows*M, nW*M, cb) spatial
    output strip group (the A^T Y A correction-term inverse)."""
    ty = y.reshape(t, t, imgs * rows, n_w, -1)
    z = jnp.einsum("mt,tugnc->mugnc", at, ty,
                   preferred_element_type=jnp.float32)
    z = jnp.einsum("pu,mugnc->mgnpc", at, z,
                   preferred_element_type=jnp.float32)
    # (M, imgs*rows, nW, M, cb) -> (imgs, rows*M, nW*M, cb)
    z = z.reshape(M, imgs, rows, n_w, M, -1)
    z = jnp.transpose(z, (1, 2, 0, 3, 4, 5))
    return z.reshape(imgs, rows * M, n_w * M, -1)


def _fused_kernel(bt_ref, at_ref, sx_ref, sw_ref, x_ref, w_ref, o_ref,
                  acc_ref, *scratch, n_w: int, M: int, L: int, bits: int,
                  n_k: int, n_o: int, grid0: int, g_h: int, imgs: int,
                  rows: int, span: int, kb: int, cache_xq: bool,
                  double_buffer: bool):
    """One (strip group, C_out block, C_in block) step of the pipeline.

    ``scratch`` holds, in order and each only when enabled: the
    quantized-strip cache (``cache_xq``), then the two-slot DMA landing
    buffer + its semaphore pair (``double_buffer``).
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bt = bt_ref[...]                               # (t, L)
    t = bt.shape[0]
    s = sx_ref[...]                                # (t, t)
    qmax = 2 ** (bits - 1) - 1

    scratch = list(scratch)
    xq_ref = scratch.pop(0) if cache_xq else None

    if double_buffer:
        buf_ref, sem_ref = scratch
        # one strip-sequence entry per CONSUMING step: with the xq cache
        # only j == 0 steps touch the input (j > 0 replays from VMEM);
        # without it every step re-reads its strip (same HBM traffic as
        # the BlockSpec path re-fetching per C_out block)
        if cache_xq:
            s_idx = i * n_k + k
            total = grid0 * n_k

            def _coords(sn):
                return sn // n_k, sn % n_k
        else:
            s_idx = (i * n_o + j) * n_k + k
            total = grid0 * n_o * n_k

            def _coords(sn):
                return sn // (n_o * n_k), sn % n_k

        def _dma(sn):
            si, sk = _coords(sn)
            bi = si // g_h
            gi = si % g_h
            return pltpu.make_async_copy(
                x_ref.at[pl.ds(bi * imgs, imgs),
                         pl.ds(gi * rows * M, span),
                         slice(None), pl.ds(sk * kb, kb)],
                buf_ref.at[sn % 2], sem_ref.at[sn % 2])

        def _pipeline():
            # warm-up: the very first step issues its own strip's DMA;
            # every consuming step then prefetches the NEXT strip into
            # the other slot before blocking on its own — the next read
            # is in flight for the whole transform+matmul of this one
            @pl.when(s_idx == 0)
            def _first():
                _dma(0).start()

            @pl.when(s_idx + 1 < total)
            def _prefetch():
                _dma(s_idx + 1).start()

            _dma(s_idx).wait()

        if cache_xq:
            pl.when(j == 0)(_pipeline)
        else:
            _pipeline()

        def _load_group():
            return buf_ref[s_idx % 2]              # (imgs, span, Wp, kb)
    else:
        def _load_group():
            return x_ref[...]                      # (imgs, span, Wp, kb)

    def _quantized_strips():
        return _quantize_strip_group(_load_group(), bt, s, qmax, imgs=imgs,
                                     rows=rows, n_w=n_w, M=M, L=L)

    if cache_xq:
        # strips depend on (strip group, k) only: compute on the first
        # C_out block, replay from VMEM for the rest
        @pl.when(j == 0)
        def _fill_cache():
            xq_ref[k] = _quantized_strips()
        xq = xq_ref[k]
    else:
        xq = _quantized_strips()
    w = w_ref[...]                                     # (P, kb, cb) int8
    acc_ref[...] += jax.lax.dot_general(
        xq, w, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)              # (P, cols, cb)

    @pl.when(k == n_k - 1)
    def _finalize():
        at = at_ref[...]                           # (M, t)
        sw = sw_ref[...]                           # (P, cb)
        scale = s.reshape(t * t)[:, None, None] * sw[:, None, :]
        y = acc_ref[...].astype(jnp.float32) * scale   # (P, cols, cb)
        o_ref[...] = _dequant_inverse_strip_group(
            y, at, t, imgs=imgs, rows=rows, n_w=n_w, M=M).astype(o_ref.dtype)


def _fused_dw_kernel(bt_ref, at_ref, sx_ref, sw_ref, x_ref, w_ref, o_ref, *,
                     n_w: int, M: int, L: int, bits: int, imgs: int,
                     rows: int):
    """One (strip group, channel block) step of the depthwise pipeline.

    Depthwise has no channel contraction, so the grid loses the C_in
    k-dimension and the C_out blocks *are* the input channel blocks: the
    t^2 MXU matmuls collapse to a VPU elementwise int32 product against
    the (P, cb) weight block, and no accumulator scratch (and no xq
    cache — each channel block is consumed exactly once) is needed.
    """
    bt = bt_ref[...]                               # (t, L)
    t = bt.shape[0]
    s = sx_ref[...]                                # (t, t)
    qmax = 2 ** (bits - 1) - 1
    xq = _quantize_strip_group(x_ref[...], bt, s, qmax, imgs=imgs,
                               rows=rows, n_w=n_w, M=M, L=L)
    w = w_ref[...]                                 # (P, cb) int8
    prod = xq.astype(jnp.int32) * w[:, None, :].astype(jnp.int32)
    at = at_ref[...]                               # (M, t)
    sw = sw_ref[...]                               # (P, cb)
    scale = s.reshape(t * t)[:, None, None] * sw[:, None, :]
    y = prod.astype(jnp.float32) * scale           # (P, cols, cb)
    o_ref[...] = _dequant_inverse_strip_group(
        y, at, t, imgs=imgs, rows=rows, n_w=n_w, M=M).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("algo", "padding", "bits",
                                             "interpret", "k_block",
                                             "cout_block", "rows_per_step",
                                             "double_buffer", "depthwise"))
def sfc_fused_conv2d(x: jnp.ndarray, wq: jnp.ndarray,
                     act_scale: jnp.ndarray, w_scale: jnp.ndarray,
                     algo: BilinearAlgorithm, *,
                     padding: str = "SAME", bits: int = 8,
                     interpret: bool = True,
                     k_block: Optional[int] = K_BLOCK,
                     cout_block: int = COUT_BLOCK,
                     rows_per_step: Optional[int] = 1,
                     double_buffer: bool = False,
                     depthwise: bool = False) -> jnp.ndarray:
    """int8 SFC convolution in one ``pallas_call``.

    x (B, H, W, Cin) f32; wq (t^2, Cin, Cout) int8; act_scale (t, t);
    w_scale (t, t, Cout) -> (B, H', W', Cout) f32.  Numerically identical
    to the staged ``quantized_fastconv2d`` (same integer grid and scales)
    at every grouping.  ``bits`` sets the activation clipping grid
    (sub-int8 policies run on the int8 carrier).  ``k_block=None`` means
    full K: the whole C_in reduction in a single k-block (``n_k = 1``) —
    the autotuner's "no reduction grid dim" candidate, same convention as
    the staged ``tdmm_int8``.  ``rows_per_step`` folds that many
    tile-rows (counting across images once one image's rows are
    exhausted — see :func:`grouping`) into a single grid step;
    ``None`` picks the largest budget-fitting group via
    :func:`auto_rows_per_step`.  ``double_buffer`` switches the input
    strip reads to a manually DMA-pipelined two-slot VMEM buffer
    (prefetch of strip s+1 overlaps compute on strip s).

    ``depthwise`` (wq (t^2, 1, C), w_scale (t, t, C)) swaps the t^2 MXU
    matmuls for the transform-domain elementwise product
    (``_fused_dw_kernel``): the grid drops the C_in reduction dim and
    blocks over the shared in==out channel axis instead.  ``k_block``
    and ``double_buffer`` are no-ops there — there is no reduction to
    block, and each channel block's strip is read exactly once, so the
    two-slot DMA pipeline has no cross-block reuse to overlap (the knobs
    are accepted so one ``KernelConfig`` sweep serves both layouts;
    every config remains bit-identical).
    """
    B, H, W, C = x.shape
    t, M, R, L = algo.t, algo.M, algo.R, algo.L
    P = t * t
    if depthwise:
        assert wq.shape == (P, 1, C), (wq.shape, P, C)
    else:
        assert wq.shape[0] == P and wq.shape[1] == C, (wq.shape, P, C)
    Cout = wq.shape[2]
    lo_h, hi_h, out_h = c2d.pad_amounts(H, M, R, padding)
    lo_w, hi_w, out_w = c2d.pad_amounts(W, M, R, padding)
    xp = jnp.pad(x, ((0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    nH = (xp.shape[1] - (R - 1)) // M
    nW = (xp.shape[2] - (R - 1)) // M
    Wp = xp.shape[2]
    # the ONE geometry derivation (grid, channel blocking, grouping, strip
    # spans, scratch set) — shared verbatim with the static resource
    # checker (repro.analysis.kernel_checks) and the serving batcher
    geom = fused_geometry(algo, B, H, W, C, Cout, padding=padding,
                          k_block=k_block, cout_block=cout_block,
                          rows_per_step=rows_per_step,
                          double_buffer=double_buffer, depthwise=depthwise)
    if depthwise:
        return _fused_depthwise(xp, wq, act_scale, w_scale, algo, geom,
                                out_h=out_h, out_w=out_w, bits=bits,
                                interpret=interpret)

    kb, Cp, cb, Op = geom.kb, geom.Cp, geom.cb, geom.Op
    n_k, n_o = geom.n_k, geom.n_o
    imgs, rows, g_h, nH_p = geom.imgs, geom.rows, geom.g_h, geom.nH_p
    span, grid0 = geom.span, geom.grid0

    # grouped-grid padding: strips of the last group read rows up to
    # (nH_p - 1) * M + L; the extra zero rows produce output rows that are
    # sliced off below.  Channel dims pad with zeros; zero channels
    # quantize to zero / carry zero scales, so they contribute nothing.
    xp = jnp.pad(xp, ((0, 0), (0, geom.x_rows - xp.shape[1]), (0, 0),
                      (0, Cp - C)))
    wqp = jnp.pad(wq, ((0, 0), (0, Cp - C), (0, Op - Cout)))
    sw = jnp.pad(w_scale.reshape(P, Cout).astype(jnp.float32),
                 ((0, 0), (0, Op - Cout)))

    cols = geom.cols
    cache_xq = geom.cache_xq
    bt_f32, _, at_f32 = c2d.transform_matrices(algo, "float32")
    kern = functools.partial(
        _fused_kernel, n_w=nW, M=M, L=L, bits=bits, n_k=n_k, n_o=n_o,
        grid0=grid0, g_h=g_h, imgs=imgs, rows=rows, span=span, kb=kb,
        cache_xq=cache_xq, double_buffer=double_buffer)
    if double_buffer:
        # the strips land via manual DMA from HBM: the operand never
        # enters the automatic BlockSpec pipeline
        x_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    else:
        # overlapping (span, Wp) strip groups at row stride rows*M,
        # straight from HBM — element-offset (Unblocked) index map
        x_spec = pl.BlockSpec(
            (imgs, span, Wp, kb),
            lambda i, j, k, _gh=g_h, _im=imgs, _rm=rows * M:
            ((i // _gh) * _im, (i % _gh) * _rm, 0, k * kb),
            indexing_mode=pl.Unblocked())
    scratch_shapes = [pltpu.VMEM((P, cols, cb), jnp.int32)]
    if cache_xq:
        scratch_shapes.append(pltpu.VMEM((n_k, P, cols, kb), jnp.int8))
    if double_buffer:
        scratch_shapes += [pltpu.VMEM((2, imgs, span, Wp, kb), jnp.float32),
                           pltpu.SemaphoreType.DMA((2,))]
    out = pl.pallas_call(
        kern,
        grid=(grid0, n_o, n_k),
        in_specs=[
            pl.BlockSpec((t, L), lambda i, j, k: (0, 0)),
            pl.BlockSpec((M, t), lambda i, j, k: (0, 0)),
            pl.BlockSpec((t, t), lambda i, j, k: (0, 0)),
            pl.BlockSpec((P, cb), lambda i, j, k: (0, j)),
            x_spec,
            pl.BlockSpec((P, kb, cb), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((imgs, rows * M, nW * M, cb),
                               lambda i, j, k, _gh=g_h: (i // _gh, i % _gh,
                                                         0, j)),
        out_shape=jax.ShapeDtypeStruct((B, nH_p * M, nW * M, Op),
                                       jnp.float32),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(bt_f32, at_f32, act_scale.astype(jnp.float32), sw, xp, wqp)
    return out[:, :out_h, :out_w, :Cout]


def _fused_depthwise(xp, wq, act_scale, w_scale, algo, geom, *, out_h,
                     out_w, bits, interpret):
    """Depthwise half of :func:`sfc_fused_conv2d` (input already padded).

    Grid = (strip groups, channel blocks): the channel axis is both the
    input and the output blocking (zero-padded channels quantize to zero
    and carry zero scales, contributing nothing).  ``geom`` carries the
    resolved :class:`FusedGeometry` (``rows_per_step`` auto-resolution
    over-counts depthwise slightly — the dense budget includes a weight
    k-block and an int32 accumulator the dw kernel does not allocate — a
    safe bound, never an overflow).
    """
    B = xp.shape[0]
    C = wq.shape[2]
    t, M, L = algo.t, algo.M, algo.L
    P = t * t
    Wp = xp.shape[2]
    nH, nW = geom.nH, geom.nW
    cb, Cp, n_c = geom.cb, geom.Cp, geom.n_o
    imgs, rows, g_h = geom.imgs, geom.rows, geom.g_h
    span, grid0 = geom.span, geom.grid0

    xp = jnp.pad(xp, ((0, 0), (0, geom.x_rows - xp.shape[1]), (0, 0),
                      (0, Cp - C)))
    wqp = jnp.pad(wq.reshape(P, C), ((0, 0), (0, Cp - C)))
    sw = jnp.pad(w_scale.reshape(P, C).astype(jnp.float32),
                 ((0, 0), (0, Cp - C)))
    bt_f32, _, at_f32 = c2d.transform_matrices(algo, "float32")

    kern = functools.partial(_fused_dw_kernel, n_w=nW, M=M, L=L, bits=bits,
                             imgs=imgs, rows=rows)
    out = pl.pallas_call(
        kern,
        grid=(grid0, n_c),
        in_specs=[
            pl.BlockSpec((t, L), lambda i, j: (0, 0)),
            pl.BlockSpec((M, t), lambda i, j: (0, 0)),
            pl.BlockSpec((t, t), lambda i, j: (0, 0)),
            pl.BlockSpec((P, cb), lambda i, j: (0, j)),
            # overlapping (span, Wp) strip groups at row stride rows*M,
            # channel-blocked by j — element-offset (Unblocked) index map
            pl.BlockSpec(
                (imgs, span, Wp, cb),
                lambda i, j, _gh=g_h, _im=imgs, _rm=rows * M:
                ((i // _gh) * _im, (i % _gh) * _rm, 0, j * cb),
                indexing_mode=pl.Unblocked()),
            pl.BlockSpec((P, cb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((imgs, rows * M, nW * M, cb),
                               lambda i, j, _gh=g_h: (i // _gh, i % _gh,
                                                      0, j)),
        out_shape=jax.ShapeDtypeStruct((B, geom.nH_p * M, nW * M, Cp),
                                       jnp.float32),
        interpret=interpret,
    )(bt_f32, at_f32, act_scale.astype(jnp.float32), sw, xp, wqp)
    return out[:, :out_h, :out_w, :C]
