"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

Shapes use the *kernel* layout:
  tiles     : (nT, L, L, C)      flattened spatial tiles, channels last
  transform : (nT, t, t, C)
  tdmm      : X (P, T, K) int8, W (P, K, N) int8 -> (P, T, N) f32
              with per-position activation scales sx (P,) and
              per-position-per-channel weight scales sw (P, N)
  inverse   : (nT, t, t, O) -> (nT, M, M, O)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.generator import BilinearAlgorithm


def sfc_transform_ref(tiles: jnp.ndarray, bt: jnp.ndarray) -> jnp.ndarray:
    # f32 accumulation to match the kernel's MXU semantics exactly
    out = jnp.einsum("ti,nijc,uj->ntuc", bt, tiles, bt,
                     preferred_element_type=jnp.float32)
    return out.astype(tiles.dtype)


def sfc_transform_quantize_ref(tiles: jnp.ndarray, bt: jnp.ndarray,
                               scale: jnp.ndarray, bits: int = 8
                               ) -> jnp.ndarray:
    """Transform + static per-frequency quantization to intN."""
    tx = sfc_transform_ref(tiles, bt)
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(tx / scale[None, :, :, None]), -qmax, qmax)
    return q.astype(jnp.int8)


def tdmm_int8_ref(xq: jnp.ndarray, wq: jnp.ndarray, sx: jnp.ndarray,
                  sw: jnp.ndarray) -> jnp.ndarray:
    """Transform-domain matmul: int8 x int8 -> int32 -> dequant f32."""
    acc = jnp.einsum("ptk,pkn->ptn", xq.astype(jnp.int32),
                     wq.astype(jnp.int32))
    return acc.astype(jnp.float32) * (sx[:, None, None] * sw[:, None, :])


def sfc_inverse_ref(ty: jnp.ndarray, at: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("mt,ntuo,pu->nmpo", at, ty, at)


def quantized_fastconv2d_ref(x: jnp.ndarray, w: jnp.ndarray,
                             algo: BilinearAlgorithm,
                             act_scale: jnp.ndarray,
                             w_scale: jnp.ndarray,
                             padding: str = "SAME") -> jnp.ndarray:
    """End-to-end oracle for the fused int8 SFC convolution pipeline.

    act_scale: (t, t) static calibrated scales; w_scale: (t, t, Cout).
    """
    from repro.core import conv2d as c2d

    B, H, W_, C = x.shape
    tx, geom = c2d.transform_input_2d(x, algo, padding)
    nH, nW = geom[2], geom[3]
    t = algo.t
    tiles_flat = tx.reshape(B * nH * nW, t, t, C)
    qmax = 127
    xq = jnp.clip(jnp.round(tiles_flat / act_scale[None, :, :, None]),
                  -qmax, qmax).astype(jnp.int8)
    tw = c2d.transform_weights_2d(w, algo)
    wq = jnp.clip(jnp.round(tw / w_scale[:, :, None, :]),
                  -qmax, qmax).astype(jnp.int8)
    P = t * t
    X = jnp.transpose(xq.reshape(B * nH * nW, P, C), (1, 0, 2))
    Wm = wq.reshape(P, C, -1)
    sx = act_scale.reshape(P)
    sw = w_scale.reshape(P, -1)
    Y = tdmm_int8_ref(X, Wm, sx, sw)                # (P, T, O)
    O = Y.shape[-1]
    ty = jnp.transpose(Y, (1, 0, 2)).reshape(B * nH * nW, t, t, O)
    y = sfc_inverse_ref(ty, jnp.asarray(algo.at(), ty.dtype))
    y = y.reshape(B, nH, nW, algo.M, algo.M, O)
    y = jnp.transpose(y, (0, 1, 3, 2, 4, 5)).reshape(
        B, nH * algo.M, nW * algo.M, O)
    return y[:, :geom[0], :geom[1], :]
