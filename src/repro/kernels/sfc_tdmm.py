"""Pallas TPU kernel: transform-domain int8 matmul with fused dequant.

The MXU hot spot of the staged SFC pipeline: for each transform-domain
position p in [0, t^2) an independent GEMM

    Y[p] = dequant( X[p] @ W[p] )        X: (T, K) int8, W: (K, N) int8

accumulated in int32 on the MXU and dequantized with the per-frequency
activation scale sx[p] and per-frequency-per-channel weight scales sw[p, :]
(paper Eq. 17).  Compared to direct int8 convolution, this stage runs
t^2 / (M^2 R^2) = 1/3.24x fewer MACs for SFC-6(6x6,3x3).

Depthwise 2-D convs have no channel contraction at all, so their
"matmul" collapses to a VPU elementwise product per position —
:func:`tdmm_int8_depthwise` is that stage (the lowering layer routes
``groups == C`` specs here instead of the t^2 GEMMs).

Blocking: grid (P, T/bt, N/bn[, K/bk]).  With ``k_block=None`` the full K
(C_in) dimension is resident per step — for bt = bn = 128, K = 2048:
256 KiB int8 X + 256 KiB W + 64 KiB int32 acc, comfortably within a v5e
core's 16 MiB VMEM, but K much beyond that blows the budget.  Passing
``k_block`` adds an innermost reduction grid dimension that accumulates
partial products into an int32 VMEM scratch and dequantizes on the last
k step, bounding VMEM residency at O(bt*bk + bk*bn) regardless of C_in.
MXU dims (bt, bk, bn) should be 128-multiples on real hardware.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

T_BLOCK = 128
N_BLOCK = 128


def _tdmm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref):
    x = x_ref[0]                                     # (bt, K) int8
    w = w_ref[0]                                     # (K, bn) int8
    acc = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)            # (bt, bn) int32
    scale = sx_ref[0] * sw_ref[0]                    # (bn,) f32
    o_ref[0] = acc.astype(jnp.float32) * scale[None, :]


def _tdmm_kblock_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *,
                        n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                     # (bt, bk) int8
    w = w_ref[0]                                     # (bk, bn) int8
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)            # (bt, bn) int32

    @pl.when(k == n_k - 1)
    def _dequant():
        scale = sx_ref[0] * sw_ref[0]                # (bn,) f32
        o_ref[0] = acc_ref[...].astype(jnp.float32) * scale[None, :]


def _tdmm_dw_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref):
    x = x_ref[0].astype(jnp.int32)                   # (bt, bc)
    w = w_ref[0].astype(jnp.int32)                   # (bc,)
    prod = x * w[None, :]                            # exact int32 products
    scale = sx_ref[0] * sw_ref[0]                    # (bc,) f32
    o_ref[0] = prod.astype(jnp.float32) * scale[None, :]


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    width = [(0, 0)] * x.ndim
    width[axis] = (0, pad)
    return jnp.pad(x, width)


@functools.partial(jax.jit, static_argnames=("interpret", "t_block",
                                             "n_block", "k_block"))
def tdmm_int8(xq: jnp.ndarray, wq: jnp.ndarray, sx: jnp.ndarray,
              sw: jnp.ndarray, *, interpret: bool = True,
              t_block: int = T_BLOCK, n_block: int = N_BLOCK,
              k_block: Optional[int] = None) -> jnp.ndarray:
    """X (P, T, K) int8 x W (P, K, N) int8 -> (P, T, N) f32."""
    P, T, K = xq.shape
    _, _, N = wq.shape
    assert wq.shape == (P, K, N) and sx.shape == (P,) and sw.shape == (P, N)
    xq = _pad_to(xq, 1, t_block)
    wq = _pad_to(wq, 2, n_block)
    sw_p = _pad_to(sw, 1, n_block)
    Tp, Np = xq.shape[1], wq.shape[2]
    sx = sx.astype(jnp.float32)
    sw_p = sw_p.astype(jnp.float32)
    if k_block is None or k_block >= K:
        out = pl.pallas_call(
            _tdmm_kernel,
            grid=(P, Tp // t_block, Np // n_block),
            in_specs=[
                pl.BlockSpec((1, t_block, K), lambda p, i, j: (p, i, 0)),
                pl.BlockSpec((1, K, n_block), lambda p, i, j: (p, 0, j)),
                pl.BlockSpec((1,), lambda p, i, j: (p,)),
                pl.BlockSpec((1, n_block), lambda p, i, j: (p, j)),
            ],
            out_specs=pl.BlockSpec((1, t_block, n_block),
                                   lambda p, i, j: (p, i, j)),
            out_shape=jax.ShapeDtypeStruct((P, Tp, Np), jnp.float32),
            interpret=interpret,
        )(xq, wq, sx, sw_p)
        return out[:, :T, :N]
    # k-blocked reduction: zero-padded K tail contributes nothing
    xq = _pad_to(xq, 2, k_block)
    wq = _pad_to(wq, 1, k_block)
    Kp = xq.shape[2]
    n_k = Kp // k_block
    kern = functools.partial(_tdmm_kblock_kernel, n_k=n_k)
    out = pl.pallas_call(
        kern,
        grid=(P, Tp // t_block, Np // n_block, n_k),
        in_specs=[
            pl.BlockSpec((1, t_block, k_block),
                         lambda p, i, j, k: (p, i, k)),
            pl.BlockSpec((1, k_block, n_block),
                         lambda p, i, j, k: (p, k, j)),
            pl.BlockSpec((1,), lambda p, i, j, k: (p,)),
            pl.BlockSpec((1, n_block), lambda p, i, j, k: (p, j)),
        ],
        out_specs=pl.BlockSpec((1, t_block, n_block),
                               lambda p, i, j, k: (p, i, j)),
        out_shape=jax.ShapeDtypeStruct((P, Tp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((t_block, n_block), jnp.int32)],
        interpret=interpret,
    )(xq, wq, sx, sw_p)
    return out[:, :T, :N]


@functools.partial(jax.jit, static_argnames=("interpret", "t_block",
                                             "n_block"))
def tdmm_int8_depthwise(xq: jnp.ndarray, wq: jnp.ndarray, sx: jnp.ndarray,
                        sw: jnp.ndarray, *, interpret: bool = True,
                        t_block: int = T_BLOCK,
                        n_block: int = N_BLOCK) -> jnp.ndarray:
    """X (P, T, C) int8 x W (P, C) int8 -> (P, T, C) f32, elementwise.

    The depthwise element-wise stage: no C_in contraction, so each
    transform-domain position is a broadcast int32 product dequantized
    with sx[p] * sw[p, c] — VPU work, no MXU, no reduction grid dim.
    """
    P, T, C = xq.shape
    assert wq.shape == (P, C) and sx.shape == (P,) and sw.shape == (P, C), \
        (xq.shape, wq.shape, sx.shape, sw.shape)
    xq = _pad_to(xq, 1, t_block)
    xq = _pad_to(xq, 2, n_block)
    wq_p = _pad_to(wq, 1, n_block)
    sw_p = _pad_to(sw, 1, n_block).astype(jnp.float32)
    Tp, Cp = xq.shape[1], xq.shape[2]
    out = pl.pallas_call(
        _tdmm_dw_kernel,
        grid=(P, Tp // t_block, Cp // n_block),
        in_specs=[
            pl.BlockSpec((1, t_block, n_block), lambda p, i, j: (p, i, j)),
            pl.BlockSpec((1, n_block), lambda p, i, j: (p, j)),
            pl.BlockSpec((1,), lambda p, i, j: (p,)),
            pl.BlockSpec((1, n_block), lambda p, i, j: (p, j)),
        ],
        out_specs=pl.BlockSpec((1, t_block, n_block),
                               lambda p, i, j: (p, i, j)),
        out_shape=jax.ShapeDtypeStruct((P, Tp, Cp), jnp.float32),
        interpret=interpret,
    )(xq, wq_p, sx.astype(jnp.float32), sw_p)
    return out[:, :T, :C]
