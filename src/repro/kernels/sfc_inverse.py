"""Pallas TPU kernel: SFC inverse transform A^T Y A.

Maps dequantized transform-domain outputs (nT, t, t, O) back to spatial
output tiles (nT, M, M, O).  A^T carries the correction-term columns, so the
circular->linear conversion of paper §4.2 happens inside this same GEMM —
no separate correction pass or extra HBM traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_BLOCK = 8
CHAN_BLOCK = 128


def _inverse_kernel(at_ref, y_ref, o_ref):
    at = at_ref[...]                                  # (M, t)
    y = y_ref[...]                                    # (TB, t, t, OB)
    z = jnp.einsum("mt,ntuc->nmuc", at, y,
                   preferred_element_type=jnp.float32)
    z = jnp.einsum("pu,nmuc->nmpc", at, z,
                   preferred_element_type=jnp.float32)
    o_ref[...] = z.astype(o_ref.dtype)


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    width = [(0, 0)] * x.ndim
    width[axis] = (0, pad)
    return jnp.pad(x, width), pad


@functools.partial(jax.jit, static_argnames=("interpret", "tile_block",
                                             "chan_block"))
def sfc_inverse(ty: jnp.ndarray, at: jnp.ndarray, *,
                interpret: bool = True, tile_block: int = TILE_BLOCK,
                chan_block: int = CHAN_BLOCK) -> jnp.ndarray:
    """(nT, t, t, O) -> (nT, M, M, O)."""
    nT, t, _, O = ty.shape
    M = at.shape[0]
    ty, _ = _pad_to(ty, 0, tile_block)
    ty, _ = _pad_to(ty, 3, chan_block)
    nTp, Op = ty.shape[0], ty.shape[3]
    out = pl.pallas_call(
        _inverse_kernel,
        grid=(nTp // tile_block, Op // chan_block),
        in_specs=[
            pl.BlockSpec((M, t), lambda i, j: (0, 0)),
            pl.BlockSpec((tile_block, t, t, chan_block),
                         lambda i, j: (i, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_block, M, M, chan_block),
                               lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nTp, M, M, Op), ty.dtype),
        interpret=interpret,
    )(at.astype(ty.dtype), ty)
    return out[:nT, :, :, :O]
