"""Pallas TPU kernels for the SFC hot spots (+ pure-jnp oracles in ref.py)."""
from repro.kernels.ops import (extract_tiles, fastconv2d_fp,
                               quantized_fastconv2d, quantize_weights, untile)
from repro.kernels.sfc_transform import sfc_transform, sfc_transform_quantize
from repro.kernels.sfc_tdmm import tdmm_int8
from repro.kernels.sfc_inverse import sfc_inverse
from repro.kernels import ref

__all__ = [
    "sfc_transform", "sfc_transform_quantize", "tdmm_int8", "sfc_inverse",
    "quantized_fastconv2d", "fastconv2d_fp", "quantize_weights",
    "extract_tiles", "untile", "ref",
]
