"""Pallas TPU kernels for the SFC hot spots (+ pure-jnp oracles in ref.py).

``quantized_fastconv2d`` / ``fastconv2d_fp`` re-exported here are
deprecation shims: new code should run convolutions through ``repro.api``
with ``backend="pallas"`` — the API owns weight preparation (offline int8
quantization) and falls back to direct convolution where these kernels do
not apply.  The individual kernels (``sfc_transform``, ``tdmm_int8``,
``sfc_inverse``) remain the supported building blocks.
"""
from repro._deprecation import deprecated as _deprecated

from repro.kernels import ops as _ops
from repro.kernels.ops import extract_tiles, quantize_weights, untile
from repro.kernels.sfc_transform import sfc_transform, sfc_transform_quantize
from repro.kernels.sfc_tdmm import tdmm_int8
from repro.kernels.sfc_inverse import sfc_inverse
from repro.kernels.sfc_fused import sfc_fused_conv2d
from repro.kernels import ref

quantized_fastconv2d = _deprecated(
    _ops.quantized_fastconv2d, "repro.kernels",
    "repro.api.plan(spec, backend='pallas') with int8 prepared weights")
fastconv2d_fp = _deprecated(
    _ops.fastconv2d_fp, "repro.kernels",
    "repro.api.plan(spec, backend='pallas').apply")

__all__ = [
    "sfc_transform", "sfc_transform_quantize", "tdmm_int8", "sfc_inverse",
    "sfc_fused_conv2d", "quantized_fastconv2d", "fastconv2d_fp",
    "quantize_weights", "extract_tiles", "untile", "ref",
]
