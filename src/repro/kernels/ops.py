"""Jit'd public wrappers assembling the Pallas SFC kernels end-to-end.

``quantized_fastconv2d`` is the deployment path of the paper's pipeline:

  tile -> [Pallas: transform + per-frequency int8 quant]   (additions only)
       -> [Pallas: t^2-position int8 MXU matmul + dequant]
       -> [Pallas: inverse transform incl. correction terms]
       -> untile

Scales are static (PTQ-calibrated): act_scale (t, t), w_scale (t, t, Cout).
On this CPU-only container the kernels run with interpret=True; on TPU pass
interpret=False (the layouts/BlockSpecs are chosen for v5e).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import conv2d as c2d
from repro.core.generator import BilinearAlgorithm
from repro.kernels.sfc_transform import sfc_transform, sfc_transform_quantize
from repro.kernels.sfc_tdmm import tdmm_int8, tdmm_int8_depthwise
from repro.kernels.sfc_inverse import sfc_inverse


def extract_tiles(x: jnp.ndarray, algo: BilinearAlgorithm,
                  padding: str = "SAME") -> Tuple[jnp.ndarray, Tuple]:
    """(B,H,W,C) -> flat tiles (B*nH*nW, L, L, C) + geometry."""
    B, H, W, C = x.shape
    M, R, L = algo.M, algo.R, algo.L
    lo_h, hi_h, out_h = c2d.pad_amounts(H, M, R, padding)
    lo_w, hi_w, out_w = c2d.pad_amounts(W, M, R, padding)
    xp = jnp.pad(x, ((0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    nH = (xp.shape[1] - (R - 1)) // M
    nW = (xp.shape[2] - (R - 1)) // M
    # single gather directly into (B, nH, nW, L, L, C) — the chained
    # xp[:, ih][:, :, :, iw] form materialized an extra (B, nH, L, Wp, C)
    # intermediate and needed a transpose afterwards
    ih = np.arange(nH)[:, None] * M + np.arange(L)[None, :]   # (nH, L)
    iw = np.arange(nW)[:, None] * M + np.arange(L)[None, :]   # (nW, L)
    tiles = xp[:, ih[:, None, :, None], iw[None, :, None, :], :]
    tiles = tiles.reshape(B * nH * nW, L, L, C)
    return tiles, (B, out_h, out_w, nH, nW)


def untile(y_tiles: jnp.ndarray, algo: BilinearAlgorithm,
           geom: Tuple) -> jnp.ndarray:
    B, out_h, out_w, nH, nW = geom
    M = algo.M
    O = y_tiles.shape[-1]
    y = y_tiles.reshape(B, nH, nW, M, M, O)
    y = jnp.transpose(y, (0, 1, 3, 2, 4, 5)).reshape(B, nH * M, nW * M, O)
    return y[:, :out_h, :out_w, :]


def quantize_weights(w: jnp.ndarray, algo: BilinearAlgorithm,
                     w_scale: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """(R,R,Cin,Cout) f32 -> (t^2, Cin, Cout) int8 — offline, once."""
    from repro.quant.fake_quant import quantize_transformed_weights
    tw = c2d.transform_weights_2d(w, algo)            # (t,t,Cin,Cout)
    return quantize_transformed_weights(tw, w_scale, bits)


@functools.partial(jax.jit, static_argnames=("algo", "padding", "bits",
                                             "interpret", "k_block",
                                             "tile_block", "chan_block"))
def quantized_fastconv2d(x: jnp.ndarray, wq: jnp.ndarray,
                         act_scale: jnp.ndarray, w_scale: jnp.ndarray,
                         algo: BilinearAlgorithm, *,
                         padding: str = "SAME", bits: int = 8,
                         interpret: bool = True,
                         k_block: Optional[int] = None,
                         tile_block: int = 8,
                         chan_block: int = 128) -> jnp.ndarray:
    """int8 SFC convolution with pre-quantized weights (staged pipeline).

    x (B,H,W,Cin) f32; wq (t^2, Cin, Cout) int8; act_scale (t,t);
    w_scale (t,t,Cout) -> (B,H',W',Cout) f32.  ``bits`` sets the
    activation clipping grid (sub-int8 policies run on the int8 carrier);
    ``k_block`` bounds the C_in VMEM residency of the transform-domain
    matmul (see ``tdmm_int8``); ``tile_block``/``chan_block`` block the
    transform/inverse stages.
    """
    t = algo.t
    bt, _, at = c2d.transform_matrices(algo, "float32")
    tiles, geom = extract_tiles(x, algo, padding)
    xq = sfc_transform_quantize(tiles, bt, act_scale, bits=bits,
                                interpret=interpret, tile_block=tile_block,
                                chan_block=chan_block)
    T = xq.shape[0]
    C = xq.shape[-1]
    X = jnp.transpose(xq.reshape(T, t * t, C), (1, 0, 2))   # (P, T, C)
    Y = tdmm_int8(X, wq, act_scale.reshape(t * t),
                  w_scale.reshape(t * t, -1), interpret=interpret,
                  k_block=k_block)
    O = Y.shape[-1]
    ty = jnp.transpose(Y, (1, 0, 2)).reshape(T, t, t, O)
    y_tiles = sfc_inverse(ty, at, interpret=interpret,
                          tile_block=tile_block, chan_block=chan_block)
    return untile(y_tiles, algo, geom)


@functools.partial(jax.jit, static_argnames=("algo", "padding", "bits",
                                             "interpret", "tile_block",
                                             "chan_block"))
def quantized_fastconv2d_depthwise(x: jnp.ndarray, wq: jnp.ndarray,
                                   act_scale: jnp.ndarray,
                                   w_scale: jnp.ndarray,
                                   algo: BilinearAlgorithm, *,
                                   padding: str = "SAME", bits: int = 8,
                                   interpret: bool = True,
                                   tile_block: int = 8,
                                   chan_block: int = 128) -> jnp.ndarray:
    """int8 depthwise SFC convolution (staged pipeline).

    x (B,H,W,C) f32; wq (t^2, 1, C) int8; act_scale (t,t); w_scale
    (t,t,C) -> (B,H',W',C) f32.  Same three stages as the dense
    ``quantized_fastconv2d`` with the t^2 GEMMs replaced by the
    transform-domain elementwise product (``tdmm_int8_depthwise``) —
    there is no channel contraction, so no k-blocking either.
    """
    t = algo.t
    bt, _, at = c2d.transform_matrices(algo, "float32")
    tiles, geom = extract_tiles(x, algo, padding)
    xq = sfc_transform_quantize(tiles, bt, act_scale, bits=bits,
                                interpret=interpret, tile_block=tile_block,
                                chan_block=chan_block)
    T = xq.shape[0]
    C = xq.shape[-1]
    X = jnp.transpose(xq.reshape(T, t * t, C), (1, 0, 2))   # (P, T, C)
    Y = tdmm_int8_depthwise(X, wq.reshape(t * t, C),
                            act_scale.reshape(t * t),
                            w_scale.reshape(t * t, C), interpret=interpret)
    ty = jnp.transpose(Y, (1, 0, 2)).reshape(T, t, t, C)
    y_tiles = sfc_inverse(ty, at, interpret=interpret,
                          tile_block=tile_block, chan_block=chan_block)
    return untile(y_tiles, algo, geom)


@functools.partial(jax.jit, static_argnames=("algo", "padding", "interpret"))
def fastconv2d_fp(x: jnp.ndarray, w: jnp.ndarray, algo: BilinearAlgorithm, *,
                  padding: str = "SAME", interpret: bool = True
                  ) -> jnp.ndarray:
    """Unquantized kernel path (transform -> f32 tdmm -> inverse)."""
    bt, _, at = c2d.transform_matrices(algo, x.dtype.name)
    t = algo.t
    tiles, geom = extract_tiles(x, algo, padding)
    tx = sfc_transform(tiles, bt, interpret=interpret)
    tw = c2d.transform_weights_2d(w, algo)
    ty = jnp.einsum("ntuc,tuco->ntuo", tx, tw)
    y_tiles = sfc_inverse(ty, at, interpret=interpret)
    return untile(y_tiles, algo, geom)
