"""Fault-tolerant training loop.

Production behaviors, all exercised by tests:
  * **checkpoint/restart**: periodic async checkpoints; on (re)start the
    trainer resumes from the latest valid snapshot and replays the data
    stream deterministically from the restored step;
  * **step-failure containment**: a configurable failure handler classifies
    exceptions; transient failures (preemption, injected faults) roll back
    to the last checkpoint and continue; repeated failures abort;
  * **straggler mitigation**: per-step wall times feed an EWMA; steps slower
    than ``straggler_factor`` x EWMA are logged and counted (on real fleets
    this signal drives hot-spare swaps; here it drives the log + metrics so
    the policy is testable);
  * **elastic rescale**: ``Trainer.restore_elastic`` reshards the latest
    checkpoint onto a new mesh/device count (see checkpointer).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.optim.optimizers import AdamW
from repro.train.steps import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    max_retries: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    grad_compression: bool = False
    microbatches: int = 1


class TransientError(RuntimeError):
    """Raised by failure injectors / preemption signals."""


@dataclasses.dataclass
class TrainerReport:
    steps_run: int = 0
    restarts: int = 0
    stragglers: int = 0
    losses: List[float] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)


class Trainer:
    def __init__(self, model, optimizer: AdamW, cfg: TrainerConfig,
                 mesh=None, state_shardings=None,
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.model = model
        self.optimizer = optimizer
        self.cfg = cfg
        self.mesh = mesh
        self.state_shardings = state_shardings
        self.fault_hook = fault_hook          # tests inject failures here
        self.ckpt = Checkpointer(cfg.checkpoint_dir, keep=cfg.keep_checkpoints)
        self._step_fn = jax.jit(
            make_train_step(model, optimizer,
                            grad_compression=cfg.grad_compression,
                            microbatches=cfg.microbatches),
            donate_argnums=(0,))

    # ------------------------------------------------------------------
    def init_or_restore(self, key) -> tuple[TrainState, int]:
        state = init_train_state(self.model, self.optimizer, key)
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, step = self.ckpt.restore(state,
                                            shardings=self.state_shardings)
            return state, step
        return state, 0

    def restore_elastic(self, key, new_shardings) -> tuple[TrainState, int]:
        """Re-shard the latest checkpoint onto a different mesh."""
        state = jax.eval_shape(
            lambda k: init_train_state(self.model, self.optimizer, k), key)
        template = jax.tree_util.tree_map(
            lambda s: np.zeros(s.shape, s.dtype), state)
        return self.ckpt.restore(template, shardings=new_shardings)

    # ------------------------------------------------------------------
    def run(self, batches: Callable[[int], Dict[str, Any]], key
            ) -> TrainerReport:
        report = TrainerReport()
        state, start = self.init_or_restore(key)
        step = start
        retries = 0
        ewma: Optional[float] = None
        while step < self.cfg.total_steps:
            t0 = time.time()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)     # may raise TransientError
                batch = batches(step)
                state, metrics = self._step_fn(state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise TransientError(f"non-finite loss at step {step}")
            except TransientError as e:
                retries += 1
                report.restarts += 1
                if retries > self.cfg.max_retries:
                    raise RuntimeError(
                        f"giving up after {retries} retries: {e}")
                self.ckpt.wait()
                state, step = self.init_or_restore(key)
                continue
            retries = 0
            dt = time.time() - t0
            report.step_times.append(dt)
            if ewma is not None and dt > self.cfg.straggler_factor * ewma:
                report.stragglers += 1
            ewma = dt if ewma is None else \
                (1 - self.cfg.ewma_alpha) * ewma + self.cfg.ewma_alpha * dt
            report.losses.append(loss)
            report.steps_run += 1
            step += 1
            if step % self.cfg.checkpoint_every == 0 or \
                    step == self.cfg.total_steps:
                self.ckpt.save(step, state)
            if step % self.cfg.log_every == 0:
                print(f"step {step:6d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
        self.ckpt.wait()
        return report
