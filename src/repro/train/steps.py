"""jit-able train / prefill / decode steps with full sharding annotations.

``make_train_step`` builds the canonical production step:
  value_and_grad over the model loss (remat inside the layer scans)
  -> optional int8 gradient compression w/ error feedback
  -> AdamW update (f32 moments, sharded like the params)
  -> donated TrainState.

``make_serve_step`` builds the one-token decode step with a donated cache.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim.optimizers import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    rng: jnp.ndarray


def init_train_state(model: Model, optimizer: AdamW, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params),
                      rng=jax.random.fold_in(key, 1))


def abstract_train_state(model: Model, optimizer: AdamW) -> TrainState:
    params = model.init_abstract()
    opt = jax.eval_shape(optimizer.init, params)
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return TrainState(params=params, opt=opt, rng=rng)


def make_train_step(model: Model, optimizer: AdamW,
                    grad_compression: bool = False,
                    microbatches: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if microbatches > 1:
            # gradient accumulation: batch is split along the batch dim; the
            # per-chunk backward pass (and its reduce-scatters) overlaps the
            # next chunk's compute in the XLA schedule.
            def chunk(i):
                return jax.tree_util.tree_map(
                    lambda a: a.reshape((microbatches,
                                         a.shape[0] // microbatches)
                                        + a.shape[1:])[i], batch)

            def acc_fn(carry, i):
                gsum, msum = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, chunk(i))
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, msum + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_fn, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            metrics = {"loss": loss_sum / microbatches}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)

        if grad_compression:
            # int8 error-feedback bottleneck before the (GSPMD-inserted)
            # gradient reduction; residual feedback lives in the trainer's
            # explicit-compression path (repro/train/trainer.py).
            from repro.optim import grad_compression as gc
            key = jax.random.PRNGKey(0)
            grads = jax.tree_util.tree_map(
                lambda g: gc.dequantize_int8(*gc.quantize_int8(
                    g.astype(jnp.float32), key)).astype(g.dtype), grads)

        new_params, new_opt, opt_metrics = optimizer.apply(
            state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return TrainState(new_params, new_opt, state.rng), metrics

    return train_step


def make_serve_step(model: Model) -> Callable:
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(params, cache, tokens, pos)
        next_tokens = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return next_tokens.astype(jnp.int32), logits, new_cache
    return serve_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, tokens, memory=None):
        return model.forward(params, tokens, memory)
    return prefill_step
