"""Training loop + jit-able steps."""
from repro.train.steps import (TrainState, abstract_train_state,
                               init_train_state, make_prefill_step,
                               make_serve_step, make_train_step)
from repro.train.trainer import (Trainer, TrainerConfig, TrainerReport,
                                 TransientError)

__all__ = ["TrainState", "abstract_train_state", "init_train_state",
           "make_train_step", "make_serve_step", "make_prefill_step",
           "Trainer", "TrainerConfig", "TrainerReport", "TransientError"]
