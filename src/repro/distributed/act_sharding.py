"""Activation sharding constraints ("logical axis" annotations).

GSPMD propagates parameter/input shardings through most of the graph, but
propagation can fail into ``while``-loop carries (observed: the flash-
attention online-softmax carry compiled with an *unsharded* batch dim —
a 10 TB buffer at qwen2.5 train_4k scale; EXPERIMENTS.md §Perf iteration 0).
Model code therefore pins the batch axis at loop boundaries via
``constrain_batch``.

The mesh context is process-global and optional: with no rules installed
(unit tests, single-device runs) every call is a no-op, so model code stays
mesh-agnostic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_RULES: Optional[Tuple[Mesh, Tuple[str, ...]]] = None


def install(mesh: Mesh, batch_axes: Tuple[str, ...]) -> None:
    global _RULES
    _RULES = (mesh, batch_axes)


def clear() -> None:
    global _RULES
    _RULES = None


def constrain(x, spec: P):
    if _RULES is None:
        return x
    mesh, _ = _RULES
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_batch(x, batch_dim: int = 0):
    """Pin ``batch_dim`` to the data axes, other dims unconstrained."""
    if _RULES is None:
        return x
    mesh, baxes = _RULES
    if x.shape[batch_dim] % _axes_size(mesh, baxes) != 0:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = baxes if len(baxes) > 1 else baxes[0]
    return constrain(x, P(*spec))


def _axes_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def data_shards() -> int:
    """Number of data-parallel shards (1 when no rules are installed).

    Used by the MoE dispatch to keep token grouping shard-local (§Perf
    hillclimb 2): the token dim is reshaped to (data_shards, T_local) so
    sort/scatter/gather stay within a shard instead of lowering to global
    collectives."""
    if _RULES is None:
        return 1
    mesh, baxes = _RULES
    return _axes_size(mesh, baxes)
