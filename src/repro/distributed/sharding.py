"""Sharding rules: parameter/activation/cache PartitionSpecs per arch.

Strategy (DESIGN.md §5):
  * TP over 'model' on head / ffn / vocab / expert dims;
  * FSDP (ZeRO-3-style) over 'data' on the other big dim of every matrix —
    GSPMD inserts the all-gather at use and the reduce-scatter in the
    backward pass; optimizer moments inherit the same specs so the full
    training state is sharded over all devices;
  * EP over 'model' for the expert dim when divisible (deepseek 256/16),
    TP-within-expert otherwise (mixtral 8 experts);
  * batch over ('pod', 'data') — the 'pod' axis is data-parallel by default
    (pipeline-parallel mapping lives in distributed/pipeline.py);
  * KV caches: batch over data, kv-head dim over 'model' (GSPMD pads
    8 kv-heads -> 16 shards; see DESIGN.md §6), SSM states head-sharded.

Rules are *path-pattern based* so they survive model refactors; stacked
layer params (leading n_layers axis under lax.scan) automatically get a
leading ``None``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _model_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


# map (leaf-name, core shape) -> base PartitionSpec (without the stacked
# leading layer axis)
def _base_spec(name: str, core_shape: Tuple[int, ...], cfg: ModelConfig,
               mesh: Mesh) -> P:
    ndim = len(core_shape)
    fs = "data"          # FSDP axis
    tp = "model"         # tensor-parallel axis

    # ---- embeddings / head ----
    if name == "embed":
        return P(tp, fs)
    if name == "lm_head":
        return P(fs, tp)

    # ---- MoE expert stacks (E, d, f) / (E, f, d) ----
    if (name in ("w_gate", "w_up", "w_down") and ndim == 3
            and cfg.n_experts and core_shape[0] == cfg.n_experts):
        ep_ok = cfg.n_experts % _model_size(mesh) == 0
        if name == "w_down":
            return P(tp, None, fs) if ep_ok else P(None, tp, fs)
        return P(tp, fs, None) if ep_ok else P(None, fs, tp)
    if name == "router":
        return P(fs, None)

    # ---- attention (per-head 3-D layout; §Perf iteration 4) ----
    if ndim == 3 and name == "wq":
        return P(fs, tp, None)        # (d, Hq, hd): q-heads over model
    if ndim == 3 and name in ("wk", "wv"):
        return P(fs, None, None)      # K/V replicated over model (small)
    if ndim == 3 and name == "wo":
        return P(tp, None, fs)        # (Hq, hd, d)

    # ---- MLA / dense / ssm projections ----
    if ndim == 2 and name in ("wq", "wk", "wv", "wq_b", "wkv_b", "w_gate",
                              "w_up", "in_proj", "proj", "wq_a", "wkv_a"):
        return P(fs, tp)
    if ndim == 2 and name in ("wo", "w_down", "out_proj"):
        return P(tp, fs)
    if ndim == 2 and name == "conv_w":
        return P(None, tp)

    # everything else (norm scales, biases, gates, A_log, D, dt_bias):
    return P(*([None] * ndim))


_STACK_KEYS = ("blocks", "dense_blocks", "cross_blocks", "enc_blocks")


def param_pspec(path: Tuple[str, ...], leaf, cfg: ModelConfig,
                mesh: Mesh) -> P:
    stacked = any(str(k) in _STACK_KEYS for k in path)
    core_shape = leaf.shape[1:] if stacked else leaf.shape
    base = _base_spec(path[-1], core_shape, cfg, mesh)
    return P(None, *base) if stacked else base


def _path_str(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def params_pspecs(abstract_params, cfg: ModelConfig, mesh: Mesh):
    """Pytree of PartitionSpecs matching the parameter pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_pspec(_path_str(p), l, cfg, mesh),
        abstract_params)


def params_shardings(abstract_params, cfg: ModelConfig, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        params_pspecs(abstract_params, cfg, mesh),
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# batches and caches
# --------------------------------------------------------------------------
def batch_pspecs(batch_specs, mesh: Mesh):
    b = batch_axes(mesh)

    def spec(leaf):
        return P(b, *([None] * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map(spec, batch_specs)


def cache_pspec(path: Tuple[str, ...], leaf, cfg: ModelConfig,
                mesh: Mesh) -> P:
    """KV caches (L?, B, S, H, D), SSM states, cross K/V.

    The *sequence* dim of attention caches shards over 'model' (GQA head
    counts 8 < 16 cannot shard the head dim; context lengths always divide).
    Softmax over the sharded key axis lowers to an all-reduce of the online
    max/sum — cheap relative to cache HBM savings (see §Roofline).
    """
    name = path[-1]
    b = batch_axes(mesh)
    nd = len(leaf.shape)
    stacked = any(str(k) in ("layers", "dense_layers", "shared")
                  for k in path) or name in ("cross_k", "cross_v")
    lead = (None,) if stacked else ()
    if name in ("k", "v", "cross_k", "cross_v"):     # (B, S, Hkv, D)
        return P(*lead, b, "model", None, None)
    if name == "c_kv":                               # (B, S, kr)
        return P(*lead, b, "model", None)
    if name == "k_rope":                             # (B, S, dr)
        return P(*lead, b, "model", None)
    if name == "state":                              # (B, H, P, N)
        return P(*lead, b, "model", None, None)
    if name == "conv":                               # (B, R-1, ch)
        return P(*lead, b, None, "model")
    return P(*([None] * nd))


def cache_pspecs(abstract_cache, cfg: ModelConfig, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: cache_pspec(_path_str(p), l, cfg, mesh),
        abstract_cache)


def opt_state_pspecs(abstract_opt_state, pspecs_params):
    """AdamW moments inherit the parameter specs; step is replicated."""
    from repro.optim.optimizers import AdamWState
    return AdamWState(step=P(), mu=pspecs_params, nu=pspecs_params)


def sanitize_pspec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes from dims they do not divide (batch=1 decode, 8 GQA
    kv-heads on a 16-way axis, ...) — explicit jit in_shardings require
    exact divisibility."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if shape[i] % size == 0 else None)
    return P(*out)


def sanitized_shardings(pspecs, abstract_tree, mesh: Mesh):
    """NamedShardings with non-divisible axes dropped per leaf."""
    return jax.tree_util.tree_map(
        lambda s, l: NamedSharding(mesh, sanitize_pspec(s, l.shape, mesh)),
        pspecs, abstract_tree,
        is_leaf=lambda x: isinstance(x, P))


def to_shardings(pspecs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
