"""Distributed runtime: sharding rules, pipeline parallelism, collectives."""
from repro.distributed import pipeline, sharding

__all__ = ["pipeline", "sharding"]
