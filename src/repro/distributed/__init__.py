"""Distributed runtime: sharding rules, pipeline parallelism, collectives,
and the sharded SPMD conv backend (``conv_spmd``, registered with
``repro.api`` as ``"pallas_spmd"``)."""
from repro.distributed import conv_spmd, pipeline, sharding

__all__ = ["conv_spmd", "pipeline", "sharding"]
