"""Pipeline parallelism over a mesh axis (GPipe schedule, shard_map).

Maps a homogeneous layer stack onto ``n_stages`` groups along a mesh axis
(the 'pod' axis in the multi-pod mesh — an alternative to treating pods as
extra data parallelism; inter-pod links carry only (micro_batch, seq, d)
activations once per microbatch per step, which is what makes PP the right
choice when inter-pod bandwidth << intra-pod bandwidth).

``pipeline_apply`` runs the classic GPipe fill/drain schedule with
``collective_permute`` hops between neighbouring stages:

    tick t: stage s processes microbatch (t - s) if 0 <= t-s < M

Activations enter at stage 0, exit at stage S-1, and are returned to every
device with a final broadcast-style psum (masked), so the caller can
compute the loss uniformly.  Correctness is tested against the sequential
stack in tests/test_pipeline.py.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def stack_params_for_stages(params_stacked, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L/S, ...)."""
    def reshape(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])
    return jax.tree_util.tree_map(reshape, params_stacked)


def pipeline_apply(stage_fn: Callable, params_staged, x: jnp.ndarray,
                   n_micro: int, mesh: Mesh, axis: str = "stage"
                   ) -> jnp.ndarray:
    """Run x (B, ...) through S pipeline stages with M microbatches.

    stage_fn(stage_params, x_micro) -> x_micro  (the per-stage layer scan);
    params_staged leaves have leading dim S (sharded over ``axis``).
    """
    S = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0
    mb = B // n_micro
    x_micro = x.reshape((n_micro, mb) + x.shape[1:])

    param_specs = jax.tree_util.tree_map(
        lambda a: P(axis, *([None] * (a.ndim - 1))), params_staged)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(param_specs, P(*([None] * (x_micro.ndim)))),
        out_specs=P(*([None] * x_micro.ndim)),
        check_rep=False)
    def run(params_local, xm):
        stage = jax.lax.axis_index(axis)
        sp = jax.tree_util.tree_map(lambda a: a[0], params_local)
        buf = jnp.zeros_like(xm[0])              # inter-stage register
        outs = jnp.zeros_like(xm)
        fwd_perm = [(i, i + 1) for i in range(S - 1)]

        def tick(t, carry):
            buf, outs = carry
            micro_idx = t - stage
            active = (micro_idx >= 0) & (micro_idx < n_micro)
            # stage 0 reads its microbatch from the input stream
            inject = jax.lax.dynamic_index_in_dim(
                xm, jnp.clip(micro_idx, 0, n_micro - 1), 0, keepdims=False)
            inp = jnp.where(stage == 0, inject, buf)
            out = stage_fn(sp, inp)
            out = jnp.where(active, out, buf)
            # last stage records its finished microbatch
            record = (stage == S - 1) & active
            outs = jax.lax.dynamic_update_index_in_dim(
                outs,
                jnp.where(record, out,
                          jax.lax.dynamic_index_in_dim(
                              outs, jnp.clip(micro_idx, 0, n_micro - 1), 0,
                              keepdims=False)),
                jnp.clip(micro_idx, 0, n_micro - 1), 0)
            # ship activations to the next stage
            buf_next = jax.lax.ppermute(out, axis, fwd_perm)
            return (buf_next, outs)

        buf, outs = jax.lax.fori_loop(0, n_micro + S - 1, tick, (buf, outs))
        # broadcast final outputs from the last stage to all stages
        mask = (stage == S - 1).astype(outs.dtype)
        outs = jax.lax.psum(outs * mask, axis)
        return outs

    y = run(params_staged, x_micro)
    return y.reshape((B,) + y.shape[2:])
