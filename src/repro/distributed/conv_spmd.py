"""Sharded SPMD conv backend: ``shard_map`` over the production mesh.

``pallas_spmd`` scales the single-device Pallas datapath across a device
mesh without touching any call site — it is a ``repro.api`` backend like
the others, registered under ``repro.api.register_backend`` and resolved
by name from ``plan(spec, backend="pallas_spmd")``.

Sharding layout (the conv analogue of ``distributed/sharding.py``):

  * batch over ``('pod', 'data')`` — SFC tiling is *halo-free across
    images*: every (L, L) input tile lives entirely inside one image, so
    splitting the batch ships whole images and needs no neighbour
    exchange (unlike spatial partitioning of a convolution, which must
    exchange R-1 boundary rows);
  * C_out over ``'model'`` — transform-domain output channels are
    independent: each shard holds its own (t^2, C_in, C_out/m) int8
    weight block plus the matching per-frequency dequant scales, and the
    fused kernel runs unchanged on the local block.

Both axes compose, and both are **bit-identical** to the single-device
backend: no cross-shard reduction exists anywhere in the datapath (the
C_in contraction stays intact per shard), so not a single float is
accumulated in a different order.

Kernel configs ride the plan through ``shard_map`` unchanged: a
``KernelConfig`` with ``rows_per_step``/``double_buffer`` (the batched,
DMA-pipelined fused grid) executes per shard exactly as on one device —
and ``rows_per_step=None`` auto-resolution sees the *local* batch (the
data axis shrinks B before the kernel wrapper runs), so a sharded small
batch folds whole images per step precisely when the shard, not the
global batch, is small.  Grouping only ever folds divisors of the local
batch, so every data-shard layout remains bit-identical.

Axes that do not divide the corresponding extent are dropped per
:func:`repro.distributed.sharding.sanitize_pspec` — batch-1 decode on a
multi-way data axis, ragged C_out — and that dimension is computed
replicated instead: graceful degradation, never an error.

Lowered (composite) plans need nothing special here: the planner's
lowering pass hands every polyphase/grouped sub-problem to ``plan(...,
backend="pallas_spmd")``, so each sub-plan is its own shard_map-wrapped
apply with its own ``place_prepared`` placement — sub-plans inherit the
shard layout by construction.  2-D depthwise specs shard their single
channel axis over 'model' on input and weights alike (the elementwise
path has no contraction to split).

:meth:`SpmdPallasBackend.place_prepared` is the offline half:
``ConvPlan.prepare_weights`` routes prepared tensors through it, so
``wq``/``w_scale`` (and fp ``tw``) land on the mesh C_out-sharded once,
ahead of traffic, instead of being broadcast at every apply.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import batch_axes, sanitize_pspec

# NOTE: repro.api imports are late (inside methods) — repro.api.backends
# imports this module at its own bottom to register the backend, so a
# top-level import here would be circular whichever side loads first.


class SpmdPallasBackend:
    """``shard_map``-wrapped Pallas datapath; one mesh per backend object.

    The default mesh is whatever the host exposes
    (``launch.mesh.make_host_mesh``: all devices on 'data', 'model' = 1);
    production launchers and the scale-out benchmarks install an explicit
    mesh with :meth:`set_mesh`.
    """

    name = "pallas_spmd"
    # same int8 x int8 -> int32 datapath as PallasBackend: the planner's
    # overflow pre-flight applies (sharding C_in does not relax the
    # bound — each shard still accumulates its full local contraction,
    # and the psum joins in int32).
    integer_datapath = True

    def __init__(self, mesh: Optional[Mesh] = None):
        self._mesh = mesh

    @property
    def mesh(self) -> Mesh:
        if self._mesh is None:
            from repro.launch.mesh import make_host_mesh
            self._mesh = make_host_mesh()
        return self._mesh

    def set_mesh(self, mesh: Optional[Mesh]) -> None:
        """Install an execution mesh (None re-resolves the host default).

        Invalidates memoized plans: their prepared-weight caches hold
        placements for the previous mesh.
        """
        self._mesh = mesh
        from repro.api import planner
        planner.invalidate_plan_cache()

    # ------------------------------------------------------------------
    # offline: prepared-weight placement (ConvPlan.prepare_weights hook)
    # ------------------------------------------------------------------
    def place_prepared(self, plan, prep):
        """Device-shard prepared weights: C_out over 'model', rest
        replicated.  Non-divisible extents degrade to replication.

        Grouped direct specs stay replicated: slicing C_out across shards
        would misalign the group <-> input-block correspondence of
        ``feature_group_count`` (grouped specs normally never get here —
        the lowering pass splits them into per-group dense sub-plans,
        each of which shards its own C_out/g — only a lowering-rejected
        grouped direct plan lands on this path).  Depthwise shards its
        single channel axis: ``apply`` co-shards the input channels.
        """
        if plan.spec.rank != 2 or plan.spec.groups > 1:
            return prep
        mesh = self.mesh

        def put(a, spec):
            if a is None:
                return None
            s = sanitize_pspec(spec, a.shape, mesh)
            return jax.device_put(a, NamedSharding(mesh, s))

        return dataclasses.replace(
            prep,
            tw=put(prep.tw, P(None, None, None, "model")),
            wq=put(prep.wq, P(None, None, "model")),
            w_scale=put(prep.w_scale, P(None, None, "model")),
            act_scale=put(prep.act_scale, P(None, None)))

    # ------------------------------------------------------------------
    # online: execution
    # ------------------------------------------------------------------
    def apply(self, plan, x, prep, *, bias=None, elementwise_hook=None):
        if elementwise_hook is not None:
            raise ValueError(
                "the pallas_spmd backend takes no elementwise_hook; bake "
                "quantization into the plan (spec.quant + calibrated "
                "prepare_weights) or use backend='reference'")
        from repro.api.backends import get_backend
        from repro.api.plan import PreparedWeights
        inner = get_backend("pallas")
        if plan.spec.rank != 2:
            # rank-1 depthwise: bandwidth-bound reference impl, replicated
            return inner.apply(plan, x, prep, bias=bias)
        mesh = self.mesh
        b_ax = batch_axes(mesh)

        # depthwise: in == out channels, so the channel axis shards over
        # 'model' on BOTH the input and the weights (each shard runs the
        # elementwise path on its channel block — still no cross-shard
        # reduction, still bit-identical).  Grouped direct stays
        # replicated on C_out: a shard slice would misalign
        # feature_group_count's group <-> input-block pairing.
        dw = plan.spec.depthwise
        c_ax = "model" if dw else None
        o_ax = None if plan.spec.groups > 1 else "model"

        operands = {"x": x}
        specs = {"x": P(b_ax, None, None, c_ax)}
        if prep.quantized:
            operands.update(wq=prep.wq, w_scale=prep.w_scale,
                            act_scale=prep.act_scale)
            specs.update(wq=P(None, None, o_ax),
                         w_scale=P(None, None, o_ax),
                         act_scale=P(None, None))
            w_key = "wq"
        elif plan.algorithm is not None:
            operands["tw"] = prep.tw
            specs["tw"] = P(None, None, None, o_ax)
            w_key = "tw"
        else:
            # direct path: HWIO weights; output channels stay independent
            operands["w"] = prep.w
            specs["w"] = P(None, None, None, o_ax)
            w_key = "w"
        if bias is not None:
            operands["bias"] = jnp.asarray(bias)
            specs["bias"] = P(o_ax)
        specs = {k: sanitize_pspec(s, jnp.shape(operands[k]), mesh)
                 for k, s in specs.items()}
        out_spec = P(specs["x"][0], None, None, specs[w_key][-1])

        def _local(ops):
            lp = PreparedWeights(w=ops.get("w"), tw=ops.get("tw"),
                                 wq=ops.get("wq"),
                                 w_scale=ops.get("w_scale"),
                                 act_scale=ops.get("act_scale"))
            return inner.apply(plan, ops["x"], lp, bias=ops.get("bias"))

        # check_rep=False: pallas_call is opaque to shard_map's replication
        # checker; replication of the dropped (non-divisible) axes is
        # guaranteed by construction — every shard sees identical operands.
        return shard_map(_local, mesh=mesh, in_specs=(specs,),
                         out_specs=out_spec, check_rep=False)(operands)
