"""Streaming serving metrics: latency histograms, SLO attainment, queue
depth, batch occupancy, padding waste.

The registry is the engine's one accounting surface — every number the
open-loop harness (``benchmarks/serving.py``) lands in
``BENCH_conv.json["serving"]`` comes out of :meth:`MetricsRegistry.snapshot`.

Histograms are *streaming*: geometric fixed buckets, O(1) memory per
observation, percentiles by linear interpolation inside the bucket.  At
the default growth factor every bucket spans <10% of its lower bound, so
a reported p99 is within 10% of the exact order statistic — tight enough
to rank serving configurations, and immune to the unbounded-sample-list
failure mode of "store everything and sort" under millions of requests.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence

# 10us .. ~300s at 1.10 growth: ~180 buckets, <10% relative error
_LO_MS = 0.01
_GROWTH = 1.10


class LatencyHistogram:
    """Fixed geometric-bucket streaming histogram of millisecond latencies."""

    def __init__(self, lo_ms: float = _LO_MS, growth: float = _GROWTH,
                 n_buckets: int = 180):
        self._lo = lo_ms
        self._log_growth = math.log(growth)
        self._bounds = [lo_ms * growth ** i for i in range(n_buckets)]
        self._counts = [0] * (n_buckets + 1)   # +1: overflow bucket
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def _index(self, ms: float) -> int:
        if ms <= self._lo:
            return 0
        i = int(math.log(ms / self._lo) / self._log_growth) + 1
        return min(i, len(self._counts) - 1)

    def record(self, ms: float) -> None:
        ms = max(0.0, float(ms))
        self._counts[self._index(ms)] += 1
        self.count += 1
        self.sum += ms
        self.max = max(self.max, ms)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """p in [0, 100]; linear interpolation inside the landing bucket."""
        if not self.count:
            return 0.0
        rank = p / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self._counts):
            if not c:
                continue
            if seen + c >= rank:
                lo = self._bounds[i - 1] if i >= 1 else 0.0
                hi = self._bounds[i] if i < len(self._bounds) else self.max
                frac = (rank - seen) / c
                return min(lo + frac * (hi - lo), self.max)
            seen += c
        return self.max

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "mean_ms": self.mean,
                "p50_ms": self.percentile(50), "p95_ms": self.percentile(95),
                "p99_ms": self.percentile(99), "max_ms": self.max}


class MetricsRegistry:
    """Thread-safe serving metrics: one instance per engine.

    Histograms: ``queue_wait_ms`` (arrival -> dispatch, also split per
    SLO class — the number a deadline-aware scheduler actually moves),
    ``service_ms`` (dispatch -> done, shared by every request in the
    batch), ``e2e_ms`` (arrival -> done, the SLO clock, also per class),
    ``hold_ms`` (batch-aging hold per dispatch).  Occupancy is tracked
    per *dispatch* (requests folded into one engine step, and the
    images-per-grid-step the fused kernel's grouping actually realized).
    SLO attainment is per class.  Padding waste accumulates
    bucket-padded vs real pixels.

    Every histogram mutation happens under the registry lock:
    ``LatencyHistogram.record`` is a non-atomic read-modify-write of
    ``counts/count/sum/max``, so an unlocked record from the dispatch
    thread racing a caller thread silently loses observations (and the
    benchmark's ``count == completed`` ledger drifts).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.queue_wait_ms = LatencyHistogram()
        self.service_ms = LatencyHistogram()
        self.e2e_ms = LatencyHistogram()
        self.hold_ms = LatencyHistogram()
        self._queue_wait_by_class: Dict[str, LatencyHistogram] = {}
        self._e2e_by_class: Dict[str, LatencyHistogram] = {}
        # self-healing counters are pre-seeded so every snapshot carries
        # them (a zero is a measurement — "no sheds under this traffic" —
        # not a missing key the benchmark has to .get() around)
        self.counters: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "rejected": 0, "completed": 0,
            "shed": 0, "quarantined": 0, "dispatch_retries": 0,
            "batch_bisections": 0, "loop_errors": 0, "aged_dispatches": 0}
        self._slo: Dict[str, Dict[str, int]] = {}
        self._occupancy: List[int] = []        # requests per dispatch
        self._imgs_per_step: List[int] = []    # fused-grid images per step
        self._queue_depths: List[int] = []     # sampled at dispatch time
        self._real_px = 0
        self._padded_px = 0

    # ---- recording -----------------------------------------------------
    def inc(self, name: str, by: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + by

    def record_slo(self, slo_name: str, met: bool) -> None:
        with self._lock:
            self._record_slo_locked(slo_name, met)

    def _record_slo_locked(self, slo_name: str, met: bool) -> None:
        d = self._slo.setdefault(slo_name, {"met": 0, "missed": 0})
        d["met" if met else "missed"] += 1

    def record_dispatch(self, *, occupancy: int, imgs_per_step: int,
                        queue_depth: int, service_ms: float) -> None:
        with self._lock:
            self._occupancy.append(int(occupancy))
            self._imgs_per_step.append(int(imgs_per_step))
            self._queue_depths.append(int(queue_depth))
            self.service_ms.record(service_ms)

    def record_hold(self, hold_ms: float) -> None:
        """Batch-aging hold time for one formed batch (0 = dispatched the
        instant it could; recorded per formation, before shed/retry)."""
        with self._lock:
            self.hold_ms.record(hold_ms)
            if hold_ms > 0:
                self.counters["aged_dispatches"] += 1

    def record_request(self, *, queue_wait_ms: float, e2e_ms: float,
                       slo_name: str, met: bool,
                       real_px: int, padded_px: int) -> None:
        with self._lock:
            self.queue_wait_ms.record(queue_wait_ms)
            self.e2e_ms.record(e2e_ms)
            self._queue_wait_by_class.setdefault(
                slo_name, LatencyHistogram()).record(queue_wait_ms)
            self._e2e_by_class.setdefault(
                slo_name, LatencyHistogram()).record(e2e_ms)
            self._record_slo_locked(slo_name, met)
            self.counters["completed"] += 1
            self._real_px += int(real_px)
            self._padded_px += int(padded_px)

    # ---- reading -------------------------------------------------------
    @staticmethod
    def _mean(xs: Sequence[float]) -> float:
        return sum(xs) / len(xs) if xs else 0.0

    def slo_attainment(self, slo_name: Optional[str] = None) -> float:
        """Fraction of finished requests that met their deadline (1.0 when
        nothing finished yet — no misses observed)."""
        with self._lock:
            if slo_name is None:
                met = sum(d["met"] for d in self._slo.values())
                tot = met + sum(d["missed"] for d in self._slo.values())
            else:
                d = self._slo.get(slo_name, {"met": 0, "missed": 0})
                met, tot = d["met"], d["met"] + d["missed"]
        return met / tot if tot else 1.0

    def batch_occupancy(self) -> Dict[str, float]:
        with self._lock:
            occ, imgs = list(self._occupancy), list(self._imgs_per_step)
        return {"dispatches": len(occ), "mean": self._mean(occ),
                "max": max(occ) if occ else 0,
                "imgs_per_step_mean": self._mean(imgs),
                "imgs_per_step_max": max(imgs) if imgs else 0}

    def snapshot(self) -> Dict:
        """Plain-dict view of everything (the benchmark row source)."""
        with self._lock:
            counters = dict(self.counters)
            slo = {k: dict(v) for k, v in self._slo.items()}
            depths = list(self._queue_depths)
            real_px, padded_px = self._real_px, self._padded_px
            queue_wait = self.queue_wait_ms.summary()
            service = self.service_ms.summary()
            e2e = self.e2e_ms.summary()
            hold = self.hold_ms.summary()
            wait_by_class = {k: h.summary()
                             for k, h in self._queue_wait_by_class.items()}
            e2e_by_class = {k: h.summary()
                            for k, h in self._e2e_by_class.items()}
        return {
            "counters": counters,
            "queue_wait_ms": queue_wait,
            "service_ms": service,
            "e2e_ms": e2e,
            "hold_ms": hold,
            "queue_wait_by_class": wait_by_class,
            "e2e_by_class": e2e_by_class,
            "slo": {name: {**d, "attainment": self.slo_attainment(name)}
                    for name, d in slo.items()},
            "slo_attainment": self.slo_attainment(),
            "batch_occupancy": self.batch_occupancy(),
            "queue_depth": {"mean": self._mean(depths),
                            "max": max(depths) if depths else 0},
            "pad_waste_frac": (padded_px - real_px) / padded_px
            if padded_px else 0.0,
        }
