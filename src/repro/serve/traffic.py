"""Synthetic open-loop traffic: arrival processes, shape mixes, prompts.

Open-loop means arrivals are scheduled by the *process*, not by the
server's completions — the generator never slows down because the engine
fell behind, which is the regime where queueing (and therefore p99 and
SLO attainment) actually shows up.  Closed-loop harnesses (submit, wait,
submit) hide exactly the tail this subsystem exists to measure.

Everything here is seedable (``np.random.RandomState``): the same seed
reproduces the same arrival times, shapes, SLO classes, and prompt
streams, so benchmark rows are comparable across PRs and engine tests
are deterministic.

``PromptStream`` is the serving launcher's prompt source —
``launch/serve.py``'s old ``RequestQueue.next_prompt`` (hardcoded
lengths 4..16) folded into the subsystem with a configurable length
distribution.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.types import BATCH, INTERACTIVE, SLOClass


# --------------------------------------------------------------------------
# arrival processes
# --------------------------------------------------------------------------
def poisson_arrivals(rate_hz: float, n: int, *, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    """n absolute arrival times of a homogeneous Poisson process
    (i.i.d. exponential gaps at ``rate_hz``)."""
    if rate_hz <= 0:
        raise ValueError(f"rate_hz must be > 0: {rate_hz}")
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    return start + np.cumsum(gaps)


def bursty_arrivals(rate_hz: float, n: int, *, seed: int = 0,
                    start: float = 0.0, burst_factor: float = 4.0,
                    period_s: float = 1.0,
                    duty: float = 0.25) -> np.ndarray:
    """Markov-modulated Poisson: the rate alternates between
    ``burst_factor * rate_hz`` (a ``duty`` fraction of each ``period_s``
    cycle, the "on" phase) and a compensating low rate, so the *average*
    rate stays ``rate_hz`` (exactly when ``duty * burst_factor <= 1``;
    above that the low phase clamps near-silent and the average rises)
    while arrivals clump — the traffic shape that separates a continuous
    batcher from a fixed-batch loop.

    A gap drawn in one phase must not leak past the phase boundary (a
    near-silent low phase would otherwise draw multi-period gaps and
    collapse the realized rate): on overshoot the clock advances TO the
    boundary and redraws — exact for exponential gaps (memorylessness).
    """
    if not 0.0 < duty < 1.0:
        raise ValueError(f"duty must be in (0, 1): {duty}")
    lo_factor = max(1e-3, (1.0 - duty * burst_factor) / (1.0 - duty))
    rng = np.random.RandomState(seed)
    times, t = [], float(start)
    while len(times) < n:
        phase = (t - start) % period_s
        on = phase < duty * period_s
        lam = rate_hz * (burst_factor if on else lo_factor)
        to_boundary = (duty * period_s if on else period_s) - phase
        gap = rng.exponential(1.0 / lam)
        if gap >= to_boundary:
            t += to_boundary
            continue
        t += gap
        times.append(t)
    return np.asarray(times)


ARRIVAL_PROCESSES = {"poisson": poisson_arrivals, "bursty": bursty_arrivals}


# --------------------------------------------------------------------------
# request shape / SLO mixes
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeMix:
    """Weighted mix of request spatial shapes."""

    shapes: Tuple[Tuple[int, int], ...]
    weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.weights is not None \
                and len(self.weights) != len(self.shapes):
            raise ValueError("weights must match shapes")

    def sample(self, rng: np.random.RandomState) -> Tuple[int, int]:
        p = None
        if self.weights is not None:
            w = np.asarray(self.weights, np.float64)
            p = w / w.sum()
        return self.shapes[int(rng.choice(len(self.shapes), p=p))]


def default_shape_mix(cap: int = 28) -> ShapeMix:
    """Heterogeneous shapes under ``cap`` — ragged on purpose, so the
    bucket table's pad-to-bucket path is exercised, not just exact hits."""
    shapes = [(h, w) for h, w in
              ((7, 9), (10, 10), (12, 8), (14, 14), (20, 17), (28, 28))
              if h <= cap and w <= cap]
    return ShapeMix(shapes=tuple(shapes))


@dataclasses.dataclass(frozen=True)
class TrafficEvent:
    """One scheduled request: when, what shape, which SLO class."""

    t: float
    shape: Tuple[int, int]
    slo: SLOClass


def synthesize(n: int, *, process: str = "poisson", rate_hz: float = 10.0,
               mix: Optional[ShapeMix] = None,
               slo_mix: Sequence[Tuple[SLOClass, float]] = (
                   (INTERACTIVE, 0.5), (BATCH, 0.5)),
               seed: int = 0, **process_kwargs) -> List[TrafficEvent]:
    """Deterministic open-loop schedule of ``n`` requests."""
    arrivals = ARRIVAL_PROCESSES[process](rate_hz, n, seed=seed,
                                          **process_kwargs)
    mix = mix or default_shape_mix()
    rng = np.random.RandomState(seed + 1)     # shapes/SLOs independent of
    slos = [c for c, _ in slo_mix]            # the arrival gaps
    pw = np.asarray([p for _, p in slo_mix], np.float64)
    pw = pw / pw.sum()
    return [TrafficEvent(t=float(t), shape=mix.sample(rng),
                         slo=slos[int(rng.choice(len(slos), p=pw))])
            for t in arrivals]


# --------------------------------------------------------------------------
# prompt stream (the LM serving launcher's request source)
# --------------------------------------------------------------------------
class PromptStream:
    """Seedable synthetic prompt source with a configurable length
    distribution.

    ``lengths=(lo, hi)`` draws uniform ints in [lo, hi); an explicit
    sequence (optionally with ``weights``) draws from those lengths —
    e.g. a bimodal short-chat / long-context mix.  Token ids are uniform
    over the vocabulary.
    """

    def __init__(self, vocab: int, *, lengths=(4, 16),
                 weights: Optional[Sequence[float]] = None, seed: int = 0):
        if vocab < 1:
            raise ValueError(f"vocab must be >= 1: {vocab}")
        self.rng = np.random.RandomState(seed)
        self.vocab = vocab
        if isinstance(lengths, tuple) and len(lengths) == 2 \
                and weights is None:
            lo, hi = int(lengths[0]), int(lengths[1])
            if not 0 < lo < hi:
                raise ValueError(f"need 0 < lo < hi: {lengths}")
            self._draw = lambda: int(self.rng.randint(lo, hi))
        else:
            ls = [int(x) for x in lengths]
            if any(x < 1 for x in ls):
                raise ValueError(f"prompt lengths must be >= 1: {ls}")
            p = None
            if weights is not None:
                wv = np.asarray(weights, np.float64)
                if len(wv) != len(ls):
                    raise ValueError("weights must match lengths")
                p = wv / wv.sum()
            self._draw = lambda: ls[int(self.rng.choice(len(ls), p=p))]

    def next_prompt(self) -> List[int]:
        n = self._draw()
        return self.rng.randint(0, self.vocab, size=n).tolist()
