"""The continuous-batching conv serving engine.

``Engine`` is the subsystem's assembly: bucket table + admission policy +
batch queue + metrics + the ConvSpec-keyed serving cache, around one conv
workload's weights.  The lifecycle:

  * construction *warms* every bucket: each bucket's ``ConvSpec`` is
    planned, its activation scales calibrated, and its weights prepared
    (transformed + int8-quantized) through ``repro.api.serving_cache`` —
    so the request path never plans, never transforms, never quantizes
    (assertable: cache ``prepares`` stays at the bucket count under load);
  * :meth:`submit` stamps arrival (``time.perf_counter``), runs admission
    (bucket fit + queue bound) and returns a ``concurrent.futures.Future``
    immediately — the caller never blocks on the batch;
  * a dispatch thread (:meth:`start`; or deterministic :meth:`step` calls
    in tests) drains the queue one same-bucket batch at a time — *which*
    batch is the engine's ``SchedulerPolicy`` (FCFS head-of-line, or
    earliest-deadline-first with optional batch aging: see
    ``batcher.SchedulerPolicy``) — pads each request to the bucket,
    stacks them, and folds the whole batch into the fused kernel's
    ``rows_per_step`` image-folding grid (``batcher.fold_rows_per_step``)
    — ≥2 concurrent requests ride ONE grid step, which is where
    continuous batching actually meets the MXU;
  * every result is cropped back to the request's own output extent and
    resolved into its future with full timing/SLO accounting.

Self-healing (the resilience tier above ``repro.api.resilience``'s
plan-level degradation chain):

  * **deadline shedding** (``shed_expired=True``): requests whose SLO
    deadline already passed are resolved with ``ShedError`` *before*
    dispatch — goodput over throughput: compute goes to requests that can
    still make their deadlines;
  * **bounded retry** (``max_dispatch_retries``): a failed batch dispatch
    retries with exponential backoff — transient faults (a flaky kernel
    the degradation chain could not absorb, an injected dispatch fault)
    never surface to callers;
  * **quarantine bisection**: a batch that keeps failing is split in
    half and each half served independently, recursively — one poison
    request ends up alone, its future resolved with ``QuarantinedError``,
    and every co-batched peer is served instead of re-killed;
  * the dispatch loop retains (and counts) its own errors instead of
    swallowing them — ``stop(raise_on_error=True)`` re-raises the last
    one, and ``loop_errors`` rides the metrics snapshot.

Every decision is counted in ``MetricsRegistry`` (``shed``,
``dispatch_retries``, ``batch_bisections``, ``quarantined``,
``loop_errors``) and plan-level resilience events from this engine's
dispatches land in the same registry via ``resilience.metrics_sink``.

Bit-identity: folding is the fused kernel's grouping dimension, which is
bit-identical across group sizes (PR 4 invariant), and bucket padding is
output-exact (``bucketing``) — so a batched engine answer equals the
per-request answer bit-for-bit (tests/test_serve_engine.py, and the
bucket specs run under ``repro.testing.assert_conv_conformance``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults
from repro.api import resilience
from repro.api import serving_cache as sc
from repro.serve.batcher import (AdmissionPolicy, Batch, BatchQueue,
                                 SchedulerPolicy, fold_rows_per_step)
from repro.serve.bucketing import Bucket, BucketTable
from repro.serve.metrics import MetricsRegistry
from repro.serve.types import (BATCH, QuarantinedError, Request,
                               RejectedError, Result, ShedError, SLOClass)


class Engine:
    """Continuous-batching serving engine over one conv workload."""

    def __init__(self, w, buckets: BucketTable, *,
                 backend: str = "pallas", algo: str = "auto",
                 interpret: bool = True, max_batch: int = 8,
                 admission: Optional[AdmissionPolicy] = None,
                 cache: Optional[sc.ServingCache] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 calib_seed: int = 0, round_batches: bool = False,
                 warm_compile: bool = False, shed_expired: bool = False,
                 scheduler: Optional[SchedulerPolicy] = None,
                 max_dispatch_retries: int = 2,
                 retry_backoff_s: float = 0.02):
        self.w = w
        self.buckets = buckets
        self.backend = backend
        self.algo = algo
        self.interpret = interpret
        self.max_batch = int(max_batch)
        self.admission = admission or AdmissionPolicy()
        self.cache = cache if cache is not None else sc.ServingCache()
        self.metrics = metrics or MetricsRegistry()
        self.clock = clock
        self.scheduler = scheduler or SchedulerPolicy()
        self.queue = BatchQueue(clock=clock)
        self._act_scales: Dict[str, Optional[jnp.ndarray]] = {}
        self.round_batches = round_batches
        self.shed_expired = shed_expired
        self.max_dispatch_retries = int(max_dispatch_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self._thread: Optional[threading.Thread] = None
        self._running = threading.Event()
        self._inflight = 0
        self._inflight_zero = threading.Condition()
        self._loop_errors = 0
        self._last_loop_error: Optional[BaseException] = None
        # per-bucket provenance of the warm-up kernel config: 'measured'
        # (timing-cache entry), 'model' (cost-model prediction for a cold
        # bucket), or 'default' (kernel resolves its own)
        self.warm_sources: Dict[str, str] = {}
        self._warm(calib_seed)
        if warm_compile:
            self._warm_compile()

    # ------------------------------------------------------------------
    # startup: warm every bucket off the request path
    # ------------------------------------------------------------------
    def _warm(self, calib_seed: int) -> None:
        """Plan + calibrate + prepare each bucket through the serving
        cache.  Activation scales are absmax-calibrated per bucket on a
        synthetic batch (a deployment would substitute PTQ calibration
        data); the scale arrays are pinned here so the cache's identity
        checks hold for the engine's lifetime."""
        from repro.api import costmodel, tuning
        from repro.api.tuning import calibrate_act_scale
        rng = np.random.RandomState(calib_seed)
        for b in self.buckets.buckets:
            p = self._plan(b)
            # warm-config provenance: a timed bucket rides its measured
            # winner; a COLD bucket with a fitted cost model rides the
            # model-predicted config (planner fallback) instead of
            # blocking construction on an exhaustive sweep
            if tuning.lookup(b.spec, self.backend, self.interpret):
                src = "measured"
            elif p.path == "fast" and getattr(p, "config", None) is not None \
                    and costmodel.is_fitted(self.backend, self.interpret):
                src = "model"
            else:
                src = "default"
            self.warm_sources[b.name] = src
            self.metrics.inc(f"warm_config_{src}")
            scale = None
            if p.spec.quant.enabled and p.path == "fast" \
                    and p.algorithm is not None:
                xc = jnp.asarray(
                    rng.randn(1, b.h, b.w, b.spec.in_channels), jnp.float32)
                scale = calibrate_act_scale(xc, p.algorithm, p.spec.quant,
                                            p.spec.padding)
            self._act_scales[b.name] = scale
            self.cache.get(b.spec, self.w, backend=self.backend,
                           algo=self.algo, interpret=self.interpret,
                           act_scale=scale, key=("serve", b.name))

    def _plan(self, bucket: Bucket):
        from repro.api import planner
        return planner.plan(bucket.spec, backend=self.backend,
                            algo=self.algo, interpret=self.interpret)

    # ---- batch-shape bounding ----------------------------------------
    def _batch_sizes(self) -> List[int]:
        """The dispatch batch shapes this engine can emit (with
        ``round_batches``: powers of two up to ``max_batch``, plus
        ``max_batch`` itself) — the set ``_warm_compile`` pre-traces."""
        if not self.round_batches:
            return list(range(1, self.max_batch + 1))
        sizes, s = [], 1
        while s < self.max_batch:
            sizes.append(s)
            s *= 2
        sizes.append(self.max_batch)
        return sizes

    def _round_batch(self, n: int) -> int:
        if not self.round_batches:
            return n
        return next(s for s in self._batch_sizes() if s >= n)

    def _warm_compile(self) -> None:
        """Trace/compile every (bucket, batch shape) dispatch off the
        request path: one zero-input dispatch per combination, routed
        through the exact request-path code (fold config included), so
        live traffic never pays a first-shape compile."""
        for b in self.buckets.buckets:
            for s in self._batch_sizes():
                reqs = [Request(x=jnp.zeros((b.h, b.w, b.spec.in_channels),
                                            jnp.float32),
                                slo=BATCH, arrival_t=self.clock())
                        for _ in range(s)]
                self._dispatch(Batch(bucket=b, requests=reqs), record=False)

    # ------------------------------------------------------------------
    # request path
    # ------------------------------------------------------------------
    def submit(self, x, slo: SLOClass = BATCH) -> Future:
        """Admit one (h, w, C_in) image; returns a Future of ``Result``.

        Rejections resolve the future immediately with
        :class:`RejectedError` — an open-loop client observes back
        pressure as failed futures, not blocked submits.
        """
        req = Request(x=x, slo=slo, arrival_t=self.clock())
        self.metrics.inc("submitted")
        h, w = req.shape
        bucket = self.buckets.bucket_for(h, w)
        ok, reason = self.admission.admit_shape(req, bucket)
        if not ok:
            self.metrics.inc("rejected")
            req.future.set_exception(RejectedError(reason))
            return req.future
        req.bucket_name = bucket.name
        with self._inflight_zero:
            self._inflight += 1
        # the depth bound is enforced atomically INSIDE the queue lock —
        # a sampled depth() followed by put() lets concurrent submitters
        # overshoot the admission bound (TOCTOU)
        if not self.queue.put_if_below(req, bucket,
                                       self.admission.max_queue_depth):
            with self._inflight_zero:
                self._inflight -= 1
                if self._inflight == 0:
                    self._inflight_zero.notify_all()
            self.metrics.inc("rejected")
            req.future.set_exception(RejectedError(
                self.admission.depth_reason(self.admission.max_queue_depth)))
            return req.future
        self.metrics.inc("admitted")
        return req.future

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------
    def step(self, timeout: Optional[float] = 0) -> int:
        """Drain ONE batch synchronously; returns requests resolved
        (served, shed, or quarantined — 0 when the queue stayed empty
        *or* batch aging is holding an underfull batch whose window is
        still open: with ``timeout=0`` the hold never blocks, so
        deterministic tests advance the injected clock instead).
        The deterministic entry point tests and the dispatch thread
        share.  Dispatch failures are absorbed by retry, bisection, and
        quarantine — ``step`` itself only raises on failures *outside*
        the serve path (e.g. batch formation), and even then every taken
        request's future is resolved first."""
        batch = self.queue.take_batch(self.max_batch, timeout=timeout,
                                      policy=self.scheduler)
        if batch is None:
            return 0
        self.metrics.record_hold(batch.hold_ms)
        n = len(batch)
        try:
            batch = self._shed_past_deadline(batch)
            if batch.requests:
                self._serve_batch(batch)
        except Exception as e:             # resolve, don't wedge callers
            for r in batch.requests:
                if not r.future.done():
                    r.future.set_exception(e)
            raise
        finally:
            with self._inflight_zero:
                self._inflight -= n
                if self._inflight == 0:
                    self._inflight_zero.notify_all()
        return n

    # ---- self-healing serve path -------------------------------------
    def _shed_past_deadline(self, batch: Batch) -> Batch:
        """Resolve already-expired requests with ``ShedError`` (counted,
        SLO-missed) and return the still-viable remainder."""
        if not self.shed_expired:
            return batch
        now = self.clock()
        kept = []
        for r in batch.requests:
            if (now - r.arrival_t) * 1e3 > r.slo.deadline_ms:
                self.metrics.inc("shed")
                self.metrics.record_slo(r.slo.name, met=False)
                r.future.set_exception(ShedError(
                    f"deadline {r.slo.deadline_ms:.0f}ms passed before "
                    f"dispatch (queued {(now - r.arrival_t) * 1e3:.0f}ms)"))
            else:
                kept.append(r)
        return Batch(bucket=batch.bucket, requests=kept)

    def _serve_batch(self, batch: Batch) -> None:
        """Dispatch with bounded retry; on persistent failure, bisect the
        batch so one poison request cannot re-kill its co-batched peers.
        Never raises: a single request that still fails alone is resolved
        with ``QuarantinedError`` carrying the underlying failure."""
        err: Optional[BaseException] = None
        for attempt in range(self.max_dispatch_retries + 1):
            # a partial failure may have resolved some futures already
            pending = [r for r in batch.requests if not r.future.done()]
            if not pending:
                return
            batch = Batch(bucket=batch.bucket, requests=pending)
            if attempt and self.retry_backoff_s > 0:
                time.sleep(self.retry_backoff_s * (2 ** (attempt - 1)))
            try:
                self._dispatch(batch)
                return
            except Exception as e:
                err = e
                if attempt < self.max_dispatch_retries:
                    self.metrics.inc("dispatch_retries")
        pending = [r for r in batch.requests if not r.future.done()]
        if len(pending) <= 1:
            for r in pending:
                self.metrics.inc("quarantined")
                q = QuarantinedError(
                    f"request {r.id} failed "
                    f"{self.max_dispatch_retries + 1} dispatch attempts")
                q.__cause__ = err
                r.future.set_exception(q)
            return
        self.metrics.inc("batch_bisections")
        mid = len(pending) // 2
        self._serve_batch(Batch(bucket=batch.bucket,
                                requests=pending[:mid]))
        self._serve_batch(Batch(bucket=batch.bucket,
                                requests=pending[mid:]))

    def _dispatch(self, batch: Batch, record: bool = True) -> None:
        if record:
            # warm-compile dispatches (record=False) are construction-time
            # plumbing, not traffic: an armed fault burst (times=...) must
            # fire under load, not be consumed warming the engine
            faults.maybe_fault(faults.DISPATCH, detail=batch)
        bucket = batch.bucket
        t_dispatch = self.clock()
        depth_after = self.queue.depth()
        B_real = len(batch)
        B = self._round_batch(B_real)
        imgs_list = [BucketTable.pad_to(r.x, bucket)
                     for r in batch.requests]
        if B > B_real:
            # round the batch shape up with zero images (outputs dropped):
            # the compile-shape set stays bounded, per-image independence
            # keeps every real output bit-identical
            zero = jnp.zeros_like(imgs_list[0])
            imgs_list += [zero] * (B - B_real)
        xb = jnp.stack(imgs_list)
        plan, prep = self.cache.get(
            bucket.spec, self.w, backend=self.backend, algo=self.algo,
            interpret=self.interpret,
            act_scale=self._act_scales[bucket.name],
            key=("serve", bucket.name))
        fold = fold_rows_per_step(plan, B)
        if fold is not None:
            rows_per_step, imgs, _ = fold
            run = plan.with_config(dataclasses.replace(
                plan.config or _default_fused(),
                rows_per_step=rows_per_step))
        else:
            imgs = 1
            run = plan
        # plan-level resilience events (fallbacks, breaker trips) raised
        # by THIS dispatch land in THIS engine's registry
        with resilience.metrics_sink(self.metrics.inc):
            y = jax.block_until_ready(run.apply(xb, prep))
        t_done = self.clock()
        if not record:
            return
        service_ms = (t_done - t_dispatch) * 1e3
        self.metrics.record_dispatch(
            occupancy=B_real, imgs_per_step=imgs,
            queue_depth=depth_after, service_ms=service_ms)
        if B > B_real:
            self.metrics.inc("batch_pad_imgs", B - B_real)
        for i, r in enumerate(batch.requests):
            if r.future.done():            # resolved on an earlier attempt
                continue
            r.t_dispatch, r.t_done = t_dispatch, t_done
            h, w = r.shape
            yi = BucketTable.crop_output(y[i], h, w, bucket)
            queue_wait_ms = (t_dispatch - r.arrival_t) * 1e3
            e2e_ms = (t_done - r.arrival_t) * 1e3
            met = r.slo.met(e2e_ms)
            self.metrics.record_request(
                queue_wait_ms=queue_wait_ms, e2e_ms=e2e_ms,
                slo_name=r.slo.name, met=met,
                real_px=h * w, padded_px=bucket.h * bucket.w)
            r.future.set_result(Result(
                y=yi, request_id=r.id, bucket_name=bucket.name,
                batch_size=len(batch), imgs_per_step=imgs,
                queue_wait_ms=queue_wait_ms, service_ms=service_ms,
                e2e_ms=e2e_ms, deadline_met=met,
                pad_waste_frac=bucket.waste(h, w)))

    # ------------------------------------------------------------------
    # async dispatch thread
    # ------------------------------------------------------------------
    def start(self) -> "Engine":
        if self._thread is not None:
            return self
        # a retained error belongs to the PREVIOUS run: stop(raise_on_
        # error=True) after a clean second run must not re-raise it
        self._last_loop_error = None
        self._running.set()

        def loop():
            while self._running.is_set():
                try:
                    self.step(timeout=0.02)
                except Exception as e:
                    # the futures of the failed batch already carry the
                    # error (``step`` resolves before re-raising); the
                    # loop keeps serving — but the failure is COUNTED and
                    # RETAINED, never silently dropped: ``loop_errors``
                    # rides every snapshot and ``stop(raise_on_error=
                    # True)`` re-raises the last one
                    self._loop_errors += 1
                    self._last_loop_error = e
                    self.metrics.inc("loop_errors")

        self._thread = threading.Thread(target=loop, name="serve-dispatch",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, raise_on_error: bool = False) -> None:
        """Stop the dispatch thread.  ``raise_on_error=True`` re-raises
        the last error the loop absorbed (if any) once the thread has
        joined — the shutdown-time check that the loop's error counter is
        not hiding a persistent failure."""
        if self._thread is not None:
            self._running.clear()
            self._thread.join()
            self._thread = None
        if raise_on_error and self._last_loop_error is not None:
            raise self._last_loop_error

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every admitted request resolved (True) or timeout."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._inflight_zero:
            while self._inflight > 0:
                rem = None if deadline is None \
                    else deadline - time.perf_counter()
                if rem is not None and rem <= 0:
                    return False
                self._inflight_zero.wait(rem if rem is not None else 0.5)
        return True

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    def snapshot(self) -> Dict:
        """Metrics + serving-cache stats (with derived hit rate) in one
        dict — the benchmark row source."""
        snap = self.metrics.snapshot()
        cstats = self.cache.stats()
        lookups = cstats["hits"] + cstats["misses"]
        snap["serving_cache"] = {
            **cstats,
            "hit_rate": cstats["hits"] / lookups if lookups else 0.0,
        }
        snap["buckets"] = [b.name for b in self.buckets.buckets]
        snap["warm_config_sources"] = dict(self.warm_sources)
        snap["scheduler"] = {"kind": self.scheduler.kind,
                             "max_hold_ms": self.scheduler.max_hold_ms}
        snap["loop_errors"] = self._loop_errors
        snap["last_loop_error"] = (repr(self._last_loop_error)
                                   if self._last_loop_error else None)
        snap["breakers"] = resilience.board_snapshot()
        return snap

    @property
    def last_loop_error(self) -> Optional[BaseException]:
        return self._last_loop_error

    def __enter__(self) -> "Engine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def _default_fused():
    from repro.api import tuning
    return tuning.DEFAULT_FUSED


def results(futures: List[Future], timeout: Optional[float] = None
            ) -> List[Result]:
    """Gather resolved results (rejected futures raise RejectedError)."""
    return [f.result(timeout=timeout) for f in futures]
