"""Request model for the serving subsystem.

A :class:`Request` is one conv workload instance in flight: the input
image, the SLO class it was admitted under, and the monotonic timestamps
the engine stamps as it moves through the pipeline
(arrival -> dispatch -> done).  All serving-path timing uses
``time.perf_counter`` — a monotonic clock — never ``time.time``: latency
is a *difference* of stamps, and the wall clock can step backwards under
NTP adjustment, which would report negative (or wildly wrong) latencies
exactly when a fleet-wide time sync happens under load.

SLO classes are deadline buckets that double as the scheduling signal:
attainment is *accounted* per class (``metrics.MetricsRegistry``), and a
deadline-aware engine (``batcher.SchedulerPolicy(kind="edf")``) *forms*
batches by earliest absolute deadline (:attr:`Request.deadline_t`), so
an urgent request is dispatched ahead of slack-rich peers instead of
merely being recorded as late afterwards.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from concurrent.futures import Future
from typing import Any, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service-level objective: a name and an end-to-end deadline."""

    name: str
    deadline_ms: float

    def met(self, e2e_ms: float) -> bool:
        return e2e_ms <= self.deadline_ms


# Default classes.  Deadlines are calibrated for the interpret-mode CPU
# container (EXPERIMENTS.md §Serving) — a real TPU deployment would tighten
# them by the interpret/compiled ratio; they are engine *defaults*, every
# entry point takes explicit SLOClass objects.
INTERACTIVE = SLOClass("interactive", deadline_ms=2_000.0)
BATCH = SLOClass("batch", deadline_ms=20_000.0)

SLO_CLASSES: Dict[str, SLOClass] = {c.name: c for c in (INTERACTIVE, BATCH)}

_IDS = itertools.count()
_IDS_LOCK = threading.Lock()


def _next_id() -> int:
    with _IDS_LOCK:
        return next(_IDS)


@dataclasses.dataclass
class Request:
    """One in-flight conv request.

    ``x`` is a single unbatched image ``(h, w, C_in)``; the engine owns
    batching (pad-to-bucket, stack, fold into the fused grid).  The
    ``future`` resolves to a :class:`Result` — or to
    :class:`RejectedError` when admission control turns the request away.
    """

    x: Any                                   # (h, w, C_in)
    slo: SLOClass
    arrival_t: float                         # perf_counter stamp at submit
    id: int = dataclasses.field(default_factory=_next_id)
    future: Future = dataclasses.field(default_factory=Future)
    # engine-stamped:
    bucket_name: Optional[str] = None
    t_dispatch: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def shape(self) -> Tuple[int, int]:
        return int(self.x.shape[0]), int(self.x.shape[1])

    @property
    def deadline_t(self) -> float:
        """Absolute clock stamp (same clock as ``arrival_t``) at which
        this request's SLO deadline expires — the EDF scheduling key."""
        return self.arrival_t + self.slo.deadline_ms * 1e-3

    def slack_ms(self, now: float) -> float:
        """Milliseconds of headroom left before the deadline (negative:
        already expired).  Bounds how long the batch former may hold this
        request waiting for co-batchable arrivals."""
        return (self.deadline_t - now) * 1e3


@dataclasses.dataclass(frozen=True)
class Result:
    """What a request's future resolves to."""

    y: Any                                   # (h', w', C_out), bucket-cropped
    request_id: int
    bucket_name: str
    batch_size: int                          # requests folded in the dispatch
    imgs_per_step: int                       # images per fused grid step
    queue_wait_ms: float
    service_ms: float
    e2e_ms: float
    deadline_met: bool
    pad_waste_frac: float                    # padded-to-bucket pixel waste


class RejectedError(RuntimeError):
    """Admission control declined the request (reason in ``args[0]``)."""


class ShedError(RuntimeError):
    """The engine shed the request: its SLO deadline had already passed
    before dispatch (``shed_expired`` engines prefer goodput over
    throughput — serving a guaranteed-late request only delays the ones
    that can still make their deadlines)."""


class QuarantinedError(RuntimeError):
    """The request was quarantined: its batch failed dispatch repeatedly,
    bisection isolated this request as the poison, and retries were
    exhausted.  The underlying failure rides ``__cause__``."""
