"""Admission control, batch scheduling, and continuous batch formation.

The batcher owns the request queue between ``Engine.submit`` and the
dispatch loop.  *How* batches are formed is a :class:`SchedulerPolicy`:

  * ``fcfs`` — head-of-line: the oldest request's bucket, joined by
    every queued same-bucket request in arrival order.  Simple, fair by
    arrival, but blind to deadlines: one slack-rich batch request at the
    head delays an urgent interactive request queued behind it in a
    different bucket.
  * ``edf`` — earliest-deadline-first: the batch is the bucket of the
    most urgent request (smallest ``Request.deadline_t``), filled with
    same-bucket peers in deadline order.  An already-expired request has
    the earliest deadline of all, so it is dispatched (and shed) first
    rather than starving unresolved behind still-viable work.  This is
    what turns the SLO classes from accounting labels into scheduling:
    ``shed_expired`` becomes the backstop EDF makes rare, not the
    mechanism.

Either policy composes with **batch aging** (``max_hold_ms > 0``): an
underfull batch is *held* — ``take_batch`` reports nothing ready — while
the head request is younger than the hold window, so co-batchable
arrivals fold into one fused grid step instead of dispatching 1-image
slivers.  The hold is bounded by the head request's own slack (a hold
must never turn a viable request into a shed), and ends the instant the
batch reaches ``max_batch``.  Hold decisions are pure functions of the
injected clock, so tests drive them deterministically.

In either mode, heterogeneous shapes never mix inside one dispatch, so
each dispatch is one warm ``ConvSpec`` and one fused-kernel launch.
Same-bucket matching is by *equality* (``Bucket`` is a frozen
dataclass), never identity: equal buckets reached via distinct objects
(two tables over one workload) must co-batch.

:func:`fold_rows_per_step` is the serving-side view of the fused kernel's
image-folding grid: given the batch the batcher formed, pick the
``rows_per_step`` that folds *whole images* — ideally the entire batch —
into one grid step.  The VMEM fit decision goes through the static
resource checker (``repro.analysis.kernel_checks.fold_fits``), which
resolves the exact launch geometry the kernel's own auto-grouping uses,
so the batcher never requests a grid step the kernel would spill on and
never imports kernel internals (the ARCH001 lint invariant).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

from repro.serve.bucketing import Bucket
from repro.serve.types import Request


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    """How the batch former picks and fills the next dispatch.

    ``kind``        ``"fcfs"`` (head-of-line arrival order) or ``"edf"``
                    (earliest-deadline-first: most urgent viable request
                    picks the bucket, peers fill in deadline order);
    ``max_hold_ms`` batch-aging window: an underfull batch is held up to
                    this long past its head request's arrival — bounded
                    by the head's SLO slack — waiting for co-batchable
                    arrivals.  0 disables aging (dispatch the instant
                    the queue is non-empty, the pre-scheduler behavior).
    """

    kind: str = "fcfs"
    max_hold_ms: float = 0.0

    def __post_init__(self):
        if self.kind not in ("fcfs", "edf"):
            raise ValueError(f"kind must be 'fcfs' or 'edf': {self.kind!r}")
        if self.max_hold_ms < 0:
            raise ValueError(f"max_hold_ms must be >= 0: {self.max_hold_ms}")


FCFS = SchedulerPolicy(kind="fcfs")
EDF = SchedulerPolicy(kind="edf")


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Bounded-queue admission: reject rather than queue unboundedly.

    ``max_queue_depth`` is the back-pressure bound (an open-loop arrival
    process does not slow down when the engine falls behind — without a
    bound the queue, and every latency behind it, grows without limit).
    Requests whose shape fits no bucket are rejected outright: padding
    down (truncation) would silently corrupt outputs.  So are requests
    whose output would be *empty* under the workload (a VALID conv on an
    image smaller than the kernel): serving a 0-row tensor is a silent
    data-loss bug, not an answer.

    The depth bound itself is enforced atomically by
    :meth:`BatchQueue.put_if_below` — checking ``queue.depth()`` first
    and putting after is a TOCTOU race under concurrent submitters.
    :meth:`admit` keeps the combined (shape + sampled-depth) check for
    single-threaded callers; the engine uses :meth:`admit_shape` plus
    the atomic put.
    """

    max_queue_depth: int = 256

    def admit_shape(self, request: Request,
                    bucket: Optional[Bucket]) -> Tuple[bool, Optional[str]]:
        """Depth-independent checks: bucket fit and output viability."""
        h, w = request.shape
        if bucket is None:
            return False, f"no bucket fits shape ({h}, {w})"
        r = bucket.spec.kernel_size
        if bucket.spec.padding == "VALID" and (h < r or w < r):
            return False, (
                f"shape ({h}, {w}) is smaller than the {r}x{r} kernel: a "
                f"VALID conv output would be empty")
        return True, None

    def depth_reason(self, queue_depth: int) -> str:
        return f"queue depth {queue_depth} at limit {self.max_queue_depth}"

    def admit(self, request: Request, bucket: Optional[Bucket],
              queue_depth: int) -> Tuple[bool, Optional[str]]:
        ok, reason = self.admit_shape(request, bucket)
        if not ok:
            return ok, reason
        if queue_depth >= self.max_queue_depth:
            return False, self.depth_reason(queue_depth)
        return True, None


@dataclasses.dataclass
class Batch:
    """One dispatch unit: same-bucket requests, in formation order.

    ``hold_ms`` is how long the batch former aged this batch (time the
    oldest member spent waiting in the hold window before formation,
    clamped to the policy's ``max_hold_ms``; 0 when aging is off).
    """

    bucket: Bucket
    requests: List[Request]
    hold_ms: float = 0.0

    def __len__(self) -> int:
        return len(self.requests)


class BatchQueue:
    """Thread-safe request queue with policy-driven batch formation.

    The queue stores arrival order; :meth:`take_batch` *forms* a batch
    according to a :class:`SchedulerPolicy` (FCFS head-of-line or EDF)
    without disturbing the positions of requests it leaves behind.  The
    clock is injected so hold-window (aging) decisions are deterministic
    under test clocks.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._q: Deque[Tuple[Request, Bucket]] = deque()
        self._clock = clock

    def depth(self) -> int:
        with self._lock:
            return len(self._q)

    def put(self, request: Request, bucket: Bucket) -> None:
        with self._nonempty:
            self._q.append((request, bucket))
            self._nonempty.notify()

    def put_if_below(self, request: Request, bucket: Bucket,
                     bound: int) -> bool:
        """Atomically enqueue iff the depth is below ``bound``.

        The admission depth check and the enqueue happen under ONE lock
        acquisition — the only way a concurrent-submitter fleet cannot
        overshoot the bound (read-depth-then-put is a TOCTOU race).
        """
        with self._nonempty:
            if len(self._q) >= bound:
                return False
            self._q.append((request, bucket))
            self._nonempty.notify()
            return True

    # ---- formation ----------------------------------------------------
    @staticmethod
    def _edf_key(req: Request) -> Tuple[float, float, int]:
        # deterministic total order: deadline, then arrival, then id
        return (req.deadline_t, req.arrival_t, req.id)

    def _candidate(self, max_batch: int, policy: SchedulerPolicy
                   ) -> List[Tuple[Request, Bucket]]:
        """The (request, bucket) pairs the policy would dispatch next.
        Caller holds the lock.  Never returns empty for a non-empty
        queue."""
        if policy.kind == "edf":
            # an expired request has the earliest deadline of all, so it
            # sorts maximally urgent and is dispatched (-> shed backstop)
            # immediately instead of starving behind still-viable work
            _, head_bucket = min(self._q,
                                 key=lambda rb: self._edf_key(rb[0]))
            peers = sorted((rb for rb in self._q if rb[1] == head_bucket),
                           key=lambda rb: self._edf_key(rb[0]))
            return peers[:max_batch]
        head_bucket = self._q[0][1]
        return [rb for rb in self._q if rb[1] == head_bucket][:max_batch]

    def _hold_until(self, taken: List[Tuple[Request, Bucket]],
                    policy: SchedulerPolicy) -> float:
        """Absolute clock stamp the aging window for this candidate
        closes at: head arrival + ``max_hold_ms``, bounded by the
        earliest member deadline (holding must never expire a request)."""
        head_arrival = min(r.arrival_t for r, _ in taken)
        earliest_deadline = min(r.deadline_t for r, _ in taken)
        return min(head_arrival + policy.max_hold_ms * 1e-3,
                   earliest_deadline)

    def take_batch(self, max_batch: int, timeout: Optional[float] = None,
                   policy: Optional[SchedulerPolicy] = None
                   ) -> Optional[Batch]:
        """Form one batch under ``policy`` (default FCFS, no aging).

        Blocks up to ``timeout`` for a first request; ``timeout=0``
        polls.  Returns None when nothing arrived — or when aging is
        holding an underfull batch whose window is still open (in poll
        mode the caller re-polls; in blocking mode the wait happens
        here, waking early if an arrival completes the batch).
        Requests left behind keep their queue positions.
        """
        policy = policy or FCFS
        with self._nonempty:
            if not self._q and timeout != 0:
                self._nonempty.wait(timeout)
            while True:
                if not self._q:
                    return None
                now = self._clock()
                taken = self._candidate(max_batch, policy)
                hold_until = (self._hold_until(taken, policy)
                              if policy.max_hold_ms > 0 else now)
                if len(taken) >= max_batch or now >= hold_until:
                    break
                # aging: the window is open and the batch is underfull
                if timeout == 0:
                    return None            # poll mode never blocks
                self._nonempty.wait(hold_until - now)
            taken_ids = {r.id for r, _ in taken}
            self._q = deque(rb for rb in self._q
                            if rb[0].id not in taken_ids)
            head_arrival = min(r.arrival_t for r, _ in taken)
            hold_ms = (min((now - head_arrival) * 1e3, policy.max_hold_ms)
                       if policy.max_hold_ms > 0 else 0.0)
            return Batch(bucket=taken[0][1],
                         requests=[r for r, _ in taken],
                         hold_ms=max(0.0, hold_ms))


def _divisors_desc(n: int) -> List[int]:
    return [d for d in range(n, 0, -1) if n % d == 0]


def fold_rows_per_step(plan, batch_size: int) -> Optional[Tuple[int, int, int]]:
    """(rows_per_step, imgs, rows) folding the batch into the fused grid.

    Prefers folding the whole batch's images into one grid step
    (``rows_per_step = imgs * nH``), walking down the divisors of the
    batch size while the per-step footprint exceeds the kernel's VMEM
    budget, then falling back to partial-image row groups.  Returns None
    for plans the folding does not apply to (direct/lowered paths,
    unquantized, or a measured config that picked the staged datapath) —
    the dispatch then runs the plan as-is and batching still amortizes
    launch overhead, just not grid-step occupancy.

    The VMEM fit decision delegates to the static resource checker
    (``repro.analysis.kernel_checks.fold_fits``), which resolves the
    exact launch geometry the kernel itself would use — the serving
    layer never re-derives (and cannot diverge from) kernel blocking
    arithmetic.
    """
    from repro.analysis import kernel_checks
    from repro.api import tuning
    from repro.core import conv2d as c2d
    spec = plan.spec
    if plan.path != "fast" or plan.algorithm is None \
            or not spec.quant.enabled or spec.depthwise \
            or spec.spatial is None:
        return None
    cfg = plan.config or tuning.DEFAULT_FUSED
    if cfg.datapath != "fused":
        return None
    algo = plan.algorithm
    H, W = spec.spatial
    lo_h, hi_h, _ = c2d.pad_amounts(H, algo.M, algo.R, spec.padding)
    nH = (H + lo_h + hi_h - (algo.R - 1)) // algo.M
    C, Cout = spec.in_channels, spec.out_channels
    b = max(1, batch_size)

    def fits(rows_per_step: int) -> bool:
        return kernel_checks.fold_fits(
            algo, cfg, b, H, W, C, Cout, padding=spec.padding,
            rows_per_step=rows_per_step)

    for imgs in _divisors_desc(b):
        if fits(imgs * nH):
            return imgs * nH, imgs, nH
    for rows in (r for r in (8, 4, 2, 1) if r < nH):
        if fits(rows):
            return rows, 1, rows
    return 1, 1, 1
