"""Admission control and continuous batch formation.

The batcher owns the request queue between ``Engine.submit`` and the
dispatch loop.  Formation is per-bucket FCFS: a batch is the head
request's bucket plus every queued request of the same bucket (up to
``max_batch``), preserving arrival order for the rest — heterogeneous
shapes never mix inside one dispatch, so each dispatch is one warm
``ConvSpec`` and one fused-kernel launch.

:func:`fold_rows_per_step` is the serving-side view of the fused kernel's
image-folding grid: given the batch the batcher formed, pick the
``rows_per_step`` that folds *whole images* — ideally the entire batch —
into one grid step.  The VMEM fit decision goes through the static
resource checker (``repro.analysis.kernel_checks.fold_fits``), which
resolves the exact launch geometry the kernel's own auto-grouping uses,
so the batcher never requests a grid step the kernel would spill on and
never imports kernel internals (the ARCH001 lint invariant).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.serve.bucketing import Bucket
from repro.serve.types import Request


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Bounded-queue admission: reject rather than queue unboundedly.

    ``max_queue_depth`` is the back-pressure bound (an open-loop arrival
    process does not slow down when the engine falls behind — without a
    bound the queue, and every latency behind it, grows without limit).
    Requests whose shape fits no bucket are rejected outright: padding
    down (truncation) would silently corrupt outputs.
    """

    max_queue_depth: int = 256

    def admit(self, request: Request, bucket: Optional[Bucket],
              queue_depth: int) -> Tuple[bool, Optional[str]]:
        if bucket is None:
            h, w = request.shape
            return False, f"no bucket fits shape ({h}, {w})"
        if queue_depth >= self.max_queue_depth:
            return False, f"queue depth {queue_depth} at limit " \
                          f"{self.max_queue_depth}"
        return True, None


@dataclasses.dataclass
class Batch:
    """One dispatch unit: same-bucket requests in arrival order."""

    bucket: Bucket
    requests: List[Request]

    def __len__(self) -> int:
        return len(self.requests)


class BatchQueue:
    """Thread-safe FCFS queue with per-bucket batch formation."""

    def __init__(self):
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._q: Deque[Tuple[Request, Bucket]] = deque()

    def depth(self) -> int:
        with self._lock:
            return len(self._q)

    def put(self, request: Request, bucket: Bucket) -> None:
        with self._nonempty:
            self._q.append((request, bucket))
            self._nonempty.notify()

    def take_batch(self, max_batch: int,
                   timeout: Optional[float] = None) -> Optional[Batch]:
        """Form one batch: the oldest request's bucket, joined by every
        queued same-bucket request up to ``max_batch`` (others keep their
        positions).  Blocks up to ``timeout`` for a first request;
        ``timeout=0`` polls.  Returns None when nothing arrived."""
        with self._nonempty:
            if not self._q and timeout != 0:
                self._nonempty.wait(timeout)
            if not self._q:
                return None
            head_bucket = self._q[0][1]
            taken, rest = [], deque()
            for req, bucket in self._q:
                if bucket is head_bucket and len(taken) < max_batch:
                    taken.append(req)
                else:
                    rest.append((req, bucket))
            self._q = rest
            return Batch(bucket=head_bucket, requests=taken)


def _divisors_desc(n: int) -> List[int]:
    return [d for d in range(n, 0, -1) if n % d == 0]


def fold_rows_per_step(plan, batch_size: int) -> Optional[Tuple[int, int, int]]:
    """(rows_per_step, imgs, rows) folding the batch into the fused grid.

    Prefers folding the whole batch's images into one grid step
    (``rows_per_step = imgs * nH``), walking down the divisors of the
    batch size while the per-step footprint exceeds the kernel's VMEM
    budget, then falling back to partial-image row groups.  Returns None
    for plans the folding does not apply to (direct/lowered paths,
    unquantized, or a measured config that picked the staged datapath) —
    the dispatch then runs the plan as-is and batching still amortizes
    launch overhead, just not grid-step occupancy.

    The VMEM fit decision delegates to the static resource checker
    (``repro.analysis.kernel_checks.fold_fits``), which resolves the
    exact launch geometry the kernel itself would use — the serving
    layer never re-derives (and cannot diverge from) kernel blocking
    arithmetic.
    """
    from repro.analysis import kernel_checks
    from repro.api import tuning
    from repro.core import conv2d as c2d
    spec = plan.spec
    if plan.path != "fast" or plan.algorithm is None \
            or not spec.quant.enabled or spec.depthwise \
            or spec.spatial is None:
        return None
    cfg = plan.config or tuning.DEFAULT_FUSED
    if cfg.datapath != "fused":
        return None
    algo = plan.algorithm
    H, W = spec.spatial
    lo_h, hi_h, _ = c2d.pad_amounts(H, algo.M, algo.R, spec.padding)
    nH = (H + lo_h + hi_h - (algo.R - 1)) // algo.M
    C, Cout = spec.in_channels, spec.out_channels
    b = max(1, batch_size)

    def fits(rows_per_step: int) -> bool:
        return kernel_checks.fold_fits(
            algo, cfg, b, H, W, C, Cout, padding=spec.padding,
            rows_per_step=rows_per_step)

    for imgs in _divisors_desc(b):
        if fits(imgs * nH):
            return imgs * nH, imgs, nH
    for rows in (r for r in (8, 4, 2, 1) if r < nH):
        if fits(rows):
            return rows, 1, rows
    return 1, 1, 1
