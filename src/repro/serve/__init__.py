"""``repro.serve`` — the continuous-batching serving subsystem.

The first real subsystem above the planner (ROADMAP item: serving tier
with SLO accounting): heterogeneous request shapes map onto a small set
of warm ``ConvSpec`` buckets (``bucketing``), admission + continuous
batching fold concurrent requests into the fused kernel's
``rows_per_step`` image-folding grid (``batcher``, ``engine``), every
latency lands in streaming histograms with per-class SLO attainment
(``metrics``), and an open-loop synthetic traffic generator drives it
(``traffic``).  The LM decode launcher's slot loop lives here too
(``slots``) so ``repro.launch.serve`` stays a thin CLI.
"""
from repro.serve.batcher import (EDF, FCFS, AdmissionPolicy, Batch,
                                 BatchQueue, SchedulerPolicy,
                                 fold_rows_per_step)
from repro.serve.bucketing import Bucket, BucketTable
from repro.serve.engine import Engine, results
from repro.serve.metrics import LatencyHistogram, MetricsRegistry
from repro.serve.slots import SlotLoop, SlotLoopStats
from repro.serve.traffic import (PromptStream, ShapeMix, TrafficEvent,
                                 bursty_arrivals, default_shape_mix,
                                 poisson_arrivals, synthesize)
from repro.serve.types import (BATCH, INTERACTIVE, SLO_CLASSES,
                               QuarantinedError, Request, RejectedError,
                               Result, ShedError, SLOClass)

__all__ = [
    "Engine", "results",
    "AdmissionPolicy", "Batch", "BatchQueue", "SchedulerPolicy",
    "FCFS", "EDF", "fold_rows_per_step",
    "Bucket", "BucketTable",
    "LatencyHistogram", "MetricsRegistry",
    "SlotLoop", "SlotLoopStats",
    "PromptStream", "ShapeMix", "TrafficEvent", "poisson_arrivals",
    "bursty_arrivals", "default_shape_mix", "synthesize",
    "Request", "Result", "RejectedError", "ShedError", "QuarantinedError",
    "SLOClass", "SLO_CLASSES", "INTERACTIVE", "BATCH",
]
