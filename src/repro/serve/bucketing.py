"""Shape bucketing: heterogeneous request shapes onto warm ``ConvSpec`` s.

A serving deployment cannot afford a cold ``plan()`` (lowering pass +
algorithm selection + weight transform + int8 quantization) on the request
path — and it cannot hold a warm plan per distinct ``(h, w)`` either,
because open traffic has unbounded shape diversity.  The bucket table is
the standard resolution: a small fixed set of spatial buckets, each with
one pre-planned ``ConvSpec`` and pre-prepared weights, and every request
padded up to the smallest bucket that contains it.

Zero-padding to a bucket is *output-exact* for the stride-1 SAME/VALID
convs served here: the conv itself zero-pads its borders, so the extra
rows/columns a smaller image borrows from the bucket are the same zeros
the unpadded conv would have synthesized — cropping the output back to
the request's own output extent recovers the unbucketed answer exactly
(asserted in tests/test_serve_bucketing.py, bit-wise on the int8 path).
The cost is *waste*: padded pixels are computed and thrown away, so the
table accounts ``waste(h, w)`` per request and the benchmark reports the
aggregate fraction — the knob that trades bucket-count (warm memory,
compile count) against wasted FLOPs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.api.spec import ConvSpec
from repro.quant.fake_quant import FP32, QuantConfig


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One warm serving shape: a name, the padded extent, and its spec."""

    name: str
    h: int
    w: int
    spec: ConvSpec

    def fits(self, h: int, w: int) -> bool:
        return h <= self.h and w <= self.w

    def waste(self, h: int, w: int) -> float:
        """Fraction of the bucket's pixels a (h, w) request pads away."""
        return 1.0 - (h * w) / float(self.h * self.w)


class BucketTable:
    """Ordered (smallest-area-first) buckets over one conv workload.

    All buckets share kernel/channels/quant — they are spatial variants of
    ONE layer workload, so one weight tensor (and per-bucket activation
    scales) serves the whole table.
    """

    def __init__(self, buckets: Sequence[Bucket]):
        if not buckets:
            raise ValueError("bucket table needs at least one bucket")
        self.buckets: List[Bucket] = sorted(
            buckets, key=lambda b: (b.h * b.w, b.h))
        names = [b.name for b in self.buckets]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate bucket names: {names}")

    @classmethod
    def for_workload(cls, shapes: Sequence[Tuple[int, int]], *,
                     kernel_size: int, in_channels: int, out_channels: int,
                     stride: int = 1, padding: str = "SAME",
                     quant: QuantConfig = FP32) -> "BucketTable":
        """Table of spatial buckets over one (R, C_in, C_out) workload."""
        return cls([
            Bucket(name=f"b{h}x{w}", h=h, w=w,
                   spec=ConvSpec(rank=2, kernel_size=kernel_size,
                                 stride=stride, padding=padding,
                                 in_channels=in_channels,
                                 out_channels=out_channels,
                                 spatial=(h, w), quant=quant))
            for h, w in dict.fromkeys((int(h), int(w)) for h, w in shapes)])

    def bucket_for(self, h: int, w: int) -> Optional[Bucket]:
        """Smallest bucket containing (h, w); None = no bucket fits
        (admission control rejects rather than silently truncating)."""
        for b in self.buckets:               # sorted by area: first fit wins
            if b.fits(h, w):
                return b
        return None

    def by_name(self, name: str) -> Bucket:
        for b in self.buckets:
            if b.name == name:
                return b
        raise KeyError(name)

    @staticmethod
    def pad_to(x, bucket: Bucket):
        """Zero-pad one (h, w, C) image to the bucket extent (bottom/right,
        matching the conv's own zero border)."""
        h, w = int(x.shape[0]), int(x.shape[1])
        if not bucket.fits(h, w):
            raise ValueError(
                f"image ({h}, {w}) exceeds bucket {bucket.name}")
        if (h, w) == (bucket.h, bucket.w):
            return x
        return jnp.pad(x, ((0, bucket.h - h), (0, bucket.w - w), (0, 0)))

    @staticmethod
    def crop_output(y, h: int, w: int, bucket: Bucket):
        """Crop one bucket-shaped output back to the request's own output
        extent (stride-aware: the bucketed grid is a superset).

        Raises instead of returning an empty tensor: a sub-kernel VALID
        request has *no* output rows (``(h - r)//s + 1 <= 0``), and
        silently serving a 0-row crop is data loss the caller cannot
        distinguish from success — admission (``AdmissionPolicy``)
        rejects such shapes up front, so reaching this is a bug.
        """
        s = bucket.spec.stride
        if bucket.spec.padding == "SAME":
            oh, ow = -(-h // s), -(-w // s)
        else:                                 # VALID
            r = bucket.spec.kernel_size
            oh, ow = (h - r) // s + 1, (w - r) // s + 1
        if oh <= 0 or ow <= 0:
            raise ValueError(
                f"empty output crop for request ({h}, {w}) under bucket "
                f"{bucket.name}: {bucket.spec.padding} {bucket.spec.kernel_size}"
                f"x{bucket.spec.kernel_size} stride {s} yields ({oh}, {ow}) "
                f"— admission should have rejected this shape")
        return y[:oh, :ow, :]
