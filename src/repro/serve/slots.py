"""Fixed-batch decode slot loop (continuous batching for the LM path).

The host-side bookkeeping behind ``repro.launch.serve``: a fixed decode
batch of ``batch`` slots, each slot consuming its prompt then generating
``gen`` tokens; finished sequences are swapped for queued requests
*without recompiling* (static shapes), subject to an admission budget
(``requests`` total — surplus slots idle/drain), with a KV safety wrap
when a sequence hits the cache length (``max_len``).

Extracted from the launcher so the admission/drain/wrap state machine is
deterministic and testable without a model: ``run`` takes any
``step_fn(tok, pos) -> next_tokens`` (the launcher passes the jitted
``decode_step`` argmax; tests pass a pure-numpy stub).  All timing uses
``time.perf_counter`` and per-request completion latency lands in a
streaming histogram.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.serve.metrics import LatencyHistogram
from repro.serve.traffic import PromptStream


@dataclasses.dataclass
class SlotLoopStats:
    """What one slot-loop run produced and how fast."""

    served: int = 0                 # completed requests (incl. truncated)
    wrapped: int = 0                # requests truncated by the KV wrap
    steps: int = 0                  # decode_step invocations
    tokens: int = 0                 # tokens pushed through active slots
    elapsed_s: float = 0.0
    latency_ms: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)

    @property
    def tok_per_s(self) -> float:
        return self.tokens / self.elapsed_s if self.elapsed_s > 0 else 0.0

    def summary(self) -> Dict:
        return {"served": self.served, "wrapped": self.wrapped,
                "steps": self.steps, "tokens": self.tokens,
                "elapsed_s": self.elapsed_s, "tok_per_s": self.tok_per_s,
                "request_latency_ms": self.latency_ms.summary()}


class SlotLoop:
    """The serve launcher's continuous-batching state machine.

    Semantics (locked by tests/test_serve_slots.py):

      * the initial fill admits ``min(batch, requests)`` prompts — the
        admission budget bounds total work, surplus slots idle from the
        start;
      * a slot first consumes its prompt token-by-token, then generates
        from ``step_fn``'s predictions until its ``gen`` budget is spent;
      * on completion the slot swaps in a new prompt only while the
        budget allows, otherwise the slot *drains* (goes inactive);
      * a slot whose position reaches ``max_len - 1`` hits the KV-cache
        safety wrap: the truncated request still counts as served, and a
        replacement is admitted under the same budget as the normal
        completion path.
    """

    def __init__(self, *, batch: int, gen: int, max_len: int,
                 requests: int, prompts: PromptStream,
                 clock: Callable[[], float] = time.perf_counter):
        if batch < 1 or gen < 1 or max_len < 2 or requests < 1:
            raise ValueError(
                f"need batch/gen/requests >= 1 and max_len >= 2: "
                f"batch={batch} gen={gen} max_len={max_len} "
                f"requests={requests}")
        self.batch, self.gen = batch, gen
        self.max_len, self.requests = max_len, requests
        self.prompts, self.clock = prompts, clock

    def run(self, step_fn: Callable[[np.ndarray, np.ndarray], np.ndarray],
            max_steps: Optional[int] = None) -> SlotLoopStats:
        """Serve ``requests`` prompts through ``step_fn``; returns stats.

        ``step_fn(tok (B, 1) int32, pos (B,) int32) -> (B,) int32`` is
        one decode step over ALL slots (inactive slots included — the
        batch shape is static); the loop ignores predictions for slots
        still consuming their prompt.  ``max_steps`` is a safety bound
        for tests (None = run to completion).
        """
        B = self.batch
        stats = SlotLoopStats()
        prompts: List[List[int]] = [self.prompts.next_prompt()
                                    for _ in range(B)]
        pos = np.zeros(B, np.int32)
        remaining = np.full(B, self.gen, np.int32)
        tok = np.array([[p[0]] for p in prompts], np.int32)
        started = min(B, self.requests)
        active = np.arange(B) < started
        admit_t = np.full(B, self.clock(), np.float64)
        done = 0
        t0 = self.clock()

        def finish(i: int) -> None:
            nonlocal done
            done += 1
            stats.latency_ms.record((self.clock() - admit_t[i]) * 1e3)

        def admit(i: int) -> bool:
            nonlocal started
            if started >= self.requests:
                return False
            prompts[i] = self.prompts.next_prompt()
            pos[i] = 0
            remaining[i] = self.gen
            tok[i, 0] = prompts[i][0]
            admit_t[i] = self.clock()
            started += 1
            return True

        while done < self.requests:
            if max_steps is not None and stats.steps >= max_steps:
                break
            nxt = np.asarray(step_fn(tok, pos), np.int32)
            stats.steps += 1
            for i in range(B):
                if not active[i]:              # drained slot: budget hit
                    continue
                stats.tokens += 1
                pos[i] += 1
                if pos[i] < len(prompts[i]):   # still consuming prompt
                    tok[i, 0] = prompts[i][pos[i]]
                elif remaining[i] > 0:         # generating
                    tok[i, 0] = nxt[i]
                    remaining[i] -= 1
                else:                          # finished -> swap or drain
                    finish(i)
                    if not admit(i):
                        active[i] = False
                if active[i] and pos[i] >= self.max_len - 1:
                    # safety wrap: the sequence hit the KV budget — the
                    # truncated request counts, and a replacement is
                    # admitted only within the same budget as the normal
                    # completion path above
                    stats.wrapped += 1
                    finish(i)
                    if not admit(i):
                        active[i] = False
        stats.served = done
        stats.elapsed_s = self.clock() - t0
        return stats
