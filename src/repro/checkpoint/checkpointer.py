"""Fault-tolerant checkpointing (no orbax offline — built on npz + JSON).

Design points for 1000+-node operation:
  * **atomic**: write to a temp dir, fsync, rename — a crash mid-save never
    corrupts the latest checkpoint;
  * **async**: device->host transfer happens on the caller thread, file IO
    on a worker thread so the train loop is not blocked;
  * **elastic restore**: arrays are stored unsharded (gathered); restore
    re-shards onto whatever mesh/device-count the new job has — tested by
    round-tripping across different mesh shapes;
  * **self-describing**: the pytree structure and dtypes are stored in a
    JSON manifest next to the arrays, with a step counter and content
    digest for integrity checks;
  * retention: keep the last N checkpoints, delete older ones only after a
    newer one is fully committed.
"""
from __future__ import annotations

import concurrent.futures
import hashlib
import json
import os
import pathlib
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_part(p) for p in path)
        flat[key] = leaf
    return flat


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[concurrent.futures.Future] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        """Snapshot ``tree`` at ``step``. Non-blocking by default."""
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        self.wait()
        self._pending = self._pool.submit(self._write, step, host)
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host: Dict[str, np.ndarray]) -> None:
        tmp = self.dir / f".tmp-{step}-{os.getpid()}"
        final = self.dir / f"step_{step:012d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        digest = hashlib.sha256()
        arrays_path = tmp / "arrays.npz"
        # npz has no bfloat16: store raw uint16 bits, dtype in the manifest
        storable = {k: (v.view(np.uint16) if v.dtype.name == "bfloat16"
                        else v) for k, v in host.items()}
        np.savez(arrays_path, **{k.replace("/", "|"): v
                                 for k, v in storable.items()})
        digest.update(arrays_path.read_bytes())
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(host.keys()),
            "dtypes": {k: str(v.dtype) for k, v in host.items()},
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "sha256": digest.hexdigest(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        with open(tmp / "manifest.json") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic commit
        self._gc()

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("step_*"))
        for old in ckpts[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("step_*"))
        if not ckpts:
            return None
        return int(ckpts[-1].name.split("_")[1])

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None, verify: bool = True):
        """Restore into the structure of ``tree_like``.

        ``shardings`` (optional pytree of NamedSharding) re-shards each
        array onto the *current* mesh — this is the elastic-restore path:
        a checkpoint written on 256 devices restores onto 8 (or 512).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:012d}"
        manifest = json.loads((d / "manifest.json").read_text())
        if verify:
            got = hashlib.sha256((d / "arrays.npz").read_bytes()).hexdigest()
            if got != manifest["sha256"]:
                raise IOError(f"checkpoint {d} digest mismatch")
        data = np.load(d / "arrays.npz")
        flat_like = _flatten(tree_like)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, like in flat_like.items():
            arr = data[key.replace("/", "|")]
            if manifest["dtypes"].get(key) == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if shardings is not None and key in flat_shard:
                out[key] = jax.device_put(arr, flat_shard[key])
            else:
                out[key] = jnp.asarray(arr)
        leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
        keys_in_order = list(_flatten(tree_like).keys())
        return jax.tree_util.tree_unflatten(
            treedef, [out[k] for k in keys_in_order]), step
