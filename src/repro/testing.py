"""Differential conformance oracle for the conv kernel zoo.

The kernel zoo now has many executable configurations of one mathematical
convolution — the reference jnp simulation, the staged three-kernel Pallas
pipeline, and the fused single-pass kernel at every (k_block, cout_block,
rows_per_step, double_buffer) grouping — plus the SPMD backend wrapping
any of them.  Each new variant used to bring its own ad-hoc parity test;
this module is the ONE oracle they all share (and the hypothesis fuzz
suite in ``tests/test_conformance.py`` drives):

  * int8 paths share a single integer grid and static scales, so every
    Pallas configuration must agree with the staged pipeline
    **bit-for-bit** (``==``, not allclose) — any reordering of the
    integer accumulation or a quantization-grid drift is a hard failure;
  * the reference backend's int8 *simulation* runs the same grid in
    fp32 jnp, so Pallas vs reference is held to the API's fp epsilon;
  * fp (unquantized) paths have no shared grid and are held to the fp
    epsilon against the reference backend.

Import from tests as ``from repro.testing import assert_conv_conformance``.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

DEFAULT_TOL = 1e-4

# the fused-kernel configurations every int8 case is checked at when the
# caller does not narrow them: the default grid, a ragged k-block, full-K,
# the batched multi-tile-row grids (incl. auto), and DMA double-buffering
DEFAULT_FUSED_VARIANTS = (
    dict(k_block=128, cout_block=128, rows_per_step=1),
    dict(k_block=64, cout_block=128, rows_per_step=2),
    dict(k_block=None, cout_block=128, rows_per_step=4),
    dict(k_block=128, cout_block=128, rows_per_step=None),
    dict(k_block=128, cout_block=128, rows_per_step=2, double_buffer=True),
)


def fused_variant_configs(variants: Sequence[dict] = DEFAULT_FUSED_VARIANTS):
    """``KernelConfig`` objects for a sequence of fused-kernel kwarg dicts."""
    from repro.api.tuning import KernelConfig
    return tuple(KernelConfig(datapath="fused", **v) for v in variants)


def calibrated_prep(x, w, spec, algo_name: str):
    """(reference plan, pallas plan, prepared weights) with absmax
    activation scales calibrated on ``x`` — the shared setup of every
    differential int8 case.  Degraded (direct) and fp plans skip
    calibration and return ``prep=None``.  Lowered (composite) plans
    calibrate per sub-problem via ``CompositePlan.calibrate``."""
    from repro.api import plan, tuning
    p_ref = plan(spec, backend="reference", algo=algo_name)
    p_pal = plan(spec, backend="pallas", algo=algo_name)
    if p_pal.path == "direct" or not spec.quant.enabled:
        return p_ref, p_pal, None
    if p_pal.path == "lowered":
        return p_ref, p_pal, p_pal.prepare_weights(
            w, act_scale=p_pal.calibrate(x))
    act = tuning.calibrate_act_scale(x, p_pal.algorithm, spec.quant,
                                     spec.padding)
    return p_ref, p_pal, p_pal.prepare_weights(w, act_scale=act)


def assert_conv_conformance(x, w, spec, algo_name: str = "auto", *,
                            variants: Sequence[dict] = DEFAULT_FUSED_VARIANTS,
                            allow_degraded: bool = False,
                            rtol: float = DEFAULT_TOL,
                            atol: float = DEFAULT_TOL) -> jnp.ndarray:
    """Assert every executable configuration of (x, w, spec) agrees.

    int8 specs: the staged pipeline and every fused variant must be
    bit-identical to each other, and fp-close to the reference int8
    simulation.  fp specs: the pallas path must be fp-close to the
    reference backend.  A spec that degrades to the direct path is an
    ERROR unless ``allow_degraded`` — a planner regression silently
    degrading fast-eligible OR lowerable specs must fail the suite
    loudly, not turn it into a vacuous direct-vs-direct comparison (only
    the deliberately-degrading cases, e.g. a lowering that the cost
    model rightly rejects, opt in).  Lowered (composite) plans sweep the
    same staged/fused variants — ``with_config`` propagates each config
    to every sub-plan, and the bit-identity contract holds because a sum
    (or concat) of bit-identical sub-outputs in a fixed order is
    bit-identical.  Raises ``AssertionError`` naming the variant that
    diverged; returns the reference output for callers that want extra
    checks.
    """
    from repro.api import tuning
    p_ref, p_pal, prep = calibrated_prep(x, w, spec, algo_name)
    assert allow_degraded or p_pal.path != "direct", \
        f"spec unexpectedly degraded to the direct path: {spec}"
    if p_pal.path == "direct" or not spec.quant.enabled:
        prep = p_pal.prepare_weights(w)
        y_ref = p_ref.apply(x, prep)
        y_pal = p_pal.apply(x, prep)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=rtol, atol=atol)
        return y_ref
    y_ref = p_ref.apply(x, prep)
    y_staged = p_pal.with_config(tuning.DEFAULT_STAGED).apply(x, prep)
    assert y_staged.shape == y_ref.shape, \
        f"staged shape {y_staged.shape} != reference {y_ref.shape}"
    np.testing.assert_allclose(np.asarray(y_staged), np.asarray(y_ref),
                               rtol=rtol, atol=atol,
                               err_msg="staged vs reference int8 simulation")
    want = np.asarray(y_staged)
    for cfg in fused_variant_configs(variants):
        y = p_pal.with_config(cfg).apply(x, prep)
        assert np.array_equal(np.asarray(y), want), (
            f"fused(k={cfg.k_block},co={cfg.cout_block},"
            f"r={cfg.rows_per_step},db={int(cfg.double_buffer)}) "
            f"is not bit-identical to staged for {spec}")
    return y_ref
