"""``repro.api`` — the one way to run a convolution.

    spec = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)
    p = plan(spec, backend="pallas", algo="auto")
    prepared = p.prepare_weights(w, act_scale=calibrated_scale)  # offline
    y = p.apply(x, prepared)                                     # online

The planner resolves the algorithm (registry name or BOPs-cost-model
auto-selection), degrades to direct convolution where fast algorithms do
not apply, and dispatches execution to the ``reference`` (pure jnp) or
``pallas`` (TPU kernels) backend behind one signature.  This module is the
extension seam for future backends — register new ones with
``register_backend`` and new algorithms with ``register_algorithm``.
"""
from repro.api import costmodel, lowering, serving_cache, tuning
from repro.api.backends import (get_backend, list_backends,
                                register_backend)
from repro.api.lowering import CompositePlan, CompositePrepared
from repro.api.plan import ConvPlan, PreparedWeights
from repro.api.planner import estimate_cost, plan, select_algorithm
from repro.api.registry import (get_algorithm, list_algorithms,
                                register_algorithm)
from repro.api.serving_cache import ServingCache, get_serving_cache
from repro.api.spec import ConvSpec
from repro.api.tuning import KernelConfig, autotune

__all__ = [
    "ConvSpec", "ConvPlan", "PreparedWeights", "plan",
    "lowering", "CompositePlan", "CompositePrepared",
    "select_algorithm", "estimate_cost",
    "register_algorithm", "get_algorithm", "list_algorithms",
    "register_backend", "get_backend", "list_backends",
    "tuning", "KernelConfig", "autotune", "costmodel",
    "serving_cache", "ServingCache", "get_serving_cache",
]
