"""ConvSpec-keyed serving cache: plan + prepared weights per workload.

The ROADMAP's batched-serving item for the LM path (``launch/serve.py``):
a serving process resolves each conv workload to one :class:`ConvPlan`
and one :class:`PreparedWeights` *once*, ahead of (or on first) traffic,
and every later hit on the same :class:`ConvSpec` re-uses both — no
re-planning, no re-transform, no re-quantization, no re-placement on the
SPMD mesh.

``plan()`` already memoizes planning and each plan FIFO-bounds a prepared
-weights cache, but the serving loop needs more than those internals give
it:

  * one *keyed, accounted* entry point — ``get(spec, w) -> (plan, prep)``
    with hit/miss/prepare counters, so over-serving regressions
    ("re-prepared weights per request") are assertable;
  * stable identity for weights that are re-sliced out of a parameter
    pytree every call (stacked layer params under ``lax.scan``): pass
    ``key=`` and the entry survives the slice objects changing;
  * LRU eviction sized for a serving deployment rather than the
    per-plan FIFO;
  * tracer transparency: under ``jit`` tracing there is nothing to cache
    — the call degrades to ``plan.prepare_weights`` (which equally skips
    tracers) so the cache can sit on a path that is sometimes compiled.

The module-level :func:`get` / :func:`stats` / :func:`clear` operate on
one process-wide default cache — the serving launcher's view.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

import jax

from repro.api.plan import ConvPlan, PreparedWeights
from repro.api.spec import ConvSpec

_ENV_MAXSIZE = "REPRO_SERVING_CACHE_SIZE"
_DEFAULT_MAXSIZE = 256


def default_maxsize() -> int:
    """Deployment-configurable bound for the default cache
    (``REPRO_SERVING_CACHE_SIZE``); invalid values fall back loudly-ish
    to the built-in default rather than crashing a serving process at
    import time."""
    raw = os.environ.get(_ENV_MAXSIZE)
    if raw is None:
        return _DEFAULT_MAXSIZE
    try:
        n = int(raw)
    except ValueError:
        return _DEFAULT_MAXSIZE
    return n if n >= 1 else _DEFAULT_MAXSIZE


class ServingCache:
    """Thread-safe LRU of (ConvSpec, backend, algo, weights) -> prepared
    execution state.  Entries pin their operands, so id-based identity
    stays valid for the entry's lifetime.  ``maxsize=None`` resolves from
    ``REPRO_SERVING_CACHE_SIZE`` (default 256)."""

    def __init__(self, maxsize: Optional[int] = None):
        if maxsize is None:
            maxsize = default_maxsize()
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1: {maxsize}")
        self._maxsize = maxsize
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, Tuple[tuple, PreparedWeights]]" \
            = OrderedDict()
        self._hits = self._misses = self._prepares = self._evictions = 0

    def get(self, spec: ConvSpec, w, *, backend: str = "reference",
            algo: str = "auto", interpret: bool = True,
            act_scale=None, w_scale=None,
            key: Optional[Any] = None) -> Tuple[ConvPlan, PreparedWeights]:
        """Resolve ``spec`` and return its cached (plan, prepared weights).

        ``key`` is an optional stable identity for the weight operands
        (e.g. a param-tree path + layer index).  The default identity is
        the operand object ids — right for long-lived weight arrays;
        pass ``key`` when the caller re-slices weights out of a larger
        pytree per call, where ids are not stable.  Keyed entries are
        trusted until :meth:`clear` — serving weights are frozen for a
        deployment, so a weight swap must clear the cache.
        """
        from repro import faults
        from repro.api import planner
        faults.maybe_fault(faults.CACHE, detail=spec)
        p = planner.plan(spec, backend=backend, algo=algo,
                         interpret=interpret)
        operands = (w, act_scale, w_scale)
        # tree_leaves: lowered (composite) plans take per-sub-plan scale
        # *sequences* — tracers hide inside them under jit
        if any(isinstance(o, jax.core.Tracer)
               for o in jax.tree_util.tree_leaves(operands)):
            # compiled path: nothing concrete to hold on to
            return p, p.prepare_weights(w, act_scale=act_scale,
                                        w_scale=w_scale)
        ck = (spec, backend, algo, interpret,
              key if key is not None else tuple(id(o) for o in operands))
        with self._lock:
            entry = self._entries.get(ck)
            # entries are only valid for the exact plan they were prepared
            # under (identity, not equality): every plan-cache
            # invalidation — a tuning record, a registered
            # algorithm/backend overwrite, an SPMD mesh swap — mints new
            # plan objects, and a prep whose algorithm selection or
            # device placement predates the invalidation must be redone,
            # never paired with the fresh plan
            if entry is not None and entry[2] is p and (
                    key is not None
                    or all(a is b for a, b in zip(entry[0], operands))):
                self._entries.move_to_end(ck)
                self._hits += 1
                return p, entry[1]
            self._misses += 1
        prep = p.prepare_weights(w, act_scale=act_scale, w_scale=w_scale)
        with self._lock:
            self._prepares += 1
            # replacing an invalidated same-key entry is not an eviction:
            # only capacity-driven LRU pops count, so a nonzero
            # ``evictions`` under steady traffic means the cache is sized
            # below the live working set (re-prepare churn on hot specs)
            while len(self._entries) >= self._maxsize \
                    and ck not in self._entries:
                self._entries.popitem(last=False)
                self._evictions += 1
            self._entries[ck] = (operands, prep, p)
            self._entries.move_to_end(ck)     # replaced entries become MRU
        return p, prep

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self._hits, "misses": self._misses,
                    "prepares": self._prepares,
                    "evictions": self._evictions,
                    "size": len(self._entries)}

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._hits = self._misses = self._prepares = self._evictions = 0


_DEFAULT = ServingCache()


def get_serving_cache() -> ServingCache:
    return _DEFAULT


def get(spec: ConvSpec, w, **kwargs) -> Tuple[ConvPlan, PreparedWeights]:
    """Process-wide default-cache :meth:`ServingCache.get`."""
    return _DEFAULT.get(spec, w, **kwargs)


def stats() -> Dict[str, int]:
    return _DEFAULT.stats()


def clear() -> None:
    _DEFAULT.clear()
