"""``ConvPlan`` — a resolved (spec, algorithm, backend) ready to execute.

A plan is produced by ``repro.api.plan()`` and owns the two halves of the
deployment story:

  * :meth:`ConvPlan.prepare_weights` — the offline half: transform weights
    into the algorithm's domain once, optionally quantizing them to int8
    with PTQ-calibrated static scales (paper §5-6: weights are stored in
    the transform domain, avoiding double quantization).  Prepared weights
    are memoized per plan, keyed on the concrete weight array.
  * :meth:`ConvPlan.apply` — the online half: one signature for every
    backend and precision.  ``apply(x, w)`` accepts either raw weights
    (prepared on the fly) or a :class:`PreparedWeights`.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.api.spec import ConvSpec
from repro.core.conv2d import transform_weights_2d
from repro.core.generator import BilinearAlgorithm
import repro.quant.fake_quant as fq

# FIFO bound on prepared weights retained per plan.  Entries pin the raw
# weights plus their ~(t/R)^2-times-larger transform-domain copies, so this
# trades memory for re-prepare cost; 16 covers every same-spec layer of the
# paper's evaluation CNNs.
_PREP_CACHE_MAX = 16


class PrepCache:
    """Identity-keyed FIFO of prepared weights, shared by :class:`ConvPlan`
    and the lowering layer's ``CompositePlan``.

    Keys are operand object ids; entries pin the operands so ids stay
    valid for the entry's lifetime.  Tracers (and pytrees containing
    tracers — composite plans pass per-sub-plan scale *sequences*) are
    never cached: under tracing there is nothing concrete to hold on to.
    """

    def __init__(self, maxsize: int = _PREP_CACHE_MAX):
        self._maxsize = maxsize
        self._lock = threading.Lock()
        self._entries: Dict[tuple, tuple] = {}

    @staticmethod
    def key_for(operands) -> Optional[tuple]:
        leaves = jax.tree_util.tree_leaves(operands)
        if any(isinstance(o, jax.core.Tracer) for o in leaves):
            return None
        return tuple(id(o) for o in operands)

    def get(self, key, operands):
        with self._lock:
            entry = self._entries.get(key)
        if entry is not None and \
                all(a is b for a, b in zip(entry[0], operands)):
            return entry[1]
        return None

    def put(self, key, operands, value) -> None:
        with self._lock:
            while len(self._entries) >= self._maxsize:
                self._entries.pop(next(iter(self._entries)))
            # the cache entry keeps the operands alive: ids stay valid
            self._entries[key] = (operands, value)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


def _normalize_w_scale(w_scale: jnp.ndarray, t: int, cout: int
                       ) -> jnp.ndarray:
    """Accept any weight-granularity scale shape; return (t, t, Cout)."""
    s = jnp.asarray(w_scale, jnp.float32)
    if s.ndim == 4:                       # keepdims (t|1, t|1, 1, Cout|1)
        return jnp.broadcast_to(s, (t, t, 1, cout))[:, :, 0, :]
    if s.shape == (t, t, cout):
        return s
    if s.shape == (t, t):                 # frequency-wise
        return jnp.broadcast_to(s[:, :, None], (t, t, cout))
    if s.ndim <= 1:                       # scalar or per-channel
        return jnp.broadcast_to(s, (t, t, cout))
    raise ValueError(f"cannot interpret w_scale shape {s.shape} "
                     f"for t={t}, Cout={cout}")


@dataclasses.dataclass(frozen=True)
class PreparedWeights:
    """Offline-processed weights for one plan.

    ``tw`` is the transform-domain fp tensor ((t, t, Cin, Cout) for rank 2,
    (t, C) for rank 1 depthwise); for int8 plans ``wq``/``w_scale``/
    ``act_scale`` additionally hold the offline-quantized weights and the
    static scales both backends consume.
    """

    w: Any                                   # raw weights as passed in
    tw: Optional[jnp.ndarray] = None
    wq: Optional[jnp.ndarray] = None         # (t^2, Cin, Cout) int8
    w_scale: Optional[jnp.ndarray] = None    # (t, t, Cout)
    act_scale: Optional[jnp.ndarray] = None  # (t, t)

    @property
    def quantized(self) -> bool:
        return self.wq is not None


@dataclasses.dataclass(eq=False)
class ConvPlan:
    """Executable plan: call :meth:`apply`; inspect ``algorithm``/``cost``."""

    spec: ConvSpec
    backend: str
    algo_name: str                            # registry name or 'direct'
    algorithm: Optional[BilinearAlgorithm]    # None = direct path
    interpret: bool = True                    # Pallas interpret mode (CPU)
    cost: Optional[float] = None              # planner's BOPs estimate
    config: Optional[Any] = None              # tuning.KernelConfig (measured)
    _prep: PrepCache = dataclasses.field(
        default_factory=PrepCache, repr=False)

    @property
    def path(self) -> str:
        return "direct" if self.algorithm is None else "fast"

    def with_config(self, config) -> "ConvPlan":
        """This plan with a different kernel config (shared prep cache)."""
        return dataclasses.replace(self, config=config)

    # ------------------------------------------------------------------
    # offline: weight preparation
    # ------------------------------------------------------------------
    def prepare_weights(self, w: jnp.ndarray, *,
                        act_scale: Optional[jnp.ndarray] = None,
                        w_scale: Optional[jnp.ndarray] = None
                        ) -> PreparedWeights:
        """Pre-transform (and for int8 plans, pre-quantize) weights.

        ``act_scale`` (t, t) comes from PTQ calibration
        (``PTQLayer.static_scales``); it is required for the static-int8
        execution path.  ``w_scale`` defaults to absmax scales at the
        spec's weight granularity, broadcast to (t, t, Cout).
        Results are cached per concrete weight array.

        Backends that define ``place_prepared(plan, prep)`` (the sharded
        SPMD backend: C_out-sharded ``wq``/``w_scale`` placement) get the
        prepared tensors routed through it before caching, so the offline
        half also covers device layout — skipped under tracing, where
        there are no concrete buffers to place.
        """
        from repro import faults
        faults.maybe_fault(faults.PREPARE, detail=self)
        operands = (w, act_scale, w_scale)
        key = PrepCache.key_for(operands)
        if key is not None:
            cached = self._prep.get(key, operands)
            if cached is not None:
                return cached
        prep = self._prepare_uncached(w, act_scale, w_scale)
        if key is not None:
            from repro.api import backends    # late: avoids import cycle
            place = getattr(backends.get_backend(self.backend),
                            "place_prepared", None)
            if place is not None:
                prep = place(self, prep)
            self._prep.put(key, operands, prep)
        return prep

    def _prepare_uncached(self, w, act_scale, w_scale) -> PreparedWeights:
        if self.algorithm is None:
            return PreparedWeights(w=w)
        algo = self.algorithm
        if self.spec.rank == 1:
            if self.spec.quant.enabled:
                raise NotImplementedError(
                    "quantized rank-1 depthwise convolution is not "
                    "implemented; use quant=FP32")
            g = jnp.asarray(algo.g(), dtype=w.dtype)
            return PreparedWeights(w=w, tw=jnp.einsum("tr,rc->tc", g, w))
        tw = transform_weights_2d(w, algo)
        if not self.spec.quant.enabled or act_scale is None:
            return PreparedWeights(w=w, tw=tw)
        t = algo.t
        cout = tw.shape[-1]
        if w_scale is None:
            axes = fq.weight_reduce_axes(
                tw.ndim, self.spec.quant.weight_granularity)
            amax = jnp.max(jnp.abs(tw), axis=tuple(axes), keepdims=True)
            w_scale = amax / fq.qmax_for_bits(self.spec.quant.bits_weight) \
                + 1e-12
        w_scale = _normalize_w_scale(w_scale, t, cout)
        wq = fq.quantize_transformed_weights(
            tw, w_scale, self.spec.quant.bits_weight)
        act_scale = jnp.asarray(act_scale, jnp.float32).reshape(t, t)
        return PreparedWeights(w=w, tw=tw, wq=wq, w_scale=w_scale,
                               act_scale=act_scale)

    # ------------------------------------------------------------------
    # online: execution
    # ------------------------------------------------------------------
    def apply(self, x: jnp.ndarray, w, *,
              bias: Optional[jnp.ndarray] = None,
              elementwise_hook: Optional[Callable] = None) -> jnp.ndarray:
        """Run the convolution.  ``w`` is raw weights or PreparedWeights.

        ``elementwise_hook(tx, tw) -> (tx, tw)`` injects transform-domain
        processing (fake quantization, calibration observers) on the
        reference backend's fast path; static-int8 plans and the Pallas
        backend do not take hooks — quantization is baked into the plan.

        Pallas-backend applies run through the resilience layer
        (``repro.api.resilience``): on kernel failure the datapath
        degrades fused -> staged (bit-identical) -> reference (fp-close),
        guarded by per-level circuit breakers so a persistently broken
        config stops being retried.  The chain disengages under tracing
        (exceptions at trace time are the caller's compile errors, and
        the guardrail cannot inspect tracer values) and when an
        elementwise hook is passed (the hook's backend errors are
        contract errors, not kernel faults).
        """
        from repro.api import backends, resilience  # late: avoids cycle
        prep = w if isinstance(w, PreparedWeights) else \
            self.prepare_weights(w)
        if elementwise_hook is None and resilience.engaged(self) \
                and not isinstance(x, jax.core.Tracer):
            return resilience.apply_resilient(self, x, prep, bias=bias)
        return backends.get_backend(self.backend).apply(
            self, x, prep, bias=bias, elementwise_hook=elementwise_hook)

    def __call__(self, x, w, **kwargs):
        return self.apply(x, w, **kwargs)
