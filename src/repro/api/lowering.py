"""Spec lowering: rewrite non-native ConvSpecs onto the SFC fast path.

The SFC transform algebra is stride-1 by construction, so the planner used
to degrade every stride-2 / grouped workload to the direct path with a
single hard branch (``ConvSpec.fast_eligible``).  This module replaces
that branch with a *lowering pass*: before algorithm selection, ``plan()``
asks :func:`maybe_lower` to rewrite the spec into a composite of native
SFC sub-problems, and only specs that neither run natively nor lower
profitably fall back to direct.

Two lowerings compose (and recurse through ``plan()`` itself):

  * **polyphase** — a stride-s RxR convolution splits into s^2 even/odd
    phases: decimating the (explicitly padded) input ``xp[a::s, b::s]``
    and the kernel ``w[a::s, b::s]`` turns each phase into a *stride-1*
    VALID convolution with ceil((R-a)/s) taps, and the strided output is
    the elementwise sum of the phase outputs.  For stride-2 3x3 the
    phases are three 2-tap sub-convs (served by the registered 2-tap SFC
    algorithms) plus one 1x1 pointwise (direct); the stride-2 7x7 stem
    lowers onto the 4- and 3-tap algorithms.  Phase kernels are zero
    -padded up to the square ``max(taps_h, taps_w)`` so each sub-problem
    is a plain square ConvSpec.
  * **grouped** — a ``groups=g`` convolution splits into g per-group
    dense sub-specs with C_in/g -> C_out/g channels.  All groups share
    ONE memoized sub-plan (identical sub-spec) and therefore one
    prepared-weight layout; only the per-group weight slices differ.

2-D depthwise (= groups == C) is NOT a composite: it plans natively
(``fast_eligible``) and executes on the transform-domain *elementwise*
path in the kernels layer (``repro.kernels``) instead of the t^2 matmuls.
A strided depthwise spec lowers by polyphase into stride-1 depthwise
sub-specs, composing both mechanisms.

Cost honesty: a lowering is only selected under ``algo="auto"`` when the
composite beats one strided direct conv.  Measured wall-clock takes
precedence, as everywhere in the planner: an ``autotune`` sweep of the
strided/grouped spec (which times the composite per algorithm name plus
the direct baseline, under the original spec's key) overrides the
analytic verdict in either direction once both sides have been timed on
this host.  Untimed specs rank by the BOPs model
(``repro.quant.bops``, which prices strided/grouped/depthwise direct
baselines) — polyphase pays 4 sub-convs for one output grid, a win for
the ResNet-18 stage-transition shapes but not universally.  An
explicitly requested fast algorithm lowers whenever any sub-problem
resolves fast, mirroring the old "explicit algo degrades gracefully"
contract.

Sub-plans inherit the backend (so the SPMD backend's shard layout and
``place_prepared`` hook apply per sub-problem) and consult the tuning and
serving caches under their own lowered sub-spec keys.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.api.plan import ConvPlan, PrepCache, PreparedWeights
from repro.api.spec import ConvSpec

# test/debug escape hatch: `with lowering.disabled(): ...` restores the
# pre-lowering planner behaviour (stride-2/grouped degrade to direct)
_DISABLED = False


@contextlib.contextmanager
def disabled():
    """Context manager: suspend lowering (plans degrade as pre-refactor).

    Plans memoized while disabled are dropped on both edges so a direct
    plan minted here can never serve a later lowerable call (and vice
    versa).
    """
    global _DISABLED
    from repro.api import planner
    prev = _DISABLED
    _DISABLED = True
    planner.invalidate_plan_cache()
    try:
        yield
    finally:
        _DISABLED = prev
        planner.invalidate_plan_cache()


# --------------------------------------------------------------------------
# polyphase geometry
# --------------------------------------------------------------------------
def phase_taps(R: int, a: int, stride: int) -> int:
    """Taps of phase ``a`` of an R-tap stride-``stride`` kernel."""
    return max(0, -(-(R - a) // stride))


def strided_lo_out(size: int, R: int, stride: int, padding: str
                   ) -> Tuple[int, int]:
    """(lo_pad, out_size) of one strided dim, XLA SAME/VALID convention."""
    if padding == "SAME":
        out = -(-size // stride)
        total = max((out - 1) * stride + R - size, 0)
        return total // 2, out
    if padding == "VALID":
        return 0, (size - R) // stride + 1
    raise ValueError(f"padding must be SAME or VALID, got {padding}")


def _phase_layout(spec: ConvSpec):
    """[(a, b, Rk)] for every phase with at least one tap per dim.

    ``Rk = max(taps_h, taps_w)`` is the square sub-kernel size the phase
    kernel is zero-padded to.
    """
    s, R = spec.stride, spec.kernel_size
    out = []
    for a in range(s):
        ra = phase_taps(R, a, s)
        if ra == 0:
            continue
        for b in range(s):
            rb = phase_taps(R, b, s)
            if rb == 0:
                continue
            out.append((a, b, max(ra, rb)))
    return out


def _phase_weights(w, a: int, b: int, stride: int, Rk: int):
    """Decimate + zero-pad one phase of an HWIO(-like) weight tensor."""
    wp = w[a::stride, b::stride]
    pad_h, pad_w = Rk - wp.shape[0], Rk - wp.shape[1]
    if pad_h or pad_w:
        width = [(0, pad_h), (0, pad_w)] + [(0, 0)] * (wp.ndim - 2)
        wp = jnp.pad(wp, width)
    return wp


# --------------------------------------------------------------------------
# composite plan
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CompositePrepared:
    """Offline-processed weights of a lowered plan: one entry per
    sub-problem (``PreparedWeights`` or a nested ``CompositePrepared``)."""

    w: Any                                   # raw weights as passed in
    subs: Tuple[Any, ...]

    @property
    def quantized(self) -> bool:
        return any(getattr(s, "quantized", False) for s in self.subs)


@dataclasses.dataclass(eq=False)
class CompositePlan:
    """A lowered spec: native sub-plans plus the glue to fan out over them.

    Duck-types the :class:`ConvPlan` surface every consumer relies on
    (``apply`` / ``prepare_weights`` / ``path`` / ``cost`` /
    ``with_config``); ``algorithm`` is ``None`` because no *single*
    bilinear algorithm covers the composite — check ``path == "direct"``,
    not ``algorithm is None``, to detect degradation.
    """

    spec: ConvSpec
    backend: str
    kind: str                                 # 'polyphase' | 'grouped'
    sub_plans: Tuple[Any, ...]                # ConvPlan | CompositePlan
    sub_meta: Tuple[Any, ...]                 # polyphase: (a, b, Rk) per sub
    interpret: bool = True
    cost: Optional[float] = None              # comparable to direct estimate
    config: Optional[Any] = None              # uniform override via with_config
    _prep: PrepCache = dataclasses.field(default_factory=PrepCache,
                                         repr=False)

    # ---- ConvPlan surface ----
    @property
    def algorithm(self):
        return None

    @property
    def path(self) -> str:
        return "lowered"

    @property
    def algo_name(self) -> str:
        names = []
        for p in self.sub_plans:
            n = p.algo_name
            if n not in names:
                names.append(n)
        return f"{self.kind}[{'+'.join(names)}]"

    def with_config(self, config) -> "CompositePlan":
        """Propagate one kernel config to every sub-plan (autotune and the
        conformance oracle sweep fused/staged variants through this)."""
        subs = tuple(p.with_config(config) for p in self.sub_plans)
        return dataclasses.replace(self, sub_plans=subs, config=config)

    # ------------------------------------------------------------------
    # sub-problem operand routing
    # ------------------------------------------------------------------
    def _sub_inputs(self, x) -> Sequence[Any]:
        """Slice the full input into one operand per sub-plan."""
        if self.kind == "grouped":
            g = self.spec.groups
            cg = x.shape[-1] // g
            return [x[..., i * cg:(i + 1) * cg] for i in range(g)]
        s, R = self.spec.stride, self.spec.kernel_size
        B, H, W, _ = x.shape
        lo_h, out_h = strided_lo_out(H, R, s, self.spec.padding)
        lo_w, out_w = strided_lo_out(W, R, s, self.spec.padding)
        # pad far enough that every phase's decimated window exists; the
        # extra zeros only ever meet the phases' zero-padded kernel taps,
        # so the kept outputs are untouched (taps 2r'+a < R read at most
        # xp[s*(out-1) + R - 1], the SAME-padded extent)
        need_h = max(s * (out_h + Rk - 2) + a + 1
                     for a, _, Rk in self.sub_meta)
        need_w = max(s * (out_w + Rk - 2) + b + 1
                     for _, b, Rk in self.sub_meta)
        xp = jnp.pad(x, ((0, 0),
                         (lo_h, max(0, need_h - H - lo_h)),
                         (lo_w, max(0, need_w - W - lo_w)),
                         (0, 0)))
        subs = []
        for a, b, Rk in self.sub_meta:
            n_h, n_w = out_h + Rk - 1, out_w + Rk - 1
            subs.append(xp[:, a::s, b::s, :][:, :n_h, :n_w, :])
        return subs

    def _sub_weights(self, w) -> Sequence[Any]:
        if self.kind == "grouped":
            g = self.spec.groups
            og = w.shape[-1] // g
            return [w[..., i * og:(i + 1) * og] for i in range(g)]
        return [_phase_weights(w, a, b, self.spec.stride, Rk)
                for a, b, Rk in self.sub_meta]

    @staticmethod
    def _per_sub(value, n: int):
        """Broadcast None or split a per-sub sequence of scales."""
        if value is None:
            return [None] * n
        if len(value) != n:
            raise ValueError(
                f"lowered plan has {n} sub-problems; got {len(value)} "
                "per-sub scale entries (pass one per sub-plan, e.g. from "
                "CompositePlan.calibrate)")
        return list(value)

    # ------------------------------------------------------------------
    # offline: weight preparation + calibration
    # ------------------------------------------------------------------
    def prepare_weights(self, w, *, act_scale=None, w_scale=None
                        ) -> CompositePrepared:
        """Fan ``prepare_weights`` out over the sub-plans.

        ``act_scale`` / ``w_scale`` are per-sub *sequences* (one entry per
        sub-plan, nested for nested composites) — each sub-problem has its
        own algorithm, tile size and input distribution, so a single
        (t, t) scale cannot serve the composite.  Use :meth:`calibrate`
        to build the activation-scale sequence from a sample batch.
        """
        operands = (w, act_scale, w_scale)
        key = PrepCache.key_for(operands)
        if key is not None:
            cached = self._prep.get(key, operands)
            if cached is not None:
                return cached
        n = len(self.sub_plans)
        acts = self._per_sub(act_scale, n)
        wss = self._per_sub(w_scale, n)
        subs = tuple(
            p.prepare_weights(ws, act_scale=a, w_scale=s)
            for p, ws, a, s in zip(self.sub_plans, self._sub_weights(w),
                                   acts, wss))
        prep = CompositePrepared(w=w, subs=subs)
        if key is not None:
            self._prep.put(key, operands, prep)
        return prep

    def calibrate(self, x) -> Tuple[Any, ...]:
        """Per-sub absmax activation scales from one batch (the composite
        analogue of ``tuning.calibrate_act_scale``); feed the result to
        :meth:`prepare_weights` as ``act_scale``."""
        from repro.api import tuning
        scales = []
        for p, xs in zip(self.sub_plans, self._sub_inputs(x)):
            if isinstance(p, CompositePlan):
                scales.append(p.calibrate(xs))
            elif p.algorithm is None:
                scales.append(None)
            else:
                scales.append(tuning.calibrate_act_scale(
                    xs, p.algorithm, self.spec.quant, p.spec.padding))
        return tuple(scales)

    # ------------------------------------------------------------------
    # online: execution
    # ------------------------------------------------------------------
    def apply(self, x, w, *, bias=None, elementwise_hook=None):
        """Run the lowered convolution; same contract as ``ConvPlan.apply``.

        ``elementwise_hook`` is forwarded to every sub-plan that has a
        transform domain (fast or nested-lowered); direct sub-problems —
        e.g. the 1x1 centre phase of a stride-2 3x3 — have no transform
        domain and are skipped.
        """
        prep = w if isinstance(w, (PreparedWeights, CompositePrepared)) \
            else self.prepare_weights(w)
        y = None
        for p, xs, pr in zip(self.sub_plans, self._sub_inputs(x), prep.subs):
            if elementwise_hook is not None and p.path != "direct":
                yi = p.apply(xs, pr, elementwise_hook=elementwise_hook)
            else:
                yi = p.apply(xs, pr)
            if self.kind == "grouped":
                y = [yi] if y is None else y + [yi]
            else:
                y = yi if y is None else y + yi
        if self.kind == "grouped":
            y = jnp.concatenate(y, axis=-1)
        return y if bias is None else y + bias

    def __call__(self, x, w, **kwargs):
        return self.apply(x, w, **kwargs)


# --------------------------------------------------------------------------
# the lowering pass
# --------------------------------------------------------------------------
def _sub_algo(algo: str, sub_spec: ConvSpec) -> str:
    """Algorithm request to forward to a sub-plan: an explicitly requested
    algorithm is kept only when its tap count fits the sub-kernel;
    otherwise the sub-problem auto-selects (the honest reading of "run
    this spec on the fast path")."""
    if algo == "auto":
        return "auto"
    from repro.api import registry
    for e in registry.entries():
        if e.name == algo:
            return algo if e.taps == sub_spec.kernel_size else "auto"
    return "auto"


def _hinted(spec: ConvSpec) -> bool:
    return spec.in_channels is not None and spec.out_channels is not None \
        and spec.spatial is not None


def _measured_override(spec, backend, interpret) -> Optional[bool]:
    """Measured wall-clock verdict on lower-vs-direct, or None.

    ``autotune`` on a strided/grouped spec times the composite under each
    requested algorithm name plus the direct baseline, all keyed on the
    ORIGINAL spec.  Mirroring ``select_algorithm``'s partial-sweep rule,
    the measurement overrides the BOPs decision only when both sides of
    the choice have been timed on this host: True = the fastest measured
    lowered entry beats direct, False = direct wins, None = no (or
    one-sided) measurements — fall back to the analytic model.
    """
    from repro.api import registry, tuning
    measured = tuning.lookup(spec, backend, interpret)
    fast = {n: m["time_s"] for n, m in measured.items()
            if n != registry.DIRECT}
    if not fast or registry.DIRECT not in measured:
        return None
    return min(fast.values()) < measured[registry.DIRECT]["time_s"]


def _auto_accepts(spec, backend, interpret, total: float) -> bool:
    """The ``algo='auto'`` gate: measured wall-clock ahead of BOPs."""
    from repro.api import planner, registry
    override = _measured_override(spec, backend, interpret)
    if override is not None:
        return override
    return total < planner.estimate_cost(spec, registry.DIRECT)


def _measured_config(spec, backend, interpret, algo):
    """Winning KernelConfig that ``autotune`` measured for the composite
    under the ORIGINAL (strided/grouped) spec key, or None.

    An end-to-end measurement of the whole composite outranks the
    per-sub-spec configs the sub-plans resolved individually, so
    ``maybe_lower`` propagates it over every sub-plan via
    ``with_config``.  The requested algorithm's own entry wins when it
    was timed; otherwise the fastest measured lowered entry.
    """
    from repro.api import registry, tuning
    measured = tuning.lookup(spec, backend, interpret)
    fast = {n: m for n, m in measured.items() if n != registry.DIRECT}
    if not fast:
        return None
    name = algo if algo in fast \
        else min(fast, key=lambda n: fast[n]["time_s"])
    return tuning.get_config(spec, backend, name, interpret)


def _sub_spatial(spec: ConvSpec, Rk: int) -> Optional[Tuple[int, int]]:
    if spec.spatial is None:
        return None
    outs = [strided_lo_out(n, spec.kernel_size, spec.stride,
                           spec.padding)[1] for n in spec.spatial]
    return (outs[0] + Rk - 1, outs[1] + Rk - 1)


def _lower_polyphase(spec, backend, algo, interpret):
    from repro.api import planner
    layout = _phase_layout(spec)
    if not layout:
        return None
    subs, plans = [], []
    for a, b, Rk in layout:
        sub = dataclasses.replace(spec, stride=1, padding="VALID",
                                  kernel_size=Rk,
                                  spatial=_sub_spatial(spec, Rk))
        subs.append(sub)
        plans.append(planner.plan(sub, backend=backend,
                                  algo=_sub_algo(algo, sub),
                                  interpret=interpret))
    if all(p.path == "direct" for p in plans):
        return None                    # nothing fast to gain: stay direct
    if _hinted(spec):
        total = sum(p.cost for p in plans)
    else:
        # surrogate frame: sub costs are relative to *their own* direct
        # (Rk^2 * K mults per output); rescale into the original R^2 frame
        total = sum(p.cost * (s.kernel_size / spec.kernel_size) ** 2
                    for p, s in zip(plans, subs))
    if algo == "auto" and not _auto_accepts(spec, backend, interpret, total):
        return None                    # polyphase loses to strided direct
    return CompositePlan(spec=spec, backend=backend, kind="polyphase",
                         sub_plans=tuple(plans), sub_meta=tuple(layout),
                         interpret=interpret, cost=total)


def _lower_grouped(spec, backend, algo, interpret):
    from repro.api import planner
    g = spec.groups
    sub = dataclasses.replace(
        spec, groups=1,
        in_channels=None if spec.in_channels is None
        else spec.in_channels // g,
        out_channels=None if spec.out_channels is None
        else spec.out_channels // g)
    sub_plan = planner.plan(sub, backend=backend,
                            algo=_sub_algo(algo, sub), interpret=interpret)
    if sub_plan.path == "direct":
        return None        # one grouped lax call beats g direct sub-calls
    total = g * sub_plan.cost if _hinted(spec) else sub_plan.cost
    if algo == "auto" and not _auto_accepts(spec, backend, interpret, total):
        return None
    # all groups share the one memoized sub-plan (and thus one prepared
    # -weight layout); only the weight slices differ per group
    return CompositePlan(spec=spec, backend=backend, kind="grouped",
                         sub_plans=(sub_plan,) * g, sub_meta=(None,) * g,
                         interpret=interpret, cost=total)


def maybe_lower(spec: ConvSpec, *, backend: str, algo: str,
                interpret: bool) -> Optional[CompositePlan]:
    """Lower ``spec`` into a :class:`CompositePlan`, or ``None`` when the
    spec is native, not lowerable, or the lowering is not profitable.

    Called by the planner for every non-``direct`` algorithm request;
    grouped splitting runs first so a grouped *strided* spec lowers to
    per-group sub-specs whose own ``plan()`` recursion applies the
    polyphase step.
    """
    if _DISABLED or spec.rank != 2 or spec.kernel_size < 1:
        return None
    if spec.groups > 1:
        comp = _lower_grouped(spec, backend, algo, interpret)
    elif spec.stride > 1 and spec.kernel_size > 1:
        comp = _lower_polyphase(spec, backend, algo, interpret)
    else:
        return None
    if comp is not None:
        # the plan carries the measured winning kernel config, same as a
        # native ConvPlan — autotune times the composite end-to-end under
        # the original spec's key
        cfg = _measured_config(spec, backend, interpret, algo)
        if cfg is not None:
            comp = comp.with_config(cfg)
    return comp


__all__ = ["CompositePlan", "CompositePrepared", "maybe_lower", "disabled",
           "phase_taps", "strided_lo_out"]
