"""Graceful degradation for ``ConvPlan.apply``: fallback chain, circuit
breakers, and an optional numerical guardrail.

A fused-kernel failure (compile error, VMEM overflow, an interpret/TPU
mismatch surfacing as a runtime crash) used to propagate straight out of
``ConvPlan.apply`` — killing every co-batched serving request, and doing
it again on the next batch because nothing remembered the failure.  This
module is the plan-tier half of the resilience story:

  * **degradation chain** — on exception, the pallas int8 datapath falls
    fused -> staged -> reference.  fused and staged share one integer
    grid and are *bit-identical* (``repro.testing.assert_conv_conformance``
    invariant), so the first fallback level changes nothing a client can
    observe; the reference int8 simulation is the fp-epsilon-close last
    resort.  fp pallas plans fall straight to the reference backend.
  * **circuit breaker per (spec, backend, level)** — ``failure_threshold``
    consecutive failures open the breaker: the broken level stops being
    *attempted* under traffic (the fallback is pinned, each request pays
    one dict lookup instead of one kernel crash).  After ``cooldown_s``
    the breaker half-opens and lets exactly one probe through; success
    closes it, failure re-opens with a fresh cool-down.
  * **numerical guardrail** (opt-in via the policy) — a cheap output
    check (NaN/Inf) plus an int8 transform-domain saturation-rate probe.
    Meng & Brothers and LANCE both document how silently a miscalibrated
    transform-domain int8 path saturates; a violation is treated exactly
    like a kernel exception, so garbage trips the same breaker instead of
    being served.

The chain engages only on the ``pallas`` backend with no elementwise
hook and never under tracing (``ConvPlan.apply`` gates it), and the
healthy path costs one breaker lookup and a ``try`` — measured in
``benchmarks/chaos.py``'s 0%-fault row against the PR 6 serving numbers.

Observability: every event increments a process-wide counter *and* the
thread-local metrics sink, so a serving engine attributes events from its
own dispatch thread to its own ``MetricsRegistry`` while module-level
``stats()`` still serves tests and scripts.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class BreakerOpenError(RuntimeError):
    """Every degradation level's breaker is open — nothing left to try."""


class GuardrailViolation(RuntimeError):
    """The numerical guardrail rejected a level's output."""


class CircuitBreaker:
    """Consecutive-failure breaker with cool-down and half-open probe.

    State machine: CLOSED --(threshold consecutive failures)--> OPEN
    --(cooldown elapsed, next ``allow``)--> HALF_OPEN (exactly one probe
    passes) --(probe success)--> CLOSED / --(probe failure)--> OPEN.
    ``clock`` is injectable so tests step the cool-down deterministically.
    """

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        if failure_threshold < 1:
            raise ValueError(f"failure_threshold must be >= 1: "
                             f"{failure_threshold}")
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self.clock = clock
        self._lock = threading.Lock()
        self._state = CLOSED
        self._failures = 0
        self._opened_at = 0.0
        self._probing = False

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """May this level be attempted now?  An OPEN breaker whose
        cool-down elapsed transitions to HALF_OPEN and admits exactly one
        probe; further calls are refused until the probe resolves."""
        with self._lock:
            if self._state == CLOSED:
                return True
            if self._state == OPEN:
                if self.clock() - self._opened_at >= self.cooldown_s:
                    self._state = HALF_OPEN
                    self._probing = True
                    return True
                return False
            # HALF_OPEN: one probe already in flight
            if not self._probing:
                self._probing = True
                return True
            return False

    def record_success(self) -> bool:
        """Returns True when this success *recovered* the breaker
        (a half-open probe came back healthy)."""
        with self._lock:
            recovered = self._state != CLOSED
            self._state = CLOSED
            self._failures = 0
            self._probing = False
            return recovered

    def record_failure(self) -> bool:
        """Returns True when this failure *tripped* the breaker
        (CLOSED -> OPEN on the threshold, or a failed half-open probe)."""
        with self._lock:
            if self._state == HALF_OPEN:
                self._state = OPEN
                self._opened_at = self.clock()
                self._probing = False
                return True
            self._failures += 1
            if self._state == CLOSED \
                    and self._failures >= self.failure_threshold:
                self._state = OPEN
                self._opened_at = self.clock()
                return True
            return False

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"state": self._state, "failures": self._failures}


@dataclasses.dataclass(frozen=True)
class Guardrail:
    """Cheap runtime output validation for quantized plans.

    ``check_nonfinite`` scans the output for NaN/Inf (one reduction over
    ``y``).  ``max_sat_frac`` additionally probes the int8 transform-domain
    saturation rate on ``sample_images`` leading images of the input: the
    fraction of transform coefficients whose magnitude exceeds the
    calibrated clip point ``act_scale * qmax``.  A rate above the bound
    means the static scales no longer cover the live activations — the
    output is quantization garbage even though nothing crashed.
    """

    check_nonfinite: bool = True
    max_sat_frac: Optional[float] = None
    sample_images: int = 1

    def check(self, plan, x, prep, y) -> Optional[str]:
        """Violation description, or None when the output passes."""
        import jax.numpy as jnp
        if self.check_nonfinite and not bool(jnp.all(jnp.isfinite(y))):
            return "non-finite values in output"
        if self.max_sat_frac is not None and prep is not None \
                and getattr(prep, "act_scale", None) is not None \
                and plan.algorithm is not None and plan.spec.rank == 2:
            from repro.core import conv2d as c2d
            from repro.quant.fake_quant import qmax_for_bits
            tx, _ = c2d.transform_input_2d(
                x[: self.sample_images], plan.algorithm, plan.spec.padding)
            clip = prep.act_scale[None, None, None, :, :, None] \
                * qmax_for_bits(plan.spec.quant.bits_act)
            sat = float(jnp.mean(jnp.abs(tx) > clip))
            if sat > self.max_sat_frac:
                return (f"int8 saturation rate {sat:.4f} exceeds "
                        f"{self.max_sat_frac} (miscalibrated scales?)")
        return None


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Process-wide configuration of the degradation chain."""

    enabled: bool = True
    failure_threshold: int = 3
    cooldown_s: float = 5.0
    guardrail: Optional[Guardrail] = None
    clock: Callable[[], float] = time.monotonic


# ---------------------------------------------------------------------------
# module state: policy, breaker board, counters, metrics sink
# ---------------------------------------------------------------------------
_POLICY = ResiliencePolicy()
_BOARD: Dict[Tuple, CircuitBreaker] = {}
_BOARD_LOCK = threading.Lock()
_COUNTS: Dict[str, int] = {}
_COUNTS_LOCK = threading.Lock()
_TLS = threading.local()


def policy() -> ResiliencePolicy:
    return _POLICY


def configure(p: ResiliencePolicy) -> None:
    """Install a new policy and reset breakers/counters (the thresholds
    and clock embedded in live breakers came from the old policy)."""
    global _POLICY
    _POLICY = p
    reset()


@contextlib.contextmanager
def configured(**kwargs):
    """Temporarily override policy fields (tests, benchmarks)."""
    prev = _POLICY
    configure(dataclasses.replace(prev, **kwargs))
    try:
        yield _POLICY
    finally:
        configure(prev)


def reset() -> None:
    """Drop every breaker and zero the counters (test isolation)."""
    with _BOARD_LOCK:
        _BOARD.clear()
    with _COUNTS_LOCK:
        _COUNTS.clear()


def breaker_for(key: Tuple) -> CircuitBreaker:
    with _BOARD_LOCK:
        br = _BOARD.get(key)
        if br is None:
            br = _BOARD[key] = CircuitBreaker(
                failure_threshold=_POLICY.failure_threshold,
                cooldown_s=_POLICY.cooldown_s, clock=_POLICY.clock)
        return br


def board_snapshot() -> Dict[str, Dict]:
    """Readable breaker states keyed by '<spec>|<backend>|<level>'."""
    with _BOARD_LOCK:
        items = list(_BOARD.items())
    return {f"{spec}|{backend}|{level}": br.snapshot()
            for (spec, backend, level), br in items}


def stats() -> Dict[str, int]:
    with _COUNTS_LOCK:
        return dict(_COUNTS)


@contextlib.contextmanager
def metrics_sink(inc: Callable[[str], None]):
    """Route this thread's resilience events into ``inc(counter_name)``
    as well as the global counters — the engine wraps each dispatch so
    events land in its own ``MetricsRegistry``."""
    stack = getattr(_TLS, "sinks", None)
    if stack is None:
        stack = _TLS.sinks = []
    stack.append(inc)
    try:
        yield
    finally:
        stack.pop()


def _emit(kind: str) -> None:
    with _COUNTS_LOCK:
        _COUNTS[kind] = _COUNTS.get(kind, 0) + 1
    stack = getattr(_TLS, "sinks", None)
    if stack:
        stack[-1](kind)


# ---------------------------------------------------------------------------
# the degradation chain
# ---------------------------------------------------------------------------
def engaged(plan) -> bool:
    """Does the chain wrap this plan's apply?  Pallas-backend plans only:
    the reference backend IS the last resort (nothing to fall back to),
    and the SPMD backend wraps per-shard pallas applies whose chains
    engage individually inside ``shard_map``-free paths."""
    return _POLICY.enabled and plan.backend == "pallas"


def _levels(plan, prep):
    """Yield (level_name, plan_variant) degradation levels in order.

    Quantized fast-path plans walk fused -> staged -> reference (skipping
    fused when the measured config already picked staged); everything
    else that has a distinct reference rendering gets it as the one
    fallback.  Direct-path plans have no fallback — the pallas backend
    already delegates them to the reference implementation.  A generator
    so the healthy path never constructs the fallback plan variants.
    """
    from repro.api import tuning
    if plan.algorithm is None:
        yield "primary", plan
        return
    if plan.spec.rank == 2 and prep is not None \
            and getattr(prep, "quantized", False):
        cfg = plan.config or tuning.DEFAULT_FUSED
        if cfg.datapath == "fused":
            yield "fused", plan
            yield "staged", plan.with_config(
                dataclasses.replace(cfg, datapath="staged"))
        else:
            yield "staged", plan
    else:
        yield "primary", plan
    yield "reference", dataclasses.replace(plan, backend="reference")


def apply_resilient(plan, x, prep, *, bias=None):
    """Run ``plan`` through the degradation chain.

    Healthy path: one breaker lookup, one try, zero copies.  On failure
    (exception or guardrail violation) the level's breaker records it and
    the next level runs; open breakers are skipped without attempting.
    Raises the last error when every level fails, or
    :class:`BreakerOpenError` when every level was breaker-skipped.
    """
    from repro.api import backends
    pol = _POLICY
    last_err: Optional[BaseException] = None
    for i, (level, lp) in enumerate(_levels(plan, prep)):
        br = breaker_for((plan.spec, plan.backend, level))
        if not br.allow():
            _emit("resilience_breaker_skip")
            continue
        probing = br.state == HALF_OPEN
        if probing:
            _emit("resilience_breaker_probe")
        try:
            y = backends.get_backend(lp.backend).apply(lp, x, prep,
                                                       bias=bias)
            if pol.guardrail is not None:
                violation = pol.guardrail.check(lp, x, prep, y)
                if violation is not None:
                    raise GuardrailViolation(f"{level}: {violation}")
        except Exception as e:               # noqa: BLE001 — the chain IS
            last_err = e                     # the handler of last resort
            _emit("resilience_apply_failure")
            if isinstance(e, GuardrailViolation):
                _emit("resilience_guardrail_trip")
            if br.record_failure():
                _emit("resilience_breaker_trip")
            continue
        if br.record_success():
            _emit("resilience_breaker_recovered")
        if i > 0:
            _emit(f"resilience_fallback_{level}")
        return y
    if last_err is not None:
        raise last_err
    raise BreakerOpenError(
        f"every degradation level's breaker is open for {plan.spec} "
        f"on backend {plan.backend!r} (cooldown {pol.cooldown_s}s)")
