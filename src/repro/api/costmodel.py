"""Calibrated analytic cost model — the planner's middle tier (ROADMAP
item 1: predict the winning kernel config for *unseen* specs instead of
timing every candidate exhaustively).

The BOPs model (``repro.quant.bops``) prices arithmetic only; measured
timings (``repro.api.tuning``) price everything but need a sweep per
spec.  This module sits between them: an analytic per-candidate latency
predictor in the roofline style,

    t_pred(candidate) = k0 + k1 * grid_steps + k2 * roof_s
    roof_s            = max(compute_s, memory_s)

where ``compute_s`` (int8 MXU matmul volume of the t^2 transform-domain
matmuls plus transform/inverse VPU work) and ``memory_s`` (HBM strip
reads, weight k-block traffic, output writeback) are derived from the
kernel's own single-sourced launch geometry — ``FusedGeometry``'s
``compute_ops()`` / ``hbm_bytes()`` accessors, resolved through
``repro.analysis.kernel_checks.geometry_for`` — and from the BOPs
workload model for the staged/direct datapaths.  The model NEVER
re-derives strip or VMEM arithmetic from shapes (lint rule COST001):
the geometry is the one place launch work is counted.

The (k0, k1, k2) overhead coefficients are *measured*, not assumed:
:func:`fit_coefficients` times a handful of probe specs (one short run,
not a per-spec sweep) and least-squares fits one coefficient set per
datapath (fused / staged / direct), so host realities the analytic
terms cannot see — interpret-mode emulation cost, dispatch overhead,
cache behaviour — are absorbed into the calibration.  Coefficients
persist next to the timing cache (``REPRO_COSTMODEL_CACHE`` env var,
default ``~/.cache/repro/costmodel.json``) keyed on backend x device x
interpret mode, so one calibration serves every later process.

Consumers (wired in ``planner`` / ``tuning`` / ``serve.engine``):

  * ``planner.select_algorithm``: measured timings first (unchanged),
    then this model, then raw BOPs;
  * ``tuning.autotune(top_k=...)``: rank all launchable candidates here
    and measure only the top-k, recording predicted-vs-measured into
    the timing cache so the model self-validates;
  * serve engine warm-up: model-predicted configs for buckets with no
    timing entry (see ``benchmarks/roofline.py run_costmodel`` for the
    validation cell feeding ``BENCH_conv.json["costmodel"]``).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.api.spec import ConvSpec
from repro.quant.bops import direct_conv_bops, fastconv_bops

_ENV_CACHE = "REPRO_COSTMODEL_CACHE"
_DEFAULT_CACHE = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                              "costmodel.json")

# Nominal at-peak rates used ONLY to normalise the analytic work terms
# into comparable "at-peak seconds" before the roofline max(); the
# fitted k2 coefficient rescales them to the actual host (on the CPU
# container, interpret-mode emulation is orders of magnitude off these
# peaks — that gap lands in the coefficients, the *ranking* information
# lives in the relative feature magnitudes).  HBM matches
# benchmarks/roofline.py's v5e figure.
PEAK_MXU_INT8_MACS = 197e12     # int8 MXU multiply-accumulates / s
PEAK_VPU_FLOPS = 3.9e12         # f32 VPU elementwise ops / s
PEAK_HBM_BYTES = 819e9          # HBM bytes / s
PEAK_BOPS = PEAK_MXU_INT8_MACS * 64.0   # bit-ops/s at 8x8-bit pricing

# feature-vector width per datapath: (1, grid_steps, roof_s) for the
# pallas datapaths, (1, roof_s) for direct (no grid)
N_FEATURES = {"fused": 3, "staged": 3, "direct": 2}

_LOCK = threading.RLock()
_STORE: Optional[Dict[str, Dict]] = None
_PATH_OVERRIDE: Optional[str] = None


# --------------------------------------------------------------------------
# coefficient store (same shape/locking discipline as the timing cache)
# --------------------------------------------------------------------------
def cache_path() -> str:
    return _PATH_OVERRIDE or os.environ.get(_ENV_CACHE, _DEFAULT_CACHE)


def set_cache_path(path: Optional[str]) -> None:
    """Point the coefficient store somewhere else (tests); None restores
    the env/default resolution."""
    global _PATH_OVERRIDE, _STORE
    with _LOCK:
        _PATH_OVERRIDE = path
        _STORE = None
    _invalidate_plans()


def clear() -> None:
    """Drop in-memory coefficients (the cache file is left untouched)."""
    global _STORE
    with _LOCK:
        _STORE = {}
    _invalidate_plans()


def _invalidate_plans() -> None:
    # memoized plans may embed configs/algorithms this model selected
    from repro.api import planner
    planner.invalidate_plan_cache()


def _load() -> Dict[str, Dict]:
    global _STORE
    with _LOCK:
        if _STORE is None:
            try:
                with open(cache_path()) as f:
                    _STORE = json.load(f)
            except (OSError, ValueError):
                _STORE = {}
        return _STORE


_WRITE_WARNED = False


def _save() -> None:
    global _WRITE_WARNED
    with _LOCK:
        snapshot = json.loads(json.dumps(_STORE or {}))
        path = cache_path()
    try:
        if os.path.dirname(path):
            os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(snapshot, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        _WRITE_WARNED = False
    except OSError as e:
        if not _WRITE_WARNED:
            _WRITE_WARNED = True
            warnings.warn(
                f"cost-model coefficients not persisted to {path!r} ({e}); "
                f"the fit remains in-memory for this process only",
                RuntimeWarning, stacklevel=3)


def _key(backend: str, interpret: bool) -> str:
    # device platform is part of the key for the same reason as the
    # timing cache: interpret-mode CPU coefficients must never price
    # compiled-TPU plans
    return f"{backend}|{jax.default_backend()}|i{int(interpret)}"


def coefficients(backend: str = "pallas",
                 interpret: bool = True) -> Optional[Dict[str, List[float]]]:
    """Fitted per-datapath coefficient vectors, or None when unfitted."""
    entry = _load().get(_key(backend, interpret))
    if not entry:
        return None
    return {dp: list(map(float, entry[dp]))
            for dp in N_FEATURES if dp in entry}


def is_fitted(backend: str = "pallas", interpret: bool = True) -> bool:
    return bool(coefficients(backend, interpret))


def set_coefficients(coefs: Dict[str, Sequence[float]],
                     backend: str = "pallas", *, interpret: bool = True,
                     persist: bool = True, meta: Optional[Dict] = None
                     ) -> None:
    """Install coefficient vectors (fit output, tests, offline calib).

    ``coefs`` maps datapath -> vector sized per :data:`N_FEATURES`.
    """
    for dp, vec in coefs.items():
        if dp not in N_FEATURES:
            raise ValueError(f"unknown datapath {dp!r}")
        if len(vec) != N_FEATURES[dp]:
            raise ValueError(
                f"{dp} coefficient vector has {len(vec)} entries, "
                f"expected {N_FEATURES[dp]}")
    with _LOCK:
        store = _load()
        entry = {dp: [float(v) for v in vec] for dp, vec in coefs.items()}
        if meta:
            entry["meta"] = meta
        store[_key(backend, interpret)] = entry
        if persist:
            _save()
    _invalidate_plans()


# --------------------------------------------------------------------------
# analytic features
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CostFeatures:
    """Analytic work terms of one (spec, algorithm, config) candidate."""

    datapath: str          # 'fused' | 'staged' | 'direct'
    grid_steps: int        # per-step overhead quanta (0 for direct)
    compute_s: float       # arithmetic at nominal peak, seconds
    memory_s: float        # HBM traffic at nominal peak, seconds
    hbm_bytes: int         # total HBM traffic, bytes
    vmem_bytes: int        # per-grid-step VMEM residency (fused only)

    @property
    def roof_s(self) -> float:
        """Roofline: the launch cannot beat its slower resource."""
        return max(self.compute_s, self.memory_s)

    def vector(self) -> Tuple[float, ...]:
        if self.datapath == "direct":
            return (1.0, self.roof_s)
        return (1.0, float(self.grid_steps), self.roof_s)


def _direct_features(spec: ConvSpec, batch: int) -> CostFeatures:
    from repro.api import planner
    wl = planner._workload(spec)
    H, W = spec.spatial
    cin_w = 1 if spec.depthwise else spec.in_channels // spec.groups
    out_sp = wl.n_outputs_spatial
    hbm = batch * (H * W * spec.in_channels + out_sp * spec.out_channels) \
        * 4 + spec.kernel_size ** 2 * cin_w * spec.out_channels * 4
    return CostFeatures(
        datapath="direct", grid_steps=0,
        compute_s=batch * direct_conv_bops(wl) / PEAK_BOPS,
        memory_s=hbm / PEAK_HBM_BYTES, hbm_bytes=hbm, vmem_bytes=0)


def _staged_features(spec: ConvSpec, algo, config, geom,
                     batch: int) -> CostFeatures:
    """Staged 3-kernel pipeline: arithmetic priced by the BOPs workload
    model, memory by the transform-domain tensor's HBM round trips (the
    traffic the fused kernel exists to eliminate).  Tile counts come
    from the resolved geometry — never re-derived."""
    from repro.api import planner
    wl = planner._workload(spec)
    H, W = spec.spatial
    C, Cout = spec.in_channels, spec.out_channels
    n_tiles = batch * geom.nH * geom.nW
    t, P, M = geom.t, geom.P, geom.M
    # input/output round trips + the int8 transform tensor (write by the
    # transform kernel, read by tdmm) + the int32 product tensor (write
    # by tdmm, read by the inverse) + int8 weights
    hbm = (batch * H * W * C * 4
           + n_tiles * P * C * 2
           + n_tiles * P * Cout * 8
           + P * C * Cout
           + batch * geom.out_h * geom.out_w * Cout * 4)
    tb, cbk = config.tile_block, config.chan_block
    n_k = 1 if config.k_block is None else math.ceil(C / config.k_block)
    steps = (math.ceil(n_tiles / tb) * math.ceil(C / cbk)          # transform
             + P * math.ceil(n_tiles / 128)                        # tdmm
             * math.ceil(Cout / 128) * n_k
             + math.ceil(n_tiles / tb) * math.ceil(Cout / cbk))    # inverse
    return CostFeatures(
        datapath="staged", grid_steps=steps,
        compute_s=batch * fastconv_bops(wl, algo) / PEAK_BOPS,
        memory_s=hbm / PEAK_HBM_BYTES, hbm_bytes=hbm,
        vmem_bytes=0)


def _fused_features(geom) -> CostFeatures:
    ops = geom.compute_ops()
    hbm = geom.hbm_bytes()
    vpu = ops["vpu_transform"] + ops["vpu_inverse"] + ops["vpu_ew"]
    return CostFeatures(
        datapath="fused", grid_steps=geom.grid_steps,
        compute_s=ops["mxu_macs"] / PEAK_MXU_INT8_MACS
        + vpu / PEAK_VPU_FLOPS,
        memory_s=hbm["total"] / PEAK_HBM_BYTES, hbm_bytes=hbm["total"],
        vmem_bytes=geom.vmem_bytes())


def features_for(spec: ConvSpec, algo, config, *,
                 batch: int = 1) -> Optional[CostFeatures]:
    """Analytic features of one candidate, or None when the model cannot
    price it (shape hints missing, or a fast-path request the geometry
    cannot resolve natively — lowered/strided/grouped specs are priced
    per sub-spec by their own plans, not here)."""
    if spec.rank != 2 or spec.spatial is None \
            or spec.in_channels is None or spec.out_channels is None:
        return None
    if algo is None:
        return _direct_features(spec, batch)
    if spec.stride != 1 or (spec.groups != 1 and not spec.depthwise):
        return None
    if algo.R != spec.kernel_size:
        return None
    from repro.analysis import kernel_checks
    H, W = spec.spatial
    geom = kernel_checks.geometry_for(
        algo, config, batch, H, W, spec.in_channels, spec.out_channels,
        padding=spec.padding, depthwise=spec.depthwise)
    if getattr(config, "datapath", "fused") == "staged":
        return _staged_features(spec, algo, config, geom, batch)
    return _fused_features(geom)


# --------------------------------------------------------------------------
# prediction / ranking
# --------------------------------------------------------------------------
def predict_time(spec: ConvSpec, algo, config, *, backend: str = "pallas",
                 interpret: bool = True, batch: int = 1
                 ) -> Optional[float]:
    """Predicted wall-clock seconds, or None when unfitted/unpriceable."""
    coefs = coefficients(backend, interpret)
    if coefs is None:
        return None
    feats = features_for(spec, algo, config, batch=batch)
    if feats is None:
        return None
    c = coefs.get(feats.datapath)
    if c is None:
        return None
    v = feats.vector()
    return max(float(np.dot(np.asarray(c), np.asarray(v))), 0.0)


def rank_candidates(spec: ConvSpec, algo, candidates=None, *,
                    backend: str = "pallas", interpret: bool = True,
                    batch: int = 1
                    ) -> Optional[List[Tuple[object, float]]]:
    """Launchable candidates sorted by predicted time (fastest first).

    Pre-flights candidates through ``kernel_checks.check_candidates``
    exactly as the autotuner does, so the ranking never proposes a
    config the kernel would reject.  Returns None when the model is
    unfitted or any launchable candidate cannot be priced — a partial
    ranking must not hide a candidate from the measured sweep.
    """
    from repro.analysis import kernel_checks
    from repro.api import tuning
    if candidates is None:
        candidates = tuning.DEFAULT_CANDIDATES
    launchable, _ = kernel_checks.check_candidates(
        spec, algo, candidates, batch=batch)
    if not launchable:
        return None
    ranked = []
    for cfg in launchable:
        pred = predict_time(spec, algo, cfg, backend=backend,
                            interpret=interpret, batch=batch)
        if pred is None:
            return None
        ranked.append((cfg, pred))
    ranked.sort(key=lambda cp: cp[1])
    return ranked


def best_config(spec: ConvSpec, backend: str, algo_name: str,
                interpret: bool = True):
    """Model-predicted best ``KernelConfig`` for one algorithm, or None.

    The planner's fallback when the timing cache has no entry — cold
    specs get a near-optimal config without a blocking sweep.
    """
    from repro.api import registry
    algo = registry.get_algorithm(algo_name)
    if algo is None:                       # direct path carries no config
        return None
    ranked = rank_candidates(spec, algo, backend=backend,
                             interpret=interpret)
    return ranked[0][0] if ranked else None


def select_algorithm(spec: ConvSpec, names: Sequence[str],
                     backend: str, interpret: bool = True
                     ) -> Optional[str]:
    """Model-predicted fastest among ``names`` (each at its predicted
    best config), or None when any candidate cannot be priced.

    All-or-nothing on purpose — the same partial-knowledge rule as the
    planner's measured branch: a model that can price only some
    eligible candidates must not hide the others, so selection falls
    back to BOPs instead.
    """
    from repro.api import registry
    best_name, best_pred = None, None
    for name in names:
        algo = registry.get_algorithm(name)
        if algo is None:
            pred = predict_time(spec, None, None, backend=backend,
                                interpret=interpret)
        else:
            ranked = rank_candidates(spec, algo, backend=backend,
                                     interpret=interpret)
            pred = ranked[0][1] if ranked else None
        if pred is None:
            return None
        if best_pred is None or pred < best_pred:
            best_name, best_pred = name, pred
    return best_name


# --------------------------------------------------------------------------
# calibration
# --------------------------------------------------------------------------
def default_probe_specs() -> List[ConvSpec]:
    """Small, shape-diverse probe set: one memory-bound small image, one
    larger-spatial, two channel-heavy — enough spread in (grid_steps,
    roof_s) to condition the 3-coefficient fit without a full sweep.

    The 512-channel probe is load-bearing: below ~256 channels every
    ``k_block`` candidate clamps to the same resolved geometry, so the
    per-grid-step coefficient is unidentifiable from small probes alone
    (total HBM bytes are invariant to k-blocking — only step count
    varies, and only at large C_in)."""
    from repro.quant.fake_quant import QuantConfig
    q = QuantConfig(enabled=True, bits_act=8, bits_weight=8)
    return [
        ConvSpec(kernel_size=3, in_channels=32, out_channels=32,
                 spatial=(14, 14), quant=q),
        ConvSpec(kernel_size=3, in_channels=64, out_channels=128,
                 spatial=(28, 28), quant=q),
        ConvSpec(kernel_size=3, in_channels=256, out_channels=256,
                 spatial=(7, 7), quant=q),
        ConvSpec(kernel_size=3, in_channels=512, out_channels=512,
                 spatial=(7, 7), quant=q),
    ]


def _fit_nonneg(X: np.ndarray, y: np.ndarray) -> List[float]:
    """Deterministic least squares with an active-set non-negativity
    pass: negative coefficients (unphysical — more work can't be
    faster) are zeroed most-negative-first and the rest refitted."""
    n = X.shape[1]
    active = list(range(n))
    coefs = np.zeros(n)
    while active:
        sol, *_ = np.linalg.lstsq(X[:, active], y, rcond=None)
        if np.all(sol >= -1e-18):
            coefs[:] = 0.0
            coefs[active] = np.maximum(sol, 0.0)
            break
        del active[int(np.argmin(sol))]
    return [float(c) for c in coefs]


def fit_coefficients(probe_specs: Optional[Sequence[ConvSpec]] = None,
                     backend: str = "pallas", *, interpret: bool = True,
                     reps: int = 3, persist: bool = True,
                     log=None) -> Dict:
    """Calibrate the model from a handful of probe runs and install the
    per-datapath coefficients.

    For each probe spec: measures the direct plan plus every launchable
    ``DEFAULT_CANDIDATES`` config of the BOPs-best fast algorithm
    (through the same ``tuning._measure_plan`` protocol the autotuner
    uses), then least-squares fits (k0, k1, k2) per datapath.  Returns
    the fit report that also lands in the coefficient store's ``meta``.
    """
    from repro.analysis import kernel_checks, ranges
    from repro.api import planner, tuning
    if probe_specs is None:
        probe_specs = default_probe_specs()
    samples: Dict[str, List[Tuple[Tuple[float, ...], float]]] = {
        dp: [] for dp in N_FEATURES}
    for spec in probe_specs:
        x, w = tuning._synthetic_operands(spec)
        p_direct = planner.plan(spec, backend=backend, algo="direct",
                                interpret=interpret)
        dt = tuning._measure_plan(p_direct, x, w, reps)
        feats = features_for(spec, None, None, batch=x.shape[0])
        if feats is not None:
            samples["direct"].append((feats.vector(), dt))
        if log:
            log(f"costmodel probe {spec.spatial} ci{spec.in_channels}"
                f"co{spec.out_channels} direct: {dt*1e3:.2f}ms")
        name = planner.select_algorithm(spec)    # pure BOPs ranking
        from repro.api import registry
        algo = registry.get_algorithm(name)
        if algo is None:
            continue
        try:
            p0 = planner.plan(spec, backend=backend, algo=name,
                              interpret=interpret)
        except ranges.AccumulatorOverflowError:
            continue
        if p0.path != "fast":
            continue
        launchable, _ = kernel_checks.check_candidates(
            spec, algo, tuning.DEFAULT_CANDIDATES, batch=x.shape[0])
        for cfg in launchable:
            p = p0.with_config(cfg)
            t = tuning._measure_plan(p, x, w, reps)
            feats = features_for(spec, algo, cfg, batch=x.shape[0])
            if feats is None:
                continue
            samples[cfg.datapath].append((feats.vector(), t))
            if log:
                log(f"costmodel probe {spec.spatial} {cfg.datapath}"
                    f"(k={cfg.k_block},r={cfg.rows_per_step}): "
                    f"{t*1e3:.2f}ms")
    coefs: Dict[str, List[float]] = {}
    report: Dict = {"backend": backend, "interpret": interpret,
                    "device": jax.default_backend(),
                    "samples": {dp: len(s) for dp, s in samples.items()},
                    "probe_specs": len(list(probe_specs))}
    for dp, rows in samples.items():
        if not rows:
            continue
        X = np.asarray([v for v, _ in rows])
        y = np.asarray([t for _, t in rows])
        coefs[dp] = _fit_nonneg(X, y)
        pred = X @ np.asarray(coefs[dp])
        err = np.abs(pred - y) / np.maximum(y, 1e-12)
        report.setdefault("fit_error", {})[dp] = {
            "mean_rel": float(err.mean()), "max_rel": float(err.max())}
    if not coefs:
        raise ValueError("no probe spec produced a measurable sample; "
                         "cannot fit cost-model coefficients")
    report["coefficients"] = {dp: list(v) for dp, v in coefs.items()}
    set_coefficients(coefs, backend, interpret=interpret, persist=persist,
                     meta={k: v for k, v in report.items()
                           if k != "coefficients"})
    return report
