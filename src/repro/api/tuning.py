"""Measured-latency autotuner feeding the planner (ROADMAP: cost model
informed by measured timings rather than BOPs alone).

The BOPs cost model ranks algorithms by arithmetic, which is blind to the
memory behaviour that dominates deployed latency (HBM round-trips, padding
waste, VMEM residency).  This module closes the loop:

  * :func:`autotune` times candidate :class:`KernelConfig` s — fused vs
    staged datapath and their block sizes — for one (ConvSpec, backend)
    on the *actual* host, per registered algorithm (plus direct);
  * results persist in a JSON timing cache (``REPRO_TUNING_CACHE`` env var,
    default ``~/.cache/repro/tuning.json``) keyed on spec x backend x
    device platform, so one calibration run serves every later process;
  * ``planner.select_algorithm`` / ``plan`` consult :func:`lookup` /
    :func:`get_config` AHEAD of the BOPs model whenever measurements
    exist — measured wall-clock overrides the analytic ranking, and the
    winning kernel config rides on the resulting ``ConvPlan``.

Nothing here requires TPU: on the CPU container the kernels run in
interpret mode and the measured numbers rank the same code paths the TPU
executes (see EXPERIMENTS.md §Perf for methodology caveats).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import warnings
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import ConvSpec

_ENV_CACHE = "REPRO_TUNING_CACHE"
_DEFAULT_CACHE = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                              "tuning.json")


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One executable configuration of the pallas int8 datapath."""

    datapath: str = "fused"       # 'fused' | 'staged'
    tile_block: int = 8           # staged transform/inverse tile block
    chan_block: int = 128         # staged transform/inverse channel block
    k_block: Optional[int] = 128  # C_in reduction block (None = full K)
    cout_block: int = 128         # fused C_out block
    # fused grid batching: tile-rows (then whole images) folded per grid
    # step; None = auto via sfc_fused.auto_rows_per_step's VMEM budget
    rows_per_step: Optional[int] = 1
    # fused DMA pipelining: prefetch the next input strip group into a
    # second VMEM slot while the current one is transformed and matmul'd
    double_buffer: bool = False

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict) -> "KernelConfig":
        # unknown keys are dropped, missing ones default: cache entries
        # written before a knob existed stay loadable
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


DEFAULT_FUSED = KernelConfig()
DEFAULT_STAGED = KernelConfig(datapath="staged", k_block=None)
# the batched/pipelined small-image variant (ROADMAP: multi-tile-row grid
# + double-buffered strips); rows_per_step=None resolves per shape
DEFAULT_BATCHED = KernelConfig(datapath="fused", rows_per_step=None)

# default candidate sweep: the fused datapath at a few block shapes
# (including full-K: single k-block, no reduction grid dim), the batched
# multi-tile-row grid with and without DMA double-buffering, plus the
# staged pipeline (full-K and k-blocked) as fallback candidates
DEFAULT_CANDIDATES = (
    KernelConfig(datapath="fused", k_block=128, cout_block=128),
    KernelConfig(datapath="fused", k_block=256, cout_block=128),
    KernelConfig(datapath="fused", k_block=128, cout_block=256),
    KernelConfig(datapath="fused", k_block=None),
    KernelConfig(datapath="fused", rows_per_step=None),
    KernelConfig(datapath="fused", rows_per_step=None, double_buffer=True),
    KernelConfig(datapath="staged", k_block=None),
    KernelConfig(datapath="staged", k_block=128),
)

_LOCK = threading.RLock()
_STORE: Optional[Dict[str, Dict]] = None   # cache-file image, lazily loaded
_PATH_OVERRIDE: Optional[str] = None


def cache_path() -> str:
    return _PATH_OVERRIDE or os.environ.get(_ENV_CACHE, _DEFAULT_CACHE)


def set_cache_path(path: Optional[str]) -> None:
    """Point the timing cache somewhere else (tests); None restores env."""
    global _PATH_OVERRIDE, _STORE
    with _LOCK:
        _PATH_OVERRIDE = path
        _STORE = None
    _invalidate_plans()


def clear() -> None:
    """Drop in-memory measurements (the cache file is left untouched)."""
    global _STORE
    with _LOCK:
        _STORE = {}
    _invalidate_plans()


def _invalidate_plans() -> None:
    # memoized plans may have consulted stale measurements (late import:
    # planner imports this module inside its functions)
    from repro.api import planner
    planner.invalidate_plan_cache()


def _load() -> Dict[str, Dict]:
    global _STORE
    with _LOCK:
        if _STORE is None:
            try:
                with open(cache_path()) as f:
                    _STORE = json.load(f)
            except (OSError, ValueError):
                _STORE = {}
        return _STORE


def _snapshot_locked() -> Dict[str, Dict]:
    """Deep copy of the store (JSON-native values) — callers hold _LOCK."""
    return json.loads(json.dumps(_STORE or {}))


_WRITE_WARNED = False


def _write(path: str, snapshot: Dict[str, Dict]) -> None:
    global _WRITE_WARNED
    try:
        if os.path.dirname(path):
            os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(snapshot, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        _WRITE_WARNED = False         # a later success re-arms the warning
    except OSError as e:
        # read-only host: measurements keep serving from memory, but say
        # so ONCE — silently dropping every record hides a fleet that
        # re-tunes from scratch each process, while warning per record
        # would flood a serving log
        if not _WRITE_WARNED:
            _WRITE_WARNED = True
            warnings.warn(
                f"tuning cache not persisted to {path!r} ({e}); "
                f"measurements remain in-memory for this process only",
                RuntimeWarning, stacklevel=3)


def _save() -> None:
    # write under the lock: concurrent snapshots must reach the file in
    # mutation order, or a stale image can overwrite a newer one
    with _LOCK:
        _write(cache_path(), _snapshot_locked())


def spec_key(spec: ConvSpec, backend: str, interpret: bool = True) -> str:
    """Stable cache key: (workload, backend, device, interpret mode).

    ``interpret`` is part of the key — interpret-mode (CPU emulation)
    timings rank completely differently from compiled TPU kernels and
    must never govern non-interpret plans.

    New spec fields append tokens only at their NON-default values
    (``g{groups}`` for grouped, ``dw`` for 2-D depthwise) — the same
    tolerance pattern as ``KernelConfig.from_json``: every timing-cache
    entry written before a field existed keys a default-valued spec, so
    old JSON caches keep resolving unchanged.
    """
    q = spec.quant
    qk = (f"a{q.bits_act}w{q.bits_weight}{q.act_granularity}"
          f"-{q.weight_granularity}" if q.enabled else "fp32")
    extra = ""
    if spec.groups != 1:
        extra += f"g{spec.groups}"
    if spec.rank == 2 and spec.depthwise:
        extra += "dw"
    return (f"r{spec.rank}k{spec.kernel_size}s{spec.stride}"
            f"p{spec.padding}ci{spec.in_channels}co{spec.out_channels}"
            f"sp{spec.spatial}q{qk}{extra}|{backend}|{jax.default_backend()}"
            f"|i{int(interpret)}")


def lookup(spec: ConvSpec, backend: str,
           interpret: bool = True) -> Dict[str, Dict]:
    """Measured entries for (spec, backend): {algo_name: {time_s, config}}.

    Empty dict when nothing has been measured — the planner then falls
    back to the BOPs model.
    """
    return dict(_load().get(spec_key(spec, backend, interpret), {}))


def get_config(spec: ConvSpec, backend: str, algo_name: str,
               interpret: bool = True) -> Optional[KernelConfig]:
    """Best measured kernel config for one algorithm, or None."""
    entry = _load().get(spec_key(spec, backend, interpret),
                        {}).get(algo_name)
    if entry is None or "config" not in entry:
        return None
    return KernelConfig.from_json(entry["config"])


def record(spec: ConvSpec, backend: str, algo_name: str, time_s: float,
           config: Optional[KernelConfig] = None, *,
           predicted_s: Optional[float] = None,
           interpret: bool = True, persist: bool = True) -> None:
    """Store one measurement (used by autotune; exposed for tests/offline
    calibration imports).  Last measurement wins — a re-tune must be able
    to correct entries that no longer reproduce (driver/library upgrades,
    different host load), so older-but-faster times are NOT kept.

    The load -> mutate -> persist span holds ONE lock acquisition: a
    concurrent ``set_cache_path()`` / ``clear()`` lands either entirely
    before (this record mutates the fresh store) or entirely after (the
    reset drops the in-memory entry, as those functions document) — it
    can never detach the dict being mutated from the one that persists,
    so a completed ``record`` is always on disk, and concurrent records
    reach the file in mutation order.
    """
    with _LOCK:
        store = _load()               # RLock: reentrant under our span
        entry = store.setdefault(spec_key(spec, backend, interpret), {})
        entry[algo_name] = {"time_s": float(time_s)}
        if config is not None:
            entry[algo_name]["config"] = config.to_json()
        if predicted_s is not None:
            # cost-model self-validation: autotune stores the model's
            # prediction for the measured winner alongside the ground
            # truth, so a drifting model is visible in the cache itself
            entry[algo_name]["predicted_s"] = float(predicted_s)
        if persist:
            _write(cache_path(), _snapshot_locked())
    _invalidate_plans()


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------
def time_fn(fn, *args, reps: int = 3, min_total_s: float = 0.02,
            max_reps: int = 64) -> float:
    """Mean wall-clock of ``fn(*args)`` after one warmup (compile) call.

    The one timing protocol shared by the autotuner, the cost-model
    calibration, and the benchmarks (``benchmarks/table3_throughput.py``).
    De-noised by an adaptive repeat: after the initial ``reps`` batch,
    timed batches double until at least ``min_total_s`` of wall-clock has
    accumulated (or ``max_reps`` calls ran) — a sub-millisecond kernel
    timed three times is mostly timer jitter, and coefficients fitted
    from jitter would mis-rank candidates.  ``min_total_s=0`` restores
    the fixed-``reps`` protocol.
    """
    jax.block_until_ready(fn(*args))              # compile + warm up once
    total, calls, batch = 0.0, 0, max(reps, 1)
    while True:
        t0 = time.perf_counter()
        for _ in range(batch):
            out = fn(*args)
            jax.block_until_ready(out)
        total += time.perf_counter() - t0
        calls += batch
        if total >= min_total_s or calls >= max_reps:
            return total / calls
        batch = min(calls, max_reps - calls)      # double, capped


def calibrate_act_scale(x: jnp.ndarray, algo, quant,
                        padding: str = "SAME") -> jnp.ndarray:
    """Absmax per-frequency activation scales (t, t) from one batch.

    Single-batch stand-in for PTQ calibration (``repro.quant.ptq``) used
    by the autotuner, benchmarks, and tests; respects ``quant.bits_act``.
    """
    from repro.core import conv2d as c2d
    from repro.quant.fake_quant import qmax_for_bits
    tx, _ = c2d.transform_input_2d(x, algo, padding)
    return jnp.abs(tx).max(axis=(0, 1, 2, 5)) \
        / qmax_for_bits(quant.bits_act) + 1e-9


def _synthetic_operands(spec: ConvSpec, seed: int = 0):
    if spec.rank != 2 or spec.in_channels is None \
            or spec.out_channels is None or spec.spatial is None:
        raise ValueError(
            "autotune needs a fully-hinted rank-2 spec (in/out channels "
            f"and spatial extents): {spec}")
    rng = np.random.RandomState(seed)
    H, W = spec.spatial
    cin_w = 1 if spec.depthwise else spec.in_channels // spec.groups
    x = jnp.asarray(rng.randn(1, H, W, spec.in_channels), jnp.float32)
    w = jnp.asarray(
        rng.randn(spec.kernel_size, spec.kernel_size, cin_w,
                  spec.out_channels) * 0.1, jnp.float32)
    return x, w


def _measure_plan(p, x, w, reps: int) -> float:
    if p.spec.quant.enabled and p.path == "lowered":
        # composite plans calibrate per sub-problem
        prep = p.prepare_weights(w, act_scale=p.calibrate(x))
    elif p.spec.quant.enabled and p.algorithm is not None:
        # absmax calibration on the synthetic batch itself — the timing is
        # scale-agnostic, only the datapath matters
        act_scale = calibrate_act_scale(x, p.algorithm, p.spec.quant,
                                        p.spec.padding)
        prep = p.prepare_weights(w, act_scale=act_scale)
    else:
        prep = p.prepare_weights(w)
    # one jit around the whole apply: the direct/reference paths are
    # otherwise eager, and dispatch overhead would skew the ranking
    return time_fn(jax.jit(lambda a: p.apply(a, prep)), x, reps=reps)


def autotune(spec: ConvSpec, backend: str = "pallas", *,
             algos: Optional[Sequence[str]] = None,
             candidates: Sequence[KernelConfig] = DEFAULT_CANDIDATES,
             include_direct: bool = True, reps: int = 3,
             top_k: Optional[int] = 3,
             interpret: bool = True, persist: bool = True,
             log=None) -> Dict[str, Dict]:
    """Measure candidate configs for ``spec`` and persist the winners.

    Times candidate (algorithm, config) pairs on synthetic operands,
    records the fastest config per algorithm (plus the direct path), and
    returns the resulting ``lookup(spec, backend)`` entries.  Subsequent
    ``plan(spec, backend=..., algo='auto')`` calls rank by these measured
    latencies instead of BOPs.  The cache file is written once at the end
    (an interrupted run persists nothing, so a partial sweep cannot skew
    the planner across processes), with the direct baseline measured
    first.

    ``top_k``: when the analytic cost model (``repro.api.costmodel``) is
    fitted for this backend/device, launchable candidates are ranked by
    predicted latency and only the top ``top_k`` are measured — the
    ROADMAP's cold-start story: a fleet spec with live traffic behind it
    pays for k timed launches, not a full sweep.  The winner's predicted
    time is recorded next to the measurement (``predicted_s``) so the
    model self-validates in the cache.  With the model unfitted (or
    ``top_k=None``) every launchable candidate is measured, exactly as
    before.
    """
    from repro.api import planner, registry
    x, w = _synthetic_operands(spec)
    if algos is None:
        algos = [e.name for e in registry.entries(taps=spec.kernel_size)]
    results: Dict[str, Dict] = {}
    if include_direct:
        p = planner.plan(spec, backend=backend, algo="direct",
                         interpret=interpret)
        dt = _measure_plan(p, x, w, reps)
        if log:
            log(f"autotune direct: {dt*1e3:.2f}ms")
        record(spec, backend, "direct", dt, interpret=interpret,
               persist=False)
        results["direct"] = {"time_s": dt}
    # lowered specs can collapse many algorithm names onto one composite
    # (every tap-mismatched name resolves its sub-specs with 'auto'):
    # measure each distinct composite once.  The signature is structural
    # — (sub-spec, resolved algorithm) per sub-plan — because recording a
    # measurement invalidates the plan cache, so object identities do not
    # survive from one name to the next.
    seen_composites: Dict[tuple, str] = {}
    from repro.analysis import kernel_checks, ranges
    for name in algos:
        try:
            p_name = planner.plan(spec, backend=backend, algo=name,
                                  interpret=interpret)
        except ranges.AccumulatorOverflowError as exc:
            # plan-time overflow pre-flight rejected the algorithm for
            # this spec/backend: never time it
            if log:
                log(f"autotune {name}: skipped, {exc}")
            continue
        if p_name.path == "lowered":
            sig = tuple((sp.spec, sp.algo_name) for sp in p_name.sub_plans)
            first = seen_composites.setdefault(sig, name)
            if first != name:
                if log:
                    log(f"autotune {name}: same lowered composite as "
                        f"{first}; skipped")
                continue
        launchable = list(candidates)
        predictions: Dict[KernelConfig, float] = {}
        if p_name.path == "fast" and p_name.algorithm is not None:
            # static resource pre-flight: drop fused configs whose launch
            # geometry breaks the VMEM budget / strip bounds / scratch
            # invariants instead of timing a kernel that would fail (or
            # silently spill) on hardware
            launchable, rejected = kernel_checks.check_candidates(
                spec, p_name.algorithm, candidates, batch=x.shape[0])
            if log:
                for cfg, errs in rejected:
                    log(f"autotune {name} {cfg.datapath}"
                        f"(k={cfg.k_block},co={cfg.cout_block},"
                        f"r={cfg.rows_per_step},"
                        f"db={int(cfg.double_buffer)}): rejected by "
                        f"pre-flight [{errs[0].code}]")
            if top_k is not None:
                # fitted cost model: measure only the predicted top-k
                from repro.api import costmodel
                ranked = costmodel.rank_candidates(
                    spec, p_name.algorithm, launchable, backend=backend,
                    interpret=interpret, batch=x.shape[0])
                if ranked is not None:
                    predictions = dict(ranked)
                    launchable = [cfg for cfg, _ in ranked[:top_k]]
                    if log and len(ranked) > len(launchable):
                        log(f"autotune {name}: cost model kept top-"
                            f"{len(launchable)} of {len(ranked)} "
                            f"launchable candidates")
        best: Optional[float] = None
        best_cfg: Optional[KernelConfig] = None
        for cfg in launchable:
            p0 = planner.plan(spec, backend=backend, algo=name,
                              interpret=interpret)
            if p0.path == "direct":        # spec degraded to direct
                continue
            p = p0.with_config(cfg)        # composite: fans out to subs
            dt = _measure_plan(p, x, w, reps)
            if log:
                log(f"autotune {name} {cfg.datapath}"
                    f"(k={cfg.k_block},co={cfg.cout_block},"
                    f"r={cfg.rows_per_step},db={int(cfg.double_buffer)}): "
                    f"{dt*1e3:.2f}ms")
            if best is None or dt < best:
                best, best_cfg = dt, cfg
        if best is not None:
            record(spec, backend, name, best, best_cfg,
                   predicted_s=predictions.get(best_cfg),
                   interpret=interpret, persist=False)
            results[name] = {"time_s": best, "config": best_cfg.to_json()}
            if best_cfg in predictions:
                results[name]["predicted_s"] = predictions[best_cfg]
    if persist:
        _save()
    return results
