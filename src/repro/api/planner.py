"""The planner: ``plan(spec, *, backend, algo="auto") -> ConvPlan``.

Algorithm resolution happens in one place, for every call site:

  * shapes a fast algorithm cannot serve natively are first handed to the
    lowering pass (``repro.api.lowering``): stride-2 convs rewrite into
    polyphase stride-1 sub-specs, grouped convs into per-group dense
    sub-specs, each sub-spec planned recursively onto the fast path and
    priced by the same cost model — ``plan`` then returns a
    ``CompositePlan`` fanning out over the sub-plans;
  * only shapes that neither run natively nor lower profitably
    (pointwise 1x1, kernel-tap mismatch with the requested algorithm,
    polyphase that loses to strided direct) degrade to the direct path —
    callers never re-implement that branch;
  * measured wall-clock from the tuning cache (``repro.api.tuning``)
    takes precedence: if this (spec, backend) has been autotuned on this
    host, ``algo="auto"`` picks the fastest measured algorithm and the
    plan carries the winning kernel config;
  * next, the calibrated analytic cost model (``repro.api.costmodel``)
    ranks candidates and predicts the best kernel config for specs with
    no timing entry — cold specs get a near-optimal plan without a
    blocking sweep (coefficients fitted once per host from probe runs);
  * otherwise ``algo="auto"`` ranks the registered candidates with the
    paper's BOPs cost model (``repro.quant.bops``: transform adds +
    element-wise MACs + inverse adds, tile geometry included via
    ceil(H/M) tiling) against the direct baseline, at the spec's
    precision.  Under int8-or-lower transform-domain quantization,
    Winograd candidates are excluded: their transform dynamic range makes
    low-precision execution inaccurate (paper Fig. 5; Fernandez-Marques
    et al., 2020), so selecting them on BOPs alone would win the cost
    model and lose the model accuracy.

Plans are memoized on (spec, backend, algo, interpret) — specs are frozen
dataclasses, so repeated call sites share one plan and its prepared-weight
cache.
"""
from __future__ import annotations

import functools
from typing import Optional

from repro.api import registry
from repro.api.plan import ConvPlan
from repro.api.spec import ConvSpec
from repro.quant.bops import ConvWorkload, direct_conv_bops, fastconv_bops

_FP_SURROGATE_BITS = 16   # cost-model bit width for unquantized specs


def _spec_bits(spec: ConvSpec):
    if spec.quant.enabled:
        return spec.quant.bits_act, spec.quant.bits_weight
    return _FP_SURROGATE_BITS, _FP_SURROGATE_BITS


def _workload(spec: ConvSpec) -> Optional[ConvWorkload]:
    if spec.rank != 2 or spec.in_channels is None \
            or spec.out_channels is None or spec.spatial is None:
        return None
    ba, bw = _spec_bits(spec)
    return ConvWorkload(spec.spatial[0], spec.spatial[1], spec.in_channels,
                        spec.out_channels, spec.kernel_size,
                        bits_act=ba, bits_weight=bw, stride=spec.stride,
                        groups=spec.groups,
                        depthwise=spec.depthwise and spec.rank == 2,
                        padding=spec.padding)


def estimate_cost(spec: ConvSpec, algo_name: str) -> float:
    """BOPs (or a dimensionless surrogate) of running ``spec`` one way."""
    algo = registry.get_algorithm(algo_name)
    if spec.rank == 1:
        # depthwise: no channel contraction — cost is multiplications per
        # output per channel (paper's 1-D counting): R direct, t/M fast.
        return float(spec.kernel_size if algo is None else algo.t / algo.M)
    wl = _workload(spec)
    if wl is not None:
        return direct_conv_bops(wl) if algo is None \
            else fastconv_bops(wl, algo)
    # no shape hints: rank by arithmetic complexity (direct == 1.0)
    return 1.0 if algo is None else algo.arithmetic_complexity_2d


def select_algorithm(spec: ConvSpec, backend: Optional[str] = None,
                     interpret: bool = True) -> str:
    """Cheapest eligible algorithm for the spec (may be 'direct').

    With ``backend`` given, selection walks three tiers of evidence:

      1. **measured** wall-clock from the tuning cache
         (``repro.api.tuning``, keyed per interpret/compiled mode) — but
         only when the BOPs-best candidate itself has been timed: a
         partial sweep (e.g. an autotune restricted to one algorithm)
         must not hide a never-measured candidate that the analytic
         model ranks first;
      2. the **calibrated cost model** (``repro.api.costmodel``), when
         fitted for this backend/device and able to price every
         eligible candidate (same partial-knowledge rule);
      3. raw **BOPs** (``repro.quant.bops``) otherwise — arithmetic
         only, but always available.
    """
    if not spec.fast_eligible:
        return registry.DIRECT
    candidates = registry.entries(taps=spec.kernel_size)
    ba, bw = _spec_bits(spec)
    if spec.quant.enabled and min(ba, bw) <= 8:
        candidates = [e for e in candidates if e.kind != "winograd"]
    best_name = registry.DIRECT
    best_cost = estimate_cost(spec, registry.DIRECT)
    for entry in candidates:
        cost = estimate_cost(spec, entry.name)
        if cost < best_cost:
            best_name, best_cost = entry.name, cost
    if backend is not None:
        from repro.api import costmodel, tuning
        measured = tuning.lookup(spec, backend, interpret)
        eligible = {registry.DIRECT} | {e.name for e in candidates}
        timed = {n: m["time_s"] for n, m in measured.items()
                 if n in eligible}
        if timed and best_name in timed:
            return min(timed, key=timed.get)
        modeled = costmodel.select_algorithm(
            spec, sorted(eligible), backend, interpret)
        if modeled is not None:
            return modeled
    return best_name


@functools.lru_cache(maxsize=512)
def _plan_cached(spec: ConvSpec, backend: str, algo: str,
                 interpret: bool) -> ConvPlan:
    from repro.api import backends
    backends.get_backend(backend)          # fail fast on unknown backend
    if algo not in ("auto", registry.DIRECT):
        # raises on unknown names even when the spec degrades to direct —
        # a typo'd config must not silently train on the direct path
        resolved = registry.get_algorithm(algo)
    if algo != registry.DIRECT:
        # the lowering pass: stride-2 -> polyphase stride-1 sub-specs,
        # groups -> per-group dense sub-specs (recursively planned and
        # cost-checked); returns None when the spec is native, not
        # lowerable, or the composite loses to strided/grouped direct
        from repro.api import lowering
        lowered = lowering.maybe_lower(spec, backend=backend, algo=algo,
                                       interpret=interpret)
        if lowered is not None:
            return lowered
    if not spec.fast_eligible:
        name = registry.DIRECT
    elif algo == "auto":
        name = select_algorithm(spec, backend, interpret)
    elif algo == registry.DIRECT:
        name = registry.DIRECT
    else:
        name = algo if resolved.R == spec.kernel_size else registry.DIRECT
    algorithm = registry.get_algorithm(name)
    if algorithm is not None \
            and getattr(backends.get_backend(backend),
                        "integer_datapath", False):
        # plan-time overflow pre-flight: on backends whose fast path
        # accumulates real int8 x int8 products in int32 (the reference
        # backend fake-quantizes in f32 and cannot wrap), reject specs
        # whose channel contraction could exceed the accumulator before
        # any kernel runs.  Raises AccumulatorOverflowError naming the
        # safe C_in bound.
        from repro.analysis import ranges
        ranges.check_spec_accumulator(spec, algorithm, algo_name=name)
    from repro.api import costmodel, tuning
    # config precedence mirrors the algorithm tiers: a measured winner
    # from the tuning cache first, else the cost model's predicted-best
    # for cold specs (None when the model is unfitted — the kernel then
    # resolves its own defaults)
    config = tuning.get_config(spec, backend, name, interpret)
    if config is None and algorithm is not None:
        config = costmodel.best_config(spec, backend, name, interpret)
    return ConvPlan(spec=spec, backend=backend, algo_name=name,
                    algorithm=algorithm,
                    interpret=interpret, cost=estimate_cost(spec, name),
                    config=config)


def plan(spec: ConvSpec, *, backend: str = "reference", algo: str = "auto",
         interpret: bool = True) -> ConvPlan:
    """Resolve a :class:`ConvSpec` into an executable plan.

    Returns a :class:`ConvPlan` for native specs, or a
    ``lowering.CompositePlan`` (same ``apply``/``prepare_weights``
    surface) when the spec lowers onto SFC sub-problems; inspect
    ``plan.path`` ('fast' | 'lowered' | 'direct') rather than
    ``plan.algorithm`` to see where execution lands.
    """
    from repro import faults
    faults.maybe_fault(faults.PLAN, detail=spec)
    return _plan_cached(spec, backend, algo, interpret)


def invalidate_plan_cache() -> None:
    """Drop memoized plans.

    The registry and the tuning cache call this when their state changes —
    memoized plans embed algorithm selections and kernel configs resolved
    against that state.
    """
    _plan_cached.cache_clear()
