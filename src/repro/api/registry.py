"""Thread-safe public registry of bilinear fast-convolution algorithms.

Replaces the private string-keyed ``_ALGOS`` cache that used to live in
``repro.models.cnn``.  Entries are lazy factories (algorithm generation runs
exact ``Fraction`` arithmetic, so instances are built once and memoized
under a lock) tagged with the kernel-tap count ``taps`` they apply to —
the planner filters candidates by ``taps`` when auto-selecting.

The registry is open: downstream code (new backends, new tile sizes)
registers additional algorithms with :func:`register_algorithm` and they
immediately become visible to ``plan(..., algo="auto")`` and to
``list_algorithms()`` consumers such as the benchmarks.
"""
from __future__ import annotations

import dataclasses
import sys
import threading
from typing import Callable, Dict, Optional, Tuple

from repro.core.generator import (BilinearAlgorithm, generate_sfc,
                                  generate_winograd)

DIRECT = "direct"


@dataclasses.dataclass(frozen=True)
class AlgorithmEntry:
    name: str
    factory: Callable[[], BilinearAlgorithm]
    taps: int                   # kernel size R the algorithm convolves
    kind: str                   # 'sfc' | 'winograd' | ...


_LOCK = threading.RLock()
_ENTRIES: Dict[str, AlgorithmEntry] = {}
_INSTANCES: Dict[str, BilinearAlgorithm] = {}


def register_algorithm(name: str, factory: Callable[[], BilinearAlgorithm],
                       *, taps: int, kind: str,
                       overwrite: bool = False) -> None:
    with _LOCK:
        if name == DIRECT:
            raise ValueError(f"'{DIRECT}' is a reserved algorithm name")
        if name in _ENTRIES and not overwrite:
            raise ValueError(f"algorithm {name!r} already registered")
        _ENTRIES[name] = AlgorithmEntry(name, factory, taps, kind)
        _INSTANCES.pop(name, None)
    # memoized plans may have auto-selected against the old registry state
    # (no-op if the planner was never imported / is still importing —
    # e.g. this very module being imported from planner's own top level:
    # no plans can exist yet)
    planner = sys.modules.get("repro.api.planner")
    invalidate = getattr(planner, "invalidate_plan_cache", None)
    if invalidate is not None:
        invalidate()


def get_algorithm(name: str) -> Optional[BilinearAlgorithm]:
    """Resolve a registered name to its (memoized) algorithm.

    ``"direct"`` resolves to ``None`` — the sentinel every execution layer
    understands as the direct-convolution path.
    """
    if name == DIRECT:
        return None
    with _LOCK:
        if name not in _ENTRIES:
            raise KeyError(
                f"unknown algorithm {name!r}; registered: "
                f"{sorted(_ENTRIES)} (+ '{DIRECT}')")
        if name not in _INSTANCES:
            _INSTANCES[name] = _ENTRIES[name].factory()
        return _INSTANCES[name]


def list_algorithms(taps: Optional[int] = None,
                    include_direct: bool = True) -> Tuple[str, ...]:
    """Registered names, optionally restricted to one kernel-tap count."""
    with _LOCK:
        names = sorted(n for n, e in _ENTRIES.items()
                       if taps is None or e.taps == taps)
    return tuple(names) + ((DIRECT,) if include_direct else ())


def entries(taps: Optional[int] = None) -> Tuple[AlgorithmEntry, ...]:
    with _LOCK:
        return tuple(e for _, e in sorted(_ENTRIES.items())
                     if taps is None or e.taps == taps)


# Paper evaluation set (§6): SFC variants + Winograd baselines for 3-tap
# 2-D convs, and the SFC-6 4-tap algorithm for the Mamba2 depthwise conv1d.
# The 2-tap SFC algorithms serve the polyphase lowering of stride-2 convs
# (``repro.api.lowering``): the even/odd phases of an R-tap strided kernel
# have ceil(R/2) taps, so stride-2 3x3 lowers onto 2-tap sub-convs (and the
# stride-2 7x7 stem onto the 4-/3-tap algorithms above).
for _name, _factory, _taps, _kind in [
    ("sfc6_7", lambda: generate_sfc(6, 7, 3), 3, "sfc"),
    ("sfc6_6", lambda: generate_sfc(6, 6, 3), 3, "sfc"),
    ("sfc4_4", lambda: generate_sfc(4, 4, 3), 3, "sfc"),
    ("wino4", lambda: generate_winograd(4, 3), 3, "winograd"),
    ("wino2", lambda: generate_winograd(2, 3), 3, "winograd"),
    ("sfc6_6_r4", lambda: generate_sfc(6, 6, 4), 4, "sfc"),
    ("sfc4_4_r2", lambda: generate_sfc(4, 4, 2), 2, "sfc"),
    ("sfc4_5_r2", lambda: generate_sfc(4, 5, 2), 2, "sfc"),
    ("sfc6_7_r2", lambda: generate_sfc(6, 7, 2), 2, "sfc"),
]:
    register_algorithm(_name, _factory, taps=_taps, kind=_kind)
