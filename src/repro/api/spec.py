"""``ConvSpec`` — the frozen, hashable description of one convolution.

A spec captures everything the planner needs to pick an algorithm and an
execution path: spatial rank, kernel taps, stride, padding, dense vs
depthwise, dtype, and the quantization policy.  Channel counts and spatial
extents are optional *cost-model hints* — planning works without them but
auto-selection degrades to arithmetic-complexity ranking.

Specs are frozen dataclasses so ``plan()`` can memoize on them directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.quant.fake_quant import FP32, QuantConfig

PADDINGS_2D = ("SAME", "VALID")
PADDING_CAUSAL = "CAUSAL"


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One convolution workload, independent of backend and algorithm."""

    rank: int = 2                    # spatial rank: 1 (sequence) | 2 (image)
    kernel_size: int = 3             # taps R per spatial dim
    stride: int = 1
    padding: str = "SAME"            # SAME | VALID | CAUSAL (rank-1 only)
    depthwise: bool = False
    in_channels: Optional[int] = None
    out_channels: Optional[int] = None
    spatial: Optional[Tuple[int, ...]] = None   # (H, W) / (T,) hint
    dtype: str = "float32"
    quant: QuantConfig = FP32

    def __post_init__(self):
        if self.rank not in (1, 2):
            raise ValueError(f"rank must be 1 or 2, got {self.rank}")
        if self.kernel_size < 1:
            raise ValueError(f"kernel_size must be >= 1: {self.kernel_size}")
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1: {self.stride}")
        if self.rank == 2 and self.padding not in PADDINGS_2D:
            raise ValueError(
                f"rank-2 padding must be one of {PADDINGS_2D}: {self.padding}")
        if self.rank == 1:
            if not self.depthwise or self.padding != PADDING_CAUSAL \
                    or self.stride != 1:
                raise ValueError(
                    "rank-1 convs are supported as stride-1 depthwise "
                    f"CAUSAL only (got depthwise={self.depthwise}, "
                    f"padding={self.padding!r}, stride={self.stride})")
        if self.rank == 2 and self.depthwise:
            raise ValueError("2-D depthwise convolution is not supported; "
                             "use rank=2 dense or rank=1 depthwise")
        if self.spatial is not None and len(self.spatial) != self.rank:
            raise ValueError(
                f"spatial hint {self.spatial} does not match rank {self.rank}")

    # ---- planner predicates ----
    @property
    def fast_eligible(self) -> bool:
        """Whether a bilinear fast algorithm can apply at all.

        Fast algorithms are stride-1 constructs over >=2-tap kernels; every
        other shape (strided, 1x1/pointwise) runs the direct path — this is
        the single place that branch lives, instead of every call site.
        """
        return self.stride == 1 and self.kernel_size > 1

    @classmethod
    def for_conv2d(cls, x_shape, w_shape, *, stride: int = 1,
                   padding: str = "SAME", dtype: str = "float32",
                   quant: QuantConfig = FP32) -> "ConvSpec":
        """Spec from concrete NHWC input / HWIO weight shapes."""
        return cls(rank=2, kernel_size=int(w_shape[0]), stride=stride,
                   padding=padding, in_channels=int(w_shape[2]),
                   out_channels=int(w_shape[3]),
                   spatial=(int(x_shape[1]), int(x_shape[2])),
                   dtype=dtype, quant=quant)

    @classmethod
    def for_conv1d_depthwise(cls, x_shape, w_shape, *,
                             dtype: str = "float32",
                             quant: QuantConfig = FP32) -> "ConvSpec":
        """Spec from (B, T, C) input / (R, C) weight shapes (causal)."""
        return cls(rank=1, kernel_size=int(w_shape[0]), depthwise=True,
                   padding=PADDING_CAUSAL, in_channels=int(w_shape[1]),
                   out_channels=int(w_shape[1]), spatial=(int(x_shape[1]),),
                   dtype=dtype, quant=quant)
