"""``ConvSpec`` — the frozen, hashable description of one convolution.

A spec captures everything the planner needs to pick an algorithm and an
execution path: spatial rank, kernel taps, stride, padding, dense vs
grouped vs depthwise, dtype, and the quantization policy.  Channel counts
and spatial extents are optional *cost-model hints* — planning works
without them but auto-selection degrades to arithmetic-complexity ranking.

A spec need not be *natively* servable by a fast algorithm to reach the
fast path: the planner's lowering pass (``repro.api.lowering``) rewrites
stride-2 specs into polyphase stride-1 sub-specs and grouped specs into
per-group dense sub-specs before algorithm selection, so
:attr:`fast_eligible` describes only the native stride-1 construct.

Specs are frozen dataclasses so ``plan()`` can memoize on them directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.quant.fake_quant import FP32, QuantConfig

PADDINGS_2D = ("SAME", "VALID")
PADDING_CAUSAL = "CAUSAL"


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One convolution workload, independent of backend and algorithm."""

    rank: int = 2                    # spatial rank: 1 (sequence) | 2 (image)
    kernel_size: int = 3             # taps R per spatial dim
    stride: int = 1
    padding: str = "SAME"            # SAME | VALID | CAUSAL (rank-1 only)
    depthwise: bool = False          # groups == channels (rank 1 or 2)
    groups: int = 1                  # grouped conv: C_in/g -> C_out/g each
    in_channels: Optional[int] = None
    out_channels: Optional[int] = None
    spatial: Optional[Tuple[int, ...]] = None   # (H, W) / (T,) hint
    dtype: str = "float32"
    quant: QuantConfig = FP32

    def __post_init__(self):
        if self.rank not in (1, 2):
            raise ValueError(f"rank must be 1 or 2, got {self.rank}")
        if self.kernel_size < 1:
            raise ValueError(f"kernel_size must be >= 1: {self.kernel_size}")
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1: {self.stride}")
        if self.rank == 2 and self.padding not in PADDINGS_2D:
            raise ValueError(
                f"rank-2 padding must be one of {PADDINGS_2D}: {self.padding}")
        if self.rank == 1:
            if not self.depthwise or self.padding != PADDING_CAUSAL \
                    or self.stride != 1:
                raise ValueError(
                    "rank-1 convs are supported as stride-1 depthwise "
                    f"CAUSAL only (got depthwise={self.depthwise}, "
                    f"padding={self.padding!r}, stride={self.stride})")
        if self.groups < 1:
            raise ValueError(f"groups must be >= 1: {self.groups}")
        if self.groups > 1:
            if self.rank != 2:
                raise ValueError("grouped convolution is rank-2 only "
                                 f"(got rank={self.rank})")
            if self.depthwise:
                raise ValueError(
                    "depthwise=True already means groups == channels; "
                    f"do not also set groups={self.groups}")
            for label, c in (("in_channels", self.in_channels),
                             ("out_channels", self.out_channels)):
                if c is not None and c % self.groups:
                    raise ValueError(
                        f"{label}={c} not divisible by groups={self.groups}")
        if self.rank == 2 and self.depthwise \
                and self.in_channels is not None \
                and self.out_channels is not None \
                and self.in_channels != self.out_channels:
            raise ValueError(
                "2-D depthwise requires out_channels == in_channels "
                f"(got {self.in_channels} -> {self.out_channels})")
        if self.spatial is not None and len(self.spatial) != self.rank:
            raise ValueError(
                f"spatial hint {self.spatial} does not match rank {self.rank}")

    # ---- planner predicates ----
    @property
    def fast_eligible(self) -> bool:
        """Whether a bilinear fast algorithm applies *natively*.

        Fast algorithms are stride-1 constructs over >=2-tap kernels
        (dense or depthwise — 2-D depthwise runs the transform-domain
        elementwise path).  Shapes outside this set are not lost causes:
        the planner first tries the lowering pass
        (``repro.api.lowering``: polyphase stride-2 decomposition,
        per-group splitting) and only then degrades to the direct path —
        this property and that pass are the two places the branch lives,
        instead of every call site.
        """
        return self.stride == 1 and self.kernel_size > 1 and self.groups == 1

    @classmethod
    def for_conv2d(cls, x_shape, w_shape, *, stride: int = 1,
                   padding: str = "SAME", groups: int = 1,
                   dtype: str = "float32",
                   quant: QuantConfig = FP32) -> "ConvSpec":
        """Spec from concrete NHWC input / HWIO weight shapes.

        Grouped convs follow the ``lax`` convention: weights are
        (R, R, C_in/groups, C_out), so ``in_channels`` is recovered as
        ``w_shape[2] * groups``.
        """
        return cls(rank=2, kernel_size=int(w_shape[0]), stride=stride,
                   padding=padding, groups=groups,
                   in_channels=int(w_shape[2]) * groups,
                   out_channels=int(w_shape[3]),
                   spatial=(int(x_shape[1]), int(x_shape[2])),
                   dtype=dtype, quant=quant)

    @classmethod
    def for_conv2d_depthwise(cls, x_shape, w_shape, *, stride: int = 1,
                             padding: str = "SAME", dtype: str = "float32",
                             quant: QuantConfig = FP32) -> "ConvSpec":
        """Spec from (B, H, W, C) input / (R, R, 1, C) weight shapes."""
        return cls(rank=2, kernel_size=int(w_shape[0]), stride=stride,
                   padding=padding, depthwise=True,
                   in_channels=int(w_shape[3]), out_channels=int(w_shape[3]),
                   spatial=(int(x_shape[1]), int(x_shape[2])),
                   dtype=dtype, quant=quant)

    @classmethod
    def for_conv1d_depthwise(cls, x_shape, w_shape, *,
                             dtype: str = "float32",
                             quant: QuantConfig = FP32) -> "ConvSpec":
        """Spec from (B, T, C) input / (R, C) weight shapes (causal)."""
        return cls(rank=1, kernel_size=int(w_shape[0]), depthwise=True,
                   padding=PADDING_CAUSAL, in_channels=int(w_shape[1]),
                   out_channels=int(w_shape[1]), spatial=(int(x_shape[1]),),
                   dtype=dtype, quant=quant)
