"""Execution backends behind ``ConvPlan.apply``.

Two backends ship today, both consuming the same ``PreparedWeights``:

  * ``reference`` — pure jnp, built from the ``repro.core.conv2d``
    primitives.  Supports elementwise hooks (dynamic fake quantization,
    PTQ calibration observers) and is the numerical oracle.
  * ``pallas``    — the ``repro.kernels`` TPU kernels (interpret mode on
    CPU).  Static precision only: fp, or int8 with PTQ-calibrated scales
    baked into the prepared weights.

Both degrade identically: the direct path (stride != 1, pointwise, taps
mismatch) runs XLA's native convolution — already optimal there, so the
Pallas backend deliberately reuses it rather than shipping a worse kernel.
The registry is open so future backends (GPU pallas, sharded, batched
serving) plug in via :func:`register_backend` without touching call sites.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import conv2d as c2d
import repro.quant.fake_quant as fq


def _add_bias(y: jnp.ndarray, bias) -> jnp.ndarray:
    return y if bias is None else y + bias


def _check_hook_supported(plan, elementwise_hook, prep) -> None:
    if elementwise_hook is None:
        return
    if plan.algorithm is None:
        raise ValueError(
            "elementwise_hook requires the fast path; this plan resolved "
            f"to direct ({plan.spec})")
    if prep.quantized:
        raise ValueError("elementwise_hook cannot be combined with "
                         "static-int8 prepared weights")


def _direct(plan, x, prep, bias) -> jnp.ndarray:
    spec = plan.spec
    if spec.rank == 1:
        return _add_bias(
            c2d.conv1d_depthwise_causal_direct(x, prep.w), bias)
    y = jax.lax.conv_general_dilated(
        x, prep.w.astype(x.dtype), (spec.stride, spec.stride), spec.padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return _add_bias(y, bias)


class ReferenceBackend:
    """Portable jnp path (the oracle); full hook support."""

    name = "reference"

    def apply(self, plan, x, prep, *, bias=None, elementwise_hook=None):
        _check_hook_supported(plan, elementwise_hook, prep)
        if plan.algorithm is None:
            return _direct(plan, x, prep, bias)
        algo = plan.algorithm
        if plan.spec.rank == 1:
            if elementwise_hook is not None:
                raise NotImplementedError(
                    "elementwise_hook is not supported on the rank-1 "
                    "depthwise fast path")
            return _add_bias(c2d.fastconv1d_depthwise_causal_pretransformed(
                x, prep.tw, algo), bias)
        tx, geom = c2d.transform_input_2d(x, algo, plan.spec.padding)
        tw = prep.tw
        if prep.quantized:
            # static-int8 simulation with the same scales/integer grid as
            # the Pallas datapath: quantize tx with the calibrated
            # frequency scales, use the offline-quantized weights.
            qc = plan.spec.quant
            s_act = prep.act_scale[None, None, None, :, :, None]
            tx = fq.dequantize(
                fq.quantize(tx, s_act, qc.bits_act), s_act)
            tw = (prep.wq.astype(jnp.float32).reshape(tw.shape)
                  * prep.w_scale[:, :, None, :]).astype(tx.dtype)
        elif elementwise_hook is not None:
            tx, tw = elementwise_hook(tx, tw)
        ty = c2d.transform_domain_matmul(tx, tw)
        return _add_bias(c2d.inverse_transform_2d(ty, algo, geom), bias)


class PallasBackend:
    """``repro.kernels`` datapath; static precision, no hooks.

    The int8 path defaults to the fused single-``pallas_call`` kernel
    (``repro.kernels.sfc_fused``) — the transform-domain tensor never
    touches HBM.  A plan carrying a measured ``KernelConfig`` (from
    ``repro.api.tuning``) can instead select the staged three-kernel
    pipeline, override the block sizes, batch multiple tile-rows per grid
    step (``rows_per_step``), or DMA-pipeline the input strip reads
    (``double_buffer``).
    """

    name = "pallas"

    def apply(self, plan, x, prep, *, bias=None, elementwise_hook=None):
        if elementwise_hook is not None:
            raise ValueError(
                "the pallas backend takes no elementwise_hook; bake "
                "quantization into the plan (spec.quant + calibrated "
                "prepare_weights) or use backend='reference'")
        if plan.algorithm is None or plan.spec.rank == 1:
            # no Pallas kernels for these; the reference impls are optimal
            # (XLA native conv) or trivially bandwidth-bound.
            return _REFERENCE.apply(plan, x, prep, bias=bias)
        from repro.kernels import ops
        algo = plan.algorithm
        if prep.quantized:
            from repro.api import tuning
            cfg = plan.config or tuning.DEFAULT_FUSED
            bits = plan.spec.quant.bits_act
            if cfg.datapath == "staged":
                y = ops.quantized_fastconv2d(
                    x, prep.wq, prep.act_scale, prep.w_scale, algo,
                    padding=plan.spec.padding, bits=bits,
                    interpret=plan.interpret, k_block=cfg.k_block,
                    tile_block=cfg.tile_block, chan_block=cfg.chan_block)
            else:
                from repro.kernels.sfc_fused import sfc_fused_conv2d
                y = sfc_fused_conv2d(
                    x, prep.wq, prep.act_scale, prep.w_scale, algo,
                    padding=plan.spec.padding, bits=bits,
                    interpret=plan.interpret,
                    k_block=cfg.k_block, cout_block=cfg.cout_block,
                    rows_per_step=cfg.rows_per_step,
                    double_buffer=cfg.double_buffer)
            return _add_bias(y, bias)
        from repro.kernels.sfc_inverse import sfc_inverse
        from repro.kernels.sfc_transform import sfc_transform
        bt = jnp.asarray(algo.bt(), x.dtype)
        at = jnp.asarray(algo.at(), x.dtype)
        tiles, geom = ops.extract_tiles(x, algo, plan.spec.padding)
        tx = sfc_transform(tiles, bt, interpret=plan.interpret)
        ty = jnp.einsum("ntuc,tuco->ntuo", tx, prep.tw.astype(x.dtype))
        y_tiles = sfc_inverse(ty, at, interpret=plan.interpret)
        return _add_bias(ops.untile(y_tiles, algo, geom), bias)


_REFERENCE = ReferenceBackend()
_BACKENDS: Dict[str, object] = {
    "reference": _REFERENCE,
    "pallas": PallasBackend(),
}


def _register_spmd() -> None:
    # conv_spmd keeps its repro.api imports lazy (either side may load
    # first); mesh resolution stays lazy too — importing repro.api must
    # not touch jax device state
    from repro.distributed.conv_spmd import SpmdPallasBackend
    _BACKENDS["pallas_spmd"] = SpmdPallasBackend()


_register_spmd()


def register_backend(name: str, backend, overwrite: bool = False) -> None:
    """Add (or with ``overwrite``, replace) an execution backend.

    Registration invalidates memoized plans: a ``ConvPlan`` records only
    the backend *name*, but its kernel config and prepared-weight cache
    were resolved against whatever object held that name at planning time
    (an overwritten backend may shard or place weights differently).
    """
    if name in _BACKENDS and not overwrite:
        raise ValueError(f"backend {name!r} already registered")
    _BACKENDS[name] = backend
    from repro.api import planner       # late: avoids import cycle
    planner.invalidate_plan_cache()


def get_backend(name: str):
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; "
                       f"registered: {sorted(_BACKENDS)}") from None


def list_backends():
    return tuple(sorted(_BACKENDS))
