"""Execution backends behind ``ConvPlan.apply``.

Two backends ship today, both consuming the same ``PreparedWeights``:

  * ``reference`` — pure jnp, built from the ``repro.core.conv2d``
    primitives.  Supports elementwise hooks (dynamic fake quantization,
    PTQ calibration observers) and is the numerical oracle.
  * ``pallas``    — the ``repro.kernels`` TPU kernels (interpret mode on
    CPU).  Static precision only: fp, or int8 with PTQ-calibrated scales
    baked into the prepared weights.

2-D depthwise specs run the transform-domain *elementwise* stage instead
of the t^2 matmuls on both backends (jnp broadcast on ``reference``; the
``tdmm_int8_depthwise`` / fused depthwise kernels on ``pallas``).  Both
backends degrade identically: the direct path (pointwise 1x1, taps
mismatch, non-profitable lowerings — strided/grouped shapes are first
rewritten by ``repro.api.lowering``) runs XLA's native convolution
(grouped/depthwise via ``feature_group_count``) — already optimal there,
so the Pallas backend deliberately reuses it rather than shipping a worse
kernel.  The registry is open so future backends (GPU pallas, sharded,
batched serving) plug in via :func:`register_backend` without touching
call sites.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import faults
from repro.core import conv2d as c2d
import repro.quant.fake_quant as fq


def _add_bias(y: jnp.ndarray, bias) -> jnp.ndarray:
    return y if bias is None else y + bias


def _check_hook_supported(plan, elementwise_hook, prep) -> None:
    if elementwise_hook is None:
        return
    if plan.algorithm is None:
        raise ValueError(
            "elementwise_hook requires the fast path; this plan resolved "
            f"to direct ({plan.spec})")
    if prep.quantized:
        raise ValueError("elementwise_hook cannot be combined with "
                         "static-int8 prepared weights")


def _direct(plan, x, prep, bias) -> jnp.ndarray:
    spec = plan.spec
    if spec.rank == 1:
        return _add_bias(
            c2d.conv1d_depthwise_causal_direct(x, prep.w), bias)
    # grouped / depthwise run through lax's feature_group_count; depthwise
    # derives the count from the weight tensor (R, R, 1, C) rather than
    # the spec so shard-local slices under the SPMD backend stay correct
    fgc = prep.w.shape[-1] if spec.depthwise else spec.groups
    y = jax.lax.conv_general_dilated(
        x, prep.w.astype(x.dtype), (spec.stride, spec.stride), spec.padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=fgc)
    return _add_bias(y, bias)


class ReferenceBackend:
    """Portable jnp path (the oracle); full hook support."""

    name = "reference"

    def apply(self, plan, x, prep, *, bias=None, elementwise_hook=None):
        faults.maybe_fault(faults.APPLY_REFERENCE, detail=plan)
        _check_hook_supported(plan, elementwise_hook, prep)
        if plan.algorithm is None:
            return _direct(plan, x, prep, bias)
        algo = plan.algorithm
        if plan.spec.rank == 1:
            if elementwise_hook is not None:
                raise NotImplementedError(
                    "elementwise_hook is not supported on the rank-1 "
                    "depthwise fast path")
            return _add_bias(c2d.fastconv1d_depthwise_causal_pretransformed(
                x, prep.tw, algo), bias)
        tx, geom = c2d.transform_input_2d(x, algo, plan.spec.padding)
        tw = prep.tw
        if prep.quantized:
            # static-int8 simulation with the same scales/integer grid as
            # the Pallas datapath: quantize tx with the calibrated
            # frequency scales, use the offline-quantized weights.
            qc = plan.spec.quant
            s_act = prep.act_scale[None, None, None, :, :, None]
            tx = fq.dequantize(
                fq.quantize(tx, s_act, qc.bits_act), s_act)
            tw = (prep.wq.astype(jnp.float32).reshape(tw.shape)
                  * prep.w_scale[:, :, None, :]).astype(tx.dtype)
        elif elementwise_hook is not None:
            tx, tw = elementwise_hook(tx, tw)
        if plan.spec.depthwise:
            # 2-D depthwise: no channel contraction — the element-wise
            # stage is a true transform-domain elementwise product
            # (tw (t, t, 1, C) broadcast over batch x tiles)
            ty = tx * tw[None, None, None, :, :, 0, :].astype(tx.dtype)
        else:
            ty = c2d.transform_domain_matmul(tx, tw)
        return _add_bias(c2d.inverse_transform_2d(ty, algo, geom), bias)


class PallasBackend:
    """``repro.kernels`` datapath; static precision, no hooks.

    The int8 path defaults to the fused single-``pallas_call`` kernel
    (``repro.kernels.sfc_fused``) — the transform-domain tensor never
    touches HBM.  A plan carrying a measured ``KernelConfig`` (from
    ``repro.api.tuning``) can instead select the staged three-kernel
    pipeline, override the block sizes, batch multiple tile-rows per grid
    step (``rows_per_step``), or DMA-pipeline the input strip reads
    (``double_buffer``).
    """

    name = "pallas"
    # real int8 x int8 -> int32 accumulation: the planner runs the
    # repro.analysis.ranges overflow pre-flight against this backend
    # (the reference backend fake-quantizes in f32 and cannot wrap).
    integer_datapath = True

    def apply(self, plan, x, prep, *, bias=None, elementwise_hook=None):
        if elementwise_hook is not None:
            raise ValueError(
                "the pallas backend takes no elementwise_hook; bake "
                "quantization into the plan (spec.quant + calibrated "
                "prepare_weights) or use backend='reference'")
        if plan.algorithm is None or plan.spec.rank == 1:
            # no Pallas kernels for these; the reference impls are optimal
            # (XLA native conv) or trivially bandwidth-bound.
            return _REFERENCE.apply(plan, x, prep, bias=bias)
        from repro.kernels import ops
        algo = plan.algorithm
        depthwise = plan.spec.depthwise
        if prep.quantized:
            from repro.api import tuning
            cfg = plan.config or tuning.DEFAULT_FUSED
            bits = plan.spec.quant.bits_act
            if cfg.datapath == "staged":
                faults.maybe_fault(faults.APPLY_STAGED, detail=plan)
                if depthwise:
                    y = ops.quantized_fastconv2d_depthwise(
                        x, prep.wq, prep.act_scale, prep.w_scale, algo,
                        padding=plan.spec.padding, bits=bits,
                        interpret=plan.interpret,
                        tile_block=cfg.tile_block,
                        chan_block=cfg.chan_block)
                else:
                    y = ops.quantized_fastconv2d(
                        x, prep.wq, prep.act_scale, prep.w_scale, algo,
                        padding=plan.spec.padding, bits=bits,
                        interpret=plan.interpret, k_block=cfg.k_block,
                        tile_block=cfg.tile_block, chan_block=cfg.chan_block)
                y = faults.maybe_corrupt(faults.APPLY_STAGED, y,
                                         detail=plan)
            else:
                from repro.kernels.sfc_fused import sfc_fused_conv2d
                faults.maybe_fault(faults.APPLY_FUSED, detail=plan)
                y = sfc_fused_conv2d(
                    x, prep.wq, prep.act_scale, prep.w_scale, algo,
                    padding=plan.spec.padding, bits=bits,
                    interpret=plan.interpret, depthwise=depthwise,
                    k_block=cfg.k_block, cout_block=cfg.cout_block,
                    rows_per_step=cfg.rows_per_step,
                    double_buffer=cfg.double_buffer)
                y = faults.maybe_corrupt(faults.APPLY_FUSED, y,
                                         detail=plan)
            return _add_bias(y, bias)
        from repro.kernels.sfc_inverse import sfc_inverse
        from repro.kernels.sfc_transform import sfc_transform
        bt, _, at = c2d.transform_matrices(algo, x.dtype.name)
        tiles, geom = ops.extract_tiles(x, algo, plan.spec.padding)
        tx = sfc_transform(tiles, bt, interpret=plan.interpret)
        if depthwise:
            # transform-domain elementwise stage (tw (t, t, 1, C))
            ty = tx * prep.tw[None, :, :, 0, :].astype(x.dtype)
        else:
            ty = jnp.einsum("ntuc,tuco->ntuo", tx, prep.tw.astype(x.dtype))
        y_tiles = sfc_inverse(ty, at, interpret=plan.interpret)
        return _add_bias(ops.untile(y_tiles, algo, geom), bias)


_REFERENCE = ReferenceBackend()
_BACKENDS: Dict[str, object] = {
    "reference": _REFERENCE,
    "pallas": PallasBackend(),
}


_SPMD_IMPORT_ERROR: Optional[ImportError] = None


def _register_spmd() -> None:
    # conv_spmd keeps its repro.api imports lazy (either side may load
    # first); mesh resolution stays lazy too — importing repro.api must
    # not touch jax device state.  When THIS import lands inside
    # conv_spmd's own import chain (e.g. `import repro.distributed` ->
    # sharding -> configs -> CNNConfig validation -> repro.api), the
    # module is only partially initialized — skip now and let
    # get_backend/list_backends finish the registration on first lookup,
    # by which point the cycle has resolved.  The exception is kept so a
    # GENUINE import failure (not the cycle) still surfaces: the lazy
    # retry fails again and get_backend chains it into its KeyError.
    global _SPMD_IMPORT_ERROR
    try:
        from repro.distributed.conv_spmd import SpmdPallasBackend
    except ImportError as e:
        _SPMD_IMPORT_ERROR = e
        return
    _SPMD_IMPORT_ERROR = None
    _BACKENDS.setdefault("pallas_spmd", SpmdPallasBackend())


_register_spmd()


def register_backend(name: str, backend, overwrite: bool = False) -> None:
    """Add (or with ``overwrite``, replace) an execution backend.

    Registration invalidates memoized plans: a ``ConvPlan`` records only
    the backend *name*, but its kernel config and prepared-weight cache
    were resolved against whatever object held that name at planning time
    (an overwritten backend may shard or place weights differently).
    """
    if name in _BACKENDS and not overwrite:
        raise ValueError(f"backend {name!r} already registered")
    _BACKENDS[name] = backend
    from repro.api import planner       # late: avoids import cycle
    planner.invalidate_plan_cache()


def get_backend(name: str):
    if name not in _BACKENDS and name == "pallas_spmd":
        _register_spmd()               # deferred past an import cycle
        if name not in _BACKENDS:
            # not the cycle: a real import failure — keep its traceback
            raise KeyError(
                "backend 'pallas_spmd' failed to register; see the "
                "chained ImportError") from _SPMD_IMPORT_ERROR
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; "
                       f"registered: {sorted(_BACKENDS)}") from None


def list_backends():
    if "pallas_spmd" not in _BACKENDS:
        _register_spmd()
    return tuple(sorted(_BACKENDS))
