"""Model registry: config -> (init, loss, forward, cache, decode) bundle."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import decode as decode_mod
from repro.models import transformer as tfm
from repro.models.layers import Params, dtype_of


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    def init(self, key) -> Params:
        return tfm.init_lm(key, self.cfg)

    def init_abstract(self) -> Params:
        """Parameter ShapeDtypeStructs — no allocation (dry-run path)."""
        return jax.eval_shape(
            lambda: tfm.init_lm(jax.random.PRNGKey(0), self.cfg))

    def loss(self, params: Params, batch: Dict[str, jnp.ndarray]):
        return tfm.lm_loss(params, self.cfg, batch)

    def forward(self, params: Params, tokens: jnp.ndarray,
                memory: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        if self.cfg.family == "encdec":
            memory = tfm.encoder_forward(params, self.cfg, memory)
        hidden, _ = tfm.lm_hidden(params, self.cfg, tokens, memory)
        return tfm.lm_logits(params, self.cfg, hidden)

    def init_cache(self, params: Params, batch: int, max_len: int,
                   memory: Optional[jnp.ndarray] = None) -> Params:
        return decode_mod.init_cache(params, self.cfg, batch, max_len,
                                     memory)

    def decode_step(self, params: Params, cache: Params,
                    tokens: jnp.ndarray, pos: jnp.ndarray):
        return decode_mod.decode_step(params, self.cfg, cache, tokens, pos)

    # ------------------------------------------------------------------
    def batch_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for one input-shape cell (training /
        prefill inputs; decode uses ``decode_specs``)."""
        B, S = shape.global_batch, shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        d = dtype_of(self.cfg.compute_dtype)
        if self.cfg.family == "vlm":
            specs["vision"] = jax.ShapeDtypeStruct(
                (B, self.cfg.n_vision_tokens, self.cfg.d_model), d)
        if self.cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, S * self.cfg.encoder_seq_ratio, self.cfg.d_model), d)
        return specs

    def decode_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        B = shape.global_batch
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        }

    def cache_abstract(self, shape: ShapeConfig) -> Params:
        """Abstract cache for lowering serve_step at a given context len."""
        B, S = shape.global_batch, shape.seq_len
        cfg = self.cfg
        d = dtype_of(cfg.compute_dtype)
        memory = None
        if cfg.family == "vlm":
            memory = jax.ShapeDtypeStruct((B, cfg.n_vision_tokens,
                                           cfg.d_model), d)
        elif cfg.family == "encdec":
            memory = jax.ShapeDtypeStruct(
                (B, S * cfg.encoder_seq_ratio, cfg.d_model), d)
        params = self.init_abstract()
        return jax.eval_shape(
            lambda p, m: decode_mod.init_cache(p, cfg, B, S, m),
            params, memory)


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
