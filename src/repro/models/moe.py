"""Mixture-of-Experts FFN with sort-based grouped dispatch (EP-shardable).

Tokens are routed top-k, sorted by expert, packed into a capacity-bounded
grouped tensor (E, C, d) and processed with a single grouped einsum — the
layout GSPMD shards cleanly: E over the 'model' axis (expert parallelism)
and the token batch over 'data'.  Over-capacity tokens are dropped (GShard
semantics); the router aux loss balances load so drops stay rare.

For small expert counts that do not divide the model axis (mixtral: 8
experts on a 16-way axis) the expert weights are instead sharded on their
d_ff dimension (TP-within-expert) — the sharding rule, not this module,
decides (see repro/distributed/sharding.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import Params


def init_moe(key, cfg, dtype) -> Params:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    import numpy as np
    std = 1.0 / np.sqrt(d)
    p = {
        "router": layers.dense_init(ks[0], d, E, dtype, scale=0.1),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * std
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * std
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                   / np.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_swiglu(
            ks[4], d, f * cfg.n_shared_experts, dtype)
    return p


def _group_local(xt, expert_ids, gate_vals, E, k, C):
    """Shard-local grouping: xt (T, d) -> grouped (E, C, d) + indices."""
    T = xt.shape[0]
    flat_expert = expert_ids.reshape(T * k)
    flat_token = jnp.repeat(jnp.arange(T), k)
    flat_gate = gate_vals.reshape(T * k)
    order = jnp.argsort(flat_expert)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    group_start = jnp.searchsorted(se, jnp.arange(E))
    slot = jnp.arange(T * k) - group_start[se]
    keep = slot < C
    safe_slot = jnp.where(keep, slot, C - 1)
    grouped = jnp.zeros((E, C, xt.shape[1]), xt.dtype)
    grouped = grouped.at[se, safe_slot].add(
        jnp.where(keep[:, None], xt[st], 0))
    return grouped, (se, st, sg, keep, safe_slot)


def _combine_local(y_grouped, idx, T, d, dtype):
    se, st, sg, keep, safe_slot = idx
    contrib = (y_grouped[se, safe_slot]
               * sg[:, None].astype(dtype)
               * keep[:, None].astype(dtype))
    return jnp.zeros((T, d), dtype).at[st].add(contrib)


def moe_block(p: Params, cfg, x: jnp.ndarray,
              capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Dispatch is **data-shard-local** (§Perf hillclimb 2): tokens reshape to
    (data_shards, T_local) and grouping/sort/scatter are vmapped per shard,
    so under GSPMD they stay on-shard; only the expert matmul crosses the
    model axis (the canonical EP all-to-all).  Global-semantics grouping
    lowered to per-layer (T, d) all-reduces + a global sort (~5 TB/step at
    deepseek-v3 train_4k scale — EXPERIMENTS.md §Perf).
    """
    from repro.distributed import act_sharding as acts
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.n_experts_active
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch/GShard form)
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_prob)

    ds = acts.data_shards()
    ds = ds if T % ds == 0 else 1
    Tl = T // ds
    C = max(int(Tl * k / E * capacity_factor), 1)

    xt_s = acts.constrain_batch(xt.reshape(ds, Tl, d))
    eid_s = acts.constrain_batch(expert_ids.reshape(ds, Tl, k))
    gv_s = acts.constrain_batch(gate_vals.reshape(ds, Tl, k))

    grouped, idx = jax.vmap(
        lambda xx, ee, gg: _group_local(xx, ee, gg, E, k, C))(
            xt_s, eid_s, gv_s)                    # (ds, E, C, d)
    grouped = acts.constrain(grouped, P("data", "model", None, None))

    h_gate = jnp.einsum("secd,edf->secf", grouped, p["w_gate"])
    h_up = jnp.einsum("secd,edf->secf", grouped, p["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    y_grouped = jnp.einsum("secf,efd->secd", h, p["w_down"])
    y_grouped = acts.constrain(y_grouped, P("data", "model", None, None))

    out = jax.vmap(
        lambda yy, i0, i1, i2, i3, i4: _combine_local(
            yy, (i0, i1, i2, i3, i4), Tl, d, xt.dtype))(
                y_grouped, *idx)                  # (ds, Tl, d)
    out = acts.constrain_batch(out).reshape(T, d)

    if cfg.n_shared_experts:
        out = out + layers.swiglu(xt, **p["shared"])
    return out.reshape(B, S, d), aux
