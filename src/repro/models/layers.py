"""Shared NN building blocks (pure-functional, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; layer stacks carry a leading
    ``n_layers`` axis and run under ``jax.lax.scan`` (small HLO, fast
    compile, remat-friendly);
  * ``init_*`` functions return parameter pytrees; for the dry-run they are
    only ever called under ``jax.eval_shape`` (no allocation);
  * compute runs in ``compute_dtype`` (bf16 by default), params are stored
    in ``param_dtype``; logits/losses in f32.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0
               ) -> jnp.ndarray:
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5
            ) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


def init_rmsnorm(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def init_swiglu(key, d: int, f: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d, f, dtype),
            "w_up": dense_init(k2, d, f, dtype),
            "w_down": dense_init(k3, f, d, dtype)}


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S)."""
    D = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(D, theta), jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,D/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """logits (..., V) f32; labels (...,) int32; mean over valid tokens."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
