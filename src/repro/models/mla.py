"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries and keys/values are projected through low-rank latents; the KV cache
stores only the compressed latent c_kv (kv_lora_rank=512) plus the decoupled
RoPE key (64) — 576 floats/token instead of 2*128*128 = 32768 for MHA.

* prefill/train: online-softmax scan over latent chunks, expanding each
  chunk's K_nope/V from c_kv *inside* the scan — the full (B,S,H,128+128)
  expansion (13 GB/device at 32k prefill) is never materialized.
* decode: **absorbed form** — W_UK folds into the query (q_eff = q W_UK) and
  W_UV into the output, so attention runs directly against the latent cache.
  Per-step cost O(B*H*(kr+dr)*L) with no cache expansion at all.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.layers import Params
from repro.models.attention import NEG_INF


def init_mla(key, cfg, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": layers.dense_init(ks[0], d, qr, dtype),
        "q_a_norm": layers.init_rmsnorm(qr, dtype),
        "wq_b": layers.dense_init(ks[1], qr, H * (dn + dr), dtype),
        "wkv_a": layers.dense_init(ks[2], d, kr + dr, dtype),
        "kv_a_norm": layers.init_rmsnorm(kr, dtype),
        "wkv_b": layers.dense_init(ks[3], kr, H * (dn + dv), dtype),
        "wo": layers.dense_init(ks[4], H * dv, d, dtype),
    }


def _mla_q(p: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray):
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_lat = layers.rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]),
                           p["q_a_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rh->bsh", q_lat, p["wq_b"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray):
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = layers.rmsnorm(kv_a[..., :cfg.kv_lora_rank], p["kv_a_norm"],
                          cfg.norm_eps)
    k_rope = layers.apply_rope(
        kv_a[..., cfg.kv_lora_rank:][:, :, None, :], positions,
        cfg.rope_theta)[:, :, 0, :]                   # (B, S, dr)
    return c_kv, k_rope


def _split_wkv_b(p: Params, cfg):
    H = cfg.n_heads
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    w = p["wkv_b"].reshape(cfg.kv_lora_rank, H, dn + dv)
    return w[..., :dn], w[..., dn:]                  # (kr,H,dn), (kr,H,dv)


def _expand_kv(p: Params, cfg, c_kv: jnp.ndarray):
    """Latent -> per-head K_nope / V (transient; recomputed under remat)."""
    w_uk, w_uv = _split_wkv_b(p, cfg)
    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, w_uk)
    v = jnp.einsum("bsr,rhd->bshd", c_kv, w_uv)
    return k_nope, v


def mla_block(p: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray
              ) -> jnp.ndarray:
    """Causal MLA (training / prefill).

    K/V are expanded from the latent transiently per layer (under remat the
    expansion is recomputed, never stored) and fed through the shared
    flash-attention custom-VJP kernel — one memory-lean attention path for
    every architecture (§Perf iteration 2).
    """
    from repro.models.attention import flash_attention
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_kv_latent(p, cfg, x, positions)
    k_nope, v = _expand_kv(p, cfg, c_kv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))],
        axis=-1)
    o = flash_attention(q[:, :, :, None, :], k, v, causal=True)
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), p["wo"])


def init_mla_cache(cfg, batch: int, max_len: int, dtype) -> Params:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(p: Params, cfg, x: jnp.ndarray, cache: Params,
               pos: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """Absorbed one-token MLA decode against the latent cache."""
    B = x.shape[0]
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, pos[:, None])      # (B,1,H,*)
    c_new, r_new = _mla_kv_latent(p, cfg, x, pos[:, None])
    c_cache = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
        c, u, (s, 0)))(cache["c_kv"], c_new, pos)
    r_cache = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
        c, u, (s, 0)))(cache["k_rope"], r_new, pos)

    w_uk, w_uv = _split_wkv_b(p, cfg)
    # absorb W_UK into the query:  q_eff (B, H, kr)
    q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    L = c_cache.shape[1]
    s = (jnp.einsum("bhr,bkr->bhk", q_eff, c_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhd,bkd->bhk", q_rope[:, 0], r_cache,
                      preferred_element_type=jnp.float32)) / np.sqrt(dn + dr)
    valid = jnp.arange(L)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    attn = jax.nn.softmax(s, axis=-1)
    # attend in latent space, then absorb W_UV on the way out
    o_lat = jnp.einsum("bhk,bkr->bhr", attn.astype(c_cache.dtype), c_cache)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv)           # (B, H, dv)
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, H * dv), p["wo"])
    return out, {"c_kv": c_cache, "k_rope": r_cache}
