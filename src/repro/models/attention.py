"""Attention: GQA with flash-style chunked softmax, sliding window, KV cache.

Prefill/training uses an online-softmax scan over KV chunks so the (S x S)
score matrix is never materialized — required to compile the 32k-prefill
and 4k-train cells at production batch sizes (see DESIGN.md §5).

Decode attends one query against the cache (optionally a ring buffer for
sliding-window archs, giving O(window) memory at 500k contexts).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.layers import Params

NEG_INF = -1e30


def padded_heads(cfg, n: int, pad_to: int = 16) -> int:
    """Physical head-count padding so the head dim always divides a 16-way
    model axis.  jit in_shardings demand exact divisibility (GSPMD padding
    only applies to internal ops — §Perf iterations 3/4 showed a dropped
    axis silently replicates attention), so we pad the *parameters*: dead
    heads start at zero, receive zero signal through the zero wo rows, and
    cost Hq_pad/Hq extra attention flops (48/40 = 20% for qwen2.5).
    """
    if n % pad_to == 0 or n < pad_to:
        return n
    return -(-n // pad_to) * pad_to


def init_attention(key, cfg, dtype) -> Params:
    """Per-head QKV layout: wq (d, Hq_pad, hd), wk/wv (d, Hkv, hd), wo
    (Hq_pad, hd, d).

    TP plan (§Perf iteration 4): padded q heads shard exactly over the
    model axis; **K/V are replicated over the model axis** (g-times smaller
    than Q) and expanded to per-q-head copies locally, so every attention
    einsum is shard-aligned — no resharding collectives (iteration-2
    bottleneck) and no 2x kv-slot padding (iteration-3 regression).
    """
    d = cfg.d_model
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hq_p = padded_heads(cfg, hq)
    ks = jax.random.split(key, 4)

    def heads(key, n, n_pad):
        w = layers.dense_init(key, d, n * hd, dtype).reshape(d, n, hd)
        if n_pad > n:
            w = jnp.concatenate(
                [w, jnp.zeros((d, n_pad - n, hd), dtype)], axis=1)
        return w

    wo = layers.dense_init(ks[3], hq * hd, d, dtype).reshape(hq, hd, d)
    if hq_p > hq:
        wo = jnp.concatenate(
            [wo, jnp.zeros((hq_p - hq, hd, d), dtype)], axis=0)
    p = {
        "wq": heads(ks[0], hq, hq_p),
        "wk": heads(ks[1], hkv, hkv),
        "wv": heads(ks[2], hkv, hkv),
        "wo": wo,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq_p, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(hd, dtype)
        p["k_norm"] = layers.init_rmsnorm(hd, dtype)
    return p


def _project_qkv(p: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray,
                 rope: bool = True):
    """Returns q (B,S,Hq_pad,hd), k (B,S,Hkv,hd), v (B,S,Hkv,hd)."""
    q = jnp.einsum("bsd,dkh->bskh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = layers.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = layers.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def expand_kv_padded(k: jnp.ndarray, cfg) -> jnp.ndarray:
    """(B,S,Hkv,hd) -> (B,S,Hq_pad,hd): per-q-head KV copies (local; the
    source is model-axis-replicated; transient under remat).  Padded head
    slots reuse kv head 0 (their scores are discarded by the zero wo)."""
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    hq_p = padded_heads(cfg, hq)
    g = hq // hkv
    out = jnp.repeat(k, g, axis=2) if g > 1 else k
    if hq_p > hq:
        pad = jnp.broadcast_to(out[:, :, :1, :],
                               out.shape[:2] + (hq_p - hq, out.shape[-1]))
        out = jnp.concatenate([out, pad], axis=2)
    return out


def attention_output(p: Params, cfg, o: jnp.ndarray) -> jnp.ndarray:
    """o (B,S,n,1,hd) or (B,S,n,hd) -> (B,S,d); n may be Hq or Hq_pad
    (wo rows are sliced to match; padded rows are zero anyway)."""
    B, S = o.shape[:2]
    o = o.reshape(B, S, -1, cfg.head_dim)
    return jnp.einsum("bskh,khd->bsd", o, p["wo"][:o.shape[2]])


def _chunk_mask(Sq, Sk, chunk, cidx, causal, window, q_offset):
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = cidx * chunk + jnp.arange(chunk)
    mask = (k_pos[None, :] <= q_pos[:, None]) if causal else \
        jnp.ones((Sq, chunk), bool)
    if window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    return mask & (k_pos < Sk)[None, :]


def _flash_fwd_scan(qg, kc_t, vc_t, Sq, Sk, chunk, causal, window,
                    q_offset, scale):
    from repro.distributed import act_sharding as acts
    B, _, Hkv, groups, D = qg.shape
    Dv = vc_t.shape[-1]
    n_chunks = kc_t.shape[0]

    def step(carry, inputs):
        m, l, acc = carry
        kb, vb, cidx = inputs
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = _chunk_mask(Sq, Sk, chunk, cidx, causal, window, q_offset)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = acts.constrain_batch(
        jnp.full((B, Sq, Hkv, groups), NEG_INF, jnp.float32))
    l0 = acts.constrain_batch(
        jnp.zeros((B, Sq, Hkv, groups), jnp.float32))
    a0 = acts.constrain_batch(
        jnp.zeros((B, Sq, Hkv, groups, Dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc_t, vc_t, jnp.arange(n_chunks)))
    return m, l, acc


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_core(q, k, v, causal, window, q_offset, chunk):
    out, _ = _flash_core_fwd(q, k, v, causal, window, q_offset, chunk)
    return out


def _flash_prep(qg, k, v, chunk):
    from repro.distributed import act_sharding as acts
    B, Sq, Hkv, groups, D = qg.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc_t = acts.constrain_batch(jnp.moveaxis(
        kp.reshape(B, n_chunks, chunk, Hkv, D), 1, 0), 1)
    vc_t = acts.constrain_batch(jnp.moveaxis(
        vp.reshape(B, n_chunks, chunk, Hkv, Dv), 1, 0), 1)
    return qg, kc_t, vc_t, chunk, n_chunks, pad


def _flash_core_fwd(q, k, v, causal, window, q_offset, chunk):
    B, Sq, Hkv, groups, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(D)
    qg, kc_t, vc_t, chunk_, n_chunks, _ = _flash_prep(q, k, v, chunk)
    m, l, acc = _flash_fwd_scan(qg, kc_t, vc_t, Sq, Sk, chunk_, causal,
                                window, q_offset, scale)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    res = (q, k, v, m, l, out)
    return out.astype(q.dtype), res


def _flash_core_bwd(causal, window, q_offset, chunk, res, dout):
    """Flash backward: recompute scores chunk-wise — the full (Sq x Sk)
    probability tensor is never materialized nor saved (§Perf iteration 2:
    the naive scan backward stacked ~5.4 GB of per-chunk residuals per
    layer at qwen2.5 train_4k scale)."""
    q, k, v, m, l, out = res
    B, Sq, Hkv, groups, D = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    scale = 1.0 / np.sqrt(D)
    qg, kc_t, vc_t, chunk_, n_chunks, pad = _flash_prep(q, k, v, chunk)
    dout_g = dout.astype(jnp.float32)
    # D_i = sum_d dout_i * out_i (the softmax-normalization term)
    delta = jnp.sum(dout_g * out, axis=-1)                 # (B,Sq,Hkv,g)
    l_safe = jnp.maximum(l, 1e-30)

    def step(dq_acc, inputs):
        kb, vb, cidx = inputs
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = _chunk_mask(Sq, Sk, chunk_, cidx, causal, window, q_offset)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / l_safe[..., None]  # (B,q,h,g,k)
        dv_c = jnp.einsum("bqhgk,bqhgd->bkhd", p, dout_g)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", dout_g, vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bqhgk,bkhd->bqhgd", ds,
                                     kb.astype(jnp.float32))
        dk_c = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qg.astype(jnp.float32))
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros(qg.shape, jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        step, dq0, (kc_t, vc_t, jnp.arange(n_chunks)))
    dk = jnp.moveaxis(dk_c, 0, 1).reshape(B, n_chunks * chunk_, Hkv, D)
    dv = jnp.moveaxis(dv_c, 0, 1).reshape(B, n_chunks * chunk_, Hkv, Dv)
    if pad:
        dk, dv = dk[:, :Sk], dv[:, :Sk]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention(qg: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    q_offset: int = 0, chunk: int = 512) -> jnp.ndarray:
    """Online-softmax attention with a memory-lean custom VJP.

    qg: (B, Sq, Hkv, g, D) grouped queries; k/v: (B, Sk, Hkv, Dv); MLA
    passes Dv != D (g=1).  ``window > 0`` restricts attention to the last
    ``window`` keys (Mixtral sliding-window).  ``q_offset`` is the absolute
    position of q[0] relative to k[0].  Returns (B, Sq, Hkv, g, Dv).
    """
    return _flash_core(qg, k, v, causal, window, q_offset, chunk)


def attention_block(p: Params, cfg, x: jnp.ndarray,
                    positions: jnp.ndarray) -> jnp.ndarray:
    """Training/prefill self-attention (causal)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    o = flash_attention(q[:, :, :, None, :], expand_kv_padded(k, cfg),
                        expand_kv_padded(v, cfg), causal=True,
                        window=cfg.sliding_window)
    return attention_output(p, cfg, o)


# --------------------------------------------------------------------------
# KV cache (decode)
# --------------------------------------------------------------------------
def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> Params:
    """Ring buffer when sliding-window, else full-length cache."""
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p: Params, cfg, x: jnp.ndarray, cache: Params,
                     pos: jnp.ndarray) -> Tuple[jnp.ndarray, Params]:
    """One-token decode: x (B, 1, d), pos (B,) absolute positions."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x, pos[:, None])
    length = cache["k"].shape[1]
    slot = (pos % length) if cfg.sliding_window else pos
    k_cache = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
        c, u, (s, 0, 0)))(cache["k"], k, slot)
    v_cache = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
        c, u, (s, 0, 0)))(cache["v"], v, slot)
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    groups = hq // hkv
    qg = q[:, :, :hq, :].reshape(B, 1, hkv, groups, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    idx = jnp.arange(length)[None, :]
    if cfg.sliding_window:
        # ring buffer: once pos >= length every slot holds a key from the
        # window; before that only slots [0, pos] have been written.
        valid = (idx <= (pos % length)[:, None]) | (pos[:, None] >= length)
    else:
        valid = idx <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", pattn.astype(v_cache.dtype), v_cache)
    out = attention_output(p, cfg, o[:, None])
    return out, {"k": k_cache, "v": v_cache}


# --------------------------------------------------------------------------
# Cross-attention (whisper decoder, llama-vision gated layers)
# --------------------------------------------------------------------------
def init_cross_attention(key, cfg, dtype) -> Params:
    p = init_attention(key, cfg, dtype)
    p["gate"] = jnp.zeros((), dtype)   # llama-vision gated cross-attn
    return p


def cross_attention_block(p: Params, cfg, x: jnp.ndarray,
                          memory: jnp.ndarray, gated: bool = False
                          ) -> jnp.ndarray:
    """x (B,S,d) attends to memory (B,Sm,d); no RoPE, not causal."""
    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hkv
    q = jnp.einsum("bsd,dkh->bskh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", memory, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", memory, p["wv"])
    if cfg.qk_norm:
        q = layers.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = layers.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    o = flash_attention(q[:, :, :, None, :], expand_kv_padded(k, cfg),
                        expand_kv_padded(v, cfg), causal=False)
    out = attention_output(p, cfg, o)
    if gated:
        out = jnp.tanh(p["gate"]) * out
    return out
