"""CNNs from the paper's evaluation (ResNet-18 family, VGG-16 family).

Every convolution routes through the ``repro.api`` planner: 3x3 stride-1
layers run the selected fast algorithm (any registered name, or ``auto``)
with optional transform-domain fake quantization — exactly the
substitution the paper performs on TorchVision models (§6.1) — and the
stride-2 stage-transition convs and the stride-2 stem are *lowered* by
the planner onto polyphase stride-1 SFC sub-convs (``repro.api.lowering``),
so they reach the fast path end-to-end instead of silently degrading.
Only 1x1 projections (and shapes whose lowering the cost model rejects)
use the direct path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ConvSpec, plan
from repro.api import registry as algo_registry
from repro.configs.resnet18 import CNNConfig
from repro.core.generator import BilinearAlgorithm
import repro.quant.fake_quant as fq

Params = Dict[str, Any]


def conv_algo(name: str) -> Optional[BilinearAlgorithm]:
    """Deprecated shim: resolve via the public ``repro.api`` registry."""
    return algo_registry.get_algorithm(name)


def quant_config(cfg: CNNConfig) -> fq.QuantConfig:
    if cfg.quant == "none":
        return fq.FP32
    bits = int(cfg.quant[3:])
    return fq.QuantConfig(bits, bits, cfg.act_granularity,
                          cfg.weight_granularity)


def conv_apply(x, w, b, cfg: CNNConfig, stride: int = 1,
               qhook=None) -> jnp.ndarray:
    """Algorithm-dispatched conv through the unified ``repro.api`` planner.

    Stride-2 convs lower onto polyphase stride-1 sub-convs (path
    'lowered'); 1x1 / tap-mismatched / lowering-rejected convs degrade to
    direct.  Quantization stays hook-driven (dynamic fake quant for
    training and PTQ simulation), so the spec itself is fp; on lowered
    plans the hook reaches each sub-conv's transform domain.
    """
    spec = ConvSpec.for_conv2d(x.shape, w.shape, stride=stride,
                               padding="SAME")
    p = plan(spec, backend="reference", algo=cfg.conv_algo)
    hook = qhook if p.path != "direct" else None
    return p.apply(x, w, bias=b, elementwise_hook=hook)


def _conv_init(key, r, cin, cout):
    fan = r * r * cin
    return (jax.random.normal(key, (r, r, cin, cout)) *
            np.sqrt(2.0 / fan)).astype(jnp.float32)


def _norm_apply(x, scale, bias):
    # BatchNorm folded into scale/bias (the paper fuses BN before quant);
    # training uses this as a per-channel affine "filter response norm" lite.
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


# --------------------------------------------------------------------------
# ResNet
# --------------------------------------------------------------------------
def init_resnet(key, cfg: CNNConfig) -> Params:
    ks = iter(jax.random.split(key, 256))
    p: Params = {}
    w0 = cfg.widths[0]
    p["stem"] = {"w": _conv_init(next(ks), cfg.stem_kernel, 3, w0),
                 "b": jnp.zeros((w0,)),
                 "scale": jnp.ones((w0,)), "bias": jnp.zeros((w0,))}
    cin = w0
    for si, (n_blocks, width) in enumerate(zip(cfg.stages, cfg.widths)):
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            blk = {
                "conv1": {"w": _conv_init(next(ks), 3, cin, width),
                          "b": jnp.zeros((width,))},
                "conv2": {"w": _conv_init(next(ks), 3, width, width),
                          "b": jnp.zeros((width,))},
                "scale1": jnp.ones((width,)), "bias1": jnp.zeros((width,)),
                "scale2": jnp.ones((width,)), "bias2": jnp.zeros((width,)),
            }
            if stride != 1 or cin != width:
                blk["proj"] = {"w": _conv_init(next(ks), 1, cin, width),
                               "b": jnp.zeros((width,))}
            p[f"s{si}b{bi}"] = blk
            cin = width
    p["head"] = {"w": (jax.random.normal(next(ks), (cin, cfg.n_classes))
                       * 0.01).astype(jnp.float32),
                 "b": jnp.zeros((cfg.n_classes,))}
    return p


def resnet_forward(p: Params, cfg: CNNConfig, x: jnp.ndarray,
                   qhooks: Optional[Dict[str, Any]] = None) -> jnp.ndarray:
    """x (B, H, W, 3) -> logits.  qhooks maps layer name -> elementwise hook
    (None = use the config-default quantizer)."""
    default_hook = quant_config(cfg).hook()

    def hook_for(name):
        if qhooks is not None and name in qhooks:
            return qhooks[name]
        return default_hook

    stem_stride = 2 if cfg.image_size >= 128 else 1
    h = conv_apply(x, p["stem"]["w"], p["stem"]["b"], cfg,
                   stride=stem_stride, qhook=None)
    h = jax.nn.relu(_norm_apply(h, p["stem"]["scale"], p["stem"]["bias"]))
    if cfg.image_size >= 128:
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                  (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
    cin = cfg.widths[0]
    for si, (n_blocks, width) in enumerate(zip(cfg.stages, cfg.widths)):
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            blk = p[f"s{si}b{bi}"]
            name = f"s{si}b{bi}"
            y = conv_apply(h, blk["conv1"]["w"], blk["conv1"]["b"], cfg,
                           stride=stride, qhook=hook_for(name + ".conv1"))
            y = jax.nn.relu(_norm_apply(y, blk["scale1"], blk["bias1"]))
            y = conv_apply(y, blk["conv2"]["w"], blk["conv2"]["b"], cfg,
                           stride=1, qhook=hook_for(name + ".conv2"))
            y = _norm_apply(y, blk["scale2"], blk["bias2"])
            sc = h
            if "proj" in blk:
                sc = conv_apply(h, blk["proj"]["w"], blk["proj"]["b"], cfg,
                                stride=stride)
            h = jax.nn.relu(y + sc)
    h = jnp.mean(h, axis=(1, 2))
    return jnp.einsum("bd,dc->bc", h, p["head"]["w"]) + p["head"]["b"]


# --------------------------------------------------------------------------
# VGG
# --------------------------------------------------------------------------
def init_vgg(key, cfg: CNNConfig) -> Params:
    ks = iter(jax.random.split(key, 64))
    p: Params = {}
    cin = 3
    for si, (n_convs, width) in enumerate(zip(cfg.stages, cfg.widths)):
        for ci in range(n_convs):
            p[f"s{si}c{ci}"] = {"w": _conv_init(next(ks), 3, cin, width),
                                "b": jnp.zeros((width,))}
            cin = width
    p["head"] = {"w": (jax.random.normal(next(ks), (cin, cfg.n_classes))
                       * 0.01).astype(jnp.float32),
                 "b": jnp.zeros((cfg.n_classes,))}
    return p


def vgg_forward(p: Params, cfg: CNNConfig, x: jnp.ndarray) -> jnp.ndarray:
    hook = quant_config(cfg).hook()
    h = x
    for si, (n_convs, width) in enumerate(zip(cfg.stages, cfg.widths)):
        for ci in range(n_convs):
            blk = p[f"s{si}c{ci}"]
            h = jax.nn.relu(conv_apply(h, blk["w"], blk["b"], cfg,
                                       stride=1, qhook=hook))
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "SAME")
    h = jnp.mean(h, axis=(1, 2))
    return jnp.einsum("bd,dc->bc", h, p["head"]["w"]) + p["head"]["b"]


def cnn_loss(p: Params, cfg: CNNConfig, batch) -> Tuple[jnp.ndarray, Dict]:
    fwd = vgg_forward if cfg.kind == "vgg" else resnet_forward
    logits = fwd(p, cfg, batch["images"])
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}
