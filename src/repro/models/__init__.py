"""Model zoo: assigned LM architectures + the paper's CNNs."""
from repro.models.registry import Model, build
from repro.models import (attention, cnn, decode, layers, mla, moe, ssm,
                          transformer)

__all__ = ["Model", "build", "attention", "cnn", "decode", "layers", "mla",
           "moe", "ssm", "transformer"]
