"""Unified decoder-LM assembly for all assigned architecture families.

Families map to block stacks that run under ``jax.lax.scan`` over stacked
layer parameters (small HLO, fast multi-pod compiles):

  dense   : [norm->attn->res ; norm->swiglu->res] x L
  moe     : same, FFN = grouped-dispatch MoE (+ optional first-k dense
            layers and MLA attention for deepseek-v3, + MTP head)
  ssm     : [norm->mamba2->res] x L
  hybrid  : ssm stack with one weight-shared attention block invoked every
            ``shared_attn_every`` layers (zamba2)
  vlm     : groups of [gated cross-attn block ; k self-attn blocks]
  encdec  : bidirectional encoder stack + causal decoder w/ cross-attention

Each family exposes: init / loss (train) / forward (prefill logits) /
init_cache / decode_step — the launch layer jits these per (arch x shape).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed import act_sharding as acts
from repro.models import attention as attn
from repro.models import layers, mla, moe, ssm
from repro.models.layers import Params, dtype_of


# --------------------------------------------------------------------------
# block init/apply
# --------------------------------------------------------------------------
def init_decoder_block(key, cfg: ModelConfig, dtype, use_moe: bool) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": layers.init_rmsnorm(cfg.d_model, dtype),
        "ln2": layers.init_rmsnorm(cfg.d_model, dtype),
    }
    p["attn"] = (mla.init_mla(k1, cfg, dtype) if cfg.use_mla
                 else attn.init_attention(k1, cfg, dtype))
    p["ffn"] = (moe.init_moe(k2, cfg, dtype) if use_moe
                else layers.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype))
    return p


def decoder_block(p: Params, cfg: ModelConfig, x, positions, use_moe: bool):
    # batch pinned at every sub-block boundary: forces GSPMD to all-gather
    # the FSDP-sharded weights instead of replicating the batch
    # (EXPERIMENTS.md §Perf iteration 2)
    h = acts.constrain_batch(layers.rmsnorm(x, p["ln1"], cfg.norm_eps))
    if cfg.use_mla:
        a = mla.mla_block(p["attn"], cfg, h, positions)
    else:
        a = attn.attention_block(p["attn"], cfg, h, positions)
    x = x + acts.constrain_batch(a)
    h = acts.constrain_batch(layers.rmsnorm(x, p["ln2"], cfg.norm_eps))
    if use_moe:
        f, aux = moe.moe_block(p["ffn"], cfg, h)
    else:
        f, aux = layers.swiglu(h, **p["ffn"]), jnp.zeros((), jnp.float32)
    return x + acts.constrain_batch(f), aux


def init_mamba_layer(key, cfg, dtype) -> Params:
    return {
        "ln": layers.init_rmsnorm(cfg.d_model, dtype),
        "mixer": ssm.init_mamba2(key, cfg, dtype),
    }


def mamba_layer(p: Params, cfg, x):
    h = acts.constrain_batch(layers.rmsnorm(x, p["ln"], cfg.norm_eps))
    return x + acts.constrain_batch(ssm.mamba2_block(p["mixer"], cfg, h))


# --------------------------------------------------------------------------
# parameter trees
# --------------------------------------------------------------------------
def _stack_init(fn, key, n: int):
    """Initialize n layers and stack leaves along a leading axis."""
    keys = jax.random.split(key, n)
    trees = [fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 12)
    V, d = cfg.padded_vocab, cfg.d_model
    p: Params = {
        "embed": layers.embed_init(ks[0], V, d, dtype),
        "final_norm": layers.init_rmsnorm(d, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(ks[1], d, V, dtype)

    fam = cfg.family
    if fam in ("dense", "moe"):
        n_moe = cfg.n_layers - cfg.first_dense_layers
        if cfg.first_dense_layers:
            p["dense_blocks"] = _stack_init(
                lambda k: init_decoder_block(k, cfg, dtype, use_moe=False),
                ks[2], cfg.first_dense_layers)
        p["blocks"] = _stack_init(
            lambda k: init_decoder_block(k, cfg, dtype,
                                         use_moe=(fam == "moe")),
            ks[3], n_moe)
        if cfg.mtp_depth:
            p["mtp"] = {
                "proj": layers.dense_init(ks[4], 2 * d, d, dtype),
                "block": init_decoder_block(ks[5], cfg, dtype,
                                            use_moe=(fam == "moe")),
                "norm": layers.init_rmsnorm(d, dtype),
            }
    elif fam == "ssm":
        p["blocks"] = _stack_init(
            lambda k: init_mamba_layer(k, cfg, dtype), ks[2], cfg.n_layers)
    elif fam == "hybrid":
        p["blocks"] = _stack_init(
            lambda k: init_mamba_layer(k, cfg, dtype), ks[2], cfg.n_layers)
        p["shared_block"] = init_decoder_block(ks[3], cfg, dtype,
                                               use_moe=False)
    elif fam == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        p["blocks"] = _stack_init(
            lambda k: init_decoder_block(k, cfg, dtype, use_moe=False),
            ks[2], cfg.n_layers)
        p["cross_blocks"] = _stack_init(
            lambda k: {
                "ln": layers.init_rmsnorm(d, dtype),
                "xattn": attn.init_cross_attention(k, cfg, dtype),
                "ln2": layers.init_rmsnorm(d, dtype),
                "ffn": layers.init_swiglu(
                    jax.random.fold_in(k, 1), d, cfg.d_ff, dtype),
                "ffn_gate": jnp.zeros((), dtype),
            }, ks[3], n_groups)
    elif fam == "encdec":
        p["enc_blocks"] = _stack_init(
            lambda k: init_decoder_block(k, cfg, dtype, use_moe=False),
            ks[2], cfg.encoder_layers)
        p["enc_norm"] = layers.init_rmsnorm(d, dtype)
        p["blocks"] = _stack_init(
            lambda k: init_decoder_block(k, cfg, dtype, use_moe=False),
            ks[3], cfg.n_layers)
        p["cross_blocks"] = _stack_init(
            lambda k: {
                "ln": layers.init_rmsnorm(d, dtype),
                "xattn": attn.init_attention(k, cfg, dtype),
            }, ks[4], cfg.n_layers)
    else:
        raise ValueError(fam)
    return p


# --------------------------------------------------------------------------
# forward (training / prefill)
# --------------------------------------------------------------------------
def _compute(x, cfg):
    return x.astype(dtype_of(cfg.compute_dtype))


def cast_compute(params: Params, cfg: ModelConfig) -> Params:
    """Cast floating params to the compute dtype (f32 masters stay in the
    optimizer; the cast is differentiable so grads flow back to masters)."""
    cd = dtype_of(cfg.compute_dtype)

    def cast(a):
        return a.astype(cd) if jnp.issubdtype(a.dtype, jnp.floating) else a
    return jax.tree_util.tree_map(cast, params)


def lm_hidden(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
              memory: Optional[jnp.ndarray] = None,
              remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token ids -> final hidden states. Returns (hidden, aux_loss)."""
    params = cast_compute(params, cfg)
    B, S = tokens.shape
    # constrain the raw gather: a vocab-sharded embedding lookup otherwise
    # materializes a full-batch (replicated) f32 output before resharding
    x = _compute(acts.constrain_batch(params["embed"][tokens]), cfg)
    x = acts.constrain_batch(x)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "moe"):
        if cfg.first_dense_layers:
            x, aux_total = _scan_blocks(
                params["dense_blocks"], cfg, x, positions, False, remat,
                aux_total)
        x, aux_total = _scan_blocks(params["blocks"], cfg, x, positions,
                                    fam == "moe", remat, aux_total)
    elif fam == "ssm":
        x = _scan_mamba(params["blocks"], cfg, x, None, remat)
    elif fam == "hybrid":
        x = _scan_mamba(params["blocks"], cfg, x, params["shared_block"],
                        remat, positions)
    elif fam == "vlm":
        assert memory is not None, "vlm needs vision embeddings"
        mem = _compute(memory, cfg)
        k = cfg.cross_attn_every
        n_groups = cfg.n_layers // k
        blocks = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, k) + a.shape[1:]),
            params["blocks"])

        def group_fn(x, inp):
            x = acts.constrain_batch(x)
            gblocks, cross = inp
            h = layers.rmsnorm(x, cross["ln"], cfg.norm_eps)
            x = x + attn.cross_attention_block(cross["xattn"], cfg, h, mem,
                                               gated=True)
            h = layers.rmsnorm(x, cross["ln2"], cfg.norm_eps)
            x = x + jnp.tanh(cross["ffn_gate"]) * layers.swiglu(
                h, **cross["ffn"])

            def inner(x, bp):
                x, _ = decoder_block(bp, cfg, x, positions, False)
                return x, None
            x, _ = jax.lax.scan(inner, x, gblocks)
            return x, None

        fn = jax.checkpoint(group_fn) if remat else group_fn
        x, _ = jax.lax.scan(fn, x, (blocks, params["cross_blocks"]))
    elif fam == "encdec":
        assert memory is not None, "encdec needs encoder output"

        def dec_fn(x, inp):
            x = acts.constrain_batch(x)
            bp, xp = inp
            h = layers.rmsnorm(x, xp["ln"], cfg.norm_eps)
            x = x + attn.cross_attention_block(xp["xattn"], cfg, h, memory)
            x, _ = decoder_block(bp, cfg, x, positions, False)
            return x, None

        fn = jax.checkpoint(dec_fn) if remat else dec_fn
        x, _ = jax.lax.scan(fn, x, (params["blocks"],
                                    params["cross_blocks"]))
    else:
        raise ValueError(fam)
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


import os
_REMAT_POLICY = os.environ.get("REPRO_REMAT_POLICY", "full")


def _checkpoint(fn):
    """Layer remat policy: 'full' recomputes everything (min memory);
    'dots' saves matmul outputs (no fwd recompute of GEMMs, more memory) —
    §Perf experiment, switchable per run via REPRO_REMAT_POLICY."""
    if _REMAT_POLICY == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _scan_blocks(blocks, cfg, x, positions, use_moe, remat, aux_total):
    def fn(x, bp):
        x = acts.constrain_batch(x)
        x, aux = decoder_block(bp, cfg, x, positions, use_moe)
        return x, aux
    fn = _checkpoint(fn) if remat else fn
    x, auxes = jax.lax.scan(fn, x, blocks)
    return x, aux_total + jnp.sum(auxes)


def _scan_mamba(blocks, cfg, x, shared_block, remat, positions=None):
    every = cfg.shared_attn_every

    def fn(carry, inp):
        x, i = carry
        x = acts.constrain_batch(x)
        bp = inp
        if shared_block is not None:
            def with_attn(x):
                y, _ = decoder_block(shared_block, cfg, x, positions, False)
                return y
            x = jax.lax.cond(i % every == 0, with_attn, lambda x: x, x)
        x = mamba_layer(bp, cfg, x)
        return (x, i + 1), None

    fn = jax.checkpoint(fn) if remat else fn
    (x, _), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.int32)), blocks)
    return x


def encoder_forward(params: Params, cfg: ModelConfig,
                    frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
    params = cast_compute(params, cfg)
    x = _compute(frames, cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def fn(x, bp):
        h = layers.rmsnorm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = attn._project_qkv(bp["attn"], cfg, h, positions)
        a = attn.flash_attention(q[:, :, :, None, :],
                                 attn.expand_kv_padded(k, cfg),
                                 attn.expand_kv_padded(v, cfg),
                                 causal=False)
        x = x + attn.attention_output(bp["attn"], cfg, a)
        h = layers.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        return x + layers.swiglu(h, **bp["ffn"]), None

    x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
    return layers.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def lm_logits(params: Params, cfg: ModelConfig, hidden: jnp.ndarray
              ) -> jnp.ndarray:
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return jnp.einsum("bsd,dv->bsv", hidden,
                      head.astype(hidden.dtype)).astype(jnp.float32)


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    params = cast_compute(params, cfg)
    memory = batch.get("vision", batch.get("frames"))
    if cfg.family == "encdec":
        memory = encoder_forward(params, cfg, batch["frames"])
    hidden, aux = lm_hidden(params, cfg, batch["tokens"], memory)
    logits = lm_logits(params, cfg, hidden)
    loss = layers.cross_entropy_loss(logits, batch["labels"])
    metrics = {"ce": loss, "aux": aux}
    if cfg.mtp_depth:
        # multi-token prediction (deepseek): predict t+2 from hidden_t and
        # the embedding of token t+1.
        emb_next = _compute(params["embed"][batch["tokens"]], cfg)
        h_in = jnp.concatenate(
            [hidden[:, :-1, :], emb_next[:, 1:, :]], axis=-1)
        h_mtp = jnp.einsum("bsd,dk->bsk", h_in, params["mtp"]["proj"])
        B, S1, _ = h_mtp.shape
        pos = jnp.broadcast_to(jnp.arange(S1)[None, :], (B, S1))
        h_mtp, _ = decoder_block(params["mtp"]["block"], cfg, h_mtp, pos,
                                 cfg.family == "moe")
        h_mtp = layers.rmsnorm(h_mtp, params["mtp"]["norm"], cfg.norm_eps)
        mtp_logits = lm_logits(params, cfg, h_mtp)
        mtp_loss = layers.cross_entropy_loss(
            mtp_logits[:, :-1], batch["labels"][:, 2:])
        metrics["mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    loss = loss + cfg.router_aux_coef * aux
    metrics["loss"] = loss
    return loss, metrics
