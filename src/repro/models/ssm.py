"""Mamba2 SSD (state-space duality) block — arXiv:2405.21060.

Chunked SSD: within chunks of length Q the recurrence is computed as a
masked attention-like quadratic form; across chunks a linear state
recurrence (lax.scan) carries (H, P, N) states.  The depthwise causal
conv1d (R = ssm_conv = 4) optionally runs the paper's SFC 1-D fast path
(``cfg.use_sfc_conv``) — the only convolution in the assigned LM pool, see
DESIGN.md §6.

Decode is O(1) per token via the (B, H, P, N) state + a (R-1)-deep conv
ring buffer — this is what makes the ``long_500k`` cell sub-quadratic.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.layers import Params


def init_mamba2(key, cfg, dtype) -> Params:
    d = cfg.d_model
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": layers.dense_init(ks[0], d, 2 * di + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": layers.init_rmsnorm(di, dtype),
        "out_proj": layers.dense_init(ks[2], di, d, dtype),
    }


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                   use_sfc: bool) -> jnp.ndarray:
    from repro.api import ConvSpec, serving_cache
    # auto planning picks the SFC fast path when an algorithm matching the
    # tap count is registered (SFC-6(6,4) for the default R=4: 12 mults /
    # 6 outputs vs 24 direct) and degrades to direct otherwise.  The
    # serving cache keys (spec, weights) -> (plan, prepared weights), so
    # eager serving/prefill hits re-use one pre-transformed weight tensor
    # (under jit tracing it degrades to plain plan + inline prepare).
    spec = ConvSpec.for_conv1d_depthwise(x.shape, w.shape)
    p, prep = serving_cache.get(spec, w,
                                algo="auto" if use_sfc else "direct")
    return jax.nn.silu(p.apply(x, prep, bias=b))


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, D: jnp.ndarray,
                chunk: int,
                init_state: jnp.ndarray = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan.  x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,N); D (H,).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        # ragged sequence lengths: zero-pad to a chunk multiple; padded
        # steps have dt=0 so they neither decay nor inject state.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_pad = nc * chunk
    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    dA = dtc * A[None, None, None, :]                      # (B,nc,Q,H) <= 0
    dA_cum = jnp.cumsum(dA, axis=2)
    # intra-chunk: masked decay kernel L[q,s] = exp(dAcum_q - dAcum_s), q>=s
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc,
                        preferred_element_type=jnp.float32)
    # pairwise contraction: a single 4-operand einsum lets XLA materialize a
    # 6-D (B,nc,Q,H,Q,P) intermediate (~17 GB/layer at prefill_32k —
    # EXPERIMENTS.md §Perf hillclimb 3); the explicit kernel (B,nc,Q,Q,H)
    # is 64x smaller and contracts straight into (B,nc,Q,H,P).
    kern = scores[..., None] * L * dtc[:, :, None, :, :]   # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", kern,
                         xc.astype(jnp.float32))

    # chunk -> state contribution and inter-chunk recurrence
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # (B,nc,Q,H)
    states = jnp.einsum("bcsn,bcsh,bcsh,bcshp->bchpn",
                        Bc, decay_to_end, dtc, xc.astype(jnp.float32))
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])              # (B,nc,H)

    def scan_fn(carry, inp):
        st_prev = carry
        st_c, dec_c = inp
        st_new = st_prev * dec_c[:, :, None, None] + st_c
        return st_new, st_prev

    st0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
           else init_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        scan_fn, st0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cc, jnp.exp(dA_cum), prev_states)
    y = (y_intra + y_inter).reshape(Bsz, S_pad, H, P)
    y = y + D[None, None, :, None] * x.astype(jnp.float32)
    return y[:, :S].astype(x.dtype), final_state


def mamba2_block(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Training/prefill Mamba2 block. x (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    di, N, H, P = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads,
                   cfg.ssm_headdim)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    xbc = _causal_conv1d(xbc, p["conv_w"], p["conv_b"], cfg.use_sfc_conv)
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xs.reshape(B, S, H, P), dt, A, Bm, Cm, p["D"],
                       min(cfg.ssm_chunk, S))
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    y = layers.rmsnorm(y, p["norm"], cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"])


# --------------------------------------------------------------------------
# decode (O(1) per token)
# --------------------------------------------------------------------------
def init_mamba2_cache(cfg, batch: int, dtype) -> Params:
    di, N, H, P = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads,
                   cfg.ssm_headdim)
    conv_ch = di + 2 * N
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def mamba2_decode(p: Params, cfg, x: jnp.ndarray, cache: Params
                  ) -> Tuple[jnp.ndarray, Params]:
    """One-token step. x (B,1,d)."""
    B = x.shape[0]
    di, N, H, P = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads,
                   cfg.ssm_headdim)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])[:, 0]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    conv_out = jnp.einsum("brc,rc->bc", window, p["conv_w"])
    xbc_c = jax.nn.silu(conv_out + p["conv_b"])
    xs, Bm, Cm = jnp.split(xbc_c, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    decay = jnp.exp(dt * A)                                       # (B,H)
    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bm.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xh
    y = (y.reshape(B, di) * jax.nn.silu(z)).astype(x.dtype)
    y = layers.rmsnorm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"])[:, None, :]
    new_cache = {"state": state, "conv": window[:, 1:, :]}
    return out, new_cache
