"""Single-token decode with per-family caches (the ``serve_step``).

Cache layouts (stacked over layers, scan-compatible):
  dense/moe : KV ring/full caches per layer (GQA) or MLA latent caches
  ssm       : (state, conv window) per layer — O(1) memory in context length
  hybrid    : ssm caches + per-invocation KV caches for the shared block
  vlm/encdec: self-attn caches + precomputed cross-attention K/V (computed
              once from the static memory at cache init — no per-step
              recompute of the vision/encoder projections)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers, mla, ssm
from repro.models.layers import Params, dtype_of
from repro.models.transformer import (_compute, decoder_block,
                                      encoder_forward, lm_logits)


def _stack_map(fn, n, *args):
    trees = [fn(*args) for _ in range(n)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _cross_kv(xattn_params, cfg, memory):
    k = jnp.einsum("bsd,dkh->bskh", memory, xattn_params["wk"])
    v = jnp.einsum("bsd,dkh->bskh", memory, xattn_params["wv"])
    return k, v


def init_cache(params: Params, cfg: ModelConfig, batch: int, max_len: int,
               memory: Optional[jnp.ndarray] = None) -> Params:
    from repro.models.transformer import cast_compute
    params = cast_compute(params, cfg)
    dtype = dtype_of(cfg.compute_dtype)
    fam = cfg.family
    cache: Params = {}
    if fam in ("dense", "moe"):
        per_layer = (
            (lambda: mla.init_mla_cache(cfg, batch, max_len, dtype))
            if cfg.use_mla else
            (lambda: attn.init_kv_cache(cfg, batch, max_len, dtype)))
        n_moe = cfg.n_layers - cfg.first_dense_layers
        cache["layers"] = _stack_map(per_layer, n_moe)
        if cfg.first_dense_layers:
            cache["dense_layers"] = _stack_map(per_layer,
                                               cfg.first_dense_layers)
    elif fam == "ssm":
        cache["layers"] = _stack_map(
            lambda: ssm.init_mamba2_cache(cfg, batch, dtype), cfg.n_layers)
    elif fam == "hybrid":
        cache["layers"] = _stack_map(
            lambda: ssm.init_mamba2_cache(cfg, batch, dtype), cfg.n_layers)
        n_inv = -(-cfg.n_layers // cfg.shared_attn_every)
        cache["shared"] = _stack_map(
            lambda: attn.init_kv_cache(cfg, batch, max_len, dtype), n_inv)
    elif fam == "vlm":
        cache["layers"] = _stack_map(
            lambda: attn.init_kv_cache(cfg, batch, max_len, dtype),
            cfg.n_layers)
        assert memory is not None
        mem = _compute(memory, cfg)
        n_groups = cfg.n_layers // cfg.cross_attn_every
        ks, vs = [], []
        for g in range(n_groups):
            xp = jax.tree_util.tree_map(
                lambda a, g=g: a[g], params["cross_blocks"])
            k, v = _cross_kv(xp["xattn"], cfg, mem)
            ks.append(k)
            vs.append(v)
        cache["cross_k"] = jnp.stack(ks)
        cache["cross_v"] = jnp.stack(vs)
    elif fam == "encdec":
        assert memory is not None, "encdec cache needs encoder frames"
        enc_out = encoder_forward(params, cfg, memory)
        cache["layers"] = _stack_map(
            lambda: attn.init_kv_cache(cfg, batch, max_len, dtype),
            cfg.n_layers)
        ks, vs = [], []
        for l in range(cfg.n_layers):
            xp = jax.tree_util.tree_map(
                lambda a, l=l: a[l], params["cross_blocks"])
            k, v = _cross_kv(xp["xattn"], cfg, enc_out)
            ks.append(k)
            vs.append(v)
        cache["cross_k"] = jnp.stack(ks)
        cache["cross_v"] = jnp.stack(vs)
    else:
        raise ValueError(fam)
    return cache


def _cross_decode(x, ln, xattn, cfg, ck, cv):
    """One-token cross-attention against precomputed memory K/V."""
    from repro.models.attention import attention_output
    B = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hkv
    h = layers.rmsnorm(x, ln, cfg.norm_eps)
    qg = jnp.einsum("bsd,dkh->bskh", h,
                    xattn["wq"])[:, :, :hq, :].reshape(B, 1, hkv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgk", qg, ck,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(cv.dtype), cv)
    return attention_output(xattn, cfg, o[:, None])


def _attn_ffn_decode(bp, cfg, x, cache_l, pos, use_moe):
    from repro.models import moe as moe_mod
    h = layers.rmsnorm(x, bp["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        a, new_cache = mla.mla_decode(bp["attn"], cfg, h, cache_l, pos)
    else:
        a, new_cache = attn.decode_attention(bp["attn"], cfg, h, cache_l, pos)
    x = x + a
    h = layers.rmsnorm(x, bp["ln2"], cfg.norm_eps)
    if use_moe:
        # serving must not drop tokens: capacity == T (lossless dispatch)
        f, _ = moe_mod.moe_block(
            bp["ffn"], cfg, h,
            capacity_factor=cfg.n_experts / cfg.n_experts_active)
    else:
        f = layers.swiglu(h, **bp["ffn"])
    return x + f, new_cache


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Params]:
    """tokens (B, 1) int32, pos (B,) int32 -> (logits (B,1,V), new cache)."""
    from repro.models.transformer import cast_compute
    params = cast_compute(params, cfg)
    B = tokens.shape[0]
    x = _compute(params["embed"][tokens], cfg)
    fam = cfg.family
    new_cache = dict(cache)

    if fam in ("dense", "moe"):
        if cfg.first_dense_layers:
            def dense_fn(x, inp):
                bp, cl = inp
                x, nc = _attn_ffn_decode(bp, cfg, x, cl, pos, False)
                return x, nc
            x, nc = jax.lax.scan(dense_fn, x, (params["dense_blocks"],
                                               cache["dense_layers"]))
            new_cache["dense_layers"] = nc

        def fn(x, inp):
            bp, cl = inp
            x, nc = _attn_ffn_decode(bp, cfg, x, cl, pos, fam == "moe")
            return x, nc
        x, nc = jax.lax.scan(fn, x, (params["blocks"], cache["layers"]))
        new_cache["layers"] = nc
    elif fam in ("ssm", "hybrid"):
        every = cfg.shared_attn_every

        def fn(carry, inp):
            x, i, shared_c = carry
            bp, cl = inp
            if fam == "hybrid":
                inv = i // every

                def with_attn(operand):
                    x, shared_c = operand
                    c_inv = jax.tree_util.tree_map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, inv, 0, keepdims=False), shared_c)
                    h = layers.rmsnorm(x, params["shared_block"]["ln1"],
                                       cfg.norm_eps)
                    a, nc = attn.decode_attention(
                        params["shared_block"]["attn"], cfg, h, c_inv, pos)
                    x = x + a
                    h = layers.rmsnorm(x, params["shared_block"]["ln2"],
                                       cfg.norm_eps)
                    x = x + layers.swiglu(h, **params["shared_block"]["ffn"])
                    shared_c = jax.tree_util.tree_map(
                        lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                            full, upd, inv, 0), shared_c, nc)
                    return x, shared_c

                x, shared_c = jax.lax.cond(
                    i % every == 0, with_attn, lambda o: o, (x, shared_c))
            h = layers.rmsnorm(x, bp["ln"], cfg.norm_eps)
            y, nc = ssm.mamba2_decode(bp["mixer"], cfg, h, cl)
            return (x + y, i + 1, shared_c), nc

        shared0 = cache.get("shared", ())
        (x, _, shared_c), nc = jax.lax.scan(
            fn, (x, jnp.zeros((), jnp.int32), shared0),
            (params["blocks"], cache["layers"]))
        new_cache["layers"] = nc
        if fam == "hybrid":
            new_cache["shared"] = shared_c
    elif fam == "vlm":
        k = cfg.cross_attn_every
        n_groups = cfg.n_layers // k
        blocks = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, k) + a.shape[1:]),
            params["blocks"])
        caches = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, k) + a.shape[1:]),
            cache["layers"])

        def group_fn(x, inp):
            gb, gc, xp, ck, cv = inp
            h = _cross_decode(x, xp["ln"], xp["xattn"], cfg, ck, cv)
            x = x + jnp.tanh(xp["xattn"]["gate"]) * h
            hh = layers.rmsnorm(x, xp["ln2"], cfg.norm_eps)
            x = x + jnp.tanh(xp["ffn_gate"]) * layers.swiglu(hh, **xp["ffn"])

            def inner(x, inp2):
                bp, cl = inp2
                x, nc = _attn_ffn_decode(bp, cfg, x, cl, pos, False)
                return x, nc
            x, ncs = jax.lax.scan(inner, x, (gb, gc))
            return x, ncs

        x, nc = jax.lax.scan(group_fn, x,
                             (blocks, caches, params["cross_blocks"],
                              cache["cross_k"], cache["cross_v"]))
        new_cache["layers"] = jax.tree_util.tree_map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), nc)
    elif fam == "encdec":
        def fn(x, inp):
            bp, xp, cl, ck, cv = inp
            x = x + _cross_decode(x, xp["ln"], xp["xattn"], cfg, ck, cv)
            x, nc = _attn_ffn_decode(bp, cfg, x, cl, pos, False)
            return x, nc
        x, nc = jax.lax.scan(fn, x, (params["blocks"],
                                     params["cross_blocks"],
                                     cache["layers"], cache["cross_k"],
                                     cache["cross_v"]))
        new_cache["layers"] = nc
    else:
        raise ValueError(fam)

    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(params, cfg, x), new_cache
