"""Deterministic, seedable fault injection for the SFC stack.

A production serving system's failure handling is only as good as its
failure *testing* — and kernel failures (compile errors, VMEM overflow,
interpret/TPU mismatches) are rare enough under healthy operation that
the degradation paths they exercise would otherwise never run in CI.
This module plants named injection sites at the plan / prepare / apply /
cache / dispatch boundaries and lets tests and benchmarks arm them with
per-site schedules:

    with faults.inject({faults.APPLY_FUSED: faults.FaultSpec(p=0.05)},
                       seed=0) as fp:
        ...drive traffic...
    assert fp.injected(faults.APPLY_FUSED) > 0

Design rules:

  * **zero overhead disarmed** — every hook is one module-global load and
    a ``None`` check; nothing else executes outside an ``inject`` block;
  * **deterministic** — each site draws from its own
    ``np.random.RandomState`` stream (seeded from the plan seed and the
    site name), so one site's firing sequence depends only on how often
    *that* site is hit, not on interleaving with other sites;
  * **two fault modes** — ``raise`` (the hook raises :class:`InjectedFault`
    at the site: the kernel "crashed") and ``corrupt`` (the hook rewrites
    the site's value, by default poisoning it with NaN: the kernel
    "served garbage"), covering both halves of the resilience story
    (exception fallback and the numerical guardrail);
  * **data-dependent faults** — ``FaultSpec.when`` predicates see the
    site's detail object (the plan at apply sites, the batch at the
    dispatch site), so a test can poison exactly one request and assert
    its co-batched peers survive quarantine bisection.

The injection sites ship in the production modules (``api/backends.py``,
``api/plan.py``, ``api/planner.py``, ``api/serving_cache.py``,
``serve/engine.py``) — faults fire inside the real code paths, not a
test double.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import zlib
from typing import Any, Callable, Dict, Optional

import numpy as np

# ---------------------------------------------------------------------------
# canonical injection sites
# ---------------------------------------------------------------------------
PLAN = "plan"                        # planner.plan entry
PREPARE = "prepare"                  # ConvPlan.prepare_weights entry
CACHE = "cache"                      # ServingCache.get entry
DISPATCH = "dispatch"                # Engine._dispatch entry (detail: Batch)
APPLY_FUSED = "apply:fused"          # pallas fused kernel call
APPLY_STAGED = "apply:staged"        # pallas staged pipeline call
APPLY_REFERENCE = "apply:reference"  # reference backend apply

SITES = (PLAN, PREPARE, CACHE, DISPATCH,
         APPLY_FUSED, APPLY_STAGED, APPLY_REFERENCE)


class InjectedFault(RuntimeError):
    """The exception an armed ``raise``-mode site throws.

    Deliberately a plain ``RuntimeError`` subclass: the resilience layer
    must treat it like any other kernel failure — nothing may special-case
    injected faults, or the test would not be testing the real path.
    """


def _nan_poison(value):
    import jax.numpy as jnp
    return jnp.full_like(value, jnp.nan)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Schedule for one site: when and how it fires.

    ``p``       per-hit firing probability (1.0 = every eligible hit);
    ``times``   total injections after which the site goes quiet
                (None = unlimited) — a bounded fault *burst*;
    ``after``   eligible hits skipped before the schedule starts;
    ``when``    optional predicate over the site's detail object — only
                matching hits are eligible (data-dependent poison);
    ``mode``    'raise' fires at :func:`maybe_fault` sites, 'corrupt' at
                :func:`maybe_corrupt` sites — one spec arms one mode;
    ``exc``     exception factory for raise mode;
    ``corrupt`` value transform for corrupt mode (default: NaN-poison).
    """

    p: float = 1.0
    times: Optional[int] = None
    after: int = 0
    when: Optional[Callable[[Any], bool]] = None
    mode: str = "raise"
    exc: Callable[[str], BaseException] = InjectedFault
    corrupt: Callable[[Any], Any] = _nan_poison

    def __post_init__(self):
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"p must be in [0, 1]: {self.p}")
        if self.mode not in ("raise", "corrupt"):
            raise ValueError(f"mode must be 'raise' or 'corrupt': "
                             f"{self.mode!r}")


class FaultPlan:
    """Armed fault schedules plus per-site hit/injection accounting.

    Thread-safe: the engine's dispatch thread and a test thread may hit
    sites concurrently.  ``last_fire_t`` records a ``perf_counter`` stamp
    per site (benchmarks measure recovery time from the end of a burst).
    """

    def __init__(self, sites: Dict[str, FaultSpec], *, seed: int = 0,
                 allow_unknown_sites: bool = False):
        unknown = [s for s in sites if s not in SITES]
        if unknown and not allow_unknown_sites:
            raise ValueError(f"unknown fault site(s) {unknown}; "
                             f"known: {list(SITES)}")
        self.specs = dict(sites)
        self._lock = threading.Lock()
        self._hits: Dict[str, int] = {}
        self._injected: Dict[str, int] = {}
        self.last_fire_t: Dict[str, float] = {}
        # per-site streams: firing order at one site is independent of
        # traffic at every other site
        self._rngs = {s: np.random.RandomState(
            (seed ^ zlib.crc32(s.encode())) & 0x7FFFFFFF) for s in sites}

    # ---- accounting ------------------------------------------------------
    def hits(self, site: str) -> int:
        with self._lock:
            return self._hits.get(site, 0)

    def injected(self, site: Optional[str] = None) -> int:
        with self._lock:
            if site is not None:
                return self._injected.get(site, 0)
            return sum(self._injected.values())

    # ---- firing decision -------------------------------------------------
    def _should_fire(self, site: str, mode: str,
                     detail: Any) -> Optional[FaultSpec]:
        spec = self.specs.get(site)
        if spec is None or spec.mode != mode:
            return None
        if spec.when is not None and not spec.when(detail):
            return None
        with self._lock:
            self._hits[site] = self._hits.get(site, 0) + 1
            if self._hits[site] <= spec.after:
                return None
            if spec.times is not None \
                    and self._injected.get(site, 0) >= spec.times:
                return None
            if spec.p < 1.0 and self._rngs[site].rand() >= spec.p:
                return None
            self._injected[site] = self._injected.get(site, 0) + 1
            import time
            self.last_fire_t[site] = time.perf_counter()
        return spec


# ---------------------------------------------------------------------------
# global arming
# ---------------------------------------------------------------------------
_ACTIVE: Optional[FaultPlan] = None
_ARM_LOCK = threading.Lock()


def active() -> Optional[FaultPlan]:
    return _ACTIVE


@contextlib.contextmanager
def inject(sites: Dict[str, FaultSpec], *, seed: int = 0,
           allow_unknown_sites: bool = False):
    """Arm fault schedules for the dynamic extent of the block.

    Yields the :class:`FaultPlan` for accounting assertions.  Nesting
    restores the previous plan on exit (inner blocks shadow, not merge).
    Arming is process-global — a serving engine's dispatch *thread* sees
    the faults its driving test armed, which is the point.
    """
    global _ACTIVE
    plan = FaultPlan(sites, seed=seed,
                     allow_unknown_sites=allow_unknown_sites)
    with _ARM_LOCK:
        prev, _ACTIVE = _ACTIVE, plan
    try:
        yield plan
    finally:
        with _ARM_LOCK:
            _ACTIVE = prev


# ---------------------------------------------------------------------------
# hooks (the production-code surface)
# ---------------------------------------------------------------------------
def maybe_fault(site: str, detail: Any = None) -> None:
    """Raise-mode hook: no-op unless armed with a matching 'raise' spec."""
    plan = _ACTIVE
    if plan is None:                       # disarmed: the hot-path cost
        return
    spec = plan._should_fire(site, "raise", detail)
    if spec is not None:
        raise spec.exc(f"injected fault at {site!r}")


def maybe_corrupt(site: str, value, detail: Any = None):
    """Corrupt-mode hook: returns ``value`` unless armed to rewrite it."""
    plan = _ACTIVE
    if plan is None:
        return value
    spec = plan._should_fire(site, "corrupt", detail)
    if spec is not None:
        return spec.corrupt(value)
    return value
