"""Static interval / bit-width analysis of the int8 SFC datapath.

The paper's headline claim is an *error analysis*: SFC's additions-only
transforms keep int8 accuracy where Winograd's fractional transforms lose
it.  This module makes the matching *overflow* analysis static.  Every
registered algorithm's transform matrices are exact ``Fraction`` values
(``repro.core.generator``), so worst-case value growth through each stage
of the deployed pipeline is derivable without running anything — the same
style of derivation Barabasz et al. ("Error Analysis and Improving the
Accuracy of Winograd Convolution") and Meng & Brothers ("Efficient
Winograd Convolution via Integer Arithmetic") carry out for Winograd.

Stages of the int8 datapath (``repro.kernels``) and their bounds, for
activations quantized to ``bits_act`` and weights to ``bits_weight`` on
the int8 carrier:

  1. forward transform  B^T X B        (fp32; for int-grid inputs
     |x| <= q the result is bounded per frequency (u, v) by
     ||B^T_u||_1 * ||B^T_v||_1 * q — tight: signs can be chosen to
     achieve it, and the 2-D worst case is the worst 1-D row squared);
  2. per-frequency quantization        clip(round(tx / s)) in
     [-qmax_act, qmax_act] — the clip makes this bound *unconditional*,
     whatever the calibrated scales are;
  3. t^2-position int8 x int8 products |xq * wq| <= qmax_act * qmax_weight;
  4. k-blocked int32 accumulation      the fused kernel's VMEM scratch
     (and the staged ``tdmm_int8`` reduction) accumulate the FULL C_in
     contraction in int32 — k-blocking only stages the reduction, it
     never resets the accumulator, so the bound binds C_in itself:
         |acc| <= C_in * qmax_act * qmax_weight <= 2^31 - 1;
  5. dequant + inverse  A^T Y A        (fp32; the int32 -> f32 cast is
     value-exact only while the accumulator fits the 24-bit f32 mantissa
     — ``dequant_exact_cin`` is the C_in up to which that cast is
     lossless).

:func:`certificate` packages the per-algorithm bounds;
:func:`check_spec_accumulator` is the cheap pre-flight ``plan()`` runs
before handing a quantized spec to an integer-datapath backend.

This module deliberately imports only ``repro.core.generator`` (exact
matrices) at module level: ``repro.quant.bops`` shares the transform
bit-growth derivation from here, and the planner pre-flight must stay
import-cycle-free and cheap.
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction
from typing import Dict, Optional

from repro.core.generator import BilinearAlgorithm

INT32_MAX = 2 ** 31 - 1
_F32_MANTISSA_BITS = 24          # f32 represents integers exactly to 2^24


def qmax(bits: int) -> int:
    """Largest magnitude of a symmetric ``bits``-wide quantization grid."""
    return 2 ** (bits - 1) - 1


# --------------------------------------------------------------------------
# transform growth (shared with the BOPs cost model)
# --------------------------------------------------------------------------
def bt_row_l1(algo: BilinearAlgorithm) -> int:
    """max_u ||B^T_u||_1 truncated to int — the 1-D transform growth factor
    the BOPs model (``repro.quant.bops``) prices transform adds at.  Kept
    bit-for-bit identical to the expression historically inlined there so
    adopting the shared helper changes no cost-model ranking."""
    return max(int(sum(abs(v) for v in row)) for row in algo.BT)


def bt_row_l1_exact(algo: BilinearAlgorithm) -> Fraction:
    """max_u ||B^T_u||_1 as an exact Fraction (certificate arithmetic)."""
    return max(sum(abs(v) for v in row) for row in algo.BT)


def at_row_l1_exact(algo: BilinearAlgorithm) -> Fraction:
    return max(sum(abs(v) for v in row) for row in algo.AT)


def g_row_l1_exact(algo: BilinearAlgorithm) -> Fraction:
    return max(sum(abs(v) for v in row) for row in algo.G)


def transform_bits_1d(algo: BilinearAlgorithm, bits_act: int) -> int:
    """Bit width of one 1-D B^T pass over ``bits_act``-wide integer data.

    This is the BOPs model's transform-add width (data grows by
    log2(||B^T||_1) bits per pass); SFC rows sum to <= N so int8 data
    stays within int16.
    """
    return bits_act + max(1, math.ceil(math.log2(max(bt_row_l1(algo), 2))))


def _signed_bits(max_abs: int) -> int:
    """Bits of a signed integer type that can hold values in [-m, m]."""
    return int(max_abs).bit_length() + 1


# --------------------------------------------------------------------------
# accumulator safety
# --------------------------------------------------------------------------
def safe_cin_bound(bits_act: int = 8, bits_weight: int = 8) -> int:
    """Max contraction length K with NO int32 overflow possible.

    Worst case per int8 x int8 product is qmax_act * qmax_weight (both
    operands are clipped to their symmetric grids by construction), so
    |acc| <= K * qmax_act * qmax_weight.  int32 overflow is impossible
    iff K <= floor((2^31 - 1) / (qmax_act * qmax_weight)).  Independent
    of ``k_block``: the kernels' int32 scratch persists across k-blocks
    and accumulates the full C_in.
    """
    return INT32_MAX // (qmax(bits_act) * qmax(bits_weight))


def dequant_exact_cin(bits_act: int = 8, bits_weight: int = 8) -> int:
    """Max contraction length for which the int32 -> f32 dequant cast is
    value-exact (accumulator within the 24-bit f32 mantissa)."""
    return (2 ** _F32_MANTISSA_BITS) // (qmax(bits_act) * qmax(bits_weight))


class AccumulatorOverflowError(ValueError):
    """A quantized spec whose int32 accumulator could wrap at runtime."""


def check_contraction(contraction: int, bits_act: int, bits_weight: int,
                      *, context: str = "") -> None:
    """Raise :class:`AccumulatorOverflowError` when a contraction of
    ``contraction`` int8 x int8 products can overflow int32."""
    bound = safe_cin_bound(bits_act, bits_weight)
    if contraction > bound:
        prod = qmax(bits_act) * qmax(bits_weight)
        raise AccumulatorOverflowError(
            f"int32 accumulator overflow risk{context}: contraction length "
            f"{contraction} exceeds the safe bound {bound} for "
            f"int{bits_act} x int{bits_weight} products (worst case "
            f"|acc| = K * {prod} must stay <= {INT32_MAX}; at K = "
            f"{contraction} it reaches {contraction * prod}).  Reduce "
            f"C_in, split the contraction across plans, or run the spec "
            f"unquantized.")


def check_spec_accumulator(spec, algorithm: Optional[BilinearAlgorithm],
                           *, algo_name: str = "") -> None:
    """``plan()`` pre-flight: reject quantized specs whose accumulator
    can wrap on the integer datapath.

    Depthwise contracts K = 1 (a pure elementwise product) and grouped
    specs contract C_in / groups; specs without channel hints pass (the
    planner cannot bound what it cannot see — the kernels' conformance
    tests cover the dynamic envelope).
    """
    if algorithm is None or not spec.quant.enabled:
        return
    if spec.in_channels is None:
        return
    k = 1 if spec.depthwise else spec.in_channels // max(1, spec.groups)
    check_contraction(
        k, spec.quant.bits_act, spec.quant.bits_weight,
        context=(f" (spec C_in={spec.in_channels}, "
                 f"algo {algo_name or algorithm.name})"))


# --------------------------------------------------------------------------
# per-algorithm certificates
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Certificate:
    """Statically derived worst-case bounds for one registered algorithm.

    All integer fields are exact (derived in Fraction arithmetic and
    ceil'd); ``None`` bounds mean "unbounded by this stage" (e.g. the
    depthwise accumulator, which contracts a single product).
    """

    algo: str                     # registry name
    kind: str                     # 'sfc' | 'winograd' | ...
    M: int
    R: int
    t: int
    bits_act: int
    bits_weight: int
    integer_transform: bool       # B^T, G integral (additions-only claim)
    bt_row_l1: float              # max 1-D input-transform row L1
    transform_growth_2d: float    # worst |tx| / |x| over frequencies (2-D)
    transform_hi: int             # |tx| bound for int-grid |x| <= qmax_act
    transform_bits: int           # signed bits holding transform_hi
    g_row_l1: float               # weight-transform growth (offline stage)
    at_row_l1: float              # 1-D inverse growth
    inverse_growth_2d: float      # worst |y| / |ty| through A^T Y A
    product_hi: int               # qmax_act * qmax_weight
    product_bits: int
    safe_cin: int                 # max C_in: int32 overflow impossible
    acc_bits_at_safe_cin: int     # accumulator width right at the bound
    dequant_exact_cin: int        # max C_in: int32 -> f32 cast lossless

    def acc_bits(self, c_in: int) -> int:
        """Signed bits the int32 accumulator needs at contraction c_in."""
        return _signed_bits(c_in * self.product_hi)

    def headroom_bits(self, c_in: int) -> int:
        """int32 bits to spare at contraction ``c_in`` (negative: unsafe)."""
        return 32 - self.acc_bits(c_in)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def certificate(algo: BilinearAlgorithm, *, name: Optional[str] = None,
                bits_act: int = 8, bits_weight: int = 8) -> Certificate:
    """Derive the static overflow/bit-width certificate for ``algo``."""
    qa, qw = qmax(bits_act), qmax(bits_weight)
    l1 = bt_row_l1_exact(algo)
    growth_2d = l1 * l1                       # separable: worst row squared
    transform_hi = math.ceil(growth_2d * qa)
    at_l1 = at_row_l1_exact(algo)
    prod = qa * qw
    safe = INT32_MAX // prod
    return Certificate(
        algo=name or algo.name, kind=algo.kind, M=algo.M, R=algo.R,
        t=algo.t, bits_act=bits_act, bits_weight=bits_weight,
        integer_transform=algo.is_integer_transform(),
        bt_row_l1=float(l1), transform_growth_2d=float(growth_2d),
        transform_hi=transform_hi,
        transform_bits=_signed_bits(transform_hi),
        g_row_l1=float(g_row_l1_exact(algo)),
        at_row_l1=float(at_l1), inverse_growth_2d=float(at_l1 * at_l1),
        product_hi=prod, product_bits=_signed_bits(prod),
        safe_cin=safe, acc_bits_at_safe_cin=_signed_bits(safe * prod),
        dequant_exact_cin=(2 ** _F32_MANTISSA_BITS) // prod,
    )


def all_certificates(*, bits_act: int = 8, bits_weight: int = 8
                     ) -> Dict[str, Certificate]:
    """One certificate per registered algorithm (registry order)."""
    from repro.api import registry       # late: keep this module cycle-free
    out = {}
    for entry in registry.entries():
        out[entry.name] = certificate(
            registry.get_algorithm(entry.name), name=entry.name,
            bits_act=bits_act, bits_weight=bits_weight)
    return out


def transform_interval_hi(algo: BilinearAlgorithm, in_hi: float) -> float:
    """|B^T X B| bound per frequency for inputs bounded by ``in_hi`` —
    what the conformance fuzz layer asserts observed transform-domain
    values against."""
    return float(bt_row_l1_exact(algo) ** 2) * in_hi
