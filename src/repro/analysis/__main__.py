"""CLI for the static analysis suite: ``python -m repro.analysis``.

Modes
-----
default            human-readable report of all three analyses
--check            same, but exit 1 if any ERROR finding (the CI gate)
--certificates P   additionally write per-algorithm overflow
                   certificates as JSON to path ``P``
--root DIR         lint this tree instead of the installed package

The report covers:
  1. the architecture linter over the source tree,
  2. the fused-kernel resource checker over every DEFAULT_CANDIDATES
     config x registry algorithm x representative workload,
  3. one overflow/bit-width certificate per registry algorithm
     (8/8-bit), including the plan-time safe-C_in bound.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on any ERROR finding")
    ap.add_argument("--certificates", metavar="PATH", default=None,
                    help="write per-algorithm certificates JSON here")
    ap.add_argument("--root", metavar="DIR", default=None,
                    help="source tree to lint (default: installed repro)")
    ap.add_argument("--bits-act", type=int, default=8)
    ap.add_argument("--bits-weight", type=int, default=8)
    args = ap.parse_args(argv)

    from repro.analysis import kernel_checks, lint, ranges

    errors = 0

    root = (pathlib.Path(args.root) if args.root is not None
            else lint.source_root())
    lint_findings = lint.run_lint(root)
    print(f"[lint] {root}: {len(lint_findings)} finding(s)")
    for f in lint_findings:
        print(f"  {f}")
    errors += sum(f.severity == kernel_checks.ERROR for f in lint_findings)

    kc_findings = kernel_checks.default_candidate_report(
        bits_act=args.bits_act, bits_weight=args.bits_weight)
    print(f"[kernel] default candidate sweep: "
          f"{len(kc_findings)} finding(s)")
    for f in kc_findings:
        print(f"  {f}")
    errors += sum(f.severity == kernel_checks.ERROR for f in kc_findings)

    certs = ranges.all_certificates(bits_act=args.bits_act,
                                    bits_weight=args.bits_weight)
    print(f"[ranges] {len(certs)} algorithm certificate(s) at "
          f"{args.bits_act}/{args.bits_weight} bits")
    hdr = (f"  {'algorithm':<12} {'kind':<9} {'tx_bits':>7} "
           f"{'prod_bits':>9} {'safe_cin':>9} {'acc_bits':>8} "
           f"{'exact_cin':>9}")
    print(hdr)
    for name in sorted(certs):
        c = certs[name]
        print(f"  {name:<12} {c.kind:<9} {c.transform_bits:>7} "
              f"{c.product_bits:>9} {c.safe_cin:>9} "
              f"{c.acc_bits_at_safe_cin:>8} {c.dequant_exact_cin:>9}")
        if not c.integer_transform:
            print(f"    note: non-integer B^T — transform bound uses "
                  f"exact L1 row norms ({c.bt_row_l1})")

    if args.certificates:
        out = pathlib.Path(args.certificates)
        out.write_text(json.dumps(
            {name: certs[name].to_json() for name in sorted(certs)},
            indent=2, sort_keys=True) + "\n", encoding="utf-8")
        print(f"[ranges] wrote {out}")

    if args.check and errors:
        print(f"FAILED: {errors} ERROR finding(s)")
        return 1
    print("OK" if not errors else f"{errors} ERROR finding(s) (advisory)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
