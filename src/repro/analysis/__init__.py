"""repro.analysis — static verification of the SFC stack.

Three analyses, all pure (no kernel launches, no RNG, no clock):

* :mod:`repro.analysis.ranges` — interval/bit-width analysis of the
  int8 datapath; per-algorithm overflow certificates and the maximal
  safe ``C_in`` bound enforced at plan time.
* :mod:`repro.analysis.kernel_checks` — Pallas fused-kernel resource
  checker (VMEM budget, strip bounds, scratch-race freedom) used as
  autotune pre-flight and by the serving batcher.
* :mod:`repro.analysis.lint` — AST architecture-invariant linter.

Submodules load lazily (PEP 562) so that importing light consumers
(e.g. ``repro.quant.bops`` → ``ranges``) does not pull in the kernel
package.
"""
from __future__ import annotations

import importlib
from typing import Any

_SUBMODULES = ("ranges", "kernel_checks", "lint")
_ATTR_HOME = {
    # ranges
    "AccumulatorOverflowError": "ranges",
    "Certificate": "ranges",
    "all_certificates": "ranges",
    "certificate": "ranges",
    "check_contraction": "ranges",
    "check_spec_accumulator": "ranges",
    "dequant_exact_cin": "ranges",
    "safe_cin_bound": "ranges",
    "transform_bits_1d": "ranges",
    # kernel_checks
    "Finding": "kernel_checks",
    "check_candidates": "kernel_checks",
    "check_config": "kernel_checks",
    "check_geometry": "kernel_checks",
    "fold_fits": "kernel_checks",
    # lint
    "run_lint": "lint",
}

__all__ = list(_SUBMODULES) + sorted(_ATTR_HOME)


def __getattr__(name: str) -> Any:
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    home = _ATTR_HOME.get(name)
    if home is not None:
        mod = importlib.import_module(f"{__name__}.{home}")
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
