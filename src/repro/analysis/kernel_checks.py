"""Static resource/safety checker for fused Pallas kernel launches.

The fused kernel (``repro.kernels.sfc_fused``) exposes its complete
launch geometry as data (:class:`~repro.kernels.sfc_fused.FusedGeometry`)
— grid, channel blocking, Unblocked strip index maps, scratch set, DMA
pipeline constants.  This module verifies, *without launching anything*:

  * **VMEM budget** — the per-grid-step footprint of the geometry fits
    ``VMEM_LIMIT_BYTES`` (a kernel that exceeds it spills or fails to
    allocate on real hardware; interpret mode would happily "run" it);
  * **strip bounds** — every Unblocked strip read (including the ragged
    last strip group of each image column) lands inside the padded HBM
    extents, and the blocked channel/output axes tile their padded
    extents exactly;
  * **scratch write races** — the int32 accumulator is read-modify-
    written only along the innermost (sequential) C_in grid axis, the
    output block index is independent of that axis (partial accumulator
    state must never flush), and the two-slot double-buffer DMA pipeline
    never lands a prefetch in the slot the current step is consuming
    (prefetch distance vs slot count).

:func:`check_candidates` is the autotuner pre-flight: it filters a
``KernelConfig`` sweep down to launchable candidates so invalid configs
are never timed.  The serving batcher uses :func:`fold_fits` for its
VMEM-aware batch folding instead of re-deriving kernel arithmetic.

This module is the sanctioned out-of-``repro.api`` consumer of
``repro.kernels`` metadata (see ``repro.analysis.lint`` ARCH001).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.generator import BilinearAlgorithm
from repro.kernels import sfc_fused as sf

ERROR = "ERROR"
WARNING = "WARNING"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One static-analysis finding (shared shape with the AST linter)."""

    code: str          # e.g. 'KC001'
    severity: str      # ERROR | WARNING
    message: str
    where: str = ""    # file:line for lint, config/geometry repr here

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.severity} {self.code}{loc}: {self.message}"


def _grid_corners(extent: int) -> Tuple[int, ...]:
    """First/last indices of one grid axis (bounds are monotone in the
    index maps, so the corners witness any violation)."""
    return (0, extent - 1) if extent > 1 else (0,)


def check_geometry(geom: sf.FusedGeometry, *,
                   vmem_limit: Optional[int] = None) -> List[Finding]:
    """Verify one resolved launch geometry.  Empty list == launchable."""
    findings: List[Finding] = []
    limit = sf.VMEM_LIMIT_BYTES if vmem_limit is None else vmem_limit
    where = (f"grid={geom.grid} kb={geom.kb} cb={geom.cb} "
             f"rows={geom.rows} imgs={geom.imgs} "
             f"db={int(geom.double_buffer)}")

    # KC001 — VMEM budget
    need = geom.vmem_bytes()
    if need > limit:
        findings.append(Finding(
            "KC001", ERROR,
            f"per-grid-step VMEM footprint {need} B exceeds the "
            f"{limit} B limit; the kernel cannot hold one step's strip/"
            f"scratch working set on-chip", where))

    # KC002 — strip/block bounds vs padded HBM extents
    bx, rx, wx, cx = geom.x_extents
    si, ssp, sw, sk = geom.strip_shape
    for i in _grid_corners(geom.grid0):
        for k in _grid_corners(geom.n_k):
            ob, orow, ocol, och = geom.strip_offset(i, k)
            hi = (ob + si, orow + ssp, ocol + sw, och + sk)
            if hi[0] > bx or hi[1] > rx or hi[2] > wx or hi[3] > cx:
                findings.append(Finding(
                    "KC002", ERROR,
                    f"input strip of grid step (i={i}, k={k}) reads "
                    f"[{ob}:{hi[0]}, {orow}:{hi[1]}, {ocol}:{hi[2]}, "
                    f"{och}:{hi[3]}] outside the padded HBM extents "
                    f"{geom.x_extents}", where))
    # the blocked axes must tile their padded extents exactly: a short
    # tiling silently drops channels, an over-tiling reads out of bounds
    if geom.n_k * geom.kb != geom.Cp or geom.Cp < geom.C:
        findings.append(Finding(
            "KC002", ERROR,
            f"C_in blocking n_k*kb = {geom.n_k}*{geom.kb} does not tile "
            f"the padded channel extent Cp={geom.Cp} (C={geom.C})", where))
    if geom.n_o * geom.cb != geom.Op or geom.Op < geom.Cout:
        findings.append(Finding(
            "KC002", ERROR,
            f"C_out blocking n_o*cb = {geom.n_o}*{geom.cb} does not tile "
            f"the padded output extent Op={geom.Op} (Cout={geom.Cout})",
            where))
    if geom.g_b * geom.imgs != geom.B:
        findings.append(Finding(
            "KC002", ERROR,
            f"image grouping g_b*imgs = {geom.g_b}*{geom.imgs} != B="
            f"{geom.B}: grouped steps would read padded images", where))
    if geom.nH_p < geom.nH or geom.grid0 != geom.g_b * geom.g_h:
        findings.append(Finding(
            "KC002", ERROR,
            f"strip-group tiling (g_h={geom.g_h}, rows={geom.rows}, "
            f"nH_p={geom.nH_p}) does not cover nH={geom.nH} tile rows "
            f"or grid0={geom.grid0} != g_b*g_h", where))

    # KC003 — scratch-accumulator write races
    if not geom.depthwise:
        if geom.rmw_axis != len(geom.grid) - 1:
            findings.append(Finding(
                "KC003", ERROR,
                f"accumulator RMW axis {geom.rmw_axis} is not the "
                f"innermost grid axis {len(geom.grid) - 1}: k-blocks "
                f"would interleave with other grid dims and the scratch "
                f"accumulation order is undefined", where))
        for i in _grid_corners(geom.grid0):
            for j in _grid_corners(geom.n_o):
                idx0 = geom.out_index(i, j, 0)
                for k in _grid_corners(geom.n_k):
                    if geom.out_index(i, j, k) != idx0:
                        findings.append(Finding(
                            "KC003", ERROR,
                            f"output block index depends on the k axis at "
                            f"(i={i}, j={j}): partial accumulator state "
                            f"would flush to HBM between k-blocks", where))
    if geom.double_buffer:
        d = geom.db_prefetch_distance
        if d % geom.db_slots == 0:
            findings.append(Finding(
                "KC003", ERROR,
                f"double-buffer prefetch distance {d} aliases the "
                f"in-flight slot (slot count {geom.db_slots}): the "
                f"prefetch DMA would overwrite the strip the current "
                f"step is consuming", where))
        elif not 0 < d < geom.db_slots + 1:
            findings.append(Finding(
                "KC003", WARNING,
                f"double-buffer prefetch distance {d} exceeds the slot "
                f"count {geom.db_slots}; strips would queue more DMA "
                f"than the landing buffer holds", where))
    return findings


def geometry_for(algo: BilinearAlgorithm, config, B: int, H: int, W: int,
                 C: int, Cout: int, *, padding: str = "SAME",
                 depthwise: bool = False) -> sf.FusedGeometry:
    """Resolve the geometry a fused launch of ``config`` would use."""
    return sf.fused_geometry(
        algo, B, H, W, C, Cout, padding=padding,
        k_block=config.k_block, cout_block=config.cout_block,
        rows_per_step=config.rows_per_step,
        double_buffer=config.double_buffer, depthwise=depthwise)


def check_config(algo: BilinearAlgorithm, config, B: int, H: int, W: int,
                 C: int, Cout: int, *, padding: str = "SAME",
                 depthwise: bool = False,
                 vmem_limit: Optional[int] = None) -> List[Finding]:
    """Findings for one ``KernelConfig`` candidate on one workload.

    Staged-datapath configs pass vacuously: the staged kernels run three
    separately blocked ``pallas_call``s whose budgets are set by their
    own (small, shape-independent) tile blocks.
    """
    if getattr(config, "datapath", "fused") != "fused":
        return []
    geom = geometry_for(algo, config, B, H, W, C, Cout, padding=padding,
                        depthwise=depthwise)
    return check_geometry(geom, vmem_limit=vmem_limit)


def check_spec_config(spec, algo: BilinearAlgorithm, config, *,
                      batch: int = 1,
                      vmem_limit: Optional[int] = None
                      ) -> Optional[List[Finding]]:
    """:func:`check_config` from a fully-hinted ``ConvSpec``.

    Returns None when the spec lacks the shape hints needed to resolve a
    geometry (the dynamic conformance tests cover those) or is not a
    rank-2 fast-path shape.
    """
    if spec.rank != 2 or spec.spatial is None \
            or spec.in_channels is None or spec.out_channels is None:
        return None
    H, W = spec.spatial
    return check_config(algo, config, batch, H, W, spec.in_channels,
                        spec.out_channels, padding=spec.padding,
                        depthwise=spec.depthwise, vmem_limit=vmem_limit)


def check_candidates(spec, algo: BilinearAlgorithm,
                     candidates: Sequence, *, batch: int = 1,
                     vmem_limit: Optional[int] = None):
    """Partition a candidate sweep into (launchable, rejected).

    ``rejected`` pairs each dropped config with its ERROR findings; the
    autotuner logs and skips them instead of timing a kernel that would
    fail (or silently spill) on hardware.
    """
    ok, rejected = [], []
    for cfg in candidates:
        findings = check_spec_config(spec, algo, cfg, batch=batch,
                                     vmem_limit=vmem_limit)
        errors = [f for f in (findings or []) if f.severity == ERROR]
        if errors:
            rejected.append((cfg, errors))
        else:
            ok.append(cfg)
    return ok, rejected


def fold_fits(algo: BilinearAlgorithm, config, batch: int, H: int, W: int,
              C: int, Cout: int, *, padding: str = "SAME",
              rows_per_step: int) -> bool:
    """Whether folding ``rows_per_step`` into one grid step fits VMEM.

    The serving batcher's view of the kernel's grouping arithmetic: the
    geometry is resolved exactly as ``sfc_fused_conv2d`` would resolve a
    dispatch of ``batch`` images at this folding, and the decision is its
    VMEM budget — so the batcher never requests a grid step the kernel
    would spill on, without re-deriving kb/cb/cache arithmetic by hand.
    """
    geom = sf.fused_geometry(
        algo, batch, H, W, C, Cout, padding=padding,
        k_block=config.k_block, cout_block=config.cout_block,
        rows_per_step=rows_per_step,
        double_buffer=config.double_buffer)
    return geom.vmem_bytes() <= sf.VMEM_LIMIT_BYTES


def default_candidate_report(*, bits_act: int = 8, bits_weight: int = 8
                             ) -> List[Finding]:
    """Check every DEFAULT_CANDIDATES config against a representative
    workload sweep (the CI ``analysis`` job's kernel gate)."""
    from repro.api import registry
    from repro.api.spec import ConvSpec
    from repro.api.tuning import DEFAULT_CANDIDATES
    from repro.quant.fake_quant import QuantConfig
    quant = QuantConfig(enabled=True, bits_act=bits_act,
                        bits_weight=bits_weight)
    findings: List[Finding] = []
    shapes = [(1, 14, 14, 128, 128), (4, 28, 28, 64, 128),
              (1, 224, 224, 64, 64), (8, 7, 7, 512, 512)]
    for entry in registry.entries(taps=3):
        if entry.kind == "winograd":
            continue               # excluded from the int8 fast path
        algo = registry.get_algorithm(entry.name)
        for B, H, W, C, Cout in shapes:
            spec = ConvSpec(kernel_size=3, in_channels=C, out_channels=Cout,
                            spatial=(H, W), quant=quant)
            for cfg in DEFAULT_CANDIDATES:
                got = check_spec_config(spec, algo, cfg, batch=B)
                for f in got or []:
                    findings.append(dataclasses.replace(
                        f, where=f"{entry.name} B{B} {H}x{W} "
                                 f"{C}->{Cout} | {f.where}"))
    return findings
