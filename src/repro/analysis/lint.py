"""Architecture-invariant linter for the ``repro`` source tree.

Pure-stdlib (``ast``) checks for invariants that unit tests cannot see —
they are properties of the *source layout*, not of any runtime value:

  ``ARCH001``  ``repro.api`` is the single entry point.  Importing
               ``repro.kernels`` or ``repro.distributed.conv_spmd``
               anywhere else couples callers to kernel internals and
               bypasses planning/tuning; only the API layer, the kernel
               and distributed packages themselves, the test harness
               (``repro.testing``) and this analysis package (which
               consumes kernel *metadata*, never launches) may.
  ``TIME001``  Serving code (``repro/serve``) must not read
               ``time.time()``: wall-clock is not monotonic, and SLO /
               latency accounting built on it breaks under NTP steps.
               Use ``time.perf_counter`` or the injected ``time_fn``.
  ``EXC001``   No bare ``except:`` — it swallows ``KeyboardInterrupt``
               and ``SystemExit``.
  ``EXC002``   No silent broad handler: ``except Exception`` whose body
               is only ``pass``/``continue`` hides real failures (the
               degradation chain must *log* what it absorbs).
  ``REG001``   ``register_algorithm``/``register_backend`` may only be
               called from the registry seams (``repro/api/registry.py``,
               ``repro/api/backends.py``).  Registration elsewhere makes
               the available-algorithm set import-order dependent.
  ``COST001``  The analytic cost model (``repro/api/costmodel.py``) may
               read launch geometry ONLY through the kernel's
               single-sourced ``fused_geometry``/``FusedGeometry``
               surface (via ``kernel_checks.geometry_for``): referencing
               the kernel's VMEM/blocking helpers (``fused_vmem_bytes``,
               ``VMEM_LIMIT_BYTES``, ``auto_rows_per_step``, ...) would
               re-derive — and inevitably fork — the resource math the
               geometry already owns.

Run via ``python -m repro.analysis --check`` (the CI ``analysis`` job)
or programmatically through :func:`run_lint`.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Iterable, List, Sequence, Tuple, Union

from repro.analysis.kernel_checks import ERROR, Finding

# Path prefixes (relative to the ``repro`` package root) allowed to
# import kernel/distributed internals.
_ARCH_ALLOWED_PREFIXES: Tuple[str, ...] = (
    "api", "kernels", "distributed", "analysis")
_ARCH_ALLOWED_FILES: Tuple[str, ...] = ("testing.py",)
_KERNEL_MODULES: Tuple[str, ...] = (
    "repro.kernels", "repro.distributed.conv_spmd")

# Files allowed to *call* the registration seams.
_REG_ALLOWED: Tuple[str, ...] = ("api/registry.py", "api/backends.py")
_REG_NAMES: Tuple[str, ...] = ("register_algorithm", "register_backend")

# COST001: the cost model's only sanctioned geometry surface.  Any other
# kernel-internal name (VMEM budget helpers, blocking heuristics) inside
# costmodel.py duplicates resource math the geometry single-sources.
_COST_FILE = "api/costmodel.py"
_COST_ALLOWED_KERNEL_NAMES: Tuple[str, ...] = ("fused_geometry",
                                               "FusedGeometry")
_COST_BANNED_NAMES: Tuple[str, ...] = (
    "fused_vmem_bytes", "_vmem_bytes", "VMEM_LIMIT_BYTES",
    "XQ_CACHE_BYTES", "auto_rows_per_step", "cache_fits")


def _package_relpath(path: pathlib.Path, root: pathlib.Path) -> str:
    """Path relative to the ``repro`` package root (or the scan root when
    the tree is not a ``repro`` checkout — lets tests lint tmp trees)."""
    rel = path.relative_to(root)
    parts = rel.parts
    if "repro" in parts:
        parts = parts[max(i for i, p in enumerate(parts)
                          if p == "repro") + 1:]
    return "/".join(parts)


def _is_kernel_module(module: str) -> bool:
    return any(module == m or module.startswith(m + ".")
               for m in _KERNEL_MODULES)


def _arch_allowed(relpath: str) -> bool:
    return (relpath in _ARCH_ALLOWED_FILES
            or any(relpath.startswith(p + "/")
                   for p in _ARCH_ALLOWED_PREFIXES))


def _silent_body(body: Sequence[ast.stmt]) -> bool:
    for stmt in body:
        if isinstance(stmt, (ast.Pass, ast.Continue)):
            continue
        if (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)):
            continue                       # docstring / Ellipsis
        return False
    return True


def _call_name(func: ast.expr) -> str:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return ""


def lint_source(source: str, relpath: str) -> List[Finding]:
    """Lint one module given its package-relative path."""
    findings: List[Finding] = []
    try:
        tree = ast.parse(source, filename=relpath)
    except SyntaxError as exc:
        return [Finding("LNT000", ERROR, f"syntax error: {exc.msg}",
                        f"{relpath}:{exc.lineno or 0}")]
    in_serve = relpath.startswith("serve/")
    arch_ok = _arch_allowed(relpath)
    reg_ok = relpath in _REG_ALLOWED
    is_cost = relpath == _COST_FILE

    for node in ast.walk(tree):
        where = f"{relpath}:{getattr(node, 'lineno', 0)}"

        if is_cost:
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if _is_kernel_module(alias.name):
                        findings.append(Finding(
                            "COST001", ERROR,
                            f"costmodel imports kernel module "
                            f"{alias.name!r} wholesale; read geometry "
                            f"only via fused_geometry/FusedGeometry "
                            f"(kernel_checks.geometry_for)", where))
            elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                    and _is_kernel_module(node.module or ""):
                bad = [a.name for a in node.names
                       if a.name not in _COST_ALLOWED_KERNEL_NAMES]
                if bad:
                    findings.append(Finding(
                        "COST001", ERROR,
                        f"costmodel imports kernel-internal name(s) "
                        f"{bad} from {node.module!r}; only "
                        f"{list(_COST_ALLOWED_KERNEL_NAMES)} are the "
                        f"sanctioned geometry surface", where))
            elif isinstance(node, ast.Name) \
                    and node.id in _COST_BANNED_NAMES:
                findings.append(Finding(
                    "COST001", ERROR,
                    f"costmodel references kernel resource helper "
                    f"{node.id!r}; the launch geometry "
                    f"(FusedGeometry accessors) already owns that "
                    f"math — do not re-derive it", where))
            elif isinstance(node, ast.Attribute) \
                    and node.attr in _COST_BANNED_NAMES:
                findings.append(Finding(
                    "COST001", ERROR,
                    f"costmodel references kernel resource helper "
                    f".{node.attr}; the launch geometry "
                    f"(FusedGeometry accessors) already owns that "
                    f"math — do not re-derive it", where))

        if isinstance(node, ast.Import) and not arch_ok:
            for alias in node.names:
                if _is_kernel_module(alias.name):
                    findings.append(Finding(
                        "ARCH001", ERROR,
                        f"import of kernel-internal module "
                        f"{alias.name!r} outside the API/kernel layers; "
                        f"route through repro.api (or repro.analysis for "
                        f"static metadata)", where))
        elif isinstance(node, ast.ImportFrom) and not arch_ok:
            mod = node.module or ""
            if node.level == 0:
                targets = [mod] + [f"{mod}.{a.name}" if mod else a.name
                                   for a in node.names]
                if any(_is_kernel_module(t) for t in targets):
                    findings.append(Finding(
                        "ARCH001", ERROR,
                        f"import from kernel-internal module {mod!r} "
                        f"outside the API/kernel layers; route through "
                        f"repro.api (or repro.analysis for static "
                        f"metadata)", where))

        elif isinstance(node, ast.Attribute):
            if (in_serve and node.attr == "time"
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "time"):
                findings.append(Finding(
                    "TIME001", ERROR,
                    "time.time() on a serving path: wall-clock is not "
                    "monotonic; use time.perf_counter or the injected "
                    "time_fn", where))

        elif isinstance(node, ast.ExceptHandler):
            if node.type is None:
                findings.append(Finding(
                    "EXC001", ERROR,
                    "bare 'except:' swallows KeyboardInterrupt/"
                    "SystemExit; catch a concrete exception type", where))
            else:
                names = []
                for t in ([node.type] if not isinstance(node.type, ast.Tuple)
                          else node.type.elts):
                    if isinstance(t, ast.Name):
                        names.append(t.id)
                if (set(names) & {"Exception", "BaseException"}
                        and _silent_body(node.body)):
                    findings.append(Finding(
                        "EXC002", ERROR,
                        "broad 'except Exception' with a silent body "
                        "hides real failures; log or narrow it", where))

        elif isinstance(node, ast.Call) and not reg_ok:
            name = _call_name(node.func)
            if name in _REG_NAMES:
                findings.append(Finding(
                    "REG001", ERROR,
                    f"{name}() called outside the registry seams "
                    f"({', '.join(_REG_ALLOWED)}); registration "
                    f"elsewhere makes the algorithm/backend set "
                    f"import-order dependent", where))
    return findings


def iter_py_files(root: pathlib.Path) -> Iterable[pathlib.Path]:
    return sorted(p for p in root.rglob("*.py")
                  if "__pycache__" not in p.parts)


def run_lint(root: Union[str, pathlib.Path]) -> List[Finding]:
    """Lint every ``*.py`` under ``root`` (normally ``src/``)."""
    root = pathlib.Path(root)
    findings: List[Finding] = []
    for path in iter_py_files(root):
        rel = _package_relpath(path, root)
        findings.extend(
            lint_source(path.read_text(encoding="utf-8"), rel))
    return findings


def source_root() -> pathlib.Path:
    """The installed ``repro`` package directory (what ``--check`` scans)."""
    import repro
    # ``repro`` is a namespace package: no __init__.py, so no __file__.
    return pathlib.Path(next(iter(repro.__path__))).resolve()
