"""Numerical error analysis of fast convolution algorithms (paper §5).

Reproduces Table 1:
  * condition numbers kappa(A^T) — reported in two documented conventions,
    since the paper does not pin the normalization:
      - 'tile'   : spectral condition number (sigma_max/sigma_min) of the
                   M x t output transform actually applied per tile;
      - 'square' : the overlapped/square form the paper derives Eq. 12-16
                   with (full slot-space inverse operator).
  * empirical MSE of each algorithm under a quantized element-wise product
    (operands rounded to a low-precision format before multiplying, the
    transforms assumed exact — exactly the paper's error model, Eq. 13),
    normalized so that direct convolution == 1.0.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.generator import (BilinearAlgorithm, direct_algorithm,
                                  paper_algorithms)


def kappa_tile(algo: BilinearAlgorithm) -> float:
    s = np.linalg.svd(algo.at(), compute_uv=False)
    return float(s.max() / s.min())


def kappa_square(algo: BilinearAlgorithm) -> float:
    """Condition number of the square/overlapped inverse operator.

    For the tile algorithms we use the full component->output operator
    padded to its row space: kappa over nonzero singular values of A^T.
    """
    s = np.linalg.svd(algo.at(), compute_uv=False)
    s = s[s > 1e-12 * s.max()]
    return float(s.max() / s.min())


def amplification(algo: BilinearAlgorithm) -> float:
    """Analytic error-amplification factor of the bilinear algorithm.

    With unit-variance inputs and relative elementwise rounding eps,
    E||dy||^2 ~ eps^2 * sum_m sum_i A[m,i]^2 ||b_i||^2 ||g_i||^2.
    Normalized by the same quantity for direct convolution, this is the
    predictor the paper's kappa(A^T) stands in for (and it is provably
    monotone in the observed MSE — tested).  1-D form; 2-D squares it.
    """
    at, bt, g = algo.at(), algo.bt(), algo.g()
    bn = np.sum(bt ** 2, axis=1)
    gn = np.sum(g ** 2, axis=1)
    amp = np.sum((at ** 2) * bn[None, :] * gn[None, :]) / algo.M
    direct = algo.R  # direct conv: R unit components per output
    return float(np.sqrt(amp / direct))


def _round_to(x: np.ndarray, fmt: str) -> np.ndarray:
    if fmt == "fp16":
        return x.astype(np.float16).astype(np.float64)
    if fmt == "fp32":
        return x.astype(np.float32).astype(np.float64)
    if fmt.startswith("int"):
        bits = int(fmt[3:])
        qmax = 2 ** (bits - 1) - 1
        scale = np.max(np.abs(x)) / qmax + 1e-30
        return np.clip(np.round(x / scale), -qmax, qmax) * scale
    raise ValueError(fmt)


def simulate_mse(algo: BilinearAlgorithm, *, fmt: str = "fp16",
                 trials: int = 256, rng: Optional[np.random.RandomState] = None,
                 per_frequency: bool = False) -> float:
    """Empirical 2-D output MSE with a quantized element-wise product.

    Error model of paper Eq. 13: transforms exact (fp64), the two operands
    of the transform-domain product are rounded to ``fmt``; the product error
    is then amplified by A^T.  ``per_frequency=True`` applies one scale per
    transform-domain coordinate (the paper's frequency-wise quantization) —
    only meaningful for intN formats.
    """
    rng = rng or np.random.RandomState(0)
    bt, g, at = algo.bt(), algo.g(), algo.at()
    # Balanced per-component scaling: for floating formats this is
    # scale-invariant (each operand has its own exponent) but prevents fp16
    # overflow for ill-scaled Winograd components; the product is invariant.
    bn = np.linalg.norm(bt, axis=1)
    gn = np.linalg.norm(g, axis=1)
    c = np.sqrt(gn / np.maximum(bn, 1e-30))
    bt = bt * c[:, None]
    g = g / c[:, None]
    errs = []
    tiles_per_trial = 16 if per_frequency else 1
    for _ in range(trials):
        x = rng.randn(tiles_per_trial, algo.L, algo.L)
        w = rng.randn(algo.R, algo.R)
        tx = np.einsum("ti,nij,uj->ntu", bt, x, bt)
        tw = g @ w @ g.T
        exact = np.einsum("mt,ntu,pu->nmp", at, tx * tw[None], at)
        if per_frequency and fmt.startswith("int"):
            # one scale per transform-domain coordinate, shared across the
            # tile batch (paper Eq. 17: s_Tx has shape [T x T])
            bits = int(fmt[3:])
            qmax = 2 ** (bits - 1) - 1
            sx = np.max(np.abs(tx), axis=0) / qmax + 1e-30
            qx = np.clip(np.round(tx / sx), -qmax, qmax) * sx
            sw = np.abs(tw) / qmax + 1e-30
            qw = np.clip(np.round(tw / sw), -qmax, qmax) * sw
        else:
            qx = _round_to(tx, fmt)
            qw = _round_to(tw, fmt)
        approx = np.einsum("mt,ntu,pu->nmp", at, qx * qw[None], at)
        errs.append(np.mean((approx - exact) ** 2))
    return float(np.mean(errs))


def table1(fmt: str = "fp16", trials: int = 256) -> Dict[str, Dict]:
    """Assemble the paper's Table 1 (plus our measured columns)."""
    algos = paper_algorithms()
    # Normalize by direct convolution of the SAME kernel size: the paper's
    # Wino(2x2,5x5) == Wino(4x4,3x3) MSE equality is the fingerprint of this
    # convention (both share N=6 and the same root points).
    base = {R: simulate_mse(direct_algorithm(R), fmt=fmt, trials=trials)
            for R in (3, 5, 7)}
    out = {}
    paper_vals = {   # (MSE, kappa, complexity%) from paper Table 1
        "direct(3x3)": (1.0, 1.0, 100.0),
        "Wino(2x2,3x3)": (2.2, 2.4, 44.4),
        "Wino(3x3,3x3)": (6.4, 14.5, 30.4),
        "Wino(4x4,3x3)": (10.5, 20.1, 25.0),
        "Wino(2x2,5x5)": (10.5, 20.1, 36.0),
        "Wino(2x2,7x7)": (28.1, 31.0, 32.6),
        "SFC-4(4x4,3x3)": (2.4, 2.7, 31.94),
        "SFC-6(6x6,3x3)": (2.4, 3.3, 27.16),
        "SFC-6(7x7,3x3)": (2.6, 3.4, 29.93),
        "SFC-6(6x6,5x5)": (3.6, 3.5, 20.44),
        "SFC-6(4x4,7x7)": (3.6, 3.5, 21.99),
    }
    for name, algo in algos.items():
        mse = simulate_mse(algo, fmt=fmt, trials=trials) / base[algo.R]
        # full-2D-Hermitian multiplication count (paper's second figure:
        # 49->46, 100->88, 144->132, 196->184): each (complex x complex)
        # frequency pair saves 3 mults relative to the separable form.
        ncc = _n_complex_freqs(algo)
        mults_hermitian = algo.mults_2d - 3 * ncc * ncc
        out[name] = {
            "mse": mse,
            "kappa_tile": kappa_tile(algo),
            "amplification": amplification(algo),
            "mults_2d": algo.mults_2d,
            "mults_2d_hermitian": mults_hermitian,
            "complexity_pct": 100.0 * algo.arithmetic_complexity_2d,
            "complexity_pct_hermitian":
                100.0 * mults_hermitian / (algo.M ** 2 * algo.R ** 2),
            "integer_transform": algo.is_integer_transform(),
            "paper": paper_vals.get(name),
        }
    return out


def _n_complex_freqs(algo: BilinearAlgorithm) -> int:
    if algo.kind != "sfc":
        return 0
    meta = dict(algo.meta)
    N = meta["N"]
    return max(0, (N - 1) // 2 if N % 2 else N // 2 - 1)


@dataclasses.dataclass
class ErrorBound:
    """kappa(A^T) * relative elementwise error (paper Eq. 16)."""

    kappa: float
    rel_elementwise: float

    @property
    def rel_output_bound(self) -> float:
        return self.kappa * self.rel_elementwise
