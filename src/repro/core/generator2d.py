"""Full-2D-Hermitian SFC algorithms — the paper's '/88' execution counts.

The separable scheme (generator.py) runs t² multiplications per 2-D tile
(100 for SFC-6(6×6,3×3)).  The paper's second figure (88) exploits the full
2-D Hermitian symmetry: for a pair of *complex* per-dim frequencies (u, v),
the separable 3×3 = 9 real products carry exactly two complex numbers —
X₂d[u, v] and X₂d[u, N−v] (their conjugates complete the 4 grid entries) —
so 6 Karatsuba products suffice: a saving of 3 per (complex×complex) block,
100 − 3·4 = 88 (and 49−3 = 46, 144−12 = 132, 196−12 = 184).

This module builds the *flat* (non-separable) bilinear algorithm
(B^T: t×L², G: t×R², A^T: M²×t) with exact rational arithmetic:

  * real×real / real×complex / corr×anything blocks keep the separable
    structure (no Hermitian savings exist there);
  * each complex×complex block is replaced by two paired 2-D frequencies,
    3 Karatsuba components each, with A^T columns recovered from
    2·Re(X₂d[u,±v]·ω^{−(u·k_r ± v·k_c)})/N² plus the per-dim correction
    bookkeeping inherited from the 1-D solver.

Validated exact (rational, zero-error) against direct 2-D correlation, with
component counts matching the paper (tests/test_generator2d.py).
"""
from __future__ import annotations

import dataclasses
import itertools
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import symbolic
from repro.core.generator import generate_sfc


@dataclasses.dataclass(frozen=True)
class Bilinear2D:
    """Flat 2-D bilinear algorithm: y = A^T ((G w)(B^T x)) on vec inputs."""

    name: str
    M: int
    R: int
    L: int
    BT: Tuple[Tuple[Fraction, ...], ...]   # t x L^2
    G: Tuple[Tuple[Fraction, ...], ...]    # t x R^2
    AT: Tuple[Tuple[Fraction, ...], ...]   # M^2 x t

    @property
    def t(self) -> int:
        return len(self.BT)

    def conv2d_exact(self, x: List[List[Fraction]],
                     w: List[List[Fraction]]) -> List[List[Fraction]]:
        xv = [v for row in x for v in row]
        wv = [v for row in w for v in row]
        tx = [sum(r * v for r, v in zip(row, xv)) for row in self.BT]
        tw = [sum(r * v for r, v in zip(row, wv)) for row in self.G]
        m = [a * b for a, b in zip(tx, tw)]
        y = [sum(r * v for r, v in zip(row, m)) for row in self.AT]
        return [y[i * self.M:(i + 1) * self.M] for i in range(self.M)]

    def bt(self):
        return np.array([[float(v) for v in r] for r in self.BT])

    def g(self):
        return np.array([[float(v) for v in r] for r in self.G])

    def at(self):
        return np.array([[float(v) for v in r] for r in self.AT])


def _kron_rows(r1: Sequence[Fraction], r2: Sequence[Fraction]
               ) -> Tuple[Fraction, ...]:
    return tuple(Fraction(a) * Fraction(b) for a in r1 for b in r2)


def generate_sfc_2d_hermitian(N: int, M: int, R: int) -> Bilinear2D:
    """Build the flat full-Hermitian 2-D SFC-N(M×M, R×R)."""
    base = generate_sfc(N, M, R)
    ring = symbolic.CyclotomicRing.for_points(N)
    freqs = symbolic.real_dft_frequencies(N)
    meta = dict(base.meta)
    n_dft = meta["n_dft_components"]
    L = base.L

    # per-dim component labels: ('real', u) once, ('cplx', u, j) j=0..2,
    # then ('corr', i) for correction rows
    labels: List[Tuple] = []
    for f in freqs:
        if f.kind == "real":
            labels.append(("real", f.u))
        else:
            labels.extend([("cplx", f.u, j) for j in range(3)])
    n_corr = base.t - n_dft
    labels.extend([("corr", i) for i in range(n_corr)])
    assert len(labels) == base.t

    BT1 = [list(r) for r in base.BT]
    G1 = [list(r) for r in base.G]
    AT1 = [list(r) for r in base.AT]

    # ---- flat components ----
    BT2: List[Tuple[Fraction, ...]] = []
    G2: List[Tuple[Fraction, ...]] = []
    # col_map: separable column pair (a, b) -> list of
    #   (flat column index, coeff) so A^T can be rebuilt exactly:
    #   m_sep[a,b] == sum coeff * m_flat[idx]  ... only needed for cc blocks;
    # all other blocks map 1:1.
    col_map: Dict[Tuple[int, int], List[Tuple[int, Fraction]]] = {}

    # index complex freqs: u -> first separable component index
    cplx_start = {}
    idx = 0
    for f in freqs:
        if f.kind == "complex":
            cplx_start[f.u] = idx
            idx += 3
        else:
            idx += 1

    def sep_rows(a: int, b: int):
        return (_kron_rows(BT1[a], BT1[b]), _kron_rows(G1[a], G1[b]))

    handled = set()
    # 1) complex x complex blocks -> paired 6-component form
    cplx_us = [f.u for f in freqs if f.kind == "complex"]
    for u in cplx_us:
        for v in cplx_us:
            au, av = cplx_start[u], cplx_start[v]
            block = [(au + i, av + j) for i in range(3) for j in range(3)]
            handled.update(block)
            # 2-D frequencies (u, v) and (u, N - v): rows over L^2 inputs.
            new_idx = []
            for sv in (v, (N - v) % N):
                a_row = [Fraction(0)] * (L * L)
                b_row = [Fraction(0)] * (L * L)
                aw = [Fraction(0)] * (R * R)
                bw = [Fraction(0)] * (R * R)
                # input side: window offset from the 1-D algorithm
                off = meta["offset"]
                for i in range(N):
                    gi = off + i
                    if gi >= L:
                        continue
                    for j in range(N):
                        gj = off + j
                        if gj >= L:
                            continue
                        a, b = ring.root_power(u * i + sv * j)
                        a_row[gi * L + gj] += a
                        b_row[gi * L + gj] += b
                # weight side: folded reversed kernel per dim
                for r1 in range(R):
                    j1 = (R - 1 - r1) % N
                    for r2 in range(R):
                        j2 = (R - 1 - r2) % N
                        a, b = ring.root_power(u * j1 + sv * j2)
                        aw[r1 * R + r2] += a
                        bw[r1 * R + r2] += b
                base_i = len(BT2)
                BT2.append(tuple(a_row))
                BT2.append(tuple(b_row))
                BT2.append(tuple(x + y for x, y in zip(a_row, b_row)))
                G2.append(tuple(aw))
                G2.append(tuple(bw))
                G2.append(tuple(x + y for x, y in zip(aw, bw)))
                new_idx.append(base_i)
            # map the separable 9 products onto the 6 new ones is not
            # needed: A^T is rebuilt from scratch for these blocks (below),
            # so just remember where they live.
            col_map[("ccblock", u, v)] = new_idx  # type: ignore

    # 2) all other separable column pairs map 1:1 (kron rows)
    flat_of_sep: Dict[Tuple[int, int], int] = {}
    for a in range(base.t):
        for b in range(base.t):
            if (a, b) in handled:
                continue
            flat_of_sep[(a, b)] = len(BT2)
            br, gr = sep_rows(a, b)
            BT2.append(br)
            G2.append(gr)

    # ---- A^T ----
    c0r, c1r = symbolic.karatsuba_recombine(ring)

    def inv_coeff_1d(u_label, slot: int) -> List[Fraction]:
        """coefficients of slot over one 1-D component group."""
        if u_label[0] == "real":
            a, b = ring.root_power((-u_label[1] * slot) % N)
            return [ring.real_part((Fraction(a), Fraction(b)))
                    / N]
        # complex: 3 coefficients (2*Re((C0+C1 s) w))/N
        u = u_label[1]
        a, b = ring.root_power((-u * slot) % N)
        w = (Fraction(a), Fraction(b))
        return [2 * ring.real_part(ring.mul(
            (Fraction(c0r[j]), Fraction(c1r[j])), w)) / N for j in range(3)]

    AT2: List[List[Fraction]] = []
    t2 = len(BT2)
    for mr in range(M):
        for mc in range(M):
            row = [Fraction(0)] * t2
            # A^T separable row = kron(AT1[mr], AT1[mc]); redistribute.
            for a in range(base.t):
                ca = AT1[mr][a]
                if ca == 0:
                    continue
                for b in range(base.t):
                    cb = AT1[mc][b]
                    if cb == 0:
                        continue
                    if (a, b) in flat_of_sep:
                        row[flat_of_sep[(a, b)]] += ca * cb
            # cc blocks: contribution = sum over grid entries
            #   (1/N^2) * 2Re( X2d[u,v] W2d[u,v] w^{-(u kr + v kc)} )
            #          + (1/N^2) * 2Re( X2d[u,N-v] ... w^{-(u kr - v kc)} )
            # where (kr, kc) are the circular slots the 1-D algorithm
            # assigned to outputs mr, mc.  Those slots are recoverable from
            # the 1-D A^T structure only if the output uses a slot; we
            # instead reconstruct directly: the separable A^T row already
            # encodes slot mixtures, so we express the cc contribution by
            # *reusing the same slot mixture*: for components (au+i, av+j)
            # the separable coefficient factorizes as
            # alpha_i(mr) * beta_j(mc) where alpha = AT1[mr][au+i].
            # The 9 separable products of block (u,v) relate linearly to
            # the 6 flat ones; solve that linear relation exactly.
            for u in cplx_us:
                for v in cplx_us:
                    au, av = cplx_start[u], cplx_start[v]
                    alphas = [AT1[mr][au + i] for i in range(3)]
                    betas = [AT1[mc][av + j] for j in range(3)]
                    if all(x == 0 for x in alphas) or \
                            all(x == 0 for x in betas):
                        continue
                    coeffs = _cc_block_coeffs(ring, alphas, betas)
                    base_i0, base_i1 = col_map[("ccblock", u, v)]
                    for j in range(3):
                        row[base_i0 + j] += coeffs[0][j]
                        row[base_i1 + j] += coeffs[1][j]
            AT2.append(row)

    algo = Bilinear2D(
        name=f"SFC-{N}({M}x{M},{R}x{R})-H2D",
        M=M, R=R, L=L,
        BT=tuple(BT2), G=tuple(G2),
        AT=tuple(tuple(r) for r in AT2))
    _validate2d(algo)
    return algo


def _cc_block_coeffs(ring, alphas, betas):
    """Express sum_{i,j} alpha_i beta_j m_sep[i,j] over the 6 flat products.

    Separable products m_sep[i,j] = (row_i(u) x)(row_j(v) x') ... with
    row_{0,1,2} = (P, Q, P+Q).  Define complex Z1 = X2d[u,v]W2d[u,v] and
    Z2 = X2d[u,N-v]W2d[u,N-v].  Using P_u P_v = products of the 1-D
    functionals, algebra over the ring gives an exact linear relation;
    we solve it numerically-exactly by evaluating both sides on a basis.
    """
    # The separable 9 products and the flat 6 products are both bilinear
    # forms in (x2d, w2d) restricted to this block's 4-dim complex subspace
    # (spanned by the 2-D freqs (u,v),(u,-v) and conjugates on each of x,w).
    # We find rational gamma (2x3) with
    #   sum_ij alpha_i beta_j m_sep[i,j] == sum_k gamma_0k m1_k + gamma_1k m2_k
    # by sampling: the x-side state is (p1, q1, p2, q2) (components of the
    # two 2-D freqs), similarly for w; both m_sep and m_flat are
    # polynomial in these 8 rationals.  Build a linear system over a basis
    # of monomials and solve exactly with Fractions.
    import itertools as it
    from fractions import Fraction as F

    alpha, beta = ring.alpha, ring.beta

    def karat(p0, p1, q0, q1):
        m1, m2, m3 = p0 * q0, p1 * q1, (p0 + p1) * (q0 + q1)
        return [m1, m2, m3]

    # separable side: 1-D components of x along dim-u: (P1x, Q1x, P1x+Q1x),
    # along dim-v: (P2x, ...). Their products relate to the 2-D freq
    # components: X2d[u,v] = (P1 + Q1 s)(P2 + Q2 s) etc. -- but the
    # separable scheme's m_sep[i,j] = (r_i(u) o r_j(v) . x) * (same on w):
    # r_i(u) o r_j(v) applied to x equals the product structure of the
    # per-dim functionals evaluated on x's rank-1 component... For the
    # validation-exact path we only need m_sep expressed in the 2-D
    # components, which holds for ALL x because both sides are the same
    # functional of x (symbolically: row_i(u) kron row_j(v) =
    # component of the product (A1 + B1 s)(A2 + B2 s') with s' an
    # independent symbol -- the 2-D transform uses s' = s).
    # Sample the 8 underlying free parameters:
    rng = np.random.RandomState(0)

    def sample():
        vals = [F(int(v)) for v in rng.randint(-9, 10, 8)]
        p1, q1, p2, q2, a1, b1, a2, b2 = vals
        # x-side 1-D comps along u: (p1, q1); along v: (p2, q2)
        # w-side: (a1, b1), (a2, b2)
        xs = [p1, q1, p1 + q1]
        xv = [p2, q2, p2 + q2]
        ws = [a1, b1, a1 + b1]
        wv = [a2, b2, a2 + b2]
        m_sep = [[xs[i] * xv[j] * ws[i] * wv[j] for j in range(3)]
                 for i in range(3)]
        # flat: X2d[u,v] = (p1 + q1 s)(p2 + q2 s) reduced
        def cmul(c0, c1, d0, d1):
            return (c0 * d0 + F(beta) * c1 * d1,
                    c0 * d1 + c1 * d0 + F(alpha) * c1 * d1)
        X1 = cmul(p1, q1, p2, q2)
        W1 = cmul(a1, b1, a2, b2)
        # X2d[u, N-v]: conj on the v factor: (p2 + q2 s~) with s~ = conj(s)
        # = s^{N-1}: express conj(s) = cs0 + cs1 s
        cs0, cs1 = ring.root_power(ring.N - 1)
        X2 = cmul(p1, q1, p2 + q2 * cs0, q2 * cs1)
        W2 = cmul(a1, b1, a2 + b2 * cs0, b2 * cs1)
        m1 = karat(X1[0], X1[1], W1[0], W1[1])
        m2 = karat(X2[0], X2[1], W2[0], W2[1])
        return m_sep, m1 + m2

    # solve for gamma (6 unknowns) from >=8 samples, with the target being
    # sum alpha_i beta_j m_sep[i,j]
    rows, rhs = [], []
    for _ in range(10):
        m_sep, flat = sample()
        rows.append(flat)
        rhs.append(sum(alphas[i] * betas[j] * m_sep[i][j]
                       for i in range(3) for j in range(3)))
    sol = _lstsq_exact(rows, rhs)
    return [sol[:3], sol[3:]]


def _lstsq_exact(rows: List[List[Fraction]], rhs: List[Fraction]
                 ) -> List[Fraction]:
    """Exact solve of an (overdetermined, consistent) rational system."""
    n = len(rows[0])
    # Gaussian elimination on the first n independent rows
    aug = [list(r) + [b] for r, b in zip(rows, rhs)]
    pivots = []
    used = [False] * len(aug)
    for col in range(n):
        piv = None
        for r in range(len(aug)):
            if not used[r] and aug[r][col] != 0:
                piv = r
                break
        if piv is None:
            pivots.append(None)
            continue
        used[piv] = True
        pivots.append(piv)
        inv = Fraction(1) / aug[piv][col]
        aug[piv] = [v * inv for v in aug[piv]]
        for r in range(len(aug)):
            if r != piv and aug[r][col] != 0:
                f = aug[r][col]
                aug[r] = [v - f * u for v, u in zip(aug[r], aug[piv])]
    sol = [Fraction(0)] * n
    for col, piv in enumerate(pivots):
        if piv is not None:
            sol[col] = aug[piv][n]
    # consistency check on the leftover rows
    for r in range(len(aug)):
        if not used[r]:
            resid = aug[r][n]
            assert resid == 0, "cc-block relation inconsistent"
    return sol


def _validate2d(algo: Bilinear2D, trials: int = 2) -> None:
    rng = np.random.RandomState(1)
    for _ in range(trials):
        x = [[Fraction(int(v)) for v in row]
             for row in rng.randint(-5, 6, (algo.L, algo.L))]
        w = [[Fraction(int(v)) for v in row]
             for row in rng.randint(-5, 6, (algo.R, algo.R))]
        got = algo.conv2d_exact(x, w)
        for mr in range(algo.M):
            for mc in range(algo.M):
                want = sum(x[mr + a][mc + b] * w[a][b]
                           for a in range(algo.R) for b in range(algo.R))
                assert got[mr][mc] == want, (
                    f"{algo.name}: mismatch at ({mr},{mc})")
