"""Symbolic arithmetic for SFC (Symbolic Fourier Convolution).

The paper's key observation: for DFT point counts N whose primitive root of
unity has cyclotomic degree <= 2 (N in {1, 2, 3, 4, 6}), every N-th root of
unity is an *integer* first-order polynomial ``a + b*s`` in one symbol ``s``,
with the quadratic reduction rule ``s^2 = alpha*s + beta`` (integer alpha,
beta). The DFT of a real sequence therefore needs only additions, and the
element-wise product in the transform domain is a polynomial product that
reduces to 3 real multiplications (a Karatsuba step, paper Eqs. 8/10).

Everything here is exact: integer root tables and `fractions.Fraction`
inverse-transform coefficients.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import List, Optional, Sequence, Tuple

# N -> (alpha, beta, Re(s)) with s = primitive N-th root of unity e^{2*pi*j/N}
# and reduction s^2 = alpha*s + beta.
_RING_TABLE = {
    1: (0, 1, Fraction(1)),            # s = 1 (degenerate, never used)
    2: (0, 1, Fraction(-1)),           # s = -1: s^2 = 1
    3: (-1, -1, Fraction(-1, 2)),      # s = e^{2pi j/3}: s^2 = -s - 1
    4: (0, -1, Fraction(0)),           # s = j: s^2 = -1
    6: (1, -1, Fraction(1, 2)),        # s = e^{j pi/3}: s^2 = s - 1
}

SUPPORTED_DFT_POINTS = tuple(sorted(_RING_TABLE))


@dataclasses.dataclass(frozen=True)
class CyclotomicRing:
    """Z[s]/(s^2 - alpha*s - beta) with s a primitive N-th root of unity."""

    N: int
    alpha: int
    beta: int
    re_s: Fraction  # real part of s, needed only for the inverse transform

    @classmethod
    def for_points(cls, N: int) -> "CyclotomicRing":
        if N not in _RING_TABLE:
            raise ValueError(
                f"DFT-{N} has irrational-free symbolic form only for "
                f"N in {SUPPORTED_DFT_POINTS}; got N={N}. (Higher N needs "
                "higher-order polynomial terms, see paper App. B.)")
        a, b, re = _RING_TABLE[N]
        return cls(N=N, alpha=a, beta=b, re_s=re)

    def root_power(self, k: int) -> Tuple[int, int]:
        """omega^k = a + b*s with integer a, b (omega = s, the generator)."""
        k = k % self.N
        if self.N <= 2:              # degenerate rings: s is real (+-1)
            return ((-1) ** k if self.N == 2 else 1, 0)
        a, b = 1, 0  # s^0
        for _ in range(k):
            # (a + b s) * s = a s + b s^2 = (b*beta) + (a + b*alpha) s
            a, b = b * self.beta, a + b * self.alpha
        return a, b

    def mul(self, p: Tuple[Fraction, Fraction],
            q: Tuple[Fraction, Fraction]) -> Tuple[Fraction, Fraction]:
        """(p0 + p1 s)(q0 + q1 s) reduced to first order."""
        p0, p1 = p
        q0, q1 = q
        c0 = p0 * q0 + self.beta * p1 * q1
        c1 = p0 * q1 + p1 * q0 + self.alpha * p1 * q1
        return c0, c1

    def real_part(self, p: Tuple[Fraction, Fraction]) -> Fraction:
        return p[0] + p[1] * self.re_s


@dataclasses.dataclass(frozen=True)
class Frequency:
    """One independent frequency of a real-input symbolic DFT.

    ``kind == 'real'``  : X_u is real, 1 component, 1 multiplication.
    ``kind == 'complex'``: X_u = P + Q*s, 3 components via Karatsuba
                           (P, Q, P+Q), 3 multiplications.
    """

    u: int
    kind: str  # 'real' | 'complex'

    @property
    def n_components(self) -> int:
        return 1 if self.kind == "real" else 3


def real_dft_frequencies(N: int) -> List[Frequency]:
    """Independent frequencies of a length-N real DFT (Hermitian symmetry)."""
    freqs = [Frequency(0, "real")]
    for u in range(1, (N + 1) // 2):     # complex freqs: 1 .. ceil(N/2)-1
        freqs.append(Frequency(u, "complex"))
    if N % 2 == 0 and N >= 2:
        freqs.append(Frequency(N // 2, "real"))
    return freqs


def forward_rows(ring: CyclotomicRing, freq: Frequency) -> List[List[int]]:
    """Integer functional rows (length N) producing freq's mult operands.

    For a real frequency: one row r with X_u = sum_i r[i] x_i.
    For a complex frequency: rows (P, Q, P+Q) — the three Karatsuba operands.
    All entries are small integers; for N in {2,3,4,6} they are in
    {-2,-1,0,1,2} (and {-1,0,1} for the plain P,Q rows), i.e. the transform
    is additions only.
    """
    N = ring.N
    a_row = [0] * N
    b_row = [0] * N
    for i in range(N):
        a, b = ring.root_power(freq.u * i)
        a_row[i] = a
        b_row[i] = b
    if freq.kind == "real":
        assert all(v == 0 for v in b_row), (
            f"frequency u={freq.u} of DFT-{N} is not real")
        return [a_row]
    return [a_row, b_row, [x + y for x, y in zip(a_row, b_row)]]


def karatsuba_recombine(ring: CyclotomicRing,
                        ) -> Tuple[List[int], List[int]]:
    """Coefficients turning (m1, m2, m3) into the product components.

    m1 = P*Pw, m2 = Q*Qw, m3 = (P+Q)(Pw+Qw); the reduced product is
    C0 + C1*s with C0 = m1 + beta*m2, C1 = m3 - m1 + (alpha-1)*m2.
    """
    c0 = [1, ring.beta, 0]
    c1 = [-1, ring.alpha - 1, 1]
    return c0, c1


def inverse_slot_coefficients(
        ring: CyclotomicRing,
        freqs: Sequence[Frequency],
        slot: int) -> List[Fraction]:
    """Exact coefficients of circular slot ``k`` over all mult components.

    y_c[k] = (1/N) * sum_{u=0}^{N-1} X''_u omega^{-u k}, where X''_u is the
    transform-domain product.  With Hermitian symmetry the sum over a
    conjugate pair (u, N-u) equals 2*Re(X''_u omega^{-u k}).  Every X''_u is
    linear in that frequency's Karatsuba outputs (m1, m2, m3), so each slot
    is an exact rational functional of the component products.
    """
    N = ring.N
    coeffs: List[Fraction] = []
    c0r, c1r = karatsuba_recombine(ring)
    for f in freqs:
        a, b = ring.root_power((-f.u * slot) % N)
        w = (Fraction(a), Fraction(b))
        if f.kind == "real":
            # Real frequencies (u = 0 and u = N/2) are self-conjugate: they
            # appear exactly once in the full sum.
            coeffs.append(ring.real_part(w) / N)
        else:
            # X''_u = C0 + C1 s, times omega^{-uk} = (a + b s); take 2*Re.
            two = Fraction(2)
            out = []
            for j in range(3):
                prod = ring.mul((Fraction(c0r[j]), Fraction(c1r[j])), w)
                out.append(two * ring.real_part(prod) / N)
            coeffs.extend(out)
    return coeffs
