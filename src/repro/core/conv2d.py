"""Tiled fast convolution execution in JAX (2-D NHWC and 1-D depthwise).

Implements the three-stage bilinear flow (paper Eq. 1) for any
``BilinearAlgorithm`` (SFC, Winograd, direct):

    Y = A^T [ (G W G^T) (.) (B^T X B) ] A

vectorized over batch x tiles x channels. The transform-domain contraction
(stage 2 amortized over C_in/C_out) is the MXU hot spot; a Pallas kernel
version lives in ``repro.kernels`` — this module is the reference/portable
path and the oracle for those kernels.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.generator import BilinearAlgorithm


# --------------------------------------------------------------------------
# Transform-matrix cache
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def transform_matrices(algo: BilinearAlgorithm, dtype: str = "float32"
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Device-resident ``(bt, g, at)`` for ``algo`` at ``dtype``, cached.

    The dtype cast of the exact transform matrices is prepare-time work:
    every kernel wrapper and backend used to rebuild ``jnp.asarray(
    algo.bt(), dtype)`` (and ``sfc_transform`` re-cast per call) on each
    invocation of the hot path.  Algorithms are frozen, hashable
    dataclasses and the registry memoizes instances, so one cache entry
    serves every plan/apply for a given (algorithm, dtype).
    """
    dt = jnp.dtype(dtype)
    # the first call for a given (algo, dtype) can land inside a jit /
    # scan / checkpoint trace; force eager construction so the cache
    # holds concrete arrays, never tracers
    with jax.ensure_compile_time_eval():
        return (jnp.asarray(algo.bt(), dt), jnp.asarray(algo.g(), dt),
                jnp.asarray(algo.at(), dt))


# --------------------------------------------------------------------------
# Tiling helpers
# --------------------------------------------------------------------------
def _overlap_tiles_1d(n_tiles: int, M: int, L: int) -> np.ndarray:
    """Row indices (n_tiles, L) of overlapping tiles with stride M."""
    return np.arange(n_tiles)[:, None] * M + np.arange(L)[None, :]


def pad_amounts(size: int, M: int, R: int, padding: str) -> Tuple[int, int, int]:
    """(lo_pad, hi_pad, out_size) for one spatial dim."""
    if padding == "SAME":
        out = size
        lo = (R - 1) // 2
    elif padding == "VALID":
        out = size - R + 1
        lo = 0
    else:
        raise ValueError(f"padding must be SAME or VALID, got {padding}")
    n_tiles = -(-out // M)  # ceil
    padded_needed = n_tiles * M + R - 1
    hi = padded_needed - size - lo
    return lo, hi, out


# --------------------------------------------------------------------------
# 2-D convolution (NHWC, HWIO weights, stride 1)
# --------------------------------------------------------------------------
def transform_input_2d(x: jnp.ndarray, algo: BilinearAlgorithm,
                       padding: str = "SAME") -> Tuple[jnp.ndarray, Tuple]:
    """(B,H,W,C) -> transform-domain tiles (B, nH, nW, t, t, C)."""
    B, H, W, C = x.shape
    M, R, L = algo.M, algo.R, algo.L
    lo_h, hi_h, out_h = pad_amounts(H, M, R, padding)
    lo_w, hi_w, out_w = pad_amounts(W, M, R, padding)
    xp = jnp.pad(x, ((0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    nH = (xp.shape[1] - (R - 1)) // M
    nW = (xp.shape[2] - (R - 1)) // M
    idx_h = _overlap_tiles_1d(nH, M, L)
    idx_w = _overlap_tiles_1d(nW, M, L)
    tiles = xp[:, idx_h, :, :]            # (B, nH, L, Wp, C)
    tiles = tiles[:, :, :, idx_w, :]      # (B, nH, L, nW, L, C)
    tiles = jnp.transpose(tiles, (0, 1, 3, 2, 4, 5))  # (B,nH,nW,L,L,C)
    bt = transform_matrices(algo, x.dtype.name)[0]
    tx = jnp.einsum("ti,bnwijc,uj->bnwtuc", bt, tiles, bt)
    return tx, (out_h, out_w, nH, nW)


def transform_weights_2d(w: jnp.ndarray, algo: BilinearAlgorithm) -> jnp.ndarray:
    """(R,R,Cin,Cout) -> (t,t,Cin,Cout)."""
    g = transform_matrices(algo, w.dtype.name)[1]
    return jnp.einsum("ti,ijco,uj->tuco", g, w, g)


def transform_domain_matmul(tx: jnp.ndarray, tw: jnp.ndarray,
                            precision=jax.lax.Precision.HIGHEST) -> jnp.ndarray:
    """(B,nH,nW,t,t,Cin) x (t,t,Cin,Cout) -> (B,nH,nW,t,t,Cout).

    The hot loop: t^2 independent GEMMs of shape
    (B*nH*nW, Cin) x (Cin, Cout), one per transform-domain position.
    """
    return jnp.einsum("bnwtuc,tuco->bnwtuo", tx, tw, precision=precision)


def inverse_transform_2d(ty: jnp.ndarray, algo: BilinearAlgorithm,
                         geom: Tuple) -> jnp.ndarray:
    """(B,nH,nW,t,t,Cout) -> (B,H_out,W_out,Cout)."""
    out_h, out_w, nH, nW = geom
    at = transform_matrices(algo, ty.dtype.name)[2]
    y = jnp.einsum("mt,bnwtuo,pu->bnwmpo", at, ty, at)  # (B,nH,nW,M,M,O)
    B = y.shape[0]
    O = y.shape[-1]
    M = algo.M
    y = jnp.transpose(y, (0, 1, 3, 2, 4, 5)).reshape(B, nH * M, nW * M, O)
    return y[:, :out_h, :out_w, :]


def fastconv2d(x: jnp.ndarray, w: jnp.ndarray, algo: BilinearAlgorithm,
               padding: str = "SAME",
               bias: Optional[jnp.ndarray] = None,
               elementwise_hook: Optional[Callable] = None) -> jnp.ndarray:
    """Fast 2-D convolution (cross-correlation, as in ML convention).

    ``elementwise_hook(tx, tw) -> (tx, tw)`` lets the quantization layer
    inject the transform-domain fake-quantization (paper Eq. 17).
    """
    assert w.shape[0] == w.shape[1] == algo.R, (w.shape, algo.R)
    tx, geom = transform_input_2d(x, algo, padding)
    tw = transform_weights_2d(w, algo)
    if elementwise_hook is not None:
        tx, tw = elementwise_hook(tx, tw)
    ty = transform_domain_matmul(tx, tw)
    y = inverse_transform_2d(ty, algo, geom)
    if bias is not None:
        y = y + bias
    return y


def conv2d_direct(x: jnp.ndarray, w: jnp.ndarray,
                  padding: str = "SAME",
                  bias: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Reference direct convolution via lax (NHWC, HWIO, stride 1)."""
    y = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), window_strides=(1, 1), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if bias is not None:
        y = y + bias
    return y


# --------------------------------------------------------------------------
# 1-D depthwise causal convolution (Mamba2 / Zamba2 short conv)
# --------------------------------------------------------------------------
def fastconv1d_depthwise_causal(x: jnp.ndarray, w: jnp.ndarray,
                                algo: BilinearAlgorithm) -> jnp.ndarray:
    """Causal depthwise conv1d: x (B, T, C), w (R, C) -> (B, T, C).

    y[b, t, c] = sum_r x[b, t - (R-1) + r, c] * w[r, c]   (left-padded)

    Depthwise has no channel contraction, so the element-wise stage is a
    true element-wise product — exactly the regime the paper's
    multiplication counting addresses (t/M mults per output vs R direct).
    """
    assert w.shape == (algo.R, x.shape[-1]), (w.shape, algo.R, x.shape)
    g = transform_matrices(algo, w.dtype.name)[1]
    tw = jnp.einsum("tr,rc->tc", g, w)
    return fastconv1d_depthwise_causal_pretransformed(x, tw, algo)


def fastconv1d_depthwise_causal_pretransformed(
        x: jnp.ndarray, tw: jnp.ndarray, algo: BilinearAlgorithm
        ) -> jnp.ndarray:
    """Same flow with offline-transformed weights tw (t, C) — the form
    ``repro.api`` prepared weights feed."""
    B, T, C = x.shape
    assert tw.shape == (algo.t, C), (tw.shape, algo.t, x.shape)
    R, M, L = algo.R, algo.M, algo.L
    n_tiles = -(-T // M)
    xp = jnp.pad(x, ((0, 0), (R - 1, n_tiles * M - T), (0, 0)))
    idx = _overlap_tiles_1d(n_tiles, M, L)
    tiles = xp[:, idx, :]                                   # (B, nT, L, C)
    bt, _, at = transform_matrices(algo, x.dtype.name)
    tx = jnp.einsum("ti,bnic->bntc", bt, tiles)
    ty = tx * tw[None, None, :, :]
    y = jnp.einsum("mt,bntc->bnmc", at, ty)                 # (B,nT,M,C)
    y = y.reshape(B, n_tiles * M, C)
    return y[:, :T, :]


def conv1d_depthwise_causal_direct(x: jnp.ndarray, w: jnp.ndarray
                                   ) -> jnp.ndarray:
    """Oracle for the depthwise causal conv1d."""
    B, T, C = x.shape
    R = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (R - 1, 0), (0, 0)))
    out = jnp.zeros((B, T, C), dtype=x.dtype)
    for r in range(R):
        out = out + xp[:, r:r + T, :] * w[r][None, None, :]
    return out
