"""Iterative SFC convolution for very large kernels (paper Appendix B).

Large (depthwise) kernels are handled by a two-level nesting: the kernel is
split into ``Ro`` tiles of ``Ri`` taps and the feature map into overlapping
tiles on a stride-``Mi`` grid; the per-tile correlations are accelerated by
an *inner* SFC algorithm and the accumulation across kernel tiles — itself a
correlation over the tile grid — by an *outer* SFC algorithm.  Total
multiplications per composed tile = t_outer * t_inner (paper: 132*132 for a
29x29 kernel == ~3% of direct).

Exactness requires the tile grid to align: **inner kernel-tile size Ri must
equal the inner output-tile size Mi** (the paper's uneven 5/6 split needs
extra unspecified corrections; we use the aligned variant and report the
achieved ratio — same order as the paper's 3%).  With

    X[p, j] = x[p*Mi + j]        p = 0..(Mo+Ro-2),  j = 0..L_i-1

the large correlation becomes a separable 2-D bilinear form over (p, j),
so the standard SFC flow applies along each axis.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core.generator import BilinearAlgorithm


def iterative_mult_count(outer: BilinearAlgorithm,
                         inner: BilinearAlgorithm,
                         two_d: bool = True) -> int:
    """Multiplications per composed output tile (App. B accounting)."""
    per_dim = outer.t * inner.t
    return per_dim * per_dim if two_d else per_dim


def iterative_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                     inner: BilinearAlgorithm,
                     outer: BilinearAlgorithm) -> jnp.ndarray:
    """1-D valid correlation with a large kernel via 2-level SFC nesting.

    x: (Mo*Mi + Ro*Ri - 1,), w: (Ro*Ri,) -> y: (Mo*Mi,)
    with the alignment condition inner.R == inner.M.
    """
    Ri, Mi, Li = inner.R, inner.M, inner.L
    Ro, Mo, Lo = outer.R, outer.M, outer.L
    if Ri != Mi:
        raise ValueError(
            f"nested SFC needs inner.R == inner.M for grid alignment; "
            f"got R={Ri}, M={Mi}")
    Rw, Mtot = Ro * Ri, Mo * Mi
    assert w.shape == (Rw,), (w.shape, Rw)
    assert x.shape[0] == Mtot + Rw - 1, (x.shape, Mtot + Rw - 1)

    # Overlapping arrangement X[p, j] = x[p*Mi + j]; the last tiles read past
    # the end of x by (Li - Mi) = Ri - 1 elements -> zero-pad.
    P = Lo  # = Mo + Ro - 1 outer positions
    xp = jnp.pad(x, (0, P * Mi + Li - Mi - x.shape[0]))
    idx = np.arange(P)[:, None] * Mi + np.arange(Li)[None, :]
    X = xp[idx]                                     # (P, Li)
    W = w.reshape(Ro, Ri)                           # (Ro, Ri)

    bo = jnp.asarray(outer.bt(), dtype=x.dtype)     # (t_o, Lo)
    bi = jnp.asarray(inner.bt(), dtype=x.dtype)     # (t_i, Li)
    go = jnp.asarray(outer.g(), dtype=x.dtype)      # (t_o, Ro)
    gi = jnp.asarray(inner.g(), dtype=x.dtype)      # (t_i, Ri)
    ao = jnp.asarray(outer.at(), dtype=x.dtype)     # (Mo, t_o)
    ai = jnp.asarray(inner.at(), dtype=x.dtype)     # (Mi, t_i)

    TX = jnp.einsum("op,ij,pj->oi", bo, bi, X)      # (t_o, t_i)
    TW = jnp.einsum("ok,ir,kr->oi", go, gi, W)      # (t_o, t_i)
    TY = TX * TW                                    # t_o * t_i mults
    Y = jnp.einsum("mo,ni,oi->mn", ao, ai, TY)      # (Mo, Mi)
    return Y.reshape(Mtot)


def large_kernel_report(kernel_size: int, inner: BilinearAlgorithm,
                        outer: BilinearAlgorithm) -> dict:
    """Multiplication accounting for one composed 2-D output tile."""
    Mtot = outer.M * inner.M
    direct = (Mtot * kernel_size) ** 2
    nested = iterative_mult_count(outer, inner, two_d=True)
    return {
        "kernel": kernel_size,
        "outputs_2d": Mtot * Mtot,
        "direct_mults": direct,
        "nested_mults": nested,
        "ratio_pct": 100.0 * nested / direct,
    }
