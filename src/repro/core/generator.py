"""Bilinear fast-convolution algorithm generators.

Every algorithm here is a bilinear triple (B^T, G, A^T) computing M
correlation outputs from L = M + R - 1 inputs and R weights:

    y = A^T @ ((G @ w) * (B^T @ x))          (1-D)
    Y = A^T @ ((G W G^T) * (B^T X B)) @ A    (2-D, by separability)

Generators:
  * ``generate_sfc(N, M, R)``    — the paper's Symbolic Fourier Convolution:
      circular DFT-N part (additions-only integer transforms) plus the
      correction-term mechanism of §4.2 that converts wrapped circular slots
      into extra valid outputs (slots may be *reused* by several outputs).
  * ``generate_winograd(M, R)``  — Toom-Cook/Winograd baseline via exact
      Lagrange interpolation with the standard small root points.
  * ``direct_algorithm(R)``      — direct convolution expressed in the same
      form (B^T = G = A^T = I-ish), for unified error analysis (paper Eq. 12).

All matrices are built with exact `fractions.Fraction` arithmetic and
validated for exactness; float64 copies are exported for numeric use.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import symbolic


# --------------------------------------------------------------------------
# Algorithm container
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BilinearAlgorithm:
    """An (M, R) fast correlation algorithm with t multiplications per dim."""

    name: str
    M: int                      # outputs per tile per dim
    R: int                      # kernel taps per dim
    BT: Tuple[Tuple[Fraction, ...], ...]   # t x L input transform
    G: Tuple[Tuple[Fraction, ...], ...]    # t x R weight transform
    AT: Tuple[Tuple[Fraction, ...], ...]   # M x t output transform
    kind: str = "generic"       # 'sfc' | 'winograd' | 'direct'
    meta: Tuple[Tuple[str, object], ...] = ()

    # ---- derived sizes ----
    @property
    def L(self) -> int:
        return self.M + self.R - 1

    @property
    def t(self) -> int:
        """Multiplications per 1-D tile (rows of B^T)."""
        return len(self.BT)

    @property
    def mults_2d(self) -> int:
        return self.t * self.t

    @property
    def arithmetic_complexity_2d(self) -> float:
        """Transform-domain mults / direct-conv mults, 2-D (paper Table 1)."""
        return self.mults_2d / float(self.M * self.M * self.R * self.R)

    # ---- numeric matrices ----
    # Memoized per instance: the exact->float conversion is pure, and the
    # kernel wrappers fetch these on every trace/apply (the frozen
    # dataclass blocks normal attribute writes, hence object.__setattr__).
    def bt(self) -> np.ndarray:
        return self._f64("BT")

    def g(self) -> np.ndarray:
        return self._f64("G")

    def at(self) -> np.ndarray:
        return self._f64("AT")

    def _f64(self, field: str) -> np.ndarray:
        cache = self.__dict__.get("_f64_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_f64_cache", cache)
        if field not in cache:
            arr = _to_f64(getattr(self, field))
            arr.setflags(write=False)     # shared instance: keep it frozen
            cache[field] = arr
        return cache[field]

    # ---- exact reference (Fractions, python lists) ----
    def conv1d_exact(self, x: Sequence[Fraction],
                     w: Sequence[Fraction]) -> List[Fraction]:
        assert len(x) == self.L and len(w) == self.R
        tx = [sum(r * v for r, v in zip(row, x)) for row in self.BT]
        tw = [sum(r * v for r, v in zip(row, w)) for row in self.G]
        m = [a * b for a, b in zip(tx, tw)]
        return [sum(r * v for r, v in zip(row, m)) for row in self.AT]

    def condition_number_at(self) -> float:
        """kappa(A^T) = sigma_max / sigma_min (paper Table 1)."""
        s = np.linalg.svd(self.at(), compute_uv=False)
        return float(s.max() / s.min())

    def transform_addition_counts(self) -> Dict[str, int]:
        """Nonzero-structure addition counts (BOPs accounting, naive)."""
        def adds(mat_rows):
            total = 0
            for row in mat_rows:
                nz = sum(1 for v in row if v != 0)
                total += max(nz - 1, 0)
            return total
        return {"input": adds(self.BT), "weight": adds(self.G),
                "output": adds(self.AT)}

    def is_integer_transform(self) -> bool:
        """True iff B^T and G are integral (the SFC additions-only claim)."""
        for mat in (self.BT, self.G):
            for row in mat:
                for v in row:
                    if Fraction(v).denominator != 1:
                        return False
        return True


def _to_f64(mat: Tuple[Tuple[Fraction, ...], ...]) -> np.ndarray:
    return np.array([[float(v) for v in row] for row in mat], dtype=np.float64)


def _freeze(mat: List[List[Fraction]]) -> Tuple[Tuple[Fraction, ...], ...]:
    return tuple(tuple(Fraction(v) for v in row) for row in mat)


# --------------------------------------------------------------------------
# SFC generator (paper §4)
# --------------------------------------------------------------------------
def _slot_pairings(N: int, R: int, offset: int, L: int, slot: int
                   ) -> List[Optional[int]]:
    """Global input index paired with tap r in circular slot ``slot``.

    Circular convolution of the windowed inputs x~[i] = x[offset+i]
    (zero when offset+i >= L) with the *folded, reversed* kernel
    f~[j] = sum_{r: (R-1-r) mod N == j} w[r].  Tap r therefore multiplies
    x~[(slot - (R-1-r)) mod N].
    """
    out: List[Optional[int]] = []
    for r in range(R):
        j = (R - 1 - r) % N
        i = (slot - j) % N
        gidx = offset + i
        out.append(gidx if gidx < L else None)
    return out


def generate_sfc(N: int, M: int, R: int,
                 offset: Optional[int] = None) -> BilinearAlgorithm:
    """Construct SFC-N(M, R) per paper §4.1–4.2.

    The circular DFT-N provides N slots; slots whose taps all match a desired
    output window are free; any other output is produced from the cheapest
    slot plus correction components ``(x_a - x_b) * w_r`` (one multiplication
    each, paper Fig. 2) — or from scratch when no slot helps.  One slot may
    serve several outputs (this is how SFC-6(7x7,3x3) reaches 144 = 12^2
    mults instead of 196).  The search over window offsets is exhaustive.
    """
    ring = symbolic.CyclotomicRing.for_points(N)
    freqs = symbolic.real_dft_frequencies(N)
    L = M + R - 1

    def solve(offset: int):
        """Greedy-optimal per-output slot assignment for a given window."""
        assignments = []  # (m, slot|None, corrections=[(r, paired_idx|None)])
        total = 0
        for m in range(M):
            best = None
            for slot in range(N):
                pairing = _slot_pairings(N, R, offset, L, slot)
                corr = [(r, pairing[r]) for r in range(R)
                        if pairing[r] != m + r]
                cost = len(corr)
                if best is None or cost < best[2]:
                    best = (slot, corr, cost)
            # building from scratch costs R multiplications
            if best[2] >= R:
                best = (None, [(r, None) for r in range(R)], R)
            assignments.append((m, best[0], best[1]))
            total += best[2]
        return total, assignments

    if offset is None:
        candidates = range(max(1, L - N + 1)) if L > N else [0]
        offset, (_, assignments) = min(
            ((o, solve(o)) for o in candidates), key=lambda kv: kv[1][0])
    else:
        _, assignments = solve(offset)

    # --- circular (DFT) components ---
    bt_rows: List[List[Fraction]] = []
    g_rows: List[List[Fraction]] = []
    for f in freqs:
        for row in symbolic.forward_rows(ring, f):
            # input side: window positions -> global columns
            brow = [Fraction(0)] * L
            for i, v in enumerate(row):
                gidx = offset + i
                if gidx < L and v:
                    brow[gidx] += v
            bt_rows.append(brow)
        # weight side: G_u[r] from omega^{u * ((R-1-r) mod N)}
        a_row = [Fraction(0)] * R
        b_row = [Fraction(0)] * R
        for r in range(R):
            j = (R - 1 - r) % N
            a, b = ring.root_power(f.u * j)
            a_row[r] += a
            b_row[r] += b
        if f.kind == "real":
            assert all(v == 0 for v in b_row)
            g_rows.append(a_row)
        else:
            g_rows.append(a_row)
            g_rows.append(b_row)
            g_rows.append([x + y for x, y in zip(a_row, b_row)])

    n_dft = len(bt_rows)
    assert n_dft == sum(f.n_components for f in freqs) == len(g_rows)

    # --- correction components (deduplicated) ---
    corr_index: Dict[Tuple[Tuple[Fraction, ...], Tuple[Fraction, ...]], int] = {}
    corr_bt: List[List[Fraction]] = []
    corr_g: List[List[Fraction]] = []
    at_rows: List[List[Fraction]] = []
    for m, slot, corrections in assignments:
        if slot is not None:
            at = list(symbolic.inverse_slot_coefficients(ring, freqs, slot))
        else:
            at = [Fraction(0)] * n_dft
        corr_cols: Dict[int, Fraction] = {}
        for r, paired in corrections:
            brow = [Fraction(0)] * L
            brow[m + r] += 1
            if paired is not None:
                brow[paired] -= 1
            grow = [Fraction(0)] * R
            grow[r] += 1
            key = (tuple(brow), tuple(grow))
            if key not in corr_index:
                corr_index[key] = len(corr_bt)
                corr_bt.append(brow)
                corr_g.append(grow)
            corr_cols[corr_index[key]] = Fraction(1)
        at_rows.append((at, corr_cols))

    t = n_dft + len(corr_bt)
    AT: List[List[Fraction]] = []
    for at, corr_cols in at_rows:
        row = list(at) + [Fraction(0)] * len(corr_bt)
        for ci, v in corr_cols.items():
            row[n_dft + ci] += v
        AT.append(row)

    algo = BilinearAlgorithm(
        name=f"SFC-{N}({M}x{M},{R}x{R})",
        M=M, R=R,
        BT=_freeze(bt_rows + corr_bt),
        G=_freeze(g_rows + corr_g),
        AT=_freeze(AT),
        kind="sfc",
        meta=(("N", N), ("offset", offset),
              ("n_dft_components", n_dft),
              ("n_corrections", len(corr_bt))),
    )
    _validate_exact(algo)
    return algo


# --------------------------------------------------------------------------
# Winograd / Toom-Cook baseline
# --------------------------------------------------------------------------
_DEFAULT_POINTS = [0, 1, -1, 2, -2, Fraction(1, 2), Fraction(-1, 2), 4, -4,
                   Fraction(1, 4), Fraction(-1, 4), 3, -3]

_INF = "inf"


def generate_winograd(M: int, R: int,
                      points: Optional[Sequence] = None) -> BilinearAlgorithm:
    """Winograd F(M, R) via the transposition of Toom-Cook interpolation.

    Linear convolution LC(M, R) evaluates the product polynomial at
    N = M + R - 1 points (last point at infinity) and interpolates; the
    correlation form F(M, R) is its transpose:
        B^T = (V^T)^{-1} (N x L),  G = E_R (N x R),  A^T = E_M^T (M x N).
    """
    N = M + R - 1
    if points is None:
        points = list(_DEFAULT_POINTS[: N - 1]) + [_INF]
    assert len(points) == N

    def eval_matrix(ncols: int) -> List[List[Fraction]]:
        rows = []
        for p in points:
            if p == _INF:
                rows.append([Fraction(0)] * (ncols - 1) + [Fraction(1)])
            else:
                pf = Fraction(p)
                rows.append([pf ** c for c in range(ncols)])
        return rows

    # Full N x N evaluation (degree N-1 product polynomial); at infinity the
    # evaluation picks the leading coefficient.
    V = eval_matrix(N)
    Vinv = _fraction_inverse(V)
    # B^T = (V^{-1})^T : N x N; input length L == N for Winograd.
    BT = [[Vinv[c][i] for c in range(N)] for i in range(N)]
    G = eval_matrix(R)
    EM = eval_matrix(M)
    AT = [[EM[i][m] for i in range(N)] for m in range(M)]

    # Practical (wincnn-style) scaling: make B^T integral by scaling each row
    # by the LCM of its denominators and compensating in the corresponding G
    # row (m_i = (b_i.x)(g_i.w) is invariant under b_i *= c, g_i /= c).  This
    # matches deployed Winograd matrices (integer input transform, fractional
    # weight transform, integral output transform) — the configuration whose
    # numerical behaviour the paper's Table 1 characterizes.
    import math
    for i in range(N):
        lcm = 1
        for v in BT[i]:
            lcm = lcm * v.denominator // math.gcd(lcm, v.denominator)
        if lcm != 1:
            BT[i] = [v * lcm for v in BT[i]]
            G[i] = [v / lcm for v in G[i]]

    algo = BilinearAlgorithm(
        name=f"Winograd({M}x{M},{R}x{R})",
        M=M, R=R,
        BT=_freeze(BT), G=_freeze(G), AT=_freeze(AT),
        kind="winograd",
        meta=(("points", tuple(str(p) for p in points)),),
    )
    _validate_exact(algo)
    return algo


def direct_algorithm(R: int) -> BilinearAlgorithm:
    """Direct convolution as a bilinear algorithm with M = 1 (paper Eq. 12)."""
    eye = [[Fraction(int(i == j)) for j in range(R)] for i in range(R)]
    algo = BilinearAlgorithm(
        name=f"direct({R}x{R})", M=1, R=R,
        BT=_freeze(eye), G=_freeze(eye),
        AT=_freeze([[Fraction(1)] * R]),
        kind="direct")
    _validate_exact(algo)
    return algo


def _fraction_inverse(mat: List[List[Fraction]]) -> List[List[Fraction]]:
    n = len(mat)
    a = [[Fraction(v) for v in row] + [Fraction(int(i == j)) for j in range(n)]
         for i, row in enumerate(mat)]
    for col in range(n):
        piv = next(r for r in range(col, n) if a[r][col] != 0)
        a[col], a[piv] = a[piv], a[col]
        inv = Fraction(1) / a[col][col]
        a[col] = [v * inv for v in a[col]]
        for r in range(n):
            if r != col and a[r][col] != 0:
                f = a[r][col]
                a[r] = [v - f * u for v, u in zip(a[r], a[col])]
    return [row[n:] for row in a]


# --------------------------------------------------------------------------
# Exactness validation (rational arithmetic, zero tolerance)
# --------------------------------------------------------------------------
def _validate_exact(algo: BilinearAlgorithm, trials: int = 3) -> None:
    rng = np.random.RandomState(0)
    for _ in range(trials):
        x = [Fraction(int(v)) for v in rng.randint(-9, 10, size=algo.L)]
        w = [Fraction(int(v)) for v in rng.randint(-9, 10, size=algo.R)]
        got = algo.conv1d_exact(x, w)
        want = [sum(x[m + r] * w[r] for r in range(algo.R))
                for m in range(algo.M)]
        if got != want:
            raise AssertionError(
                f"{algo.name}: bilinear algorithm is NOT exact.\n"
                f"got  = {[str(v) for v in got]}\n"
                f"want = {[str(v) for v in want]}")


# --------------------------------------------------------------------------
# Registry of paper algorithms
# --------------------------------------------------------------------------
def paper_algorithms() -> Dict[str, BilinearAlgorithm]:
    """All algorithms appearing in paper Table 1 (plus direct conv)."""
    algos = {
        "direct(3x3)": direct_algorithm(3),
        "Wino(2x2,3x3)": generate_winograd(2, 3),
        "Wino(3x3,3x3)": generate_winograd(3, 3),
        "Wino(4x4,3x3)": generate_winograd(4, 3),
        "Wino(2x2,5x5)": generate_winograd(2, 5),
        "Wino(2x2,7x7)": generate_winograd(2, 7),
        "SFC-4(4x4,3x3)": generate_sfc(4, 4, 3),
        "SFC-6(6x6,3x3)": generate_sfc(6, 6, 3),
        "SFC-6(7x7,3x3)": generate_sfc(6, 7, 3),
        "SFC-6(6x6,5x5)": generate_sfc(6, 6, 5),
        "SFC-6(4x4,7x7)": generate_sfc(6, 4, 7),
    }
    return algos
