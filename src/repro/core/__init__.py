"""SFC core: the paper's contribution as a composable JAX module.

The conv *entry points* re-exported here (``fastconv2d``,
``fastconv1d_depthwise_causal``, ``conv2d_direct``, ...) are deprecation
shims: new code should go through ``repro.api``
(``ConvSpec`` -> ``plan`` -> ``ConvPlan.apply``), which owns algorithm
selection, weight preparation, and backend dispatch.  The transform
primitives (``transform_input_2d`` etc.) remain the supported low-level
building blocks the API backends are made of.
"""
from repro._deprecation import deprecated as _deprecated

from repro.core.generator import (BilinearAlgorithm, direct_algorithm,
                                  generate_sfc, generate_winograd,
                                  paper_algorithms)
from repro.core import conv2d as _conv2d
from repro.core.conv2d import (transform_domain_matmul, transform_input_2d,
                               transform_weights_2d, inverse_transform_2d)
from repro.core.generator2d import Bilinear2D, generate_sfc_2d_hermitian
from repro.core import error_analysis, iterative, symbolic

fastconv2d = _deprecated(
    _conv2d.fastconv2d, "repro.core",
    "repro.api.plan(ConvSpec(...)).apply")
conv2d_direct = _deprecated(
    _conv2d.conv2d_direct, "repro.core",
    "repro.api.plan(ConvSpec(...), algo='direct')")
fastconv1d_depthwise_causal = _deprecated(
    _conv2d.fastconv1d_depthwise_causal, "repro.core",
    "repro.api.plan(ConvSpec.for_conv1d_depthwise(...)).apply")
conv1d_depthwise_causal_direct = _deprecated(
    _conv2d.conv1d_depthwise_causal_direct, "repro.core",
    "repro.api.plan(ConvSpec.for_conv1d_depthwise(...), algo='direct')")

__all__ = [
    "BilinearAlgorithm", "direct_algorithm", "generate_sfc",
    "generate_winograd", "paper_algorithms", "fastconv2d", "conv2d_direct",
    "fastconv1d_depthwise_causal", "conv1d_depthwise_causal_direct",
    "transform_domain_matmul", "transform_input_2d", "transform_weights_2d",
    "inverse_transform_2d", "error_analysis", "iterative", "symbolic",
]
