"""SFC core: the paper's contribution as a composable JAX module."""
from repro.core.generator import (BilinearAlgorithm, direct_algorithm,
                                  generate_sfc, generate_winograd,
                                  paper_algorithms)
from repro.core.conv2d import (conv1d_depthwise_causal_direct, conv2d_direct,
                               fastconv1d_depthwise_causal, fastconv2d,
                               transform_domain_matmul, transform_input_2d,
                               transform_weights_2d, inverse_transform_2d)
from repro.core.generator2d import Bilinear2D, generate_sfc_2d_hermitian
from repro.core import error_analysis, iterative, symbolic

__all__ = [
    "BilinearAlgorithm", "direct_algorithm", "generate_sfc",
    "generate_winograd", "paper_algorithms", "fastconv2d", "conv2d_direct",
    "fastconv1d_depthwise_causal", "conv1d_depthwise_causal_direct",
    "transform_domain_matmul", "transform_input_2d", "transform_weights_2d",
    "inverse_transform_2d", "error_analysis", "iterative", "symbolic",
]
