import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, record memory/cost/collective analysis for §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 2-pod mesh

Results are cached as JSON under experiments/dryrun/ so the sweep is
resumable; EXPERIMENTS.md §Dry-run / §Roofline are generated from them.
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, cells, get_config
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_lib
from repro.models.registry import build
from repro.optim.optimizers import AdamW
from repro.train import steps as steps_lib

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]{1,0}' -> bytes."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str):
    """Sum operand bytes of every collective op in the (SPMD) HLO."""
    totals = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:%?[\w.\-]+\s*=\s*)?"
                     r"(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
                     r"([a-z0-9\-]+)", ls)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-start"):
            op = op[:-6]
        if op not in _COLLECTIVES:
            continue
        # operand shapes: inside the op's argument list
        args = ls.split(op, 1)[1]
        shapes = re.findall(r"([a-z0-9]+\[[0-9,]*\])", args)
        totals[op] += sum(_shape_bytes(s) for s in shapes)
        counts[op] += 1
    return totals, counts


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build(cfg)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))

    from repro.distributed import act_sharding as acts
    acts.install(mesh, shd.batch_axes(mesh))
    with mesh:
        params_abs = model.init_abstract()
        pspecs = shd.params_pspecs(params_abs, cfg, mesh)
        p_shard = shd.sanitized_shardings(pspecs, params_abs, mesh)

        if shape.kind == "train":
            opt = AdamW(lr=3e-4)
            state_abs = steps_lib.abstract_train_state(model, opt)
            state_pspecs = steps_lib.TrainState(
                params=pspecs,
                opt=shd.opt_state_pspecs(state_abs.opt, pspecs),
                rng=jax.sharding.PartitionSpec())
            state_shard = shd.sanitized_shardings(state_pspecs, state_abs,
                                                  mesh)
            batch_abs = model.batch_specs(shape)
            b_shard = shd.sanitized_shardings(
                shd.batch_pspecs(batch_abs, mesh), batch_abs, mesh)
            step = steps_lib.make_train_step(model, opt)
            jitted = jax.jit(step,
                             in_shardings=(state_shard, b_shard),
                             out_shardings=(state_shard, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            batch_abs = model.batch_specs(shape)
            b_shard = shd.sanitized_shardings(
                shd.batch_pspecs(batch_abs, mesh), batch_abs, mesh)
            memory = batch_abs.get("vision", batch_abs.get("frames"))
            mem_shard = (None if memory is None else
                         shd.sanitized_shardings(
                             shd.batch_pspecs(memory, mesh), memory, mesh))
            step = steps_lib.make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(
                p_shard, b_shard["tokens"], mem_shard))
            lowered = jitted.lower(params_abs, batch_abs["tokens"], memory)
        else:  # decode
            cache_abs = model.cache_abstract(shape)
            c_pspecs = shd.cache_pspecs(cache_abs, cfg, mesh)
            c_shard = shd.sanitized_shardings(c_pspecs, cache_abs, mesh)
            dec = model.decode_specs(shape)
            b = shd.batch_axes(mesh)
            tok_shard = shd.sanitized_shardings(
                jax.sharding.PartitionSpec(b, None), dec["tokens"], mesh)
            pos_shard = shd.sanitized_shardings(
                jax.sharding.PartitionSpec(b), dec["pos"], mesh)
            step = steps_lib.make_serve_step(model)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, c_shard, tok_shard,
                                           pos_shard),
                             out_shardings=(tok_shard, None, c_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, dec["tokens"],
                                   dec["pos"])

        compiled = lowered.compile()
        from repro.launch import hlo_analysis
        cost = hlo_analysis.normalize_cost_analysis(compiled.cost_analysis())
        try:
            mem = compiled.memory_analysis()
            mem_info = {
                "argument_size_in_bytes": getattr(
                    mem, "argument_size_in_bytes", None),
                "output_size_in_bytes": getattr(
                    mem, "output_size_in_bytes", None),
                "temp_size_in_bytes": getattr(
                    mem, "temp_size_in_bytes", None),
                "generated_code_size_in_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            }
        except Exception as e:          # CPU backend may not implement it
            mem_info = {"error": str(e)}
        hlo = compiled.as_text()
        coll, coll_counts = collective_bytes(hlo)
        # loop-aware analysis (cost_analysis counts while bodies once; see
        # repro/launch/hlo_analysis.py) + archive the HLO for §Perf work
        summary = hlo_analysis.analyze(hlo)
        import gzip
        hlo_dir = OUT_DIR.parent / "hlo"
        hlo_dir.mkdir(parents=True, exist_ok=True)
        mesh_tag = "pod2" if multi_pod else "pod1"
        with gzip.open(hlo_dir / f"{mesh_tag}_{arch}_{shape_name}.hlo.gz",
                       "wt") as f:
            f.write(hlo)

    acts.clear()
    n_params = sum(np.prod(l.shape) for l in
                   jax.tree_util.tree_leaves(params_abs))
    model_flops = 6 * cfg.active_param_count() * (
        shape.seq_len * shape.global_batch if shape.kind == "train"
        else (shape.seq_len * shape.global_batch if shape.kind == "prefill"
              else shape.global_batch))
    if shape.kind != "train":
        model_flops = model_flops / 3  # fwd only = 2ND

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "n_params": int(n_params),
        "active_params": int(cfg.active_param_count()),
        "hlo_flops": cost.get("flops"),
        "hlo_bytes": cost.get("bytes accessed"),
        "model_flops": float(model_flops),
        "collective_bytes": coll,
        "collective_counts": coll_counts,
        "memory": mem_info,
        # loop-corrected (per-device, trip counts multiplied through)
        "la_flops": summary.flops,
        "la_collective_bytes": summary.collective_bytes,
        "la_collective_counts": summary.collective_counts,
        "la_traffic_bytes": summary.traffic_bytes,
        "la_param_bytes": summary.param_bytes,
        "la_loop_trips": {k: v for k, v in
                          sorted(summary.loop_trips.items())[:40]},
    }


def run_cell(arch, shape_name, multi_pod, force=False):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    mesh_tag = "pod2" if multi_pod else "pod1"
    out = OUT_DIR / f"{mesh_tag}_{arch}_{shape_name}.json"
    if out.exists() and not force:
        print(f"[skip] {out.name} (cached)")
        return json.loads(out.read_text())
    t0 = time.time()
    print(f"[lower+compile] {mesh_tag} {arch} {shape_name} ...", flush=True)
    try:
        rec = lower_cell(arch, shape_name, multi_pod)
        rec["compile_seconds"] = time.time() - t0
        out.write_text(json.dumps(rec, indent=1))
        print(f"[ok] {out.name} flops={rec['hlo_flops']:.3e} "
              f"({rec['compile_seconds']:.0f}s)", flush=True)
        return rec
    except Exception:
        err = traceback.format_exc()
        print(f"[FAIL] {mesh_tag} {arch} {shape_name}\n{err}", flush=True)
        (OUT_DIR / f"FAIL_{mesh_tag}_{arch}_{shape_name}.txt").write_text(err)
        return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    todo = cells()
    if args.arch:
        todo = [(a, s) for a, s in todo if a == args.arch]
    if args.shape:
        todo = [(a, s) for a, s in todo if s == args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    ok = fail = 0
    for multi_pod in meshes:
        for arch, shape_name in todo:
            rec = run_cell(arch, shape_name, multi_pod, force=args.force)
            ok += rec is not None
            fail += rec is None
    print(f"\ndry-run: {ok} ok, {fail} failed")
    sys.exit(1 if fail else 0)


if __name__ == "__main__":
    main()
