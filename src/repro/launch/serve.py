"""Serving launcher: continuous-batch greedy decoding loop (thin CLI).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b \
      --batch 4 --gen 32

Production shape: requests queue in, are packed into the fixed decode
batch, and finished sequences are replaced without recompiling (static
shapes).  The admission/drain/KV-wrap state machine lives in
``repro.serve.slots.SlotLoop`` and the prompt source in
``repro.serve.traffic.PromptStream`` — this module only parses arguments,
builds the model, and feeds the jitted ``decode_step`` to the loop.  On
the 16x16 mesh the same ``decode_step`` the dry-run proves out serves
decode_32k / long_500k; ``--smoke`` (the default) runs the reduced config
on CPU and ``--no-smoke`` serves the full ``get_config`` architecture.

Conv-bearing architectures (the mamba/hybrid families) warm the
ConvSpec-keyed serving cache (``repro.api.serving_cache``) before traffic
is admitted: every conv layer's plan and pre-transformed weights resolve
once at startup (see ``warm_conv_plans`` for exactly what that buys this
decode-loop launcher), and repeated hits on one spec re-use one cached
entry.
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.registry import build
from repro.serve import PromptStream, SlotLoop


def parse_args(argv: Optional[Sequence[str]] = None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=list(ARCH_IDS))
    # BooleanOptionalAction: ``--no-smoke`` serves the full config — the
    # old ``action="store_true", default=True`` could never be turned off
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced smoke config (--no-smoke: full config)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-min", type=int, default=4)
    ap.add_argument("--prompt-max", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def resolve_config(args: argparse.Namespace):
    return get_smoke_config(args.arch) if args.smoke else get_config(args.arch)


def warm_conv_plans(cfg, params, batch: int, seq: int) -> Dict[str, int]:
    """Pre-resolve conv plans + prepared weights through the serving cache.

    What this buys *this* launcher: the token-by-token decode loop runs
    the ring-buffer conv einsum and never replans, so the warm moves the
    per-layer planning + SFC weight transform to startup, where a failure
    (missing algorithm, bad spec) surfaces before traffic is admitted,
    and the memoized plans it resolves are shared with every later
    ``plan()`` call on the same specs.  The prepared-weight entries serve
    eager ``_causal_conv1d`` callers — prefill-style evaluation, PTQ
    calibration, a future chunked-prefill path — not the jitted decode
    step (tracers bypass the cache by design).

    Walks the parameter tree for depthwise conv weights.  Unstacked
    (R, C) leaves are long-lived arrays, warmed *unkeyed*: the entry is
    the same id-keyed one the runtime ``_causal_conv1d`` lookup computes
    for a (batch, seq)-shaped input.  Stacked (L, R, C) layer weights
    execute under ``lax.scan`` (traced), so their per-layer entries are
    warmed with stable tree-path keys: idempotent across repeated calls
    (slicing creates fresh arrays each time), e.g. a weight-reload
    re-warm.  Returns the serving-cache stats after the warm.
    """
    from repro.api import ConvSpec, serving_cache
    use_sfc = bool(getattr(cfg, "use_sfc_conv", False))
    algo = "auto" if use_sfc else "direct"
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        name = getattr(path[-1], "key", None)
        if name != "conv_w" or not hasattr(leaf, "ndim"):
            continue
        tag = tuple(str(k) for k in path)
        layers = [(None, leaf)] if leaf.ndim == 2 else \
            [(tag + (i,), leaf[i]) for i in range(leaf.shape[0])]
        for key, w in layers:
            spec = ConvSpec.for_conv1d_depthwise((batch, seq, w.shape[1]),
                                                 w.shape)
            serving_cache.get(spec, w, algo=algo, key=key)
    return serving_cache.stats()


def main(argv: Optional[Sequence[str]] = None):
    args = parse_args(argv)

    cfg = resolve_config(args)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = PromptStream(cfg.vocab_size,
                           lengths=(args.prompt_min, args.prompt_max),
                           seed=args.seed)

    cache_stats = warm_conv_plans(cfg, params, args.batch, args.max_len)
    if cache_stats["size"]:
        print(f"conv serving cache warmed: {cache_stats}")

    memory = None
    if cfg.family == "vlm":
        memory = jnp.zeros((args.batch, cfg.n_vision_tokens, cfg.d_model),
                           jnp.float32)
    if cfg.family == "encdec":
        memory = jnp.zeros((args.batch, args.max_len, cfg.d_model),
                           jnp.float32)

    serve = jax.jit(model.decode_step, donate_argnums=(1,))
    cache = model.init_cache(params, args.batch, args.max_len, memory)

    def step_fn(tok: np.ndarray, pos: np.ndarray) -> np.ndarray:
        nonlocal cache
        logits, cache = serve(params, cache, jnp.asarray(tok),
                              jnp.asarray(pos))
        return np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)

    loop = SlotLoop(batch=args.batch, gen=args.gen, max_len=args.max_len,
                    requests=args.requests, prompts=prompts)
    stats = loop.run(step_fn)
    lat = stats.latency_ms
    print(f"served {stats.served} requests in {stats.elapsed_s:.1f}s "
          f"({stats.steps} steps, {stats.tok_per_s:.0f} tok/s on "
          f"{jax.devices()[0].platform}; {stats.wrapped} KV-wrapped; "
          f"request latency p50={lat.percentile(50):.0f}ms "
          f"p99={lat.percentile(99):.0f}ms)")
    if cache_stats["size"]:
        from repro.api import serving_cache
        print(f"conv_cache,{serving_cache.stats()}")
    return stats


if __name__ == "__main__":
    main()
