"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 50 --batch 8 --seq 128

``--smoke`` selects the reduced config (CPU-runnable); without it the full
config is used (requires a real TPU fleet; the mesh/shardings are the same
ones the dry-run proves out).  The launcher wires: config -> model -> data
pipeline -> sharded train step -> fault-tolerant Trainer (checkpoints,
auto-resume, straggler log).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data import SyntheticTokenPipeline, TokenPipelineConfig
from repro.launch import mesh as mesh_lib
from repro.models.registry import build
from repro.optim.optimizers import AdamW, cosine_schedule
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=max(args.steps // 20, 1),
                                   total=args.steps))
    pipe = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    def batches(step: int):
        b = pipe.batch(step)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}
        if cfg.family == "vlm":
            out["vision"] = jnp.zeros(
                (args.batch, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            out["frames"] = jnp.zeros(
                (args.batch, args.seq, cfg.d_model), jnp.float32)
        return out

    trainer = Trainer(model, opt, TrainerConfig(
        total_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
    ))
    report = trainer.run(batches, jax.random.PRNGKey(0))
    first = np.mean(report.losses[:5])
    last = np.mean(report.losses[-5:])
    print(f"\ndone: {report.steps_run} steps, loss {first:.3f} -> {last:.3f},"
          f" restarts={report.restarts} stragglers={report.stragglers}")


if __name__ == "__main__":
    main()
