import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Dry-run for the paper's own architecture at production scale.

Lowers + compiles an SFC-int8 ResNet-18 / VGG-16 training step on the
16x16 (and 2x16x16) mesh — the paper's technique exercised through the
full distributed stack (data-parallel batch, output-channel TP on the
transform-domain matmuls), with the same roofline instrumentation as the
LM cells.

  PYTHONPATH=src python -m repro.launch.dryrun_cnn [--multi-pod] \
      [--model resnet18|vgg16] [--algo sfc6_7|direct|wino4]
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.resnet18 import RESNET18, VGG16
from repro.launch import mesh as mesh_lib
from repro.launch.dryrun import OUT_DIR, collective_bytes
from repro.models.cnn import cnn_loss, init_resnet, init_vgg
from repro.optim.optimizers import AdamW

GLOBAL_BATCH = 4096          # ImageNet-scale training batch


def cnn_param_pspec(path, leaf, mesh):
    """Convs: output channels over 'model'; everything else replicated.
    The batch carries the 'data'(+'pod') parallelism."""
    name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    if name == "w" and len(leaf.shape) == 4:      # (R, R, Cin, Cout)
        if leaf.shape[-1] % mesh.shape["model"] == 0:
            return P(None, None, None, "model")
    if name == "w" and len(leaf.shape) == 2:      # head
        if leaf.shape[-1] % mesh.shape["model"] == 0:
            return P(None, "model")
    return P(*([None] * len(leaf.shape)))


def lower_cnn(model_name: str, algo: str, multi_pod: bool):
    cfg = dataclasses.replace(
        RESNET18 if model_name == "resnet18" else VGG16,
        conv_algo=algo, quant="int8" if algo != "direct" else "none")
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    b_axes = ("pod", "data") if multi_pod else ("data",)
    init = init_resnet if cfg.kind == "resnet" else init_vgg
    opt = AdamW(lr=1e-3)

    with mesh:
        params_abs = jax.eval_shape(
            lambda: init(jax.random.PRNGKey(0), cfg))
        p_shard = jax.tree_util.tree_map_with_path(
            lambda p, l: NamedSharding(mesh, cnn_param_pspec(p, l, mesh)),
            params_abs)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_shard = type(opt_abs)(
            step=NamedSharding(mesh, P()), mu=p_shard, nu=p_shard)
        batch_abs = {
            "images": jax.ShapeDtypeStruct(
                (GLOBAL_BATCH, cfg.image_size, cfg.image_size, 3),
                jnp.float32),
            "labels": jax.ShapeDtypeStruct((GLOBAL_BATCH,), jnp.int32),
        }
        b_shard = {
            "images": NamedSharding(mesh, P(b_axes, None, None, None)),
            "labels": NamedSharding(mesh, P(b_axes)),
        }

        def train_step(params, opt_state, batch):
            (loss, m), g = jax.value_and_grad(
                lambda p: cnn_loss(p, cfg, batch), has_aux=True)(params)
            params, opt_state, _ = opt.apply(params, g, opt_state)
            return params, opt_state, loss

        jitted = jax.jit(train_step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        t0 = time.time()
        lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        compiled = lowered.compile()
        from repro.launch import hlo_analysis
        cost = hlo_analysis.normalize_cost_analysis(compiled.cost_analysis())
        hlo = compiled.as_text()
        s = hlo_analysis.analyze(hlo)
        coll, _ = collective_bytes(hlo)
        mesh_tag = "pod2" if multi_pod else "pod1"
        rec = {
            "arch": f"{model_name}-{algo}", "shape": f"train_b{GLOBAL_BATCH}",
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_chips": 512 if multi_pod else 256,
            "kind": "train",
            "hlo_flops": cost.get("flops"),
            "la_flops": s.flops,
            "la_traffic_bytes": s.traffic_bytes,
            "la_collective_bytes": s.collective_bytes,
            "collective_bytes": coll,
            "model_flops": 0.0,
            "compile_seconds": time.time() - t0,
        }
        out = OUT_DIR / f"{mesh_tag}_{model_name}-{algo}_train.json"
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rec, indent=1))
        print(f"[ok] {out.name}: la_flops/device={s.flops:.3e} "
              f"t_comp={s.flops/mesh_lib.PEAK_BF16_FLOPS*1e3:.1f}ms "
              f"t_coll={s.total_collective/mesh_lib.ICI_BW*1e3:.1f}ms "
              f"({rec['compile_seconds']:.0f}s compile)")
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="resnet18",
                    choices=["resnet18", "vgg16"])
    ap.add_argument("--algo", default="sfc6_7",
                    choices=["direct", "sfc6_7", "sfc6_6", "sfc4_4",
                             "wino4"])
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    lower_cnn(args.model, args.algo, args.multi_pod)


if __name__ == "__main__":
    main()
