"""Loop-aware cost analysis of compiled (SPMD-partitioned) HLO.

``compiled.cost_analysis()`` counts a ``while`` body **once** (verified in
EXPERIMENTS.md §Dry-run), silently undercounting every scanned layer stack
and flash-attention chunk loop.  This module parses the optimized HLO text,
reads each while loop's trip count from its ``backend_config``
(``known_trip_count``, emitted by XLA for counted loops; fallback: the
``compare(iv, constant)`` bound in the condition computation), and walks
the call graph multiplying costs through the loop nest:

  * FLOPs: ``dot`` (2 x output_elems x contracted_elems) + ``convolution``;
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, x trip multipliers;
  * HBM traffic: operand+result bytes of every *materialized* buffer —
    i.e. ops at fusion boundaries (fusion nodes, dots, convs, collectives,
    copies...), with free ops (get-tuple-element, bitcast, tuple,
    parameter, constant) excluded and fusion-internal ops excluded (they
    live in registers/VMEM).  Each buffer is counted on write (result) and
    on read (operand), matching HBM round trips on the TPU target.

All quantities are per-device (the input is the SPMD-partitioned module).
"""
from __future__ import annotations

import dataclasses
import gzip
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

def normalize_cost_analysis(cost) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Older JAX returned a list with one properties-dict per partition
    (``[{"flops": ...}]``); newer JAX returns the dict directly (and may
    return ``None`` on backends without cost analysis).  Always returns a
    dict, possibly empty.
    """
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return dict(cost) if cost else {}


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^=]*?\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([a-z0-9\-]+)\((.*)$")


def _type_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total elems/bytes of a (possibly tuple) HLO type string."""
    elems = byts = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES.get(dt, 0)
    return elems, byts


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    args: str
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Dict[str, str]]:
    comps: Dict[str, Computation] = {}
    symbols: Dict[str, str] = {}       # op name -> result type string
    cur: Optional[Computation] = None
    comment = re.compile(r"/\*.*?\*/")
    for line in text.splitlines():
        line = comment.sub("", line)
        stripped = line.strip()
        if cur is None:
            if ("{" in stripped and "->" in stripped
                    and not stripped.startswith("//")):
                m = _COMP_HEAD.match(stripped)
                if m:
                    cur = Computation(m.group(1),
                                      stripped.startswith("ENTRY"), [])
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, kind, rest = m.groups()
        # args run to the first unnested ')'
        depth = 1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        args, attrs = rest[:i], rest[i + 1:]
        op = Op(name, kind, rtype, args, attrs)
        cur.ops.append(op)
        symbols[name] = rtype
    return comps, symbols


def _operand_types(op: Op, symbols: Dict[str, str]) -> List[str]:
    return [symbols.get(n, "") for n in re.findall(r"%([\w.\-]+)", op.args)]


def _while_trip_count(op: Op, comps: Dict[str, Computation],
                      symbols: Dict[str, str]) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.attrs)
    if m:
        return int(m.group(1))
    # fallback: largest positive s32 constant in the condition computation
    cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
    cond = comps.get(cm.group(1)) if cm else None
    best = 1
    if cond is not None:
        for o in cond.ops:
            if o.kind == "constant":
                k = re.search(r"constant\((\d+)\)", o.args + o.attrs)
                if k:
                    best = max(best, int(k.group(1)))
    return best


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    out_elems, _ = _type_elems_bytes(op.result_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    operands = _operand_types(op, symbols)
    if not m or not operands:
        return 2.0 * out_elems
    sm = _SHAPE_RE.search(operands[0])
    dims = [int(d) for d in sm.group(2).split(",") if d] if sm else []
    contract = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(dims):
            contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, symbols: Dict[str, str]) -> float:
    out_elems, _ = _type_elems_bytes(op.result_type)
    operands = _operand_types(op, symbols)
    if len(operands) < 2:
        return 2.0 * out_elems
    sm = _SHAPE_RE.search(operands[1])
    kdims = [int(d) for d in sm.group(2).split(",") if d] if sm else []
    kelems = 1
    for d in kdims:
        kelems *= d
    out_feat = kdims[-1] if kdims else 1
    return 2.0 * out_elems * max(kelems // max(out_feat, 1), 1)


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    traffic_bytes: float = 0.0
    param_bytes: float = 0.0
    loop_trips: Dict[str, int] = dataclasses.field(default_factory=dict)
    dot_flops_by_site: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def total_collective(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str, keep_sites: bool = False) -> CostSummary:
    comps, symbols = parse_hlo(text)
    entry = next((n for n, c in comps.items() if c.is_entry), None)
    if entry is None:
        entry = next(iter(comps), None)
    out = CostSummary()

    _FREE = ("parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "conditional", "after-all",
             "partition-id", "replica-id", "iota")

    def _bytes_of(op: Op) -> float:
        _, rb = _type_elems_bytes(op.result_type)
        ob = sum(_type_elems_bytes(t)[1]
                 for t in _operand_types(op, symbols))
        return rb + ob

    def walk(comp_name: str, mult: float, depth: int = 0,
             materialized: bool = True):
        """``materialized``: ops in this computation own HBM buffers
        (false inside fusion bodies — those live in registers/VMEM)."""
        comp = comps.get(comp_name)
        if comp is None or depth > 60:
            return
        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                trip = _while_trip_count(op, comps, symbols)
                out.loop_trips[op.name] = trip
                bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                if bm:
                    walk(bm.group(1), mult * trip, depth + 1, materialized)
                continue
            if kind == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if materialized:
                    # fusions rooted at a dynamic-(update-)slice are
                    # in-place / windowed on TPU (buffer aliasing): count
                    # the slice, not the whole carried buffer
                    root_kind = None
                    callee = comps.get(cm.group(1)) if cm else None
                    if callee is not None and callee.ops:
                        root_kind = callee.ops[-1].kind
                    if root_kind == "dynamic-update-slice":
                        upd_t = callee.ops[-1]
                        ops_t = _operand_types(upd_t, symbols)
                        upd = (_type_elems_bytes(ops_t[1])[1]
                               if len(ops_t) > 1 else 0)
                        out.traffic_bytes += mult * 2 * upd
                    elif root_kind == "dynamic-slice":
                        _, rb = _type_elems_bytes(op.result_type)
                        out.traffic_bytes += mult * 2 * rb
                    else:
                        out.traffic_bytes += mult * _bytes_of(op)
                if cm:
                    walk(cm.group(1), mult, depth + 1, materialized=False)
                continue
            if kind == "call":
                cm = re.search(r"to_apply=%?([\w.\-]+)", op.attrs)
                if cm:
                    walk(cm.group(1), mult, depth + 1, materialized)
                continue
            if kind == "conditional":
                names = re.findall(
                    r"(?:true_computation|false_computation)=%?([\w.\-]+)",
                    op.attrs)
                bm = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
                if bm:
                    names += [c.strip().lstrip("%")
                              for c in bm.group(1).split(",")]
                for n in names:
                    walk(n, mult, depth + 1, materialized)
                continue
            base = kind[:-6] if kind.endswith("-start") else kind
            if base in _COLLECTIVES:
                b = sum(_type_elems_bytes(t)[1]
                        for t in _operand_types(op, symbols))
                out.collective_bytes[base] += mult * b
                out.collective_counts[base] += mult
                if materialized:
                    out.traffic_bytes += mult * _bytes_of(op)
                continue
            if kind == "dot":
                f = _dot_flops(op, symbols)
                out.flops += mult * f
                if keep_sites:
                    site = re.search(r'op_name="([^"]*)"', op.attrs)
                    key = site.group(1) if site else op.name
                    out.dot_flops_by_site[key] = \
                        out.dot_flops_by_site.get(key, 0.0) + mult * f
                if materialized:
                    out.traffic_bytes += mult * _bytes_of(op)
            elif kind == "convolution":
                out.flops += mult * _conv_flops(op, symbols)
                if materialized:
                    out.traffic_bytes += mult * _bytes_of(op)
            elif kind == "parameter":
                if comp_name == entry:
                    _, pb = _type_elems_bytes(op.result_type)
                    out.param_bytes += pb
            elif kind == "dynamic-update-slice":
                # in-place on TPU (aliased buffers): traffic = update write
                # + read, not the full operand buffer
                if materialized:
                    ops_t = _operand_types(op, symbols)
                    upd = (_type_elems_bytes(ops_t[1])[1]
                           if len(ops_t) > 1 else 0)
                    out.traffic_bytes += mult * 2 * upd
            elif kind == "dynamic-slice":
                if materialized:
                    _, rb = _type_elems_bytes(op.result_type)
                    out.traffic_bytes += mult * 2 * rb
            elif materialized and kind not in _FREE:
                # copies, reshapes-with-layout-change, scatters, ... move
                # real bytes
                out.traffic_bytes += mult * _bytes_of(op)

    if entry:
        walk(entry, 1.0)
    return out


def analyze_file(path: str, keep_sites: bool = False) -> CostSummary:
    opener = gzip.open if str(path).endswith(".gz") else open
    with opener(path, "rt") as f:
        return analyze(f.read(), keep_sites=keep_sites)
