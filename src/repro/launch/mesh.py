"""Production meshes (TPU v5e pods): 16x16 = 256 chips/pod, 2 pods = 512.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host actually has (tests / examples / smoke runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_BF16_FLOPS = 197e12          # 197 TFLOP/s
HBM_BW = 819e9                    # 819 GB/s
ICI_BW = 50e9                     # ~50 GB/s per link
