"""Production meshes (TPU v5e pods): 16x16 = 256 chips/pod, 2 pods = 512.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host actually has (tests / examples / smoke runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def make_forced_host_mesh(shape, axes=("data", "model")):
    """Mesh over the first prod(shape) host devices — may use a subset.

    For SPMD tests and scale-out sweeps on the CPU container under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``: unlike
    ``jax.make_mesh`` this does not insist on covering every device, so
    one 8-device process can sweep 1/2/4/8-way meshes.
    """
    import numpy as np
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(f"mesh shape {shape} needs {n} devices, "
                         f"host has {len(devices)}")
    return Mesh(np.array(devices[:n]).reshape(shape), axes)


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_BF16_FLOPS = 197e12          # 197 TFLOP/s
HBM_BW = 819e9                    # 819 GB/s
ICI_BW = 50e9                     # ~50 GB/s per link
