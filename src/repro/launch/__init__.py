"""Launch layer: meshes, dry-run, training driver."""
