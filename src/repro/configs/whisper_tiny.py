"""whisper-tiny [audio]: enc-dec, 4 encoder + 4 decoder layers, d=384,
6H MHA, d_ff=1536, vocab=51865 (padded 51968).  [arXiv:2212.04356]

The conv frontend is a STUB per the task spec: ``input_specs`` provides
precomputed frame embeddings (B, S_frames, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, encoder_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, encoder_layers=2, d_model=48, n_heads=3, n_kv_heads=3,
    d_ff=96, vocab_size=512, head_dim=16,
)
