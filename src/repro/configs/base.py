"""Model/run configuration dataclasses.

One ``ModelConfig`` describes any architecture in the assigned pool; family-
specific fields default to "absent".  Configs are hashable/frozen so they can
be static jit arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


def pad_vocab(v: int, multiple: int = 256) -> int:
    return -(-v // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0       # 0 = full attention
    # MoE
    n_experts: int = 0
    n_experts_active: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0   # deepseek: first k layers use dense FFN
    router_aux_coef: float = 0.01
    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    mtp_depth: int = 0            # multi-token-prediction extra heads
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    use_sfc_conv: bool = False    # SFC fast path for the depthwise conv1d
    # hybrid (zamba2)
    shared_attn_every: int = 0    # insert the shared attention block every k
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq_ratio: int = 1    # encoder frames per decoder token (stub)
    # VLM (llama-3.2-vision): cross-attention block every k self-attn layers
    cross_attn_every: int = 0
    n_vision_tokens: int = 1601   # stub frontend output length
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    max_seq_len: int = 524288

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        # pad so the vocab dim shards over a 16-way model axis
        return pad_vocab(self.vocab_size, 256)

    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-FLOPs)."""
        d, f, V = self.d_model, self.d_ff, self.padded_vocab
        n_attn_layers = self.n_layers
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        if self.family in ("dense", "moe", "vlm", "encdec"):
            if self.use_mla:
                attn = (d * self.q_lora_rank
                        + self.q_lora_rank * self.n_heads
                        * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                        + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                        + self.kv_lora_rank * self.n_heads
                        * (self.qk_nope_head_dim + self.v_head_dim)
                        + self.n_heads * self.v_head_dim * d)
            else:
                attn = d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * self.head_dim * d
            dense_ffn = 3 * d * f
            if self.family == "moe":
                moe_ffn = 3 * d * f * self.n_experts \
                    + self.n_shared_experts * 3 * d * f + d * self.n_experts
                n_moe = self.n_layers - self.first_dense_layers
                total += self.first_dense_layers * (attn + dense_ffn)
                total += n_moe * (attn + moe_ffn)
            else:
                total += n_attn_layers * (attn + dense_ffn)
            if self.family == "vlm" and self.cross_attn_every:
                n_cross = self.n_layers // self.cross_attn_every
                total += n_cross * (attn + dense_ffn)
            if self.family == "encdec":
                total += self.encoder_layers * (attn + dense_ffn) \
                    + self.n_layers * attn  # decoder cross-attention
        elif self.family == "ssm":
            di, N, H = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * N + H) + di * d + self.ssm_conv * (di + 2 * N)
            total += self.n_layers * per
        elif self.family == "hybrid":
            di, N, H = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            per = d * (2 * di + 2 * N + H) + di * d + self.ssm_conv * (di + 2 * N)
            attn = 4 * d * self.n_heads * self.head_dim + 3 * d * f
            total += self.n_layers * per + attn  # one shared block
        return int(total)

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts only routed-active experts."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full = self.param_count()
        n_moe = self.n_layers - self.first_dense_layers
        inactive = n_moe * 3 * d * f * (self.n_experts - self.n_experts_active)
        return int(full - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# smoke-test shapes (reduced)
SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
