"""mamba2-1.3b [ssm]: 48L d=2048, attention-free SSD (state-space duality),
ssm_state=128, vocab=50280 (padded to 50432 so the embedding shards over a
16-way model axis).  [arXiv:2405.21060]

The depthwise causal conv1d (R=4) inside every block runs the paper's SFC
1-D fast path when ``use_sfc_conv`` is set (SFC-6(3,4): 8 mults per 3
outputs vs 12 direct — see DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, head_dim=0,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_headdim=64,
    use_sfc_conv=True,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=512, head_dim=0,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_headdim=16,
    use_sfc_conv=True, ssm_chunk=16,
)
