"""Architecture registry: ``--arch <id>`` resolves here."""
from repro.configs import (deepseek_v3_671b, llama_3_2_vision_11b,
                           mamba2_1_3b, mixtral_8x7b, phi4_mini_3_8b,
                           qwen2_5_32b, qwen3_14b, resnet18, stablelm_3b,
                           whisper_tiny, zamba2_1_2b)
from repro.configs.base import SHAPES, SMOKE_SHAPE, ModelConfig, ShapeConfig

_MODULES = {
    "llama-3.2-vision-11b": llama_3_2_vision_11b,
    "qwen2.5-32b": qwen2_5_32b,
    "qwen3-14b": qwen3_14b,
    "stablelm-3b": stablelm_3b,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "mixtral-8x7b": mixtral_8x7b,
    "zamba2-1.2b": zamba2_1_2b,
    "mamba2-1.3b": mamba2_1_3b,
    "whisper-tiny": whisper_tiny,
}

ARCH_IDS = tuple(_MODULES)

# long_500k needs sub-quadratic attention: run only where the architecture
# is SSM/hybrid/sliding-window (see DESIGN.md §6 for the skip rationale).
LONG_CONTEXT_ARCHS = ("mamba2-1.3b", "zamba2-1.2b", "mixtral-8x7b")


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].SMOKE_CONFIG


def cells():
    """All (arch, shape) dry-run cells, with documented skips applied."""
    out = []
    for arch in ARCH_IDS:
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            out.append((arch, sname))
    return out
