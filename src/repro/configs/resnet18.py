"""The paper's own evaluation CNNs (ResNet-18 family / VGG-16 family).

These drive the accuracy/BOPs benchmarks (paper Fig. 4, Tables 2/4/5) and
the end-to-end SFC training example.  ``CIFAR_RESNET18`` is the reduced
offline-trainable variant (synthetic/CIFAR-scale images).
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    stages: Tuple[int, ...]          # blocks per stage (resnet) / convs (vgg)
    widths: Tuple[int, ...]
    image_size: int
    n_classes: int
    kind: str = "resnet"             # resnet | vgg
    stem_kernel: int = 3
    # 'auto', 'direct', or any name in api.registry.list_algorithms()
    # (sfc6_7 / sfc6_6 / sfc4_4 / wino4 / wino2 / ... — the registry is
    # open, so downstream-registered algorithms are valid here too);
    # validated at construction so a typo'd config fails loudly instead
    # of silently training on the direct path
    conv_algo: str = "direct"
    quant: str = "none"              # none | int8 | int6 | int4
    act_granularity: str = "frequency"
    weight_granularity: str = "channel+frequency"

    def __post_init__(self):
        # late import: the registry pulls in the algorithm generators,
        # and configs must stay importable on their own
        from repro.api.registry import list_algorithms
        valid = ("auto",) + list_algorithms()
        if self.conv_algo not in valid:
            raise ValueError(
                f"conv_algo={self.conv_algo!r} is not registered; "
                f"valid: {sorted(valid)}")


RESNET18 = CNNConfig(
    name="resnet18", stages=(2, 2, 2, 2), widths=(64, 128, 256, 512),
    image_size=224, n_classes=1000, stem_kernel=7)

VGG16 = CNNConfig(
    name="vgg16", kind="vgg", stages=(2, 2, 3, 3, 3),
    widths=(64, 128, 256, 512, 512), image_size=224, n_classes=1000)

# offline-trainable scale (the end-to-end example trains this from scratch)
CIFAR_RESNET18 = CNNConfig(
    name="cifar-resnet18", stages=(2, 2, 2, 2), widths=(32, 64, 128, 256),
    image_size=32, n_classes=10)

SMOKE_CNN = CNNConfig(
    name="smoke-cnn", stages=(1, 1), widths=(8, 16), image_size=16,
    n_classes=10)
