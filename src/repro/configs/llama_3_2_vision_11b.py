"""llama-3.2-vision-11b [vlm]: 40L (32 self + 8 gated cross-attn) d=4096,
32H GQA kv=8, d_ff=14336, vocab=128256.  [hf:meta-llama/Llama-3.2-11B-Vision]

The vision frontend is a STUB per the task spec: ``input_specs`` provides
precomputed patch embeddings of shape (B, n_vision_tokens, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=128256, head_dim=128, rope_theta=5e5,
    cross_attn_every=4, n_vision_tokens=1601,
)

SMOKE_CONFIG = ModelConfig(
    name="llama-3.2-vision-smoke", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16, cross_attn_every=2, n_vision_tokens=9,
)
