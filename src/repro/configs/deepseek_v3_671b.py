"""deepseek-v3-671b [moe]: 61L d=7168 128H MLA, per-expert d_ff=2048,
vocab=129280, MoE 1 shared + 256 routed top-8, first 3 layers dense
(d_ff dense = 18432), MTP depth 1.  [arXiv:2412.19437]

Trained in bf16 param dtype here so the fully-sharded optimizer state fits
the 512 x 16 GiB production mesh (see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab_size=129280, head_dim=128,
    n_experts=256, n_experts_active=8, n_shared_experts=1,
    first_dense_layers=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    mtp_depth=1, param_dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-v3-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab_size=512, head_dim=16,
    n_experts=8, n_experts_active=2, n_shared_experts=1,
    first_dense_layers=1,
    use_mla=True, q_lora_rank=32, kv_lora_rank=16,
    qk_rope_head_dim=8, qk_nope_head_dim=16, v_head_dim=16,
    mtp_depth=1,
)
