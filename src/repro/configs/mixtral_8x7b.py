"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) per-expert d_ff=14336,
vocab=32000, 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128,
    n_experts=8, n_experts_active=2, sliding_window=4096,
)

SMOKE_CONFIG = ModelConfig(
    name="mixtral-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16,
    n_experts=4, n_experts_active=2, sliding_window=32,
)
