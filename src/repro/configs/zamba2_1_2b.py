"""zamba2-1.2b [hybrid]: 38 Mamba2 layers d=2048 + one shared attention
block (32H MHA, d_ff=8192) applied every 6 layers; ssm_state=64,
vocab=32000.  [arXiv:2411.15242]

The shared block reuses one set of attention+MLP weights at every insertion
point (the Zamba2 weight-sharing scheme; we omit the per-invocation LoRA
deltas and input-concat, noted in DESIGN.md).  The Mamba2 depthwise conv1d
supports the SFC fast path (use_sfc_conv).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_headdim=64,
    shared_attn_every=6, use_sfc_conv=True,
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, head_dim=16,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_headdim=16,
    shared_attn_every=2, use_sfc_conv=True, ssm_chunk=16,
)
