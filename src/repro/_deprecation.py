"""Shared warn-and-forward helper for the legacy conv entry points."""
from __future__ import annotations

import functools
import warnings


def deprecated(fn, owner: str, replacement: str):
    """Wrap ``fn`` so calls warn that ``owner.<name>`` moved to ``replacement``."""
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        warnings.warn(
            f"{owner}.{fn.__name__} is deprecated; use {replacement}",
            DeprecationWarning, stacklevel=2)
        return fn(*args, **kwargs)
    return wrapper
