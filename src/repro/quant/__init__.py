"""Quantization substrate: fake quant, PTQ calibration, BOPs metric.

NOTE: the bare ``fake_quant`` *function* is intentionally not re-exported —
it would shadow the ``repro.quant.fake_quant`` module attribute; import it
from ``repro.quant.fake_quant`` directly.
"""
from repro.quant.fake_quant import (FP32, INT4_FREQ, INT6_FREQ, INT8_FREQ,
                                    INT8_TENSOR, QuantConfig, dequantize,
                                    fake_quant_activation,
                                    fake_quant_weight, qmax_for_bits,
                                    quantize)
from repro.quant.bops import (ConvWorkload, bops_reduction, direct_conv_bops,
                              fastconv_bops)
from repro.quant.ptq import CalibrationState, PTQLayer, mse_scale_search

__all__ = [
    "QuantConfig", "FP32", "INT8_FREQ", "INT8_TENSOR", "INT6_FREQ",
    "INT4_FREQ", "quantize", "dequantize",
    "fake_quant_activation", "fake_quant_weight", "qmax_for_bits",
    "ConvWorkload", "direct_conv_bops", "fastconv_bops", "bops_reduction",
    "CalibrationState", "PTQLayer", "mse_scale_search",
]
