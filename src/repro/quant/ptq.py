"""Post-training quantization calibration (paper §6.1).

The paper calibrates on ~500 images with AdaQuant; offline we implement the
robust core of that recipe:

  * **scale search**: per scale-group grid search over a multiplier of the
    absmax scale, minimizing the MSE between the fake-quantized and fp
    tensors (LoWino-style distance minimization, same objective family as
    AdaQuant's first stage);
  * **calibration buffers**: running absmax/percentile statistics collected
    over calibration batches, producing *static* scales for deployment (the
    paper stores transform-domain tensors, avoiding double quantization);
  * a hook factory that plugs the calibrated static scales into the
    element-wise stage of a ``repro.api`` ConvPlan (reference backend),
    and :meth:`PTQLayer.prepare` / :meth:`PTQLayer.static_scales`, which
    export those scales into ``ConvPlan.prepare_weights`` for the offline
    int8 deployment path (both backends).

Typical flow::

    p = plan(spec, backend="pallas")
    layer = PTQLayer(config=spec.quant)
    ref = plan(spec, backend="reference", algo=p.algo_name)
    for batch in calib:                       # calibration (reference)
        ref.apply(batch, w, elementwise_hook=layer.calibration_hook())
    prepared = layer.prepare(p, w)            # offline int8 weights
    y = p.apply(x, prepared)                  # deployment
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.quant.fake_quant as fq


def mse_scale_search(x: jnp.ndarray, bits: int, reduce_axes: Sequence[int],
                     n_grid: int = 32, lo: float = 0.3) -> jnp.ndarray:
    """Grid-search the scale multiplier minimizing fake-quant MSE per group."""
    amax_scale = jnp.max(jnp.abs(x), axis=tuple(reduce_axes), keepdims=True) \
        / fq.qmax_for_bits(bits) + 1e-12
    best_scale = amax_scale
    best_err = jnp.full(amax_scale.shape, jnp.inf)
    for m in np.linspace(lo, 1.0, n_grid):
        s = amax_scale * m
        err = jnp.sum((fq.dequantize(fq.quantize(x, s, bits), s) - x) ** 2,
                      axis=tuple(reduce_axes), keepdims=True)
        best_scale = jnp.where(err < best_err, s, best_scale)
        best_err = jnp.minimum(err, best_err)
    return best_scale


@dataclasses.dataclass
class CalibrationState:
    """Running absmax statistics for one tensor's scale group."""

    amax: Optional[np.ndarray] = None

    def update(self, x: np.ndarray, reduce_axes: Sequence[int]) -> None:
        cur = np.max(np.abs(x), axis=tuple(reduce_axes), keepdims=True)
        self.amax = cur if self.amax is None else np.maximum(self.amax, cur)

    def scale(self, bits: int) -> np.ndarray:
        assert self.amax is not None, "no calibration data seen"
        return self.amax / fq.qmax_for_bits(bits) + 1e-12


@dataclasses.dataclass
class PTQLayer:
    """Calibrated transform-domain quantizer for one conv layer."""

    config: fq.QuantConfig
    act_state: CalibrationState = dataclasses.field(
        default_factory=CalibrationState)
    weight_scale: Optional[jnp.ndarray] = None

    # ---- calibration pass ----
    def observe(self, tx: jnp.ndarray, tw: jnp.ndarray) -> None:
        axes = fq.activation_reduce_axes(tx.ndim, self.config.act_granularity)
        self.act_state.update(np.asarray(tx), axes)
        if self.weight_scale is None:
            w_axes = fq.weight_reduce_axes(tw.ndim,
                                           self.config.weight_granularity)
            self.weight_scale = mse_scale_search(
                tw, self.config.bits_weight, w_axes)

    def calibration_hook(self) -> Callable:
        def _hook(tx, tw):
            self.observe(tx, tw)
            return tx, tw  # calibration runs in fp
        return _hook

    # ---- deployment pass ----
    def quantized_hook(self) -> Callable:
        act_scale = jnp.asarray(self.act_state.scale(self.config.bits_act))

        def _hook(tx, tw):
            txq = fq.fake_quant(tx, self.config.bits_act,
                                reduce_axes=(), scale=act_scale)
            twq = fq.fake_quant(tw, self.config.bits_weight,
                                reduce_axes=(), scale=self.weight_scale)
            return txq, twq
        return _hook

    # ---- offline deployment (repro.api integration) ----
    def static_scales(self, t: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Calibrated (act_scale (t, t), weight_scale) for prepare_weights.

        Frequency-wise activation scales are the paper's s_Tx (Eq. 17);
        tensor-granularity calibration broadcasts to the same shape so the
        static datapath is granularity-agnostic.
        """
        act = np.squeeze(np.asarray(
            self.act_state.scale(self.config.bits_act)))
        if act.ndim == 0:
            act = np.full((t, t), float(act))
        if act.shape != (t, t):
            raise ValueError(
                f"calibrated activation scale has shape {act.shape}, "
                f"expected broadcastable to ({t}, {t})")
        return jnp.asarray(act, jnp.float32), self.weight_scale

    def prepare(self, plan, w: jnp.ndarray):
        """Offline-quantize ``w`` for ``plan`` using the calibrated scales.

        Direct plans have no transform domain — the raw weights pass
        through unquantized, as before.  Lowered (composite) plans are
        REJECTED rather than silently degraded: one PTQLayer holds ONE
        (t, t) scale state, but a composite's sub-convs have different
        tile sizes and input distributions (its calibration hook would
        mix tensor shapes, too).  Calibrate composites per sub-problem
        with ``CompositePlan.calibrate(x)`` ->
        ``prepare_weights(w, act_scale=...)`` instead.
        """
        if plan.path == "lowered":
            raise NotImplementedError(
                "PTQLayer calibrates a single transform domain; lowered "
                f"(composite) plans have one per sub-conv ({plan.algo_name})."
                " Use CompositePlan.calibrate(x) + prepare_weights(w, "
                "act_scale=<per-sub scales>) for the static-int8 path.")
        if plan.algorithm is None:
            return plan.prepare_weights(w)
        act_scale, w_scale = self.static_scales(plan.algorithm.t)
        return plan.prepare_weights(w, act_scale=act_scale, w_scale=w_scale)
