"""Fake (simulated) integer quantization with the paper's granularities.

Symmetric uniform quantization: q = clip(round(x / s), -qmax, qmax), with a
scale-factor *group* structure (paper §5, Eq. 17):

  activations (transform domain, shape (..., t, t, C)):
     'tensor'     : one scale for the whole tensor
     'frequency'  : one scale per transform-domain coordinate  -> s[t, t]
  weights (transform domain, shape (t, t, Cin, Cout)):
     'channel'          : per output channel                   -> s[Cout]
     'frequency'        : per coordinate                       -> s[t, t]
     'channel+frequency': per coordinate per channel           -> s[t,t,Cout]

Spatial-domain tensors use 'tensor' (activations) / 'channel' (weights).
All ops are jittable; the straight-through estimator is used for gradients
so the same code serves PTQ simulation and quantization-aware fine-tuning.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def qmax_for_bits(bits: int) -> int:
    return 2 ** (bits - 1) - 1


def _absmax_scale(x: jnp.ndarray, reduce_axes: Sequence[int], bits: int
                  ) -> jnp.ndarray:
    amax = jnp.max(jnp.abs(x), axis=tuple(reduce_axes), keepdims=True)
    return amax / qmax_for_bits(bits) + 1e-12


def activation_reduce_axes(ndim: int, granularity: str,
                           t_axes: Tuple[int, int] = (-3, -2)) -> Tuple[int, ...]:
    """Axes to reduce when computing activation scales.

    For transform-domain activations (..., t, t, C) with 'frequency'
    granularity we keep the two t axes and reduce everything else
    (including channels — the paper's s_Tx is [T x T]).
    """
    t_axes = tuple(a % ndim for a in t_axes)
    if granularity == "tensor":
        return tuple(range(ndim))
    if granularity == "frequency":
        return tuple(a for a in range(ndim) if a not in t_axes)
    raise ValueError(f"activation granularity: {granularity}")


def weight_reduce_axes(ndim: int, granularity: str) -> Tuple[int, ...]:
    """Weights are (t, t, Cin, Cout) (transform) or (R, R, Cin, Cout)."""
    if granularity == "channel":          # keep Cout
        return tuple(range(ndim - 1))
    if granularity == "frequency":        # keep (t, t)
        return (ndim - 2, ndim - 1)
    if granularity == "channel+frequency":  # keep (t, t, Cout)
        return (ndim - 2,)
    if granularity == "tensor":
        return tuple(range(ndim))
    raise ValueError(f"weight granularity: {granularity}")


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


def quantize(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Real -> integer grid (still float dtype, values are integers)."""
    q = qmax_for_bits(bits)
    return jnp.clip(_ste_round(x / scale), -q, q)


def dequantize(xq: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return xq * scale


def fake_quant(x: jnp.ndarray, bits: int, reduce_axes: Sequence[int],
               scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """quantize+dequantize; scale computed from data unless provided."""
    s = scale if scale is not None else _absmax_scale(x, reduce_axes, bits)
    return dequantize(quantize(x, s, bits), s)


def fake_quant_activation(x: jnp.ndarray, bits: int, granularity: str,
                          scale: Optional[jnp.ndarray] = None,
                          t_axes: Tuple[int, int] = (-3, -2)) -> jnp.ndarray:
    axes = activation_reduce_axes(x.ndim, granularity, t_axes)
    return fake_quant(x, bits, axes, scale)


def fake_quant_weight(w: jnp.ndarray, bits: int, granularity: str,
                      scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    axes = weight_reduce_axes(w.ndim, granularity)
    return fake_quant(w, bits, axes, scale)


def quantize_transformed_weights(tw: jnp.ndarray, w_scale: jnp.ndarray,
                                 bits: int = 8) -> jnp.ndarray:
    """Offline weight quantization for the static deployment path.

    (t, t, Cin, Cout) fp transform-domain weights + (t, t, Cout) scales
    -> (t^2, Cin, Cout) int8, the layout ``tdmm_int8`` consumes.  The one
    implementation shared by ``repro.api`` weight preparation and
    ``repro.kernels.quantize_weights``.
    """
    q = qmax_for_bits(bits)
    t = tw.shape[0]
    wq = jnp.clip(jnp.round(tw / w_scale[:, :, None, :]), -q, q)
    return wq.astype(jnp.int8).reshape(t * t, tw.shape[2], tw.shape[3])


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Transform-domain quantization recipe (paper Eq. 17 + §6.3 ablation)."""

    bits_act: int = 8
    bits_weight: int = 8
    act_granularity: str = "frequency"          # 'tensor' | 'frequency'
    weight_granularity: str = "channel+frequency"
    enabled: bool = True

    def hook(self):
        """elementwise_hook for ``repro.api`` ConvPlan.apply (reference)."""
        if not self.enabled:
            return None

        def _hook(tx, tw):
            txq = fake_quant_activation(
                tx, self.bits_act, self.act_granularity, t_axes=(-3, -2))
            twq = fake_quant_weight(tw, self.bits_weight,
                                    self.weight_granularity)
            return txq, twq
        return _hook


FP32 = QuantConfig(enabled=False)
INT8_FREQ = QuantConfig(8, 8, "frequency", "channel+frequency")
INT8_TENSOR = QuantConfig(8, 8, "tensor", "channel")
INT6_FREQ = QuantConfig(6, 6, "frequency", "channel+frequency")
INT4_FREQ = QuantConfig(4, 4, "frequency", "channel+frequency")
