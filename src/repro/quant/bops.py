"""Bit-operations (BOPs) cost model — paper §6 metric.

An n-bit addition costs n BOPs; an n-bit multiplication costs n(n-1) BOPs
(n-1 shifted additions).  We account for all three stages of the fast
convolution (transform costs included, as the paper requires) plus the
direct-convolution baseline.

Accumulator width for a dot product of K products of a-bit x w-bit operands:
    acc_bits = a + w + ceil(log2(K))
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.generator import BilinearAlgorithm


def add_bops(bits: int) -> int:
    return bits


def mult_bops(a_bits: int, w_bits: int) -> int:
    n = max(a_bits, w_bits)
    return n * (n - 1)


@dataclasses.dataclass(frozen=True)
class ConvWorkload:
    H: int
    W: int
    C_in: int
    C_out: int
    R: int
    bits_act: int = 8
    bits_weight: int = 8


def direct_conv_bops(wl: ConvWorkload) -> float:
    """Direct convolution: H*W*Cout dot products of length R^2*Cin."""
    K = wl.R * wl.R * wl.C_in
    acc_bits = wl.bits_act + wl.bits_weight + math.ceil(math.log2(K))
    per_out = K * mult_bops(wl.bits_act, wl.bits_weight) + (K - 1) * add_bops(acc_bits)
    return wl.H * wl.W * wl.C_out * per_out


def fastconv_bops(wl: ConvWorkload, algo: BilinearAlgorithm,
                  transform_bits: Optional[int] = None) -> float:
    """Fast convolution (SFC / Winograd) under the same cost model.

    * input transform: per tile per C_in, 2-D separable adds at
      ``transform_bits`` (data width grows by log2(||B^T||_1) — SFC rows sum
      to <= N so int8 data stays within int16).
    * element-wise stage: t^2 x C_in x C_out MACs per tile.
    * output transform: per tile per C_out adds at accumulator width.
    * weight transform is amortized (precomputed once) — paper assumption.
    """
    M, t, L = algo.M, algo.t, algo.L
    n_tiles = math.ceil(wl.H / M) * math.ceil(wl.W / M)
    adds = algo.transform_addition_counts()

    if transform_bits is None:
        row_l1 = max(int(sum(abs(v) for v in row)) for row in algo.BT)
        transform_bits = wl.bits_act + max(1, math.ceil(math.log2(max(row_l1, 2))))
    # 2-D separable input transform: rows then cols.
    input_adds = (adds["input"] * L + adds["input"] * t)  # per channel per tile
    input_cost = n_tiles * wl.C_in * input_adds * add_bops(transform_bits)

    # element-wise stage: accumulate over C_in at wide accumulator.
    K = wl.C_in
    acc_bits = wl.bits_act + wl.bits_weight + math.ceil(math.log2(max(K, 2)))
    ew_cost = n_tiles * t * t * wl.C_out * (
        K * mult_bops(wl.bits_act, wl.bits_weight) + (K - 1) * add_bops(acc_bits))

    # output transform at accumulator width (dequant fused into scales).
    out_adds = adds["output"] * t + adds["output"] * M
    out_cost = n_tiles * wl.C_out * out_adds * add_bops(acc_bits)

    return input_cost + ew_cost + out_cost


def bops_reduction(wl: ConvWorkload, algo: BilinearAlgorithm) -> float:
    """Direct/fast BOPs ratio (paper reports 1.6x-2.5x vs int8 direct)."""
    return direct_conv_bops(wl) / fastconv_bops(wl, algo)
