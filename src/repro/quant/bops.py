"""Bit-operations (BOPs) cost model — paper §6 metric.

An n-bit addition costs n BOPs; an n-bit multiplication costs n(n-1) BOPs
(n-1 shifted additions).  We account for all three stages of the fast
convolution (transform costs included, as the paper requires) plus the
direct-convolution baseline.

The workload description covers the planner's full spec space:

  * ``stride``   — direct convolution computes ceil(H/s) x ceil(W/s)
    outputs; fast (bilinear) algorithms are stride-1 constructs, so the
    lowering layer prices a strided workload as the *sum* of its
    polyphase stride-1 sub-workloads and compares against the strided
    direct baseline here (polyphase is only a win when the 4 sub-convs
    beat one strided direct conv);
  * ``groups``   — both paths contract C_in/groups channels per output;
  * ``depthwise``— no channel contraction at all: the element-wise stage
    is t^2 true elementwise mults per channel per tile, and the
    transforms run once per channel (groups == C_in == C_out).

Accumulator width for a dot product of K products of a-bit x w-bit operands:
    acc_bits = a + w + ceil(log2(K))
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.generator import BilinearAlgorithm


def add_bops(bits: int) -> int:
    return bits


def mult_bops(a_bits: int, w_bits: int) -> int:
    n = max(a_bits, w_bits)
    return n * (n - 1)


@dataclasses.dataclass(frozen=True)
class ConvWorkload:
    H: int                      # INPUT spatial extents
    W: int
    C_in: int
    C_out: int
    R: int
    bits_act: int = 8
    bits_weight: int = 8
    stride: int = 1
    groups: int = 1
    depthwise: bool = False
    padding: str = "SAME"       # SAME | VALID — decides the output grid

    @property
    def contraction(self) -> int:
        """Channels contracted per output (the K of one dot product)."""
        if self.depthwise:
            return 1
        return self.C_in // self.groups

    def out_extent(self, size: int) -> int:
        if self.padding == "SAME":
            return math.ceil(size / self.stride)
        return (size - self.R) // self.stride + 1

    @property
    def n_outputs_spatial(self) -> int:
        return self.out_extent(self.H) * self.out_extent(self.W)


def direct_conv_bops(wl: ConvWorkload) -> float:
    """Direct convolution: one length-R^2*(C_in/g) dot product per output.

    Strided workloads produce ceil(H/s)*ceil(W/s) outputs — the baseline
    the polyphase lowering has to beat.
    """
    K = wl.R * wl.R * wl.contraction
    acc_bits = wl.bits_act + wl.bits_weight + math.ceil(math.log2(max(K, 1)))
    per_out = K * mult_bops(wl.bits_act, wl.bits_weight) \
        + (K - 1) * add_bops(acc_bits)
    return wl.n_outputs_spatial * wl.C_out * per_out


def fastconv_bops(wl: ConvWorkload, algo: BilinearAlgorithm,
                  transform_bits: Optional[int] = None) -> float:
    """Fast convolution (SFC / Winograd) under the same cost model.

    * input transform: per tile per C_in, 2-D separable adds at
      ``transform_bits`` (data width grows by log2(||B^T||_1) — SFC rows sum
      to <= N so int8 data stays within int16).
    * element-wise stage: t^2 x (C_in/g) x C_out MACs per tile — or, for
      depthwise workloads, t^2 x C true elementwise mults per tile (no
      contraction; the transform-domain elementwise path).
    * output transform: per tile per C_out adds at accumulator width.
    * weight transform is amortized (precomputed once) — paper assumption.

    Fast algorithms are stride-1 constructs: strided workloads must be
    lowered (``repro.api.lowering``) before being priced here.
    """
    if wl.stride != 1:
        raise ValueError(
            f"fast algorithms are stride-1 constructs; lower the stride-"
            f"{wl.stride} workload to polyphase sub-workloads first")
    M, t, L = algo.M, algo.t, algo.L
    # tiles cover the OUTPUT grid (== input for stride-1 SAME; R-1 smaller
    # for VALID, the lowering layer's polyphase sub-problems)
    n_tiles = math.ceil(wl.out_extent(wl.H) / M) \
        * math.ceil(wl.out_extent(wl.W) / M)
    adds = algo.transform_addition_counts()

    if transform_bits is None:
        # single source of truth for transform-domain data width — the
        # same bound repro.analysis.ranges certifies (bit-identical to
        # the historical inline formula)
        from repro.analysis import ranges
        transform_bits = ranges.transform_bits_1d(algo, wl.bits_act)
    # 2-D separable input transform: rows then cols.
    input_adds = (adds["input"] * L + adds["input"] * t)  # per channel per tile
    input_cost = n_tiles * wl.C_in * input_adds * add_bops(transform_bits)

    # element-wise stage: accumulate over the contracted channels at wide
    # accumulator width (depthwise: K == 1, a pure elementwise product).
    K = wl.contraction
    acc_bits = wl.bits_act + wl.bits_weight + math.ceil(math.log2(max(K, 2)))
    ew_cost = n_tiles * t * t * wl.C_out * (
        K * mult_bops(wl.bits_act, wl.bits_weight) + (K - 1) * add_bops(acc_bits))

    # output transform at accumulator width (dequant fused into scales).
    out_adds = adds["output"] * t + adds["output"] * M
    out_cost = n_tiles * wl.C_out * out_adds * add_bops(acc_bits)

    return input_cost + ew_cost + out_cost


def bops_reduction(wl: ConvWorkload, algo: BilinearAlgorithm) -> float:
    """Direct/fast BOPs ratio (paper reports 1.6x-2.5x vs int8 direct)."""
    return direct_conv_bops(wl) / fastconv_bops(wl, algo)
