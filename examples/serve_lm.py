"""Serve a small LM with batched requests through the production decode path.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-1.3b]

Trains a smoke-scale model briefly (so generations aren't pure noise), then
runs a batched serving loop: ragged prompts, per-sequence positions, greedy
decode — the same ``decode_step`` the multi-pod dry-run lowers at
decode_32k/long_500k scale.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.data import SyntheticTokenPipeline, TokenPipelineConfig
from repro.models import build
from repro.optim.optimizers import AdamW
from repro.train.steps import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b", choices=list(ARCH_IDS))
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build(cfg)
    pipe = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=48, global_batch=args.batch))

    # brief training
    opt = AdamW(lr=5e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    extra = {}
    if cfg.family == "vlm":
        extra["vision"] = jnp.zeros(
            (args.batch, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        extra["frames"] = jnp.zeros((args.batch, 48, cfg.d_model),
                                    jnp.float32)
    for i in range(args.train_steps):
        b = pipe.batch(i)
        state, m = step_fn(state, {
            "tokens": jnp.asarray(b["tokens"]),
            "labels": jnp.asarray(b["labels"]), **extra})
    print(f"trained {args.train_steps} steps, final loss "
          f"{float(m['loss']):.3f}")

    # batched serving: ragged prompts
    rng = np.random.RandomState(7)
    prompt_lens = rng.randint(4, 12, size=args.batch)
    max_prompt = int(prompt_lens.max())
    prompts = pipe.batch(999)["tokens"][:, :max_prompt]
    memory = extra.get("vision", extra.get("frames"))
    total = max_prompt + args.gen_len
    cache = model.init_cache(state.params, args.batch, total, memory)
    serve = jax.jit(model.decode_step, donate_argnums=(1,))

    tok = jnp.asarray(prompts[:, 0:1], jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for t in range(total - 1):
        logits, cache = serve(state.params, cache, tok,
                              jnp.full((args.batch,), t, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        in_prompt = (t + 1) < prompt_lens
        tok = jnp.where(jnp.asarray(in_prompt)[:, None],
                        jnp.asarray(prompts[:, min(t + 1, max_prompt - 1)]
                                    [:, None], jnp.int32), nxt)
        out_tokens.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)
    print(f"served {args.batch} sequences x {total} steps in {dt:.1f}s "
          f"({args.batch*(total-1)/dt:.0f} tok/s on CPU)")
    for i in range(args.batch):
        print(f"  seq{i} prompt={gen[i,:prompt_lens[i]].tolist()} "
              f"gen={gen[i, prompt_lens[i]:prompt_lens[i]+8].tolist()}...")


if __name__ == "__main__":
    main()
