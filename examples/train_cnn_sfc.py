"""End-to-end driver: train a CNN, PTQ-quantize its SFC convs, compare.

    PYTHONPATH=src python examples/train_cnn_sfc.py [--steps 150]

Mirrors the paper's §6.1 experiment offline: train fp32 -> swap every 3x3
stride-1 conv for quantized SFC-6 -> measure accuracy retention, vs the
same swap with Winograd F(4x4,3x3).  Runs in a few minutes on CPU.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.resnet18 import CNNConfig
from repro.data import ImagePipelineConfig, SyntheticImagePipeline
from repro.models.cnn import cnn_loss, init_resnet, resnet_forward
from repro.optim.optimizers import AdamW, cosine_schedule

CFG = CNNConfig(name="example-cnn", stages=(1, 1), widths=(16, 32),
                image_size=24, n_classes=10)


def accuracy(cfg, params, pipe, n=6, start=5000):
    correct = total = 0
    for i in range(start, start + n):
        b = pipe.batch(i)
        logits = resnet_forward(params, cfg, jnp.asarray(b["images"]))
        correct += int((np.argmax(np.asarray(logits), -1)
                        == b["labels"]).sum())
        total += len(b["labels"])
    return correct / total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    pipe = SyntheticImagePipeline(ImagePipelineConfig(
        image_size=CFG.image_size, n_classes=CFG.n_classes,
        global_batch=32, seed=3))
    params = init_resnet(jax.random.PRNGKey(0), CFG)
    opt = AdamW(lr=cosine_schedule(3e-3, 10, args.steps), weight_decay=1e-4)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p: cnn_loss(p, CFG, batch), has_aux=True)(params)
        params, state, _ = opt.apply(params, g, state)
        return params, state, m

    t0 = time.time()
    for i in range(args.steps):
        b = pipe.batch(i)
        params, state, m = step(params, state,
                                {"images": jnp.asarray(b["images"]),
                                 "labels": jnp.asarray(b["labels"])})
        if i % 25 == 0:
            print(f"step {i:4d}  loss {float(m['loss']):.3f}  "
                  f"acc {float(m['acc']):.3f}")
    print(f"trained {args.steps} steps in {time.time()-t0:.0f}s\n")

    rows = [("direct fp32", dataclasses.replace(CFG)),
            ("direct int8", dataclasses.replace(
                CFG, quant="int8", conv_algo="direct")),
            ("SFC-6(6x6,3x3) int8", dataclasses.replace(
                CFG, conv_algo="sfc6_6", quant="int8")),
            ("SFC-6(7x7,3x3) int8", dataclasses.replace(
                CFG, conv_algo="sfc6_7", quant="int8")),
            ("SFC-6 int6", dataclasses.replace(
                CFG, conv_algo="sfc6_6", quant="int6")),
            ("Wino(4x4,3x3) int8", dataclasses.replace(
                CFG, conv_algo="wino4", quant="int8")),
            ("Wino(4x4,3x3) int6", dataclasses.replace(
                CFG, conv_algo="wino4", quant="int6"))]
    print(f"{'variant':26s} accuracy")
    base = None
    for name, cfg in rows:
        acc = accuracy(cfg, params, pipe)
        base = acc if base is None else base
        print(f"{name:26s} {acc:.3f}  (delta {acc-base:+.3f})")
    print("\nExpected: SFC int8 within noise of fp32 (paper: -0.17%); "
          "Winograd degrades, especially at int6 (paper: -5.4%).")


if __name__ == "__main__":
    main()
