"""Quickstart: SFC fast convolution as a drop-in, with int8 quantization.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's core loop: generate an SFC algorithm, run a convolution
through the three-stage transform flow, quantize the transform domain to
int8 with frequency-wise scales, and compare accuracy + multiplication
counts against direct convolution and Winograd.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (conv2d_direct, fastconv2d, generate_sfc,
                        generate_winograd)
from repro.quant import INT8_FREQ, ConvWorkload, bops_reduction


def main():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(1, 56, 56, 32), jnp.float32)   # NHWC
    w = jnp.asarray(rng.randn(3, 3, 32, 64) * 0.1, jnp.float32)

    y_ref = conv2d_direct(x, w)

    print("algorithm            mults/tile  complexity  rel.err(fp32)  "
          "rel.err(int8-freq)")
    for algo in [generate_sfc(6, 6, 3), generate_sfc(6, 7, 3),
                 generate_sfc(4, 4, 3), generate_winograd(4, 3),
                 generate_winograd(2, 3)]:
        y_fp = fastconv2d(x, w, algo)
        y_q = fastconv2d(x, w, algo, elementwise_hook=INT8_FREQ.hook())
        err_fp = float(jnp.linalg.norm(y_fp - y_ref)
                       / jnp.linalg.norm(y_ref))
        err_q = float(jnp.linalg.norm(y_q - y_ref) / jnp.linalg.norm(y_ref))
        print(f"{algo.name:20s} {algo.mults_2d:10d}  "
              f"{100*algo.arithmetic_complexity_2d:9.2f}%  "
              f"{err_fp:13.2e}  {err_q:12.4f}")

    wl = ConvWorkload(56, 56, 32, 64, 3)
    print(f"\nBOPs reduction (int8, 56x56x32->64):")
    for algo in [generate_sfc(6, 7, 3), generate_sfc(6, 6, 3)]:
        print(f"  {algo.name}: {bops_reduction(wl, algo):.2f}x vs "
              "direct int8")
    print("\nKey claim: SFC-6 reaches Winograd-class multiplication "
          "reduction with direct-conv-class int8 accuracy.")


if __name__ == "__main__":
    main()
