"""Quickstart: one convolution API — ConvSpec -> plan -> apply.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's deployment story through the unified ``repro.api``
front-end: describe the convolution once (``ConvSpec``), let the planner
pick the algorithm with the BOPs cost model (or name one from the public
registry), pre-transform + int8-quantize the weights offline
(``ConvPlan.prepare_weights``), and execute the same plan on the
``reference`` (pure jnp) or ``pallas`` (TPU kernel) backend.
"""
import jax.numpy as jnp
import numpy as np

from repro.api import ConvSpec, list_algorithms, plan
from repro.core import conv2d as c2d
from repro.quant import INT8_FREQ, ConvWorkload, bops_reduction


def main():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(1, 56, 56, 32), jnp.float32)   # NHWC
    w = jnp.asarray(rng.randn(3, 3, 32, 64) * 0.1, jnp.float32)

    # --- 1. describe the convolution once --------------------------------
    spec = ConvSpec.for_conv2d(x.shape, w.shape)
    spec_q = ConvSpec.for_conv2d(x.shape, w.shape, quant=INT8_FREQ)

    # --- 2. plan: registry names or cost-model auto-selection ------------
    p_direct = plan(spec, algo="direct")
    y_ref = p_direct.apply(x, w)

    print("algorithm            mults/tile  complexity  rel.err(fp32)  "
          "rel.err(int8-freq)")
    for name in list_algorithms(taps=3, include_direct=False):
        p = plan(spec, algo=name)
        pq = plan(spec_q, algo=name)
        algo = p.algorithm
        y_fp = p.apply(x, w)
        y_q = pq.apply(x, w, elementwise_hook=INT8_FREQ.hook())
        err_fp = float(jnp.linalg.norm(y_fp - y_ref)
                       / jnp.linalg.norm(y_ref))
        err_q = float(jnp.linalg.norm(y_q - y_ref) / jnp.linalg.norm(y_ref))
        print(f"{algo.name:20s} {algo.mults_2d:10d}  "
              f"{100*algo.arithmetic_complexity_2d:9.2f}%  "
              f"{err_fp:13.2e}  {err_q:12.4f}")

    auto = plan(spec_q, algo="auto")
    print(f"\nauto-selected (int8 BOPs cost model): {auto.algo_name} "
          f"(~{auto.cost/1e6:.0f} MBOPs; direct would be "
          f"~{plan(spec_q, algo='direct').cost/1e6:.0f} MBOPs)")
    # strided / pointwise shapes degrade to direct in the planner — no
    # caller-side branching:
    print("stride-2 resolves to:",
          plan(ConvSpec.for_conv2d(x.shape, w.shape, stride=2)).algo_name)

    # --- 3. offline weight prep + static-int8 deployment -----------------
    # calibrate frequency-wise activation scales on one batch (see
    # repro.quant.ptq.PTQLayer for the full running-stats recipe)
    tx, _ = c2d.transform_input_2d(x, auto.algorithm)
    act_scale = jnp.abs(tx).max(axis=(0, 1, 2, 5)) / 127 + 1e-9
    prepared = auto.prepare_weights(w, act_scale=act_scale)
    y_int8 = auto.apply(x, prepared)        # int8 ints, static scales
    err = float(jnp.linalg.norm(y_int8 - y_ref) / jnp.linalg.norm(y_ref))
    print(f"static-int8 deployment path ({auto.algo_name}): "
          f"rel.err {err:.4f}")

    # same plan, Pallas kernel backend (interpret mode on CPU)
    p_pallas = plan(spec_q, backend="pallas", algo=auto.algo_name)
    y_pal = p_pallas.apply(x, p_pallas.prepare_weights(
        w, act_scale=act_scale))
    print(f"pallas backend agrees with reference to "
          f"{float(jnp.abs(y_pal - y_int8).max()):.1e}")

    wl = ConvWorkload(56, 56, 32, 64, 3)
    print(f"\nBOPs reduction (int8, 56x56x32->64):")
    for name in ("sfc6_7", "sfc6_6"):
        algo = plan(spec_q, algo=name).algorithm
        print(f"  {algo.name}: {bops_reduction(wl, algo):.2f}x vs "
              "direct int8")
    print("\nKey claim: SFC-6 reaches Winograd-class multiplication "
          "reduction with direct-conv-class int8 accuracy.")


if __name__ == "__main__":
    main()
