"""SFC inside an assigned architecture: Mamba2's depthwise conv1d.

    PYTHONPATH=src python examples/mamba_sfc_conv.py

The only convolution in the assigned LM pool is Mamba2/Zamba2's causal
depthwise conv1d (R=4).  This example runs it through the unified
``repro.api`` planner — auto-selection picks the SFC-6(6,4) fast path —
shows it is numerically identical to the direct path, counts the
multiplication savings, and benchmarks the standalone op.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ConvSpec, plan
from repro.configs import get_smoke_config
from repro.models import build


def main():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 2048, 256), jnp.float32)
    w = jnp.asarray(rng.randn(4, 256) * 0.3, jnp.float32)

    spec = ConvSpec.for_conv1d_depthwise(x.shape, w.shape)
    p_fast = plan(spec, algo="auto")       # resolves to SFC-6(6,4)
    p_ref = plan(spec, algo="direct")
    algo = p_fast.algorithm
    print(f"planner picked {p_fast.algo_name} ({algo.name}): {algo.t} mults "
          f"per {algo.M} outputs (direct: {algo.M * algo.R}) -> "
          f"{algo.M*algo.R/algo.t:.2f}x multiplication reduction")

    y_fast = p_fast.apply(x, w)
    y_ref = p_ref.apply(x, w)
    print(f"max abs err vs direct: {float(jnp.abs(y_fast-y_ref).max()):.2e}")

    fast = jax.jit(lambda x, w: p_fast.apply(x, w))
    ref = jax.jit(lambda x, w: p_ref.apply(x, w))
    for name, fn in [("direct", ref), ("sfc", fast)]:
        fn(x, w).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            fn(x, w).block_until_ready()
        print(f"{name:8s} {1e3*(time.perf_counter()-t0)/10:.2f} ms/call "
              "(CPU; on TPU the win is the t/M mult ratio)")

    # whole-model equivalence: mamba2 with and without the SFC path
    cfg = get_smoke_config("mamba2-1.3b")
    cfg32 = cfg.__class__(**{**cfg.__dict__, "compute_dtype": "float32"})
    cfg_direct = cfg32.__class__(**{**cfg32.__dict__, "use_sfc_conv": False})
    m_sfc, m_dir = build(cfg32), build(cfg_direct)
    params = m_sfc.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 64)), jnp.int32)
    d = float(jnp.abs(m_sfc.forward(params, toks)
                      - m_dir.forward(params, toks)).max())
    print(f"mamba2 smoke model, SFC vs direct conv path: max logit diff "
          f"{d:.2e}")


if __name__ == "__main__":
    main()
